//! Stand-alone TCP serving demo: starts the server on an ephemeral port,
//! runs a client workload against it from another thread, prints the
//! transcript. Demonstrates the deployable surface without needing two
//! terminals.
//!
//! ```bash
//! cargo run --release --example serve_tcp
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

use anyhow::Result;
use mcsharp::backend::NativeBackend;
use mcsharp::config::PmqConfig;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::server;
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    println!("== MC# TCP serving demo ==");
    let base = train_or_load("mix-tiny", 300, false)?;
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    let mut rng = Rng::new(3);
    let calib = corpus.batch(6, 48, &mut rng);
    let cal = calibrate(&base, &calib, 192);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("server on {addr} (PMQ {:.2}-bit, native backend)", q.avg_model_bits());

    let n_requests = 5usize;
    std::thread::scope(|s| -> Result<()> {
        s.spawn(|| {
            let be = NativeBackend::quant(&q);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Quant(&q), &be, None));
            server::serve(listener, &engine, 4, Some(n_requests)).unwrap();
        });
        let mut stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut line = String::new();
        stream.write_all(b"PING\n")?;
        reader.read_line(&mut line)?;
        print!("client: PING → {line}");
        let mut crng = Rng::new(77);
        for i in 0..n_requests {
            let prompt = corpus.sample(8, &mut crng);
            let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
            let req = format!("GEN 8 {}\n", toks.join(","));
            stream.write_all(req.as_bytes())?;
            line.clear();
            reader.read_line(&mut line)?;
            print!("client: req {i} → {line}");
        }
        Ok(())
    })?;
    println!("serve_tcp OK");
    Ok(())
}
