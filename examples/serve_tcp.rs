//! Stand-alone TCP serving demo: starts the server on an ephemeral port,
//! runs a client workload against it from another thread, prints the
//! transcript. Demonstrates the deployable surface without needing two
//! terminals: the protocol-v1 [`Client`] (pipelined + streaming), plus
//! one raw legacy v0 line to show both dialects share the connection.
//!
//! ```bash
//! cargo run --release --example serve_tcp
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

use anyhow::Result;
use mcsharp::backend::NativeBackend;
use mcsharp::config::PmqConfig;
use mcsharp::coordinator::client::Client;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::server;
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    println!("== MC# TCP serving demo ==");
    let base = train_or_load("mix-tiny", 300, false)?;
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    let mut rng = Rng::new(3);
    let calib = corpus.batch(6, 48, &mut rng);
    let cal = calibrate(&base, &calib, 192);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!("server on {addr} (PMQ {:.2}-bit, native backend)", q.avg_model_bits());

    let n_requests = 6usize; // 3 pipelined + 1 streamed + 1 lockstep + 1 legacy v0
    std::thread::scope(|s| -> Result<()> {
        s.spawn(|| {
            let be = NativeBackend::quant(&q);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Quant(&q), &be, None));
            server::serve(listener, &engine, 4, Some(n_requests)).unwrap();
        });
        let mut client = Client::connect(addr)?;
        client.ping()?;
        println!("client: PING → PONG");
        let mut crng = Rng::new(77);
        // 3 requests pipelined on this one connection: all in flight at
        // once, sharing engine steps, responses reordered by tag
        let reqs: Vec<(Vec<u16>, usize)> =
            (0..3).map(|_| (corpus.sample(8, &mut crng), 8)).collect();
        for (i, out) in client.gen_pipelined(&reqs)?.iter().enumerate() {
            println!(
                "client: pipelined req {i} → {:?} (latency {} µs, queued {} µs)",
                out.tokens, out.latency_us, out.queue_us
            );
        }
        // a streaming request: TOK partials arrive per engine step
        let prompt = corpus.sample(8, &mut crng);
        print!("client: streamed tokens →");
        let out = client.gen_stream(&prompt, 8, |t| print!(" {t}"))?;
        println!(" (terminal OK, {} tokens total)", out.tokens.len());
        // plain lockstep v1
        let prompt = corpus.sample(8, &mut crng);
        let out = client.gen(&prompt, 8)?;
        println!("client: lockstep req → {:?}", out.tokens);
        println!("client: STATS → {}", client.stats()?);
        drop(client);
        // the legacy v0 dialect still works, raw bytes on the socket
        let mut stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut line = String::new();
        stream.write_all(b"GEN 8 1,9,17\n")?;
        reader.read_line(&mut line)?;
        print!("client: legacy v0 GEN → {line}");
        Ok(())
    })?;
    println!("serve_tcp OK");
    Ok(())
}
