//! End-to-end driver — the full MC# system on a real (synthetic) workload,
//! proving all three layers compose. Recorded in EXPERIMENTS.md.
//!
//! 1. **Pretrain** the `mix-tiny` MoE decoder on the C4-analog corpus,
//!    logging the loss curve.
//! 2. **Calibrate** (routing stats, ε table, GPTQ Hessians).
//! 3. **PMQ** — integer-program bit allocation @ ~2 bits, GPTQ packing.
//! 4. **OTP** — train the learnable top-any pruners on the quantized model.
//! 5. **Serve** a batch of generation requests through the continuous
//!    batcher with the **PJRT backend** (the AOT Pallas kernels), and
//!    again with the native backend and with fp16 weights, reporting
//!    latency / throughput / activated bytes / pruning ratio.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use anyhow::Result;
use mcsharp::backend::{NativeBackend, PjrtBackend};
use mcsharp::config::{OtpConfig, PmqConfig};
use mcsharp::coordinator::batcher::Batcher;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::request::GenRequest;
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::runtime::Runtime;
use mcsharp::train::{TrainConfig, Trainer};
use mcsharp::util::bench::Table;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    println!("== MC# end-to-end: train → compress → OTP → serve ==\n");

    // ---- 1. pretrain ------------------------------------------------------
    let cfg = mcsharp::config::ModelConfig::load("mix-tiny")?;
    let steps = std::env::var("E2E_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let ckpt = mcsharp::config::repo_path(&format!("checkpoints/mix-tiny-s{steps}.bin"));
    let base = if let Ok(m) = mcsharp::moe::MoeModel::load(&ckpt) {
        println!("[1] loaded cached checkpoint {ckpt}");
        m
    } else {
        println!("[1] pretraining mix-tiny for {steps} steps ({} params)", cfg.total_params());
        let tc = TrainConfig { steps, ..Default::default() };
        let mut t = Trainer::new(&cfg, tc);
        let corpus = Trainer::default_corpus(&cfg);
        t.train(&corpus, false)?;
        println!("  loss curve: {:?}", t.loss_curve);
        t.model.save(&ckpt)?;
        t.model
    };

    // ---- 2. calibrate -----------------------------------------------------
    println!("\n[2] calibration");
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    let mut rng = Rng::new(0xE2E);
    let calib = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);

    // ---- 3. PMQ -----------------------------------------------------------
    println!("[3] PMQ @ avg 2 expert bits (GPTQ)");
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
    println!(
        "  {} → {} ({:.1}×, {:.2} model bits)",
        human_bytes(base.nbytes_fp16()),
        human_bytes(q.nbytes()),
        base.nbytes_fp16() as f64 / q.nbytes() as f64,
        q.avg_model_bits()
    );
    let eval = corpus.batch(4, 48, &mut rng);
    let ppl_fp = base.perplexity(&eval, &mut ForwardOpts::default());
    let ppl_q = q
        .model
        .perplexity(&eval, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
    println!("  perplexity: fp16 {ppl_fp:.3} → PMQ {ppl_q:.3}");

    // ---- 4. OTP ------------------------------------------------------------
    println!("\n[4] OTP router training (λ=1)");
    let oc = OtpConfig { steps: 200, ..Default::default() };
    let rep = train_otp(&q, &calib, &oc, 0xF00D);
    let final_ratio = rep.curve.last().map(|c| c.1).unwrap_or(0.0);
    println!("  learned mask ratio ≈ {:.1}%", 100.0 * final_ratio);

    // ---- 5. serve ----------------------------------------------------------
    println!("\n[5] serving 24 batched generation requests (prompt 16, gen 16)\n");
    let rt = Runtime::open_default()?;
    let make_requests = |rng: &mut Rng| -> Vec<GenRequest> {
        (0..24)
            .map(|i| GenRequest::greedy(i, corpus.sample(16, rng), 16))
            .collect()
    };
    let mut table = Table::new(&[
        "config", "backend", "tok/s", "p50 ms", "p95 ms", "act KB/tok", "pruned %",
    ]);
    // fp16 native
    {
        let be = NativeBackend::fp(&base);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&base), &be, None);
        let mut b = Batcher::new(8, 4096);
        let mut r = Rng::new(777);
        for req in make_requests(&mut r) {
            b.submit(req);
        }
        b.run(&mut eng)?;
        push_row(&mut table, "fp16", &eng);
    }
    // PMQ native
    {
        let be = NativeBackend::quant(&q);
        let mut eng = DecodeEngine::new(EngineModel::Quant(&q), &be, None);
        let mut b = Batcher::new(8, 4096);
        let mut r = Rng::new(777);
        for req in make_requests(&mut r) {
            b.submit(req);
        }
        b.run(&mut eng)?;
        push_row(&mut table, "PMQ-2.05b", &eng);
    }
    // PMQ+OTP native
    {
        let be = NativeBackend::quant(&q);
        let pruner = OtpPruner { routers: rep.routers.clone() };
        let mut eng =
            DecodeEngine::new(EngineModel::Quant(&q), &be, Some(Box::new(pruner)));
        let mut b = Batcher::new(8, 4096);
        let mut r = Rng::new(777);
        for req in make_requests(&mut r) {
            b.submit(req);
        }
        b.run(&mut eng)?;
        push_row(&mut table, "PMQ+OTP", &eng);
    }
    // PMQ via PJRT (the AOT Pallas kernels)
    {
        let be = PjrtBackend::new(&rt, &q, true)?;
        let mut eng = DecodeEngine::new(EngineModel::Quant(&q), &be, None);
        let mut b = Batcher::new(8, 4096);
        let mut r = Rng::new(777);
        for req in make_requests(&mut r) {
            b.submit(req);
        }
        let results = b.run(&mut eng)?;
        push_row(&mut table, "PMQ (pjrt)", &eng);
        let (compiles, execs) = *rt.stats.lock().unwrap();
        println!(
            "pjrt: {} executable compiles (warmup), {} kernel executions, {} results\n",
            compiles,
            execs,
            results.len()
        );
    }
    table.print();
    println!("\ne2e_serve OK — see EXPERIMENTS.md §End-to-end for the recorded run");
    Ok(())
}

fn push_row(table: &mut Table, name: &str, eng: &DecodeEngine) {
    let m = &eng.metrics;
    table.row(vec![
        name.to_string(),
        eng.backend_name().to_string(),
        format!("{:.1}", m.tokens_per_sec()),
        format!("{:.1}", m.latency_percentile_us(0.5) as f64 / 1e3),
        format!("{:.1}", m.latency_percentile_us(0.95) as f64 / 1e3),
        format!("{:.1}", m.routed_bytes_per_token() / 1024.0),
        format!("{:.1}", 100.0 * m.pruning_ratio()),
    ]);
}
