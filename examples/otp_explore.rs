//! OTP exploration: the λ sweep of paper Fig. 13 plus per-layer mask
//! behaviour. Trains the learnable routers at several sparsity weights
//! and prints the mask-ratio training curves and the quality/pruning
//! trade-off each λ lands on.
//!
//! ```bash
//! cargo run --release --example otp_explore [-- dsvl-s]
//! ```

use anyhow::Result;
use mcsharp::config::{OtpConfig, PmqConfig};
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::bench::Table;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "dsvl-s".to_string());
    println!("== OTP λ sweep on {model_name} (paper Fig. 13) ==\n");
    let base = train_or_load(&model_name, 300, false)?;
    let cfg = base.cfg.clone();
    let kind = if cfg.modalities > 1 { CorpusKind::Multimodal } else { CorpusKind::General };
    let corpus = Corpus::new(kind, 0xDA7A);
    let mut rng = Rng::new(0x07F);
    let calib = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
    let eval = corpus.batch(4, 48, &mut rng);
    let ppl_q = q
        .model
        .perplexity(&eval, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
    println!("PMQ-only perplexity: {ppl_q:.3}\n");

    let mut summary = Table::new(&["lambda", "trained mask %", "measured pruned %", "ppl"]);
    for &lambda in &[0.5f32, 1.0, 1.5, 2.0] {
        let oc = OtpConfig { lambda, steps: 200, ..Default::default() };
        let rep = train_otp(&q, &calib, &oc, 0xF00D + lambda as u64);
        println!("λ = {lambda}: mask-ratio curve (step, pruned-frac, distill-loss)");
        for (s, m, l) in rep.curve.iter().step_by(4) {
            println!("  {s:>4}  {m:.3}  {l:.5}");
        }
        let mut pruner = OtpPruner { routers: rep.routers };
        let mut counter = (0u64, 0u64);
        let ppl = q.model.perplexity(
            &eval,
            &mut ForwardOpts {
                provider: Some(&q),
                pruner: Some(&mut pruner),
                pruning_counter: Some(&mut counter),
                ..Default::default()
            },
        );
        let measured = 1.0 - counter.0 as f64 / counter.1.max(1) as f64;
        summary.row(vec![
            format!("{lambda}"),
            format!("{:.1}", 100.0 * rep.curve.last().unwrap().1),
            format!("{:.1}", 100.0 * measured),
            format!("{ppl:.3}"),
        ]);
        println!();
    }
    println!("λ sweep summary (higher λ ⇒ more pruning — Fig. 13 shape):");
    summary.print();
    Ok(())
}
