//! Quickstart: compress a pretrained MoE with MC# and generate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Steps: load (or briefly pretrain) the `mix-tiny` MoE → calibrate on
//! the C4-analog corpus → PMQ bit allocation at an average of 2 bits →
//! GPTQ-quantize → generate text with the quantized model and print the
//! compression summary.

use anyhow::Result;
use mcsharp::backend::NativeBackend;
use mcsharp::config::PmqConfig;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    println!("== MC# quickstart ==");
    let base = train_or_load("mix-tiny", 300, false)?;
    println!(
        "model: mix-tiny — {} params, {} at fp16",
        base.n_params(),
        human_bytes(base.nbytes_fp16())
    );

    // calibration pass (C4-analog)
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    let mut rng = Rng::new(1);
    let calib = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);

    // PMQ integer program at avg 2 bits
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    println!("\nPMQ allocation (bits per expert):");
    for (l, row) in alloc.iter().enumerate() {
        println!("  layer {l}: {row:?}");
    }

    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
    println!(
        "\npacked: {} → {} ({:.1}× smaller, {:.2} avg model bits)",
        human_bytes(base.nbytes_fp16()),
        human_bytes(q.nbytes()),
        base.nbytes_fp16() as f64 / q.nbytes() as f64,
        q.avg_model_bits()
    );

    // quality check: held-out perplexity
    let eval = corpus.batch(4, 48, &mut rng);
    let ppl_fp = base.perplexity(&eval, &mut ForwardOpts::default());
    let ppl_q = q
        .model
        .perplexity(&eval, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
    println!("perplexity: fp16 {ppl_fp:.3} → PMQ {ppl_q:.3}");

    // generate a continuation with the compressed model
    let prompt = corpus.sample(12, &mut rng);
    let be = NativeBackend::quant(&q);
    let mut engine = DecodeEngine::new(EngineModel::Quant(&q), &be, None);
    let t0 = std::time::Instant::now();
    let out = engine.generate(&prompt, 16)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("\nprompt tokens : {:?}", &out[..prompt.len()]);
    println!("generated     : {:?}", &out[prompt.len()..]);
    println!("decode throughput: {:.0} tok/s (native-quant)", 16.0 / dt);
    println!("\nquickstart OK");
    Ok(())
}
