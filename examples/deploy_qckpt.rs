//! Pre-loading deployment: compress **once**, ship the packed checkpoint,
//! serve **without** calibration at boot — the workflow the paper's PMQ
//! phase is named after ("Pre-Loading Mixed-Precision Quantization").
//!
//! ```bash
//! cargo run --release --example deploy_qckpt
//! ```
//!
//! 1. Offline (the "compressor" box): pretrain/load `mix-tiny`,
//!    calibrate, PMQ-allocate, GPTQ-pack, and write
//!    `checkpoints/mix-tiny-q2.bin`.
//! 2. Online (the "edge" box): load the packed checkpoint only — no
//!    calibration data, no Hessians, no fp16 weights — and serve a batch
//!    of requests, verifying the outputs match the pre-save model
//!    token-for-token.

use anyhow::Result;
use mcsharp::backend::NativeBackend;
use mcsharp::config::{repo_path, PmqConfig};
use mcsharp::coordinator::batcher::Batcher;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::request::GenRequest;
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qcheckpoint;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    println!("== MC# pre-loading deployment ==\n");
    let qpath = repo_path("checkpoints/mix-tiny-q2.bin");

    // ---- offline: compress & ship ----------------------------------------
    println!("[offline] compressing mix-tiny @ ~2 expert bits");
    let base = train_or_load("mix-tiny", 300, false)?;
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    let mut rng = Rng::new(0xD3B0);
    let calib = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
    let t0 = std::time::Instant::now();
    qcheckpoint::save(&q, &qpath)?;
    let fsize = std::fs::metadata(&qpath)?.len();
    println!(
        "  wrote {qpath}\n  {} on disk vs {} fp16 in memory ({:.1}× smaller payload), saved in {:.0} ms",
        human_bytes(fsize),
        human_bytes(base.nbytes_fp16()),
        base.nbytes_fp16() as f64 / q.nbytes() as f64,
        t0.elapsed().as_secs_f64() * 1e3,
    );

    // reference generations from the in-memory model before shipping
    let prompts: Vec<Vec<u16>> = (0..8).map(|_| corpus.sample(12, &mut rng)).collect();
    let be_ref = NativeBackend::quant(&q);
    let mut eng_ref = DecodeEngine::new(EngineModel::Quant(&q), &be_ref, None);
    let want: Vec<Vec<u16>> = prompts
        .iter()
        .map(|p| eng_ref.generate(p, 12))
        .collect::<Result<_>>()?;

    // ---- online: load & serve ---------------------------------------------
    println!("\n[online] booting from the packed checkpoint only");
    let t0 = std::time::Instant::now();
    let q2 = qcheckpoint::load(&qpath)?;
    println!(
        "  loaded in {:.0} ms — {:.2} avg model bits, {} packed",
        t0.elapsed().as_secs_f64() * 1e3,
        q2.avg_model_bits(),
        human_bytes(q2.nbytes()),
    );
    let be = NativeBackend::quant(&q2);
    let mut eng = DecodeEngine::new(EngineModel::Quant(&q2), &be, None);
    let mut b = Batcher::new(4, 4096);
    for (i, p) in prompts.iter().enumerate() {
        b.submit(GenRequest::greedy(i as u64, p.clone(), 12));
    }
    let mut results = b.run(&mut eng)?;
    results.sort_by_key(|r| r.id);

    // outputs must match the pre-save model token-for-token
    let mut ok = 0;
    for (r, w) in results.iter().zip(&want) {
        assert_eq!(&r.tokens, w, "generation diverged after save/load (req {})", r.id);
        ok += 1;
    }
    println!(
        "  served {ok}/{} requests, outputs bit-identical to the pre-save model",
        want.len()
    );
    println!(
        "  {:.1} tok/s | p50 {:.1} ms | act {:.1} KB/token",
        eng.metrics.tokens_per_sec(),
        eng.metrics.latency_percentile_us(0.5) as f64 / 1e3,
        eng.metrics.routed_bytes_per_token() / 1024.0,
    );
    println!("\ndeploy_qckpt OK");
    Ok(())
}
