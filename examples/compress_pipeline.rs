//! The MC# pipeline, stage by stage (paper Fig. 3 walkthrough):
//!
//! 1. expert significance analysis (§3.2.1–3.2.2: φ, w, drop-F-norm)
//! 2. per-bit reconstruction error ε (Eq. 6)
//! 3. integer-program bit allocation (Eq. 7) vs every baseline strategy
//! 4. GPTQ packing + memory accounting
//! 5. Online Top-any Pruning training (§3.4) and its effect
//!
//! ```bash
//! cargo run --release --example compress_pipeline [-- dsvl-s]
//! ```

use anyhow::Result;
use mcsharp::config::{OtpConfig, PmqConfig};
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::{drop_fnorm, eps_table};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::bench::Table;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

fn main() -> Result<()> {
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "mix-tiny".to_string());
    println!("== MC# pipeline walkthrough on {model_name} ==\n");
    let base = train_or_load(&model_name, 300, false)?;
    let cfg = base.cfg.clone();
    let kind = if cfg.modalities > 1 { CorpusKind::Multimodal } else { CorpusKind::General };
    let corpus = Corpus::new(kind, 0xDA7A);
    let mut rng = Rng::new(42);

    // -- stage 1: expert significance (Fig. 4 quantities) -----------------
    println!("[1] expert significance analysis");
    let calib = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib, 256);
    let fnorm = drop_fnorm(&base, &cal.acts);
    let mut t = Table::new(&["layer-0 expert", "freq φ", "mean-w", "drop-Fnorm"]);
    for e in 0..cfg.n_experts.min(8) {
        t.row(vec![
            e.to_string(),
            format!("{:.3}", cal.stats.frequency(0, e)),
            format!("{:.3}", cal.stats.mean_weight(0, e)),
            format!("{:.3}", fnorm[0][e]),
        ]);
    }
    t.print();
    println!(
        "mean routing imbalance (gini): {:.3}\n",
        cal.stats.mean_imbalance()
    );

    // -- stage 2: ε table --------------------------------------------------
    println!("[2] per-expert per-bit reconstruction error ε (Eq. 6), layer 0");
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let mut t = Table::new(&["expert", "ε@1bit", "ε@2bit", "ε@3bit"]);
    for e in 0..cfg.n_experts.min(8) {
        t.row(vec![
            e.to_string(),
            format!("{:.4}", eps[0][e][0]),
            format!("{:.4}", eps[0][e][1]),
            format!("{:.4}", eps[0][e][2]),
        ]);
    }
    t.print();

    // -- stage 3: allocation strategies ------------------------------------
    println!("\n[3] bit allocation @ avg 2.0 expert bits, every strategy");
    let eval = corpus.batch(4, 48, &mut rng);
    let mut t = Table::new(&["strategy", "layer-0 bits", "ppl"]);
    for s in Strategy::ALL {
        let alloc = strategies::allocation(s, &base, &cal, &eps, &pmq, 2.0, &mut rng);
        let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
        let ppl = q
            .model
            .perplexity(&eval, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        t.row(vec![s.name().to_string(), format!("{:?}", alloc[0]), format!("{ppl:.3}")]);
    }
    let ppl_fp = base.perplexity(&eval, &mut ForwardOpts::default());
    t.row(vec!["fp16".into(), "-".into(), format!("{ppl_fp:.3}")]);
    t.print();

    // -- stage 4: packing --------------------------------------------------
    println!("\n[4] GPTQ packing");
    let alloc =
        strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
    println!(
        "  fp16 {} → packed {} ({:.1}×), avg model bits {:.2}",
        human_bytes(base.nbytes_fp16()),
        human_bytes(q.nbytes()),
        base.nbytes_fp16() as f64 / q.nbytes() as f64,
        q.avg_model_bits()
    );

    // -- stage 5: OTP -------------------------------------------------------
    println!("\n[5] Online Top-any Pruning (λ=1)");
    let oc = OtpConfig { steps: 150, ..Default::default() };
    let rep = train_otp(&q, &calib, &oc, 0xF00D);
    for (step, ratio, loss) in rep.curve.iter().step_by(3) {
        println!("  step {step:>4}  mask-ratio {:.3}  distill-loss {loss:.5}", ratio);
    }
    let mut pruner = OtpPruner { routers: rep.routers };
    let mut counter = (0u64, 0u64);
    let ppl_otp = q.model.perplexity(
        &eval,
        &mut ForwardOpts {
            provider: Some(&q),
            pruner: Some(&mut pruner),
            pruning_counter: Some(&mut counter),
            ..Default::default()
        },
    );
    let ppl_q = q
        .model
        .perplexity(&eval, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
    println!(
        "  PMQ ppl {ppl_q:.3} → PMQ+OTP ppl {ppl_otp:.3} while pruning {:.1}% of activations",
        100.0 * (1.0 - counter.0 as f64 / counter.1.max(1) as f64)
    );
    println!("\npipeline walkthrough OK");
    Ok(())
}
