/* Standalone C validation harness + measurement rig for the fused
 * dequant x matmul kernel layer (rust/src/quant/kernels/).
 *
 * This is a line-for-line port of the Rust kernels — same interleaved
 * repack layout, same scalar LUT-chain inner loop, same AVX2 mask-compare
 * decode (including the FMA epilogues and the 16-wide token accumulator)
 * — compiled with gcc so the kernel *algorithms* can be equivalence-
 * checked and timed on hosts where the Rust toolchain is unavailable
 * (this repo's container). The Rust property suite
 * (rust/tests/kernel_equivalence.rs) is the authoritative gate in CI;
 * this harness exists to (a) cross-validate the intrinsic sequences and
 * (b) produce the measured rows checked in as BENCH_perf_hotpath.json
 * ("harness": "c-port-gcc") until a `cargo bench --bench perf_hotpath
 * -- --json` run can refresh them in place.
 *
 * Build & run:
 *   gcc -O2 -mavx2 -mfma -o /tmp/bench_kernels tools/bench_kernels.c -lm
 *   /tmp/bench_kernels --json BENCH_perf_hotpath.json
 */

#include <immintrin.h>
#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* ------------------------------------------------------------ helpers */

static uint64_t rng_state = 0x9E2FULL;
static uint64_t rng_next(void) {
  /* splitmix64 — deterministic across runs */
  uint64_t z = (rng_state += 0x9E3779B97F4A7C15ULL);
  z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
  z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
  return z ^ (z >> 31);
}
static float rng_normal(void) {
  /* Box-Muller on uniform doubles */
  double u1 = ((rng_next() >> 11) + 1.0) * (1.0 / 9007199254740993.0);
  double u2 = (rng_next() >> 11) * (1.0 / 9007199254740992.0);
  return (float)(sqrt(-2.0 * log(u1)) * cos(2.0 * M_PI * u2));
}

static size_t pad8(size_t n) { return (n + 7) / 8 * 8; }

static double now_ns(void) {
  struct timespec ts;
  clock_gettime(CLOCK_MONOTONIC, &ts);
  return ts.tv_sec * 1e9 + ts.tv_nsec;
}

static int cmp_d(const void *a, const void *b) {
  double x = *(const double *)a, y = *(const double *)b;
  return (x > y) - (x < y);
}

typedef struct {
  double mean_ns, p50_ns, p95_ns;
  int iters;
} stats_t;

#define MAX_SAMPLES 20000
static double samples[MAX_SAMPLES];

/* Adaptive timer mirroring util::bench::time: warm up, sample until the
 * budget elapses (>= 5 samples), report mean/p50/p95. */
#define TIME(budget_ms, out, body)                                         \
  do {                                                                     \
    { body }                                                               \
    { body }                                                               \
    int n = 0;                                                             \
    double start = now_ns();                                               \
    while (n < 5 || (now_ns() - start < (budget_ms) * 1e6 && n < MAX_SAMPLES)) { \
      double t0 = now_ns();                                                \
      { body }                                                             \
      samples[n++] = now_ns() - t0;                                        \
    }                                                                      \
    qsort(samples, n, sizeof(double), cmp_d);                              \
    double sum = 0;                                                        \
    for (int i = 0; i < n; i++) sum += samples[i];                         \
    (out).mean_ns = sum / n;                                               \
    (out).p50_ns = samples[(int)((n - 1) * 0.5 + 0.5)];                    \
    (out).p95_ns = samples[(int)((n - 1) * 0.95 + 0.5)];                   \
    (out).iters = n;                                                       \
  } while (0)

/* --------------------------------------------- pack / repack / dequant */

typedef struct {
  size_t d_in, d_out, bits, group, dp;
  uint8_t *planes;  /* [bits][d_in/8][d_out] — canonical layout */
  float *scales, *zeros;        /* [d_in/group][d_out] */
  uint8_t *rp_data;             /* [(d_in/8) * bits * dp] interleaved */
  float *rp_scales, *rp_zeros;  /* [d_in/group][dp] zero-padded */
} packed_t;

/* RTN group quantization (mirrors quant::rtn::quantize_rtn). */
static void quantize_rtn(const float *w, size_t d_in, size_t d_out,
                         size_t bits, size_t group, uint8_t *codes,
                         float *scales, float *zeros) {
  size_t levels = (1u << bits) - 1;
  for (size_t gi = 0; gi < d_in / group; gi++) {
    for (size_t o = 0; o < d_out; o++) {
      float wmin = 1e30f, wmax = -1e30f;
      for (size_t r = 0; r < group; r++) {
        float v = w[(gi * group + r) * d_out + o];
        if (v < wmin) wmin = v;
        if (v > wmax) wmax = v;
      }
      float span = wmax - wmin;
      if (span < 1e-8f) span = 1e-8f;
      float scale = span / (float)levels;
      float zero = roundf(-wmin / scale);
      scales[gi * d_out + o] = scale;
      zeros[gi * d_out + o] = zero;
      for (size_t r = 0; r < group; r++) {
        float v = w[(gi * group + r) * d_out + o];
        float q = roundf(v / scale + zero);
        if (q < 0) q = 0;
        if (q > (float)levels) q = (float)levels;
        codes[(gi * group + r) * d_out + o] = (uint8_t)q;
      }
    }
  }
}

static packed_t pack(const float *w, size_t d_in, size_t d_out, size_t bits,
                     size_t group) {
  packed_t p = {d_in, d_out, bits, group, pad8(d_out), 0, 0, 0, 0, 0, 0};
  size_t rows = d_in / 8, n_groups = d_in / group;
  uint8_t *codes = calloc(d_in * d_out, 1);
  p.scales = calloc(n_groups * d_out, 4);
  p.zeros = calloc(n_groups * d_out, 4);
  quantize_rtn(w, d_in, d_out, bits, group, codes, p.scales, p.zeros);
  p.planes = calloc(bits * rows * d_out, 1);
  for (size_t pl = 0; pl < bits; pl++)
    for (size_t r = 0; r < d_in; r++)
      for (size_t o = 0; o < d_out; o++)
        p.planes[pl * rows * d_out + (r / 8) * d_out + o] |=
            (uint8_t)(((codes[r * d_out + o] >> pl) & 1) << (r % 8));
  free(codes);
  /* interleaved repack: data[(br*bits + pl)*dp + o], zero-padded params */
  p.rp_data = calloc(rows * bits * p.dp, 1);
  for (size_t pl = 0; pl < bits; pl++)
    for (size_t br = 0; br < rows; br++)
      memcpy(p.rp_data + (br * bits + pl) * p.dp,
             p.planes + pl * rows * d_out + br * d_out, d_out);
  p.rp_scales = calloc(n_groups * p.dp, 4);
  p.rp_zeros = calloc(n_groups * p.dp, 4);
  for (size_t g = 0; g < n_groups; g++) {
    memcpy(p.rp_scales + g * p.dp, p.scales + g * d_out, d_out * 4);
    memcpy(p.rp_zeros + g * p.dp, p.zeros + g * d_out, d_out * 4);
  }
  return p;
}

/* Binary variant: planes = sign bits, rp_scales = padded alpha. */
static packed_t pack_binary(const float *w, size_t d_in, size_t d_out) {
  packed_t p = {d_in, d_out, 1, d_in, pad8(d_out), 0, 0, 0, 0, 0, 0};
  size_t rows = d_in / 8;
  p.planes = calloc(rows * d_out, 1);
  p.scales = calloc(d_out, 4);
  for (size_t o = 0; o < d_out; o++) {
    float l1 = 0;
    for (size_t r = 0; r < d_in; r++) {
      float v = w[r * d_out + o];
      l1 += fabsf(v);
      if (v >= 0) p.planes[(r / 8) * d_out + o] |= (uint8_t)(1 << (r % 8));
    }
    p.scales[o] = l1 / (float)d_in;
  }
  p.rp_data = calloc(rows * p.dp, 1);
  for (size_t br = 0; br < rows; br++)
    memcpy(p.rp_data + br * p.dp, p.planes + br * d_out, d_out);
  p.rp_scales = calloc(p.dp, 4);
  memcpy(p.rp_scales, p.scales, d_out * 4);
  return p;
}

static void pfree(packed_t *p) {
  free(p->planes); free(p->scales); free(p->zeros);
  free(p->rp_data); free(p->rp_scales); free(p->rp_zeros);
}

/* Dense reconstruction (the unfused baseline's first half). */
static void dequantize(const packed_t *p, float *out /* [d_in][d_out] */) {
  size_t rows = p->d_in / 8;
  for (size_t r = 0; r < p->d_in; r++)
    for (size_t o = 0; o < p->d_out; o++) {
      unsigned q = 0;
      for (size_t pl = 0; pl < p->bits; pl++)
        q |= (unsigned)((p->planes[pl * rows * p->d_out + (r / 8) * p->d_out + o] >>
                         (r % 8)) & 1) << pl;
      if (p->zeros) { /* packed */
        size_t gi = r / p->group;
        out[r * p->d_out + o] = ((float)q - p->zeros[gi * p->d_out + o]) *
                                p->scales[gi * p->d_out + o];
      } else { /* binary */
        out[r * p->d_out + o] = p->scales[o] * (2.0f * (float)q - 1.0f);
      }
    }
}

/* ------------------------------------------------------ scalar kernels */

static float BIT_LUT[256][8];
static void init_lut(void) {
  for (int b = 0; b < 256; b++)
    for (int j = 0; j < 8; j++) BIT_LUT[b][j] = (float)((b >> j) & 1);
}

static void scalar_matvec(const packed_t *p, const float *x, float *y,
                          float *qacc) {
  size_t dp = p->dp, bits = p->bits, bpg = p->group / 8;
  for (size_t gi = 0; gi < p->d_in / p->group; gi++) {
    memset(qacc, 0, dp * 4);
    float xsum = 0;
    for (size_t bq = 0; bq < bpg; bq++) {
      size_t br = gi * bpg + bq;
      const float *x8 = x + br * 8;
      int allz = 1;
      for (int j = 0; j < 8; j++) allz &= (x8[j] == 0.0f);
      if (allz) continue;
      for (int j = 0; j < 8; j++) xsum += x8[j];
      for (size_t pl = 0; pl < bits; pl++) {
        float pw = (float)(1u << pl);
        float xw[8];
        for (int j = 0; j < 8; j++) xw[j] = x8[j] * pw;
        const uint8_t *row = p->rp_data + (br * bits + pl) * dp;
        for (size_t o = 0; o < p->d_out; o++) {
          const float *l = BIT_LUT[row[o]];
          qacc[o] += l[0] * xw[0] + l[1] * xw[1] + l[2] * xw[2] +
                     l[3] * xw[3] + l[4] * xw[4] + l[5] * xw[5] +
                     l[6] * xw[6] + l[7] * xw[7];
        }
      }
    }
    const float *srow = p->rp_scales + gi * dp, *zrow = p->rp_zeros + gi * dp;
    for (size_t o = 0; o < p->d_out; o++)
      y[o] += srow[o] * (qacc[o] - zrow[o] * xsum);
  }
}

static void scalar_binary_matvec(const packed_t *p, const float *x, float *y,
                                 float *qacc) {
  size_t dp = p->dp;
  memset(qacc, 0, dp * 4);
  float xsum = 0;
  for (size_t br = 0; br < p->d_in / 8; br++) {
    const float *x8 = x + br * 8;
    int allz = 1;
    for (int j = 0; j < 8; j++) allz &= (x8[j] == 0.0f);
    if (allz) continue;
    for (int j = 0; j < 8; j++) xsum += x8[j];
    const uint8_t *row = p->rp_data + br * dp;
    for (size_t o = 0; o < p->d_out; o++) {
      const float *l = BIT_LUT[row[o]];
      qacc[o] += l[0] * x8[0] + l[1] * x8[1] + l[2] * x8[2] + l[3] * x8[3] +
                 l[4] * x8[4] + l[5] * x8[5] + l[6] * x8[6] + l[7] * x8[7];
    }
  }
  for (size_t o = 0; o < p->d_out; o++)
    y[o] += p->rp_scales[o] * (2.0f * qacc[o] - xsum);
}

static void token_acc_scalar(const packed_t *p, const float *tile, size_t rows,
                             const float *x, size_t t, size_t row0, float *y) {
  size_t dp = p->dp;
  for (size_t ti = 0; ti < t; ti++) {
    const float *xr = x + ti * p->d_in + row0;
    float *yrow = y + ti * p->d_out;
    for (size_t rq = 0; rq < rows; rq++) {
      float xv = xr[rq];
      if (xv == 0.0f) continue;
      const float *trow = tile + rq * dp;
      for (size_t o = 0; o < p->d_out; o++) yrow[o] += xv * trow[o];
    }
  }
}

static void scalar_matmul(const packed_t *p, const float *x, size_t t,
                          float *y, float *tile) {
  size_t dp = p->dp, bits = p->bits, bpg = p->group / 8;
  for (size_t gi = 0; gi < p->d_in / p->group; gi++) {
    const float *srow = p->rp_scales + gi * dp, *zrow = p->rp_zeros + gi * dp;
    for (size_t bq = 0; bq < bpg; bq++) {
      size_t br = gi * bpg + bq;
      for (size_t o = 0; o < p->d_out; o++) {
        float q[8] = {0};
        for (size_t pl = 0; pl < bits; pl++) {
          float pw = (float)(1u << pl);
          const float *l = BIT_LUT[p->rp_data[(br * bits + pl) * dp + o]];
          for (int j = 0; j < 8; j++) q[j] += pw * l[j];
        }
        for (int j = 0; j < 8; j++)
          tile[(bq * 8 + j) * dp + o] = (q[j] - zrow[o]) * srow[o];
      }
    }
    token_acc_scalar(p, tile, p->group, x, t, gi * p->group, y);
  }
}

static void scalar_binary_matmul(const packed_t *p, const float *x, size_t t,
                                 float *y, float *tile, size_t block) {
  size_t dp = p->dp;
  for (size_t row0 = 0; row0 < p->d_in; ) {
    size_t rows = block < p->d_in - row0 ? block : p->d_in - row0;
    for (size_t bq = 0; bq < rows / 8; bq++) {
      size_t br = row0 / 8 + bq;
      for (size_t o = 0; o < p->d_out; o++) {
        const float *l = BIT_LUT[p->rp_data[br * dp + o]];
        float a = p->rp_scales[o];
        for (int j = 0; j < 8; j++)
          tile[(bq * 8 + j) * dp + o] = a * (2.0f * l[j] - 1.0f);
      }
    }
    token_acc_scalar(p, tile, rows, x, t, row0, y);
    row0 += rows;
  }
}

/* -------------------------------------------------------- AVX2 kernels */

static inline __m256i load8(const uint8_t *p8) {
  return _mm256_cvtepu8_epi32(_mm_loadl_epi64((const __m128i *)p8));
}

static void avx2_matvec(const packed_t *p, const float *x, float *y,
                        float *qacc) {
  size_t dp = p->dp, bits = p->bits, bpg = p->group / 8;
  __m256i masks[8];
  for (int j = 0; j < 8; j++) masks[j] = _mm256_set1_epi32(1 << j);
  for (size_t gi = 0; gi < p->d_in / p->group; gi++) {
    memset(qacc, 0, dp * 4);
    float xsum = 0;
    for (size_t bq = 0; bq < bpg; bq++) {
      size_t br = gi * bpg + bq;
      const float *x8 = x + br * 8;
      int allz = 1;
      for (int j = 0; j < 8; j++) allz &= (x8[j] == 0.0f);
      if (allz) continue;
      for (int j = 0; j < 8; j++) xsum += x8[j];
      for (size_t pl = 0; pl < bits; pl++) {
        float pw = (float)(1u << pl);
        __m256 xw[8];
        for (int j = 0; j < 8; j++) xw[j] = _mm256_set1_ps(x8[j] * pw);
        const uint8_t *row = p->rp_data + (br * bits + pl) * dp;
        for (size_t oc = 0; oc < dp; oc += 8) {
          __m256i v = load8(row + oc);
          __m256 acc = _mm256_loadu_ps(qacc + oc);
          for (int j = 0; j < 8; j++) {
            __m256i hit =
                _mm256_cmpeq_epi32(_mm256_and_si256(v, masks[j]), masks[j]);
            acc = _mm256_add_ps(acc,
                                _mm256_and_ps(_mm256_castsi256_ps(hit), xw[j]));
          }
          _mm256_storeu_ps(qacc + oc, acc);
        }
      }
    }
    const float *srow = p->rp_scales + gi * dp, *zrow = p->rp_zeros + gi * dp;
    __m256 xs = _mm256_set1_ps(xsum);
    size_t o = 0;
    for (; o + 8 <= p->d_out; o += 8) {
      __m256 q = _mm256_loadu_ps(qacc + o);
      __m256 z = _mm256_loadu_ps(zrow + o);
      __m256 sv = _mm256_loadu_ps(srow + o);
      __m256 acc = _mm256_fnmadd_ps(z, xs, q);
      __m256 yv = _mm256_loadu_ps(y + o);
      _mm256_storeu_ps(y + o, _mm256_fmadd_ps(sv, acc, yv));
    }
    for (; o < p->d_out; o++) y[o] += srow[o] * (qacc[o] - zrow[o] * xsum);
  }
}

static void avx2_binary_matvec(const packed_t *p, const float *x, float *y,
                               float *qacc) {
  size_t dp = p->dp;
  __m256i masks[8];
  for (int j = 0; j < 8; j++) masks[j] = _mm256_set1_epi32(1 << j);
  memset(qacc, 0, dp * 4);
  float xsum = 0;
  for (size_t br = 0; br < p->d_in / 8; br++) {
    const float *x8 = x + br * 8;
    int allz = 1;
    for (int j = 0; j < 8; j++) allz &= (x8[j] == 0.0f);
    if (allz) continue;
    for (int j = 0; j < 8; j++) xsum += x8[j];
    __m256 xw[8];
    for (int j = 0; j < 8; j++) xw[j] = _mm256_set1_ps(x8[j]);
    const uint8_t *row = p->rp_data + br * dp;
    for (size_t oc = 0; oc < dp; oc += 8) {
      __m256i v = load8(row + oc);
      __m256 acc = _mm256_loadu_ps(qacc + oc);
      for (int j = 0; j < 8; j++) {
        __m256i hit =
            _mm256_cmpeq_epi32(_mm256_and_si256(v, masks[j]), masks[j]);
        acc = _mm256_add_ps(acc,
                            _mm256_and_ps(_mm256_castsi256_ps(hit), xw[j]));
      }
      _mm256_storeu_ps(qacc + oc, acc);
    }
  }
  __m256 xs = _mm256_set1_ps(xsum), two = _mm256_set1_ps(2.0f);
  size_t o = 0;
  for (; o + 8 <= p->d_out; o += 8) {
    __m256 q = _mm256_loadu_ps(qacc + o);
    __m256 a = _mm256_loadu_ps(p->rp_scales + o);
    __m256 acc = _mm256_fmsub_ps(two, q, xs);
    __m256 yv = _mm256_loadu_ps(y + o);
    _mm256_storeu_ps(y + o, _mm256_fmadd_ps(a, acc, yv));
  }
  for (; o < p->d_out; o++) y[o] += p->rp_scales[o] * (2.0f * qacc[o] - xsum);
}

static void token_acc_avx2(const packed_t *p, const float *tile, size_t rows,
                           const float *x, size_t t, size_t row0, float *y) {
  size_t dp = p->dp, oc = 0;
  for (; oc + 16 <= p->d_out; oc += 16) {
    for (size_t ti = 0; ti < t; ti++) {
      const float *xr = x + ti * p->d_in + row0;
      float *yp = y + ti * p->d_out + oc;
      __m256 a0 = _mm256_loadu_ps(yp), a1 = _mm256_loadu_ps(yp + 8);
      for (size_t rq = 0; rq < rows; rq++) {
        float xv = xr[rq];
        if (xv == 0.0f) continue;
        const float *tp = tile + rq * dp + oc;
        __m256 xb = _mm256_set1_ps(xv);
        a0 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(tp), a0);
        a1 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(tp + 8), a1);
      }
      _mm256_storeu_ps(yp, a0);
      _mm256_storeu_ps(yp + 8, a1);
    }
  }
  if (oc + 8 <= p->d_out) {
    for (size_t ti = 0; ti < t; ti++) {
      const float *xr = x + ti * p->d_in + row0;
      float *yp = y + ti * p->d_out + oc;
      __m256 a0 = _mm256_loadu_ps(yp);
      for (size_t rq = 0; rq < rows; rq++) {
        float xv = xr[rq];
        if (xv == 0.0f) continue;
        a0 = _mm256_fmadd_ps(_mm256_set1_ps(xv),
                             _mm256_loadu_ps(tile + rq * dp + oc), a0);
      }
      _mm256_storeu_ps(yp, a0);
    }
    oc += 8;
  }
  if (oc < p->d_out)
    for (size_t ti = 0; ti < t; ti++) {
      const float *xr = x + ti * p->d_in + row0;
      for (size_t rq = 0; rq < rows; rq++) {
        float xv = xr[rq];
        if (xv == 0.0f) continue;
        const float *trow = tile + rq * dp;
        for (size_t o = oc; o < p->d_out; o++)
          y[ti * p->d_out + o] += xv * trow[o];
      }
    }
}

static void avx2_matmul(const packed_t *p, const float *x, size_t t, float *y,
                        float *tile) {
  size_t dp = p->dp, bits = p->bits, bpg = p->group / 8;
  __m256i masks[8], pw_i[4];
  for (int j = 0; j < 8; j++) masks[j] = _mm256_set1_epi32(1 << j);
  for (size_t pl = 0; pl < bits; pl++) pw_i[pl] = _mm256_set1_epi32(1 << pl);
  for (size_t gi = 0; gi < p->d_in / p->group; gi++) {
    const float *srow = p->rp_scales + gi * dp, *zrow = p->rp_zeros + gi * dp;
    for (size_t bq = 0; bq < bpg; bq++) {
      size_t br = gi * bpg + bq;
      for (size_t oc = 0; oc < dp; oc += 8) {
        __m256i planes[4];
        for (size_t pl = 0; pl < bits; pl++)
          planes[pl] = load8(p->rp_data + (br * bits + pl) * dp + oc);
        __m256 sv = _mm256_loadu_ps(srow + oc), zv = _mm256_loadu_ps(zrow + oc);
        for (int j = 0; j < 8; j++) {
          __m256i qi = _mm256_setzero_si256();
          for (size_t pl = 0; pl < bits; pl++) {
            __m256i hit = _mm256_cmpeq_epi32(
                _mm256_and_si256(planes[pl], masks[j]), masks[j]);
            qi = _mm256_add_epi32(qi, _mm256_and_si256(hit, pw_i[pl]));
          }
          __m256 w = _mm256_mul_ps(
              _mm256_sub_ps(_mm256_cvtepi32_ps(qi), zv), sv);
          _mm256_storeu_ps(tile + (bq * 8 + j) * dp + oc, w);
        }
      }
    }
    token_acc_avx2(p, tile, p->group, x, t, gi * p->group, y);
  }
}

static void avx2_binary_matmul(const packed_t *p, const float *x, size_t t,
                               float *y, float *tile, size_t block) {
  size_t dp = p->dp;
  __m256i masks[8], onei = _mm256_set1_epi32(1);
  __m256 two = _mm256_set1_ps(2.0f), onef = _mm256_set1_ps(1.0f);
  for (int j = 0; j < 8; j++) masks[j] = _mm256_set1_epi32(1 << j);
  for (size_t row0 = 0; row0 < p->d_in; ) {
    size_t rows = block < p->d_in - row0 ? block : p->d_in - row0;
    for (size_t bq = 0; bq < rows / 8; bq++) {
      size_t br = row0 / 8 + bq;
      for (size_t oc = 0; oc < dp; oc += 8) {
        __m256i v = load8(p->rp_data + br * dp + oc);
        __m256 a = _mm256_loadu_ps(p->rp_scales + oc);
        for (int j = 0; j < 8; j++) {
          __m256i hit =
              _mm256_cmpeq_epi32(_mm256_and_si256(v, masks[j]), masks[j]);
          __m256 b = _mm256_cvtepi32_ps(_mm256_and_si256(hit, onei));
          __m256 w = _mm256_mul_ps(a, _mm256_fmsub_ps(two, b, onef));
          _mm256_storeu_ps(tile + (bq * 8 + j) * dp + oc, w);
        }
      }
    }
    token_acc_avx2(p, tile, rows, x, t, row0, y);
    row0 += rows;
  }
}

/* ------------------------------------------------ equivalence checking */

static int n_checks = 0, n_fail = 0;

static void expect_close(const float *a, const float *b, size_t n, float tol,
                         const char *what) {
  n_checks++;
  for (size_t i = 0; i < n; i++) {
    float scale = fabsf(a[i]) > 1.0f ? fabsf(a[i]) : 1.0f;
    if (fabsf(a[i] - b[i]) > tol * scale) {
      fprintf(stderr, "FAIL %s: elem %zu: %g vs %g\n", what, i, a[i], b[i]);
      n_fail++;
      return;
    }
  }
}

/* Reference: y += x @ dequant(p), one token at a time. */
static void reference_acc(const packed_t *p, const float *x, size_t t,
                          float *y, float *wd) {
  dequantize(p, wd);
  for (size_t ti = 0; ti < t; ti++)
    for (size_t r = 0; r < p->d_in; r++) {
      float xv = x[ti * p->d_in + r];
      if (xv == 0.0f) continue;
      for (size_t o = 0; o < p->d_out; o++)
        y[ti * p->d_out + o] += xv * wd[r * p->d_out + o];
    }
}

static void verify_case(size_t bits, size_t group, size_t d_in, size_t d_out,
                        size_t t) {
  float *w = malloc(d_in * d_out * 4);
  for (size_t i = 0; i < d_in * d_out; i++) w[i] = rng_normal();
  packed_t p = bits == 0 ? pack_binary(w, d_in, d_out)
                         : pack(w, d_in, d_out, bits, group);
  float *x = malloc(t * d_in * 4);
  for (size_t i = 0; i < t * d_in; i++) x[i] = rng_normal();
  for (size_t c = 0; c < t * d_in / 8; c++)            /* zero-skip coverage */
    if (rng_next() % 4 == 0) memset(x + c * 8, 0, 32);
  size_t ny = t * d_out;
  float *want = calloc(ny, 4), *got_s = calloc(ny, 4), *got_v = calloc(ny, 4);
  float *wd = malloc(d_in * d_out * 4);
  float *qacc = malloc(p.dp * 4);
  size_t tile_rows = bits == 0 ? (d_in < 64 ? d_in : 64) : group;
  float *tile = malloc(tile_rows * p.dp * 4);
  reference_acc(&p, x, t, want, wd);
  char what[128];
  snprintf(what, sizeof what, "bits=%zu group=%zu %zux%zu t=%zu", bits, group,
           d_in, d_out, t);
  if (t == 1) {
    if (bits == 0) { scalar_binary_matvec(&p, x, got_s, qacc);
                     avx2_binary_matvec(&p, x, got_v, qacc); }
    else           { scalar_matvec(&p, x, got_s, qacc);
                     avx2_matvec(&p, x, got_v, qacc); }
  } else {
    if (bits == 0) { scalar_binary_matmul(&p, x, t, got_s, tile, tile_rows);
                     avx2_binary_matmul(&p, x, t, got_v, tile, tile_rows); }
    else           { scalar_matmul(&p, x, t, got_s, tile);
                     avx2_matmul(&p, x, t, got_v, tile); }
  }
  expect_close(got_s, want, ny, 1e-4f, what);
  expect_close(got_v, want, ny, 1e-4f, what);
  expect_close(got_s, got_v, ny, 1e-4f, what);
  free(w); free(x); free(want); free(got_s); free(got_v);
  free(wd); free(qacc); free(tile); pfree(&p);
}

/* ----------------------------------------------------------- benchmark */

typedef struct {
  const char *op;
  int bits, tokens;
  stats_t unfused, fscalar, fsimd;
} row_t;

static void bench_case(const char *op, size_t bits, size_t d_in, size_t d_out,
                       size_t t, double budget_ms, row_t *row) {
  float *w = malloc(d_in * d_out * 4);
  for (size_t i = 0; i < d_in * d_out; i++) w[i] = rng_normal();
  packed_t p = bits == 1 ? pack_binary(w, d_in, d_out)
                         : pack(w, d_in, d_out, bits, 32);
  int is_bin = (bits == 1);
  float *x = malloc(t * d_in * 4);
  for (size_t i = 0; i < t * d_in; i++) x[i] = rng_normal();
  float *y = calloc(t * d_out, 4);
  float *wd = malloc(d_in * d_out * 4);
  float *qacc = malloc(p.dp * 4);
  size_t tile_rows = is_bin ? 64 : p.group;
  float *tile = malloc(tile_rows * p.dp * 4);

  row->op = op; row->bits = (int)bits; row->tokens = (int)t;
  TIME(budget_ms, row->unfused, {
    memset(y, 0, t * d_out * 4);
    reference_acc(&p, x, t, y, wd);
  });
  if (t == 1) {
    TIME(budget_ms, row->fscalar, {
      memset(y, 0, d_out * 4);
      if (is_bin) scalar_binary_matvec(&p, x, y, qacc);
      else        scalar_matvec(&p, x, y, qacc);
    });
    TIME(budget_ms, row->fsimd, {
      memset(y, 0, d_out * 4);
      if (is_bin) avx2_binary_matvec(&p, x, y, qacc);
      else        avx2_matvec(&p, x, y, qacc);
    });
  } else {
    TIME(budget_ms, row->fscalar, {
      memset(y, 0, t * d_out * 4);
      if (is_bin) scalar_binary_matmul(&p, x, t, y, tile, tile_rows);
      else        scalar_matmul(&p, x, t, y, tile);
    });
    TIME(budget_ms, row->fsimd, {
      memset(y, 0, t * d_out * 4);
      if (is_bin) avx2_binary_matmul(&p, x, t, y, tile, tile_rows);
      else        avx2_matmul(&p, x, t, y, tile);
    });
  }
  free(w); free(x); free(y); free(wd); free(qacc); free(tile); pfree(&p);
}

static void stats_json(FILE *f, const char *key, const stats_t *s) {
  fprintf(f,
          "\"%s\": {\"iters\": %d, \"mean_ns\": %.1f, \"p50_ns\": %.1f, "
          "\"p95_ns\": %.1f}",
          key, s->iters, s->mean_ns, s->p50_ns, s->p95_ns);
}

int main(int argc, char **argv) {
  const char *json_path = NULL;
  for (int i = 1; i < argc - 1; i++)
    if (!strcmp(argv[i], "--json")) json_path = argv[i + 1];
  init_lut();

  /* equivalence sweep: packed bits 1..4 x groups {16,32,64} x odd shapes,
   * binary (bits=0 sentinel), matvec and matmul */
  size_t shapes[][2] = {{128, 256}, {64, 96}, {32, 17}, {96, 40}, {64, 7}};
  for (size_t bits = 1; bits <= 4; bits++)
    for (size_t g = 16; g <= 64; g *= 2)
      for (size_t si = 0; si < 5; si++) {
        size_t d_in = shapes[si][0], d_out = shapes[si][1];
        if (d_in % g) continue;
        verify_case(bits, g, d_in, d_out, 1);
        verify_case(bits, g, d_in, d_out, 16);
      }
  for (size_t si = 0; si < 5; si++) {
    verify_case(0, 0, shapes[si][0], shapes[si][1], 1);
    verify_case(0, 0, shapes[si][0], shapes[si][1], 16);
  }
  printf("equivalence: %d checks, %d failures\n", n_checks, n_fail);
  if (n_fail) return 1;

  /* measurement: same shape as the Rust bench section
   * (h=128 -> f=256, group 32, matmul T=16) */
  row_t rows[8];
  int nr = 0;
  for (size_t bits = 1; bits <= 4; bits++) {
    bench_case("matvec", bits, 128, 256, 1, 300.0, &rows[nr++]);
    bench_case("matmul", bits, 128, 256, 16, 300.0, &rows[nr++]);
  }
  printf("%-8s %-5s %-7s %12s %14s %12s %8s %8s\n", "op", "bits", "tokens",
         "unfused_ns", "fused_scal_ns", "fused_simd_ns", "fxu", "sxs");
  for (int i = 0; i < nr; i++) {
    row_t *r = &rows[i];
    printf("%-8s %-5d %-7d %12.0f %14.0f %12.0f %7.2fx %7.2fx\n", r->op,
           r->bits, r->tokens, r->unfused.p50_ns, r->fscalar.p50_ns,
           r->fsimd.p50_ns, r->unfused.p50_ns / r->fsimd.p50_ns,
           r->fscalar.p50_ns / r->fsimd.p50_ns);
  }
  if (json_path) {
    FILE *f = fopen(json_path, "w");
    if (!f) { perror("open json"); return 1; }
    fprintf(f,
            "{\"bench\": \"perf_hotpath\", \"section\": \"kernels\", "
            "\"harness\": \"c-port-gcc\", \"smoke\": false, "
            "\"host_isa\": \"avx2+fma\", "
            "\"note\": \"measured by tools/bench_kernels.c, a line-for-line "
            "C port of rust/src/quant/kernels (same repack layout, scalar "
            "LUT chain and AVX2 mask-compare intrinsics); refresh with "
            "cargo bench --bench perf_hotpath -- --json when a Rust "
            "toolchain is available\", "
            "\"shape\": {\"d_in\": 128, \"d_out\": 256, \"group\": 32, "
            "\"t_matmul\": 16}, \"rows\": [");
    for (int i = 0; i < nr; i++) {
      row_t *r = &rows[i];
      double best = r->fsimd.p50_ns < r->fscalar.p50_ns ? r->fsimd.p50_ns
                                                        : r->fscalar.p50_ns;
      fprintf(f, "%s{\"op\": \"%s\", \"bits\": %d, \"tokens\": %d, ",
              i ? ", " : "", r->op, r->bits, r->tokens);
      stats_json(f, "unfused", &r->unfused); fprintf(f, ", ");
      stats_json(f, "fused_scalar", &r->fscalar); fprintf(f, ", ");
      stats_json(f, "fused_simd", &r->fsimd);
      fprintf(f,
              ", \"speedup_fused_vs_unfused\": %.3f, "
              "\"speedup_simd_vs_scalar\": %.3f}",
              r->unfused.p50_ns / best, r->fscalar.p50_ns / r->fsimd.p50_ns);
    }
    fprintf(f, "]}\n");
    fclose(f);
    printf("wrote %s\n", json_path);
  }
  return 0;
}
