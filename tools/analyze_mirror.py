#!/usr/bin/env python3
"""Python mirror of rust/tools/analyze (mcsharp-analyze).

The container this repo grows in has no Rust toolchain, so — like the C
port that cross-validated the PR 5 kernels — this mirror re-implements
the analyzer's lexer and six passes 1:1 and is runnable today:

    python3 tools/analyze_mirror.py [root] [--inventory ANALYSIS.md]

Keep the logic in lockstep with rust/tools/analyze/src/lib.rs: any
behavioural change must land in both.  The fixture expectations under
rust/tools/analyze/fixtures/ are validated against this mirror.
"""

import os
import re
import sys

# --------------------------------------------------------------- lexer


class Tok:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind  # ident | punct | str | char | lifetime | num | comment
        self.text = text
        self.line = line

    def __repr__(self):
        return f"{self.kind}({self.text!r}@{self.line})"


def lex(src):
    toks = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            j = n if j < 0 else j
            toks.append(Tok("comment", src[i:j], line))
            i = j
            continue
        if src.startswith("/*", i):
            depth, j, start = 1, i + 2, line
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    if src[j] == "\n":
                        line += 1
                    j += 1
            toks.append(Tok("comment", src[i:j], start))
            i = j
            continue
        # raw / byte strings
        m = re.match(r'(?:b?r)(#*)"', src[i:])
        if m and (c == "r" or src.startswith("br", i) or (c == "b" and src[i + 1 : i + 2] == "r")):
            hashes = m.group(1)
            close = '"' + hashes
            j = src.find(close, i + m.end())
            j = n if j < 0 else j + len(close)
            start = line
            line += src.count("\n", i, j)
            toks.append(Tok("str", src[i:j], start))
            i = j
            continue
        if c == '"' or (c == "b" and src[i + 1 : i + 2] == '"'):
            j = i + (2 if c == "b" else 1)
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == '"':
                    j += 1
                    break
                if src[j] == "\n":
                    line += 1
                j += 1
            toks.append(Tok("str", src[i:j], line))
            i = j
            continue
        if c == "'":
            # lifetime ('a) vs char literal ('x', '\n', '\'')
            m = re.match(r"'[A-Za-z_][A-Za-z0-9_]*(?!')", src[i:])
            if m and not src.startswith("'", i + m.end()):
                toks.append(Tok("lifetime", m.group(0), line))
                i += m.end()
                continue
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == "'":
                    j += 1
                    break
                j += 1
            toks.append(Tok("char", src[i:j], line))
            i = j
            continue
        m = re.match(r"[A-Za-z_][A-Za-z0-9_]*", src[i:])
        if m:
            toks.append(Tok("ident", m.group(0), line))
            i += m.end()
            continue
        m = re.match(r"[0-9][0-9A-Za-z_]*", src[i:])
        if m:
            toks.append(Tok("num", m.group(0), line))
            i += m.end()
            continue
        toks.append(Tok("punct", c, line))
        i += 1
    return toks


def strip_tests(toks):
    """Drop `#[cfg(test)] <item> { .. }` regions (tests are exempt)."""
    out, i, n = [], 0, len(toks)
    while i < n:
        t = toks[i]
        if (
            t.kind == "punct"
            and t.text == "#"
            and i + 6 < n
            and [x.text for x in toks[i + 1 : i + 7]]
            == ["[", "cfg", "(", "test", ")", "]"]
        ):
            j = i + 7
            while j < n and not (toks[j].kind == "punct" and toks[j].text == "{"):
                if toks[j].kind == "punct" and toks[j].text == ";":
                    break  # cfg(test) on a bodiless item
                j += 1
            if j < n and toks[j].text == "{":
                depth = 0
                while j < n:
                    if toks[j].kind == "punct" and toks[j].text == "{":
                        depth += 1
                    elif toks[j].kind == "punct" and toks[j].text == "}":
                        depth -= 1
                        if depth == 0:
                            break
                    j += 1
            i = j + 1
            continue
        out.append(t)
        i += 1
    return out


class SrcFile:
    def __init__(self, rel, text):
        self.rel = rel.replace(os.sep, "/")
        self.lines = text.split("\n")
        self.toks = strip_tests([t for t in lex(text)])
        self.code = [t for t in self.toks if t.kind != "comment"]

    def line(self, ln):
        return self.lines[ln - 1] if 1 <= ln <= len(self.lines) else ""


class Finding:
    def __init__(self, pass_name, rel, line, msg):
        self.pass_name, self.rel, self.line, self.msg = pass_name, rel, line, msg

    def __str__(self):
        return f"[{self.pass_name}] {self.rel}:{self.line}: {self.msg}"


# ---------------------------------------------------- function extraction


class Fn:
    def __init__(self, name, line, body, sfile):
        self.name, self.line, self.body, self.sfile = name, line, body, sfile


def functions(sfile):
    """Every `fn name(..) { .. }` with a body, as (name, code-token slice)."""
    toks = sfile.code
    fns, i, n = [], 0, len(toks)
    while i < n:
        if toks[i].kind == "ident" and toks[i].text == "fn" and i + 1 < n and toks[i + 1].kind == "ident":
            name, fline = toks[i + 1].text, toks[i].line
            j, paren = i + 2, 0
            body = None
            while j < n:
                t = toks[j]
                if t.kind == "punct":
                    if t.text == "(":
                        paren += 1
                    elif t.text == ")":
                        paren -= 1
                    elif t.text == ";" and paren == 0:
                        break  # trait method without a body
                    elif t.text == "{" and paren == 0:
                        depth, k = 0, j
                        while k < n:
                            if toks[k].kind == "punct" and toks[k].text == "{":
                                depth += 1
                            elif toks[k].kind == "punct" and toks[k].text == "}":
                                depth -= 1
                                if depth == 0:
                                    break
                            k += 1
                        body = toks[j : k + 1]
                        j = k
                        break
                j += 1
            if body is not None:
                fns.append(Fn(name, fline, body, sfile))
                i = j + 1
                continue
        i += 1
    return fns


def header_block(sfile, fn_line):
    """Comment/attribute lines immediately above a declaration line
    (doc comments, attributes, blanks in between)."""
    block, ln = [], fn_line - 1
    while ln >= 1:
        s = sfile.line(ln).strip()
        if s == "" or s.startswith("//") or s.startswith("#["):
            block.append(s)
            ln -= 1
        else:
            break
    return block


def decl_line(fn):
    """First line of the declaration (walk up over pub/unsafe/attr lines
    that share the fn keyword's line in the token stream)."""
    return fn.line


# ----------------------------------------------------------- pass 1: locks

RANK = {"scheduler": 0, "engine": 1, "pool": 2, "store": 3}
IO_IDENTS = {
    "read_command_line",
    "read_line",
    "write_all",
    "read_exact",
    "read_to_end",
    "read_to_string",
    "flush",
    "connect",
    "connect_timeout",
    "accept",
    "sleep",
}


def classify_lock(recv, rel):
    if "pool" in recv:
        return "pool"
    if recv == "inner":
        if rel.endswith("coordinator/scheduler.rs"):
            return "scheduler"
        if rel.endswith("quant/store.rs") or rel.endswith("quant/remote.rs"):
            return "store"
        return None
    if recv in ("eng", "engine"):
        return "engine"
    return None


def has_waiver(sfile, line, tag):
    for ln in (line, line - 1, line - 2):
        if f"analyze: allow({tag})" in sfile.line(ln):
            return True
    return False


def fn_waiver(fn, tag):
    return any(f"analyze: allow({tag})" in s for s in header_block(fn.sfile, fn.line))


def pass_lock_order(files):
    findings = []
    for sf in files:
        for fn in functions(sf):
            findings.extend(check_fn_locks(fn))
    return findings


def check_fn_locks(fn):
    findings = []
    toks = fn.body
    held = []  # (class, name-or-None, depth)
    depth = 0
    stmt_start = 0
    i, n = 0, len(toks)
    while i < n:
        t = toks[i]
        if t.kind == "punct" and t.text == "{":
            depth += 1
            stmt_start = i + 1
        elif t.kind == "punct" and t.text == "}":
            depth -= 1
            held = [h for h in held if h[2] <= depth]
            stmt_start = i + 1
        elif t.kind == "punct" and t.text == ";":
            stmt_start = i + 1
        elif (
            t.kind == "ident"
            and t.text == "drop"
            and i + 2 < n
            and toks[i + 1].text == "("
            and toks[i + 2].kind == "ident"
        ):
            name = toks[i + 2].text
            held = [h for h in held if h[1] != name]
        elif (
            t.kind == "punct"
            and t.text == "."
            and i + 3 < n
            and toks[i + 1].kind == "ident"
            and toks[i + 1].text == "lock"
            and toks[i + 2].text == "("
            and toks[i + 3].text == ")"
        ):
            recv = receiver_before(toks, i)
            cls = classify_lock(recv, fn.sfile.rel)
            if cls is not None:
                rank = RANK[cls]
                for hcls, _, _ in held:
                    if RANK[hcls] >= rank and not (
                        has_waiver(fn.sfile, t.line, "lock-order")
                        or fn_waiver(fn, "lock-order")
                    ):
                        findings.append(
                            Finding(
                                "lock-order",
                                fn.sfile.rel,
                                t.line,
                                f"acquires `{cls}` lock while holding `{hcls}` "
                                f"(declared order: scheduler -> engine -> pool -> store) in fn {fn.name}",
                            )
                        )
                # bound to a let-guard? held until scope end / drop()
                name = let_binding(toks, stmt_start, i)
                if name is not False:
                    held.append((cls, name, depth))
            i += 4
            continue
        elif t.kind == "ident" and t.text in IO_IDENTS and held:
            if not (has_waiver(fn.sfile, t.line, "lock-across-io") or fn_waiver(fn, "lock-across-io")):
                hcls = held[-1][0]
                findings.append(
                    Finding(
                        "lock-order",
                        fn.sfile.rel,
                        t.line,
                        f"blocking call `{t.text}` while holding `{hcls}` lock in fn {fn.name}",
                    )
                )
        i += 1
    return findings


def receiver_before(toks, dot_i):
    """Identifier naming the receiver of `.lock()`: the ident before the
    dot, or — when the receiver is a call like `kv_pool()` — the method
    name before its parens."""
    j = dot_i - 1
    if j >= 0 and toks[j].kind == "punct" and toks[j].text == ")":
        depth = 0
        while j >= 0:
            if toks[j].text == ")":
                depth += 1
            elif toks[j].text == "(":
                depth -= 1
                if depth == 0:
                    break
            j -= 1
        j -= 1
    if j >= 0 and toks[j].kind == "ident":
        return toks[j].text
    return ""


def let_binding(toks, stmt_start, lock_i):
    """`let [mut] name = ..lock()..` => name; `let (a,b) = ..` => None
    (scope-held, anonymous); no let => False (statement temporary)."""
    for j in range(stmt_start, lock_i):
        if toks[j].kind == "ident" and toks[j].text == "let":
            k = j + 1
            if k < lock_i and toks[k].kind == "ident" and toks[k].text == "mut":
                k += 1
            if k < lock_i and toks[k].kind == "ident":
                return toks[k].text
            return None
    return False


# -------------------------------------------------------- pass 2: hot path

DENIED_METHODS = {"to_vec", "collect", "clone", "cloned", "to_owned", "to_string"}
DENIED_CTORS = {"Vec", "String", "Box"}
DENIED_CTOR_FNS = {"new", "with_capacity", "from"}


def is_hot_path(fn):
    return any("analyze: hot-path" in s for s in header_block(fn.sfile, fn.line))


def pass_hot_path(files):
    findings = []
    for sf in files:
        for fn in functions(sf):
            if not is_hot_path(fn):
                continue
            findings.extend(check_hot_fn(fn))
    return findings


def check_hot_fn(fn):
    findings = []
    toks = fn.body
    n = len(toks)

    def flag(t, what):
        if not has_waiver(fn.sfile, t.line, "alloc"):
            findings.append(
                Finding(
                    "hot-path",
                    fn.sfile.rel,
                    t.line,
                    f"allocation `{what}` in hot-path fn {fn.name} "
                    "(scratch-arena contract; waive with `// analyze: allow(alloc): <why>`)",
                )
            )

    for i, t in enumerate(toks):
        if t.kind != "ident":
            continue
        if t.text in ("vec", "format") and i + 1 < n and toks[i + 1].text == "!":
            flag(t, f"{t.text}!")
        elif (
            t.text in DENIED_CTORS
            and i + 3 < n
            and toks[i + 1].text == ":"
            and toks[i + 2].text == ":"
            and toks[i + 3].kind == "ident"
            and toks[i + 3].text in DENIED_CTOR_FNS
        ):
            flag(t, f"{t.text}::{toks[i + 3].text}")
        elif (
            t.text in DENIED_METHODS
            and i >= 1
            and toks[i - 1].text == "."
            and i + 1 < n
            and toks[i + 1].text == "("
        ):
            flag(t, f".{t.text}()")
    return findings


# ---------------------------------------------------- pass 3: unsafe audit

STMT_ENDERS = (";", "{", "}", ",")


def unsafe_sites(sfile):
    """(kind, line) for every unsafe fn / impl / block outside tests."""
    sites = []
    toks = sfile.code
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == "unsafe":
            nxt = toks[i + 1] if i + 1 < len(toks) else None
            if nxt is not None and nxt.kind == "ident" and nxt.text == "impl":
                sites.append(("impl", t.line))
            elif nxt is not None and nxt.kind == "ident" and nxt.text == "fn":
                sites.append(("fn", t.line))
            else:
                sites.append(("block", t.line))
    return sites


def block_justified(sfile, line):
    if "SAFETY:" in sfile.line(line):
        return True
    ln = line - 1
    while ln >= 1:
        s = sfile.line(ln).strip()
        if s.startswith("//"):
            if "SAFETY:" in s:
                return True
            ln -= 1
            continue
        if s == "":
            return False
        if s.endswith(STMT_ENDERS):
            return False  # crossed a statement boundary with no comment
        ln -= 1  # continuation line of the same statement
    return False


def fn_justified(sfile, line):
    block = header_block(sfile, line)
    return any("SAFETY" in s or "# Safety" in s for s in block) or "SAFETY:" in sfile.line(line)


def pass_unsafe(files, inventory_text):
    findings = []
    counts = {}
    for sf in files:
        c = [0, 0, 0]  # fns, impls, blocks
        for kind, line in unsafe_sites(sf):
            if kind == "fn":
                c[0] += 1
                ok = fn_justified(sf, line)
            elif kind == "impl":
                c[1] += 1
                ok = block_justified(sf, line)
            else:
                c[2] += 1
                ok = block_justified(sf, line)
            if not ok:
                findings.append(
                    Finding(
                        "unsafe-audit",
                        sf.rel,
                        line,
                        f"unsafe {kind} without an adjacent `// SAFETY:` justification",
                    )
                )
        if c != [0, 0, 0]:
            counts[sf.rel] = tuple(c)
    if inventory_text is None:
        return findings
    inv = parse_inventory(inventory_text)
    for rel, c in sorted(counts.items()):
        if rel not in inv:
            findings.append(
                Finding("unsafe-audit", rel, 0, f"unsafe code not in the ANALYSIS.md inventory (fns={c[0]} impls={c[1]} blocks={c[2]})")
            )
        elif inv[rel] != c:
            findings.append(
                Finding(
                    "unsafe-audit",
                    rel,
                    0,
                    f"inventory drift: ANALYSIS.md says fns={inv[rel][0]} impls={inv[rel][1]} blocks={inv[rel][2]}, tree has fns={c[0]} impls={c[1]} blocks={c[2]}",
                )
            )
    for rel in sorted(inv):
        if rel not in counts:
            findings.append(
                Finding("unsafe-audit", rel, 0, "stale inventory row: file has no unsafe code (or no longer exists)")
            )
    return findings


def parse_inventory(text):
    inv = {}
    for line in text.split("\n"):
        m = re.match(r"\|\s*`([^`]+)`\s*\|\s*(\d+)\s*\|\s*(\d+)\s*\|\s*(\d+)\s*\|", line)
        if m:
            inv[m.group(1)] = (int(m.group(2)), int(m.group(3)), int(m.group(4)))
    return inv


# ------------------------------------------------- pass 4: protocol point

WIRE_PATTERNS = ("OK id=", "ERR id=", "REC id=", "TOK id=", "BUSY id=", "GEN id=", "FETCH ", "TRACE ")


def pass_protocol(files):
    findings = []
    for sf in files:
        if sf.rel.endswith("coordinator/protocol.rs"):
            continue
        for t in sf.toks:
            if t.kind != "str":
                continue
            body = t.text.lstrip("br#").lstrip('"')
            for pat in WIRE_PATTERNS:
                # wire frames are whole lines: only a literal that BEGINS
                # with a tag is framing (error text mentioning FETCH is not)
                if body.startswith(pat):
                    findings.append(
                        Finding(
                            "protocol-point",
                            sf.rel,
                            t.line,
                            f'wire literal "{pat}.." outside coordinator/protocol.rs '
                            "(all framing goes through protocol::format_*/parse_*)",
                        )
                    )
                    break
    return findings


# ------------------------------------------------ pass 5: gauge staleness


def gauge_fields(sf):
    """Fields of `struct Metrics` whose preceding comment carries
    `analyze: gauge`."""
    toks = sf.code
    fields = []
    for i, t in enumerate(toks):
        if (
            t.kind == "ident"
            and t.text == "struct"
            and i + 1 < len(toks)
            and toks[i + 1].text == "Metrics"
        ):
            j = i + 2
            while j < len(toks) and toks[j].text != "{":
                j += 1
            depth = 0
            while j < len(toks):
                tj = toks[j]
                if tj.kind == "punct" and tj.text == "{":
                    depth += 1
                elif tj.kind == "punct" and tj.text == "}":
                    depth -= 1
                    if depth == 0:
                        break
                elif (
                    depth == 1
                    and tj.kind == "ident"
                    and j + 1 < len(toks)
                    and toks[j + 1].text == ":"
                    and toks[j + 2].text != ":"
                ):
                    block = header_block(sf, tj.line)
                    if any("analyze: gauge" in s for s in block):
                        fields.append((tj.text, tj.line))
                j += 1
            break
    return fields


def pass_gauges(files):
    findings = []
    metrics = next((f for f in files if f.rel.endswith("coordinator/metrics.rs")), None)
    engine = next((f for f in files if f.rel.endswith("coordinator/engine.rs")), None)
    if metrics is None or engine is None:
        return findings
    fields = gauge_fields(metrics)
    if not fields:
        findings.append(
            Finding(
                "gauge-staleness",
                metrics.rel,
                0,
                "no Metrics field carries an `// analyze: gauge` marker — the staleness contract has rotted",
            )
        )
        return findings
    step = next((fn for fn in functions(engine) if fn.name == "step"), None)
    if step is None:
        findings.append(Finding("gauge-staleness", engine.rel, 0, "DecodeEngine::step not found"))
        return findings
    for field, fline in fields:
        if not assigns_metrics_field(step.body, field):
            findings.append(
                Finding(
                    "gauge-staleness",
                    metrics.rel,
                    fline,
                    f"gauge field `{field}` is never refreshed inside DecodeEngine::step "
                    "(the per-step loop must republish it)",
                )
            )
    return findings


def assigns_metrics_field(toks, field):
    n = len(toks)
    for i in range(n - 3):
        if (
            toks[i].kind == "ident"
            and toks[i].text == "metrics"
            and toks[i + 1].text == "."
            and toks[i + 2].kind == "ident"
            and toks[i + 2].text == field
            and toks[i + 3].text == "="
            and (i + 4 >= n or toks[i + 4].text != "=")
        ):
            return True
    return False


# -------------------------------------------------- pass 6: trace guard


def pass_trace_guard(files):
    findings = []
    for sf in files:
        for fn in functions(sf):
            findings.extend(check_fn_trace_guard(fn))
    return findings


def check_fn_trace_guard(fn):
    """`let _ = <expr containing .span( or SpanGuard>;` — the guard drops
    at the end of the statement, so the recorded span is zero-length and
    the timing is silently lost."""
    findings = []
    toks = fn.body
    i, n = 0, len(toks)
    while i < n:
        if (
            toks[i].kind == "ident"
            and toks[i].text == "let"
            and i + 2 < n
            and toks[i + 1].kind == "ident"
            and toks[i + 1].text == "_"
            and toks[i + 2].kind == "punct"
            and toks[i + 2].text == "="
        ):
            let_line = toks[i].line
            j = i + 3
            guardish = False
            while j < n and not (toks[j].kind == "punct" and toks[j].text == ";"):
                t = toks[j]
                if t.kind == "ident" and (
                    (t.text == "span" and j + 1 < n and toks[j + 1].kind == "punct" and toks[j + 1].text == "(")
                    or t.text == "SpanGuard"
                ):
                    guardish = True
                j += 1
            if guardish and not (
                has_waiver(fn.sfile, let_line, "trace-guard") or fn_waiver(fn, "trace-guard")
            ):
                findings.append(
                    Finding(
                        "trace-guard",
                        fn.sfile.rel,
                        let_line,
                        "`let _ = ..span(..)` drops the SpanGuard immediately — the span "
                        f"records zero length and measures nothing; bind a named guard in fn {fn.name} "
                        "(waive with `// analyze: allow(trace-guard): <why>`)",
                    )
                )
            i = j
            continue
        i += 1
    return findings


# ----------------------------------------------------------------- driver


def load_tree(root):
    files = []
    for dirpath, dirs, names in os.walk(root):
        dirs.sort()  # deterministic walk, matching the Rust tool
        for name in sorted(names):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, os.path.dirname(os.path.dirname(root)))
            with open(path, encoding="utf-8") as f:
                files.append(SrcFile(rel, f.read()))
    return files


def run_all(root, inventory_path):
    files = load_tree(root)
    inv_text = None
    if inventory_path and os.path.exists(inventory_path):
        with open(inventory_path, encoding="utf-8") as f:
            inv_text = f.read()
    findings = []
    findings += pass_lock_order(files)
    findings += pass_hot_path(files)
    findings += pass_unsafe(files, inv_text)
    findings += pass_protocol(files)
    findings += pass_gauges(files)
    findings += pass_trace_guard(files)
    return findings


def main(argv):
    root = "rust/src"
    inventory = "ANALYSIS.md"
    args = argv[1:]
    pos = []
    i = 0
    while i < len(args):
        if args[i] == "--inventory":
            inventory = args[i + 1]
            i += 2
        elif args[i] == "--no-inventory":
            inventory = None
            i += 1
        else:
            pos.append(args[i])
            i += 1
    if pos:
        root = pos[0]
    if not os.path.isdir(root):
        print(f"analyze: source root {root} not found", file=sys.stderr)
        return 2
    findings = run_all(root, inventory)
    for f in findings:
        print(f)
    print(f"analyze: {len(findings)} finding(s) over 6 passes", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
