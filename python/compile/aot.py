"""AOT compile path: lower every L2 graph to HLO text for the Rust runtime.

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. Lowered with ``return_tuple=True``; the Rust side
unwraps with ``to_tuple``.

Writes ``artifacts/<config>_<graph>_t<bucket>.hlo.txt`` plus
``artifacts/manifest.json`` describing argument shapes/dtypes and output
arity — the Rust artifact registry consumes the manifest instead of
re-deriving shapes.

Python runs ONCE here (``make artifacts``); it is never on the request
path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_CONFIGS = ("mix-tiny", "dsvl-s")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_meta(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_config(cfg: model.ModelConfig, out_dir: str, manifest: dict) -> None:
    for t in cfg.buckets:
        for name, fn, specs in model.graph_specs(cfg, t):
            key = f"{cfg.name}_{name}_t{t}"
            path = os.path.join(out_dir, f"{key}.hlo.txt")
            t0 = time.time()
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            n_out = len(jax.eval_shape(fn, *specs))
            manifest["artifacts"][key] = {
                "file": os.path.basename(path),
                "config": cfg.name,
                "graph": name,
                "bucket": t,
                "args": [spec_meta(s) for s in specs],
                "n_outputs": n_out,
            }
            print(f"  {key}: {len(text)} chars, {time.time() - t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS),
                    help="comma-separated config names under configs/")
    ap.add_argument("--configs-dir", default="../configs")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"group": model.GROUP, "artifacts": {}}
    for name in args.configs.split(","):
        cfg = model.ModelConfig.load(os.path.join(args.configs_dir, f"{name}.json"))
        print(f"lowering {cfg.name} (H={cfg.d_model} F={cfg.d_ff} E={cfg.n_experts} k={cfg.top_k})")
        lower_config(cfg, args.out_dir, manifest)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
