"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts each kernel's
output is allclose to the function of the same name here, and the Rust
native backend is in turn tested against the PJRT execution of the
lowered kernels — so all three implementations are pinned to this file.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def unpack_planes(planes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Unpack ``[bits, d_in//8, d_out]`` uint8 planes → ``[d_in, d_out]`` f32 codes."""
    b, rows, d_out = planes.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    # [bits, rows, 8, d_out]: bit j of each byte is code row 8*i + j.
    bitsarr = (planes[:, :, None, :] >> shifts[None, None, :, None]) & 1
    bitsarr = bitsarr.reshape(b, rows * 8, d_out).astype(jnp.float32)
    weights = (2.0 ** jnp.arange(bits, dtype=jnp.float32))[:, None, None]
    return (bitsarr * weights).sum(axis=0)


def dequant_weight(planes, scales, zeros, bits: int, group: int = 32) -> jnp.ndarray:
    """Group-wise dequantization ``w = (q - z) * s`` from packed planes."""
    q = unpack_planes(planes, bits)
    s = jnp.repeat(scales, group, axis=0)
    z = jnp.repeat(zeros, group, axis=0)
    return (q - z) * s


def dequant_matmul(x, planes, scales, zeros, bits: int, group: int = 32) -> jnp.ndarray:
    """``x @ dequant(planes)`` — oracle for the Pallas dequant-matmul."""
    return x @ dequant_weight(planes, scales, zeros, bits, group)


def binary_weight(plane, alpha) -> jnp.ndarray:
    """1-bit weight reconstruction ``alpha * (2*b - 1)`` (Eq. 8/9)."""
    b = unpack_planes(plane[None] if plane.ndim == 2 else plane, 1)
    return alpha[None, :] * (2.0 * b - 1.0)


def binary_matmul(x, plane, alpha) -> jnp.ndarray:
    """Oracle for the Pallas binary matmul (Eq. 9)."""
    return x @ binary_weight(plane, alpha)


def silu(x):
    return x * jax.nn.sigmoid(x)


def expert_ffn_fp(x, wg, wu, wd) -> jnp.ndarray:
    """SwiGLU expert FFN: ``(silu(x@wg) * (x@wu)) @ wd``."""
    return (silu(x @ wg) * (x @ wu)) @ wd


def expert_ffn_quant(x, packs, bits: int, group: int = 32) -> jnp.ndarray:
    """Quantized expert FFN; ``packs`` = ((pg,sg,zg),(pu,su,zu),(pd,sd,zd))."""
    (pg, sg, zg), (pu, su, zu), (pd, sd, zd) = packs
    h = silu(dequant_matmul(x, pg, sg, zg, bits, group)) * dequant_matmul(x, pu, su, zu, bits, group)
    return dequant_matmul(h, pd, sd, zd, bits, group)


def expert_ffn_binary(x, packs) -> jnp.ndarray:
    """1-bit expert FFN; ``packs`` = ((pg, ag), (pu, au), (pd, ad))."""
    (pg, ag), (pu, au), (pd, ad) = packs
    h = silu(binary_matmul(x, pg, ag)) * binary_matmul(x, pu, au)
    return binary_matmul(h, pd, ad)


def gating(x, w_gate) -> jnp.ndarray:
    """Softmax routing scores over experts (top-k selection happens in L2/L3)."""
    return jax.nn.softmax(x @ w_gate, axis=-1)


def candidate_masks(k: int) -> jnp.ndarray:
    """The nested top-any candidate set C_k (paper Eq. 10): row c keeps the
    first k-c rank-sorted experts. |C| == k."""
    return (jnp.arange(k)[None, :] < (k - jnp.arange(k))[:, None]).astype(jnp.float32)


def otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau) -> tuple:
    """Learnable top-any router (paper §3.4.1, Table 1).

    Args:
      x: ``[T, H]`` tokens. gate_w: ``[T, k]`` rank-sorted top-k gate weights.
      fc1_w/fc1_b: ``[H, k]`` / ``[k]``. fc2_w/fc2_b: ``[2k, k]`` / ``[k]``.
      noise: ``[T, k]`` Gumbel noise ``-log(-log(u))`` (RNG lives in Rust).
      tau: ``[1]`` softmax temperature.

    Returns:
      ``(y, mask)``: candidate probabilities ``[T, |C|]`` (Eq. 13) and the
      soft expert mask ``[T, k]`` = y @ C_k.
    """
    h = jax.nn.relu(x @ fc1_w + fc1_b[None, :])
    logits = jnp.concatenate([h, gate_w], axis=-1) @ fc2_w + fc2_b[None, :]
    y = jax.nn.softmax((logits + noise) / tau[0], axis=-1)
    mask = y @ candidate_masks(gate_w.shape[1])
    return y, mask
