"""Pallas 1-bit binary matmul (paper Eq. 9).

Weights are stored as the ``(sign(W)+1)/2`` bit matrix (Eq. 8) packed
8-per-byte along the reduction axis, plus one per-output-channel L1 scale
``alpha = ||W||_1 / d`` (Eq. 4). The kernel reconstructs ±1 tiles with a
select (no multiplies against weights) and scales once per output column:

    s * (x @ B) = s * (sum_{b=1} x_j  -  sum_{b=0} x_j)

which is the multiply-free accumulate the paper uses to cut MACs from
``d*m`` to ``m``. On real TPU hardware the ±1 expansion feeds the MXU as
bf16; here the structure (packed VMEM residency + single scale multiply)
is what we validate, under ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dequant_matmul import pick_tile_o


def _binary_matmul_kernel(x_ref, plane_ref, alpha_ref, o_ref):
    x = x_ref[...]                       # [T, d_in]
    plane = plane_ref[...]               # [d_in//8, TILE_O] uint8
    rows, tile_o = plane.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (plane[:, None, :] >> shifts[None, :, None]) & 1
    b01 = bits.reshape(rows * 8, tile_o).astype(jnp.float32)
    # ±1 expansion via select-accumulate: B = 2*b - 1 (Eq. 8 inverse).
    pm1 = 2.0 * b01 - 1.0
    acc = x @ pm1                        # [T, TILE_O]; adds/subs only per Eq. 9
    o_ref[...] = acc * alpha_ref[...][None, 0, :]


@jax.jit
def binary_matmul(x, plane, alpha):
    """``x:[T,d_in] @ (alpha * (2*unpack(plane)-1)) -> [T,d_out]``."""
    t, d_in = x.shape
    rows, d_out = plane.shape
    assert rows * 8 == d_in
    tile_o = pick_tile_o(d_out)
    grid = (d_out // tile_o,)
    return pl.pallas_call(
        _binary_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d_in), lambda i: (0, 0)),
            pl.BlockSpec((rows, tile_o), lambda i: (0, i)),
            pl.BlockSpec((1, tile_o), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((t, tile_o), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, plane, alpha.reshape(1, -1))
