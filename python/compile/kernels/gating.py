"""Gating + OTP router kernels.

``gating_scores`` is the MoE router softmax (top-k index selection happens
in L2 with ``lax.top_k`` so the Rust coordinator receives both weights and
indices from a single artifact). ``otp_router`` is the paper's learnable
top-any pruner (§3.4): FC1(H→k) → concat with rank-sorted gate weights →
FC2(2k→|C|) → Gumbel-Softmax over the nested candidate masks C_k. The
Gumbel noise is an *input* — randomness stays in the Rust coordinator so
the lowered graph is deterministic and replayable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _gating_kernel(x_ref, wg_ref, o_ref):
    logits = x_ref[...] @ wg_ref[...]
    m = logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    o_ref[...] = e / e.sum(axis=-1, keepdims=True)


@jax.jit
def gating_scores(x, w_gate):
    """Softmax expert scores ``[T, E]`` as a Pallas kernel."""
    t, h = x.shape
    e = w_gate.shape[1]
    return pl.pallas_call(
        _gating_kernel,
        out_shape=jax.ShapeDtypeStruct((t, e), jnp.float32),
        interpret=True,
    )(x, w_gate)


def _otp_router_kernel(x_ref, gw_ref, fc1w_ref, fc1b_ref, fc2w_ref, fc2b_ref,
                       noise_ref, tau_ref, y_ref, mask_ref, *, k: int):
    x = x_ref[...]
    gw = gw_ref[...]
    h = jnp.maximum(x @ fc1w_ref[...] + fc1b_ref[...][0][None, :], 0.0)
    z = jnp.concatenate([h, gw], axis=-1) @ fc2w_ref[...] + fc2b_ref[...][0][None, :]
    z = (z + noise_ref[...]) / tau_ref[...][0, 0]
    m = z.max(axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    y = e / e.sum(axis=-1, keepdims=True)
    cand = (jnp.arange(k)[None, :] < (k - jnp.arange(k))[:, None]).astype(jnp.float32)
    y_ref[...] = y
    mask_ref[...] = y @ cand


@functools.partial(jax.jit, static_argnames=())
def otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau):
    """Learnable top-any router; returns ``(y:[T,|C|], mask:[T,k])``."""
    t, h = x.shape
    k = gate_w.shape[1]
    return pl.pallas_call(
        functools.partial(_otp_router_kernel, k=k),
        out_shape=(
            jax.ShapeDtypeStruct((t, k), jnp.float32),
            jax.ShapeDtypeStruct((t, k), jnp.float32),
        ),
        interpret=True,
    )(x, gate_w, fc1_w, fc1_b.reshape(1, -1), fc2_w, fc2_b.reshape(1, -1),
      noise, tau.reshape(1, 1))
