"""Fused SwiGLU expert FFN kernels (fp + quantized + binary variants).

The full-precision variant fuses gate/up/down into a single Pallas kernel
so the ``[T, d_ff]`` intermediate never leaves VMEM. Quantized variants
compose the dequant/binary matmul kernels — each matmul keeps its packed
weights resident and the SwiGLU elementwise runs between kernel calls,
which XLA fuses after lowering (checked in the L2 perf pass).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .binary_matmul import binary_matmul
from .dequant_matmul import dequant_matmul


def _expert_ffn_fp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...]
    h = x @ wg_ref[...]
    h = h * jax.nn.sigmoid(h)       # silu, in VMEM
    h = h * (x @ wu_ref[...])
    o_ref[...] = h @ wd_ref[...]


@jax.jit
def expert_ffn_fp(x, wg, wu, wd):
    """``(silu(x@wg) * (x@wu)) @ wd`` as one fused Pallas kernel."""
    t, h = x.shape
    f = wg.shape[1]
    return pl.pallas_call(
        _expert_ffn_fp_kernel,
        out_shape=jax.ShapeDtypeStruct((t, h), jnp.float32),
        interpret=True,
    )(x, wg, wu, wd)


def expert_ffn_quant(x, packs, *, bits: int, group: int = 32):
    """Quantized SwiGLU FFN from three packed matrices (see ref.py)."""
    (pg, sg, zg), (pu, su, zu), (pd, sd, zd) = packs
    g = dequant_matmul(x, pg, sg, zg, bits=bits, group=group)
    u = dequant_matmul(x, pu, su, zu, bits=bits, group=group)
    h = ref.silu(g) * u
    return dequant_matmul(h, pd, sd, zd, bits=bits, group=group)


def expert_ffn_binary(x, packs):
    """1-bit SwiGLU FFN from three (plane, alpha) pairs."""
    (pg, ag), (pu, au), (pd, ad) = packs
    h = ref.silu(binary_matmul(x, pg, ag)) * binary_matmul(x, pu, au)
    return binary_matmul(h, pd, ad)
