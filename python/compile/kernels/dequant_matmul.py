"""Pallas dequant-matmul: the paper's pre-loading-compression hot path.

Computes ``x @ W_hat`` where ``W_hat = (q - z) * s`` is reconstructed
in-kernel from bit-plane-packed 2/3/4-bit codes (see ``packing.py``).

TPU mapping of the HQQ CUDA kernel the paper ships (DESIGN.md
§Hardware-Adaptation): instead of one warp per quantization group, the
kernel tiles the *output* dimension with a BlockSpec grid; each grid step
streams a ``[bits, d_in/8, TILE_O]`` packed tile (plus the matching
``[n_groups, TILE_O]`` scale/zero tiles) HBM→VMEM, expands it to a
``[d_in, TILE_O]`` f32 tile in registers/VMEM, and issues one MXU matmul
against the resident ``[T, d_in]`` activation block. Packed weights are
16/b× smaller than f32 in both HBM traffic and VMEM footprint — the
dequant is fused so full-precision weights never exist in HBM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what
``aot.py`` serializes for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_matmul_kernel(x_ref, planes_ref, scales_ref, zeros_ref, o_ref, *, bits: int, group: int):
    """One output tile: unpack → dequant → matmul."""
    x = x_ref[...]                      # [T, d_in]
    planes = planes_ref[...]            # [bits, d_in//8, TILE_O] uint8
    b, rows, tile_o = planes.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bitsarr = (planes[:, :, None, :] >> shifts[None, None, :, None]) & 1
    q = bitsarr.reshape(b, rows * 8, tile_o).astype(jnp.float32)
    weights = (2.0 ** jnp.arange(bits, dtype=jnp.float32))[:, None, None]
    q = (q * weights).sum(axis=0)       # [d_in, TILE_O]
    s = jnp.repeat(scales_ref[...], group, axis=0)
    z = jnp.repeat(zeros_ref[...], group, axis=0)
    w = (q - z) * s                     # dequantized tile, [d_in, TILE_O]
    o_ref[...] = x @ w


def pick_tile_o(d_out: int, target: int = 128) -> int:
    """Largest divisor of ``d_out`` not exceeding ``target`` (MXU lane width)."""
    t = min(d_out, target)
    while d_out % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bits", "group"))
def dequant_matmul(x, planes, scales, zeros, *, bits: int, group: int = 32):
    """``x:[T,d_in] @ dequant(planes:[bits,d_in//8,d_out]) -> [T,d_out]``."""
    t, d_in = x.shape
    _, rows, d_out = planes.shape
    n_groups = scales.shape[0]
    assert rows * 8 == d_in and d_in % group == 0
    tile_o = pick_tile_o(d_out)
    grid = (d_out // tile_o,)
    return pl.pallas_call(
        functools.partial(_dequant_matmul_kernel, bits=bits, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d_in), lambda i: (0, 0)),
            pl.BlockSpec((bits, rows, tile_o), lambda i: (0, 0, i)),
            pl.BlockSpec((n_groups, tile_o), lambda i: (0, i)),
            pl.BlockSpec((n_groups, tile_o), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((t, tile_o), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, planes, scales, zeros)
