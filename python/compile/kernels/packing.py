"""Bit-plane packing shared between the Rust store and the Pallas kernels.

Quantized weight codes ``q`` with bit-width ``b`` over a ``[d_in, d_out]``
matrix are stored as ``b`` bit-planes, each a ``[d_in // 8, d_out]`` uint8
array. Bit ``j`` of byte ``plane[p][i, o]`` holds bit ``p`` of
``q[8 * i + j, o]``. The Rust side (`rust/src/quant/packed.rs`) implements
the identical layout; `python/tests/test_packing.py` pins the format with
fixed vectors so the two can never drift apart.

The layout packs along ``d_in`` (the reduction axis) so a kernel streaming
a ``[d_in, TILE_O]`` weight tile reads ``b * d_in / 8`` contiguous bytes
per output column — 32/b× less HBM traffic than f32 weights, which is the
entire point of the paper's pre-loading compression.
"""

from __future__ import annotations

import numpy as np


def pack_codes(q: np.ndarray, bits: int) -> np.ndarray:
    """Pack integer codes ``q`` in ``[0, 2**bits)`` into bit-planes.

    Args:
      q: ``[d_in, d_out]`` integer array, ``d_in % 8 == 0``.
      bits: bit-width ``b`` in 1..=4.

    Returns:
      ``[bits, d_in // 8, d_out]`` uint8 array of packed planes.
    """
    d_in, d_out = q.shape
    assert d_in % 8 == 0, f"d_in={d_in} must be a multiple of 8"
    assert 1 <= bits <= 4
    assert q.min() >= 0 and q.max() < (1 << bits), "codes out of range"
    q = q.astype(np.uint8)
    planes = np.zeros((bits, d_in // 8, d_out), dtype=np.uint8)
    for p in range(bits):
        bit = (q >> p) & 1  # [d_in, d_out]
        for j in range(8):
            planes[p] |= bit[j::8] << j
    return planes


def unpack_codes(planes: np.ndarray, bits: int) -> np.ndarray:
    """Inverse of :func:`pack_codes` → ``[d_in, d_out]`` uint8 codes."""
    assert planes.shape[0] == bits
    _, rows, d_out = planes.shape
    q = np.zeros((rows * 8, d_out), dtype=np.uint8)
    for p in range(bits):
        for j in range(8):
            q[j::8] |= ((planes[p] >> j) & 1) << p
    return q


def quantize_rtn(w: np.ndarray, bits: int, group: int = 32):
    """Group-wise round-to-nearest quantizer (the paper's Eq. 3 layout).

    Groups run along ``d_in`` (axis 0). Returns ``(codes, scales, zeros)``
    with ``scales``/``zeros`` of shape ``[d_in // group, d_out]`` and the
    dequantization ``w_hat = (codes - zeros) * scales``.
    """
    d_in, d_out = w.shape
    assert d_in % group == 0
    g = d_in // group
    wg = w.reshape(g, group, d_out)
    wmin = wg.min(axis=1)  # [g, d_out]
    wmax = wg.max(axis=1)
    span = np.maximum(wmax - wmin, 1e-8)
    scales = span / (2**bits - 1)
    zeros = np.round(-wmin / scales)
    codes = np.clip(np.round(wg / scales[:, None, :]) + zeros[:, None, :], 0, 2**bits - 1)
    return codes.reshape(d_in, d_out).astype(np.uint8), scales.astype(np.float32), zeros.astype(np.float32)


def binarize(w: np.ndarray):
    """1-bit sign/scale binarization (paper Eq. 4 / Eq. 8).

    Returns ``(bits01, alpha)``: ``bits01`` is the ``(sign(W)+1)/2`` matrix
    in {0,1} and ``alpha`` the per-output-channel L1 scale ``||W||_1 / d``.
    """
    bits01 = (w >= 0).astype(np.uint8)
    alpha = (np.abs(w).sum(axis=0) / w.shape[0]).astype(np.float32)
    return bits01, alpha


def dequantize(codes: np.ndarray, scales: np.ndarray, zeros: np.ndarray, group: int = 32) -> np.ndarray:
    """Dequantize group-wise codes back to f32 (reference for tests)."""
    d_in, d_out = codes.shape
    g = d_in // group
    s = np.repeat(scales, group, axis=0)
    z = np.repeat(zeros, group, axis=0)
    return ((codes.astype(np.float32) - z) * s).astype(np.float32)
