"""L2: the JAX compute graphs the Rust runtime executes.

Each function here is a pure, shape-static jax function over explicit
weight arguments (weights live in Rust and are passed per call / kept in
PJRT buffers). ``aot.py`` lowers every (config, graph, bucket) pair to
HLO text under ``artifacts/``.

Graph inventory (per model config ``c`` and token bucket ``T``):

  expert_ffn_fp   (x[T,H], wg[H,F], wu[H,F], wd[F,H])           -> y[T,H]
  expert_ffn_q{b} (x[T,H], 3×(planes,scales,zeros))             -> y[T,H]
  expert_ffn_q1   (x[T,H], 3×(plane,alpha))                     -> y[T,H]
  gating_topk     (x[T,H], w_gate[H,E])                         -> (w[T,k], idx[T,k] i32)
  otp_router      (x[T,H], gate_w[T,k], fc1_w, fc1_b,
                   fc2_w, fc2_b, noise[T,k], tau[1])            -> (y[T,k], mask[T,k])

The MoE *block* itself (token→expert scatter/gather, shared experts,
attention, KV cache) is the Rust coordinator's job — exactly the split
the paper's serving story implies: routing and pruning decisions are
cheap control flow; expert FFNs are the compiled hot path.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import gating as gating_k
from .kernels import moe_ffn

GROUP = 32  # quantization group size along d_in; must match rust/src/quant


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    n_experts: int
    top_k: int
    n_shared_experts: int
    max_seq_len: int
    rope_theta: float
    modalities: int
    buckets: tuple

    @staticmethod
    def load(path: str) -> "ModelConfig":
        with open(path) as f:
            d = json.load(f)
        d["buckets"] = tuple(d["buckets"])
        return ModelConfig(**d)


def expert_ffn_fp(x, wg, wu, wd):
    """Full-precision SwiGLU expert (fused Pallas kernel)."""
    return (moe_ffn.expert_ffn_fp(x, wg, wu, wd),)


def make_expert_ffn_quant(bits: int):
    """Quantized expert FFN over flat packed args (AOT-friendly signature)."""

    def fn(x, pg, sg, zg, pu, su, zu, pd, sd, zd):
        packs = ((pg, sg, zg), (pu, su, zu), (pd, sd, zd))
        return (moe_ffn.expert_ffn_quant(x, packs, bits=bits, group=GROUP),)

    return fn


def expert_ffn_q1(x, pg, ag, pu, au, pd, ad):
    """1-bit (binary) expert FFN."""
    return (moe_ffn.expert_ffn_binary(x, ((pg, ag), (pu, au), (pd, ad))),)


def make_gating_topk(k: int):
    """Softmax scores (Pallas) + top-k select; weights renormalized to sum 1.

    Top-k is expressed via argsort rather than ``jax.lax.top_k``: recent
    jax lowers top_k to a ``topk(..., largest=true)`` HLO attribute that
    the xla_extension 0.5.1 text parser (behind the Rust runtime)
    rejects; ``sort`` round-trips fine.
    """

    def fn(x, w_gate):
        scores = gating_k.gating_scores(x, w_gate)
        idx = jnp.argsort(-scores, axis=-1)[:, :k]
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / w.sum(axis=-1, keepdims=True)
        return w, idx.astype(jnp.int32)

    return fn


def otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau):
    """Learnable top-any pruning router (Pallas kernel, §3.4)."""
    y, mask = gating_k.otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau)
    return y, mask


def f32(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def u8(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.uint8)


def graph_specs(c: ModelConfig, t: int):
    """(name, fn, arg_specs) for every graph lowered at bucket size ``t``."""
    h, f, e, k = c.d_model, c.d_ff, c.n_experts, c.top_k
    gh, gf = h // GROUP, f // GROUP
    specs = [
        ("expert_ffn_fp", expert_ffn_fp, [f32(t, h), f32(h, f), f32(h, f), f32(f, h)]),
        ("gating_topk", make_gating_topk(k), [f32(t, h), f32(h, e)]),
        (
            "otp_router",
            otp_router,
            [f32(t, h), f32(t, k), f32(h, k), f32(k), f32(2 * k, k), f32(k), f32(t, k), f32(1)],
        ),
        (
            "expert_ffn_q1",
            expert_ffn_q1,
            [f32(t, h), u8(h // 8, f), f32(f), u8(h // 8, f), f32(f), u8(f // 8, h), f32(h)],
        ),
    ]
    for bits in (2, 3):
        specs.append(
            (
                f"expert_ffn_q{bits}",
                make_expert_ffn_quant(bits),
                [
                    f32(t, h),
                    u8(bits, h // 8, f), f32(gh, f), f32(gh, f),
                    u8(bits, h // 8, f), f32(gh, f), f32(gh, f),
                    u8(bits, f // 8, h), f32(gf, h), f32(gf, h),
                ],
            )
        )
    return specs
