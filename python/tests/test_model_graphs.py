"""L2 graph-level tests: shapes, manifest consistency, numerical parity
of the graph functions against the oracles across the model zoo configs."""

import glob
import json
import os

import numpy as np
import jax
import pytest

from compile import model
from compile.kernels import packing, ref

CONFIG_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "configs")


def load_cfg(name):
    return model.ModelConfig.load(os.path.join(CONFIG_DIR, f"{name}.json"))


@pytest.mark.parametrize("name", ["mix-tiny", "dsvl-s"])
def test_graph_specs_shapes(name):
    cfg = load_cfg(name)
    for t in cfg.buckets:
        specs = model.graph_specs(cfg, t)
        names = [s[0] for s in specs]
        assert set(names) == {
            "expert_ffn_fp", "gating_topk", "otp_router",
            "expert_ffn_q1", "expert_ffn_q2", "expert_ffn_q3",
        }
        for gname, fn, args in specs:
            outs = jax.eval_shape(fn, *args)
            assert len(outs) >= 1, gname
            if gname.startswith("expert_ffn"):
                assert outs[0].shape == (t, cfg.d_model)
            if gname == "gating_topk":
                assert outs[0].shape == (t, cfg.top_k)
                assert outs[1].shape == (t, cfg.top_k)


def test_gating_topk_weights_sorted_and_normalized():
    cfg = load_cfg("mix-tiny")
    fn = model.make_gating_topk(cfg.top_k)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, cfg.d_model)).astype(np.float32)
    wg = rng.normal(size=(cfg.d_model, cfg.n_experts)).astype(np.float32)
    w, idx = fn(x, wg)
    w, idx = np.asarray(w), np.asarray(idx)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    assert np.all(np.diff(w, axis=-1) <= 1e-6), "not rank-sorted"
    # indices must match the top-k of the reference softmax scores
    scores = np.asarray(ref.gating(x, wg))
    for i in range(8):
        want = set(np.argsort(scores[i])[::-1][: cfg.top_k])
        assert set(idx[i]) == want


@pytest.mark.parametrize("bits", [2, 3])
def test_expert_ffn_quant_graph_matches_oracle(bits):
    cfg = load_cfg("mix-tiny")
    h, f = cfg.d_model, cfg.d_ff
    rng = np.random.default_rng(bits)
    x = rng.normal(size=(4, h)).astype(np.float32)

    def pack(d_in, d_out):
        w = rng.normal(size=(d_in, d_out)).astype(np.float32)
        codes, s, z = packing.quantize_rtn(w, bits, model.GROUP)
        return packing.pack_codes(codes, bits), s, z

    pg, sg, zg = pack(h, f)
    pu, su, zu = pack(h, f)
    pd, sd, zd = pack(f, h)
    fn = model.make_expert_ffn_quant(bits)
    (got,) = fn(x, pg, sg, zg, pu, su, zu, pd, sd, zd)
    want = ref.expert_ffn_quant(x, ((pg, sg, zg), (pu, su, zu), (pd, sd, zd)), bits, model.GROUP)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_manifest_covers_all_graphs_and_buckets():
    man_path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("run `make artifacts` first")
    man = json.load(open(man_path))
    assert man["group"] == model.GROUP
    arts = man["artifacts"]
    for name in ("mix-tiny", "dsvl-s"):
        cfg = load_cfg(name)
        for t in cfg.buckets:
            for g in ("expert_ffn_fp", "expert_ffn_q1", "expert_ffn_q2",
                      "expert_ffn_q3", "gating_topk", "otp_router"):
                key = f"{name}_{g}_t{t}"
                assert key in arts, key
                meta = arts[key]
                assert meta["bucket"] == t
                # first arg is always the token block [t, H]
                assert meta["args"][0]["shape"] == [t, cfg.d_model]
    # files actually exist
    art_dir = os.path.dirname(man_path)
    for meta in arts.values():
        assert os.path.exists(os.path.join(art_dir, meta["file"]))


def test_hlo_artifacts_are_text_not_proto():
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    files = glob.glob(os.path.join(art_dir, "*.hlo.txt"))
    if not files:
        pytest.skip("run `make artifacts` first")
    head = open(files[0]).read(200)
    assert "HloModule" in head, "expected HLO text interchange format"
