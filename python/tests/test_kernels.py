"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_matmul as bmm
from compile.kernels import dequant_matmul as dqm
from compile.kernels import gating as gk
from compile.kernels import moe_ffn, packing, ref

RNG = np.random.default_rng(42)


def rand(*shape):
    return RNG.normal(size=shape).astype(np.float32)


def packed_weight(d_in, d_out, bits, group=32):
    w = rand(d_in, d_out)
    codes, scales, zeros = packing.quantize_rtn(w, bits, group)
    planes = packing.pack_codes(codes, bits)
    return planes, scales, zeros


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize("t,d_in,d_out", [(4, 64, 96), (16, 128, 256), (3, 32, 160)])
def test_dequant_matmul_vs_ref(bits, t, d_in, d_out):
    x = rand(t, d_in)
    planes, scales, zeros = packed_weight(d_in, d_out, bits)
    got = dqm.dequant_matmul(x, planes, scales, zeros, bits=bits)
    want = ref.dequant_matmul(x, planes, scales, zeros, bits)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    bits=st.integers(2, 4),
    t=st.integers(1, 24),
    d_in=st.sampled_from([32, 64, 128]),
    d_out=st.sampled_from([8, 64, 96, 256]),
    seed=st.integers(0, 2**31),
)
def test_dequant_matmul_prop(bits, t, d_in, d_out, seed):
    r = np.random.default_rng(seed)
    x = r.normal(size=(t, d_in)).astype(np.float32)
    w = r.normal(size=(d_in, d_out)).astype(np.float32)
    codes, scales, zeros = packing.quantize_rtn(w, bits, 32)
    planes = packing.pack_codes(codes, bits)
    got = dqm.dequant_matmul(x, planes, scales, zeros, bits=bits)
    want = ref.dequant_matmul(x, planes, scales, zeros, bits)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_dequant_matmul_exactness():
    """Dequant-matmul of RTN-quantized weights ≈ x @ w_hat computed in numpy."""
    x = rand(8, 64)
    w = rand(64, 32)
    codes, scales, zeros = packing.quantize_rtn(w, 3, 32)
    planes = packing.pack_codes(codes, 3)
    w_hat = packing.dequantize(codes, scales, zeros, 32)
    got = dqm.dequant_matmul(x, planes, scales, zeros, bits=3)
    np.testing.assert_allclose(got, x @ w_hat, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,d_in,d_out", [(4, 64, 96), (16, 128, 256), (1, 32, 8)])
def test_binary_matmul_vs_ref(t, d_in, d_out):
    w = rand(d_in, d_out)
    bits01, alpha = packing.binarize(w)
    plane = packing.pack_codes(bits01, 1)[0]
    x = rand(t, d_in)
    got = bmm.binary_matmul(x, plane, alpha)
    want = ref.binary_matmul(x, plane, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # also against the direct sign-matmul semantics of Eq. 4/9
    direct = x @ (np.where(w >= 0, 1.0, -1.0).astype(np.float32) * alpha[None, :])
    np.testing.assert_allclose(got, direct, rtol=1e-3, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 24),
    d_in=st.sampled_from([32, 64, 128]),
    d_out=st.sampled_from([8, 64, 96, 256]),
    seed=st.integers(0, 2**31),
)
def test_binary_matmul_prop(t, d_in, d_out, seed):
    r = np.random.default_rng(seed)
    w = r.normal(size=(d_in, d_out)).astype(np.float32)
    x = r.normal(size=(t, d_in)).astype(np.float32)
    bits01, alpha = packing.binarize(w)
    plane = packing.pack_codes(bits01, 1)[0]
    got = bmm.binary_matmul(x, plane, alpha)
    want = ref.binary_matmul(x, plane, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    bits=st.integers(2, 3),
    t=st.integers(1, 16),
    h=st.sampled_from([32, 64]),
    f=st.sampled_from([32, 96]),
    seed=st.integers(0, 2**31),
)
def test_expert_ffn_quant_prop(bits, t, h, f, seed):
    r = np.random.default_rng(seed)
    x = r.normal(size=(t, h)).astype(np.float32)

    def pw(d_in, d_out):
        w = r.normal(size=(d_in, d_out)).astype(np.float32)
        codes, scales, zeros = packing.quantize_rtn(w, bits, 32)
        return packing.pack_codes(codes, bits), scales, zeros

    packs = (pw(h, f), pw(h, f), pw(f, h))
    got = moe_ffn.expert_ffn_quant(x, packs, bits=bits)
    want = ref.expert_ffn_quant(x, packs, bits)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_expert_ffn_fp_vs_ref():
    x, wg, wu, wd = rand(16, 128), rand(128, 256), rand(128, 256), rand(256, 128)
    got = moe_ffn.expert_ffn_fp(x, wg, wu, wd)
    want = ref.expert_ffn_fp(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bits", [2, 3])
def test_expert_ffn_quant_vs_ref(bits):
    h, f = 64, 96
    x = rand(8, h)
    packs = tuple(packed_weight(*dims, bits) for dims in ((h, f), (h, f), (f, h)))
    got = moe_ffn.expert_ffn_quant(x, packs, bits=bits)
    want = ref.expert_ffn_quant(x, packs, bits)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_expert_ffn_binary_vs_ref():
    h, f = 64, 96
    x = rand(8, h)

    def bin_pack(d_in, d_out):
        w = rand(d_in, d_out)
        bits01, alpha = packing.binarize(w)
        return packing.pack_codes(bits01, 1)[0], alpha

    packs = (bin_pack(h, f), bin_pack(h, f), bin_pack(f, h))
    got = moe_ffn.expert_ffn_binary(x, packs)
    want = ref.expert_ffn_binary(x, packs)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_gating_scores_vs_ref():
    x, wg = rand(16, 128), rand(128, 8)
    got = gk.gating_scores(x, wg)
    want = ref.gating(x, wg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-5)


def test_candidate_masks_match_eq10():
    c6 = np.asarray(ref.candidate_masks(6))
    expected = np.array(
        [
            [1, 1, 1, 1, 1, 1],
            [1, 1, 1, 1, 1, 0],
            [1, 1, 1, 1, 0, 0],
            [1, 1, 1, 0, 0, 0],
            [1, 1, 0, 0, 0, 0],
            [1, 0, 0, 0, 0, 0],
        ],
        dtype=np.float32,
    )
    np.testing.assert_array_equal(c6, expected)


@pytest.mark.parametrize("k,t,h", [(2, 8, 64), (6, 16, 128)])
def test_otp_router_vs_ref(k, t, h):
    x = rand(t, h)
    gate_w = np.abs(rand(t, k))
    gate_w = np.sort(gate_w, axis=-1)[:, ::-1].copy()  # rank-sorted
    fc1_w, fc1_b = rand(h, k), rand(k)
    fc2_w, fc2_b = rand(2 * k, k), rand(k)
    noise = -np.log(-np.log(RNG.uniform(1e-6, 1 - 1e-6, size=(t, k)))).astype(np.float32)
    tau = np.array([1.0], dtype=np.float32)
    got_y, got_m = gk.otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau)
    want_y, want_m = ref.otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau)
    np.testing.assert_allclose(got_y, want_y, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-4, atol=1e-5)
    # every soft mask is monotone non-increasing across ranks (nested C_k)
    gm = np.asarray(got_m)
    assert np.all(np.diff(gm, axis=-1) <= 1e-6)


def test_otp_router_low_tau_is_near_onehot():
    k, t, h = 6, 8, 64
    x = rand(t, h)
    gate_w = np.abs(rand(t, k))
    fc1_w, fc1_b = rand(h, k), rand(k)
    fc2_w, fc2_b = rand(2 * k, k), rand(k)
    noise = np.zeros((t, k), dtype=np.float32)
    tau = np.array([0.05], dtype=np.float32)
    y, _ = gk.otp_router(x, gate_w, fc1_w, fc1_b, fc2_w, fc2_b, noise, tau)
    assert np.all(np.asarray(y).max(axis=-1) > 0.95)
