"""Packing format tests — pin the bit-plane layout shared with Rust."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import packing


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_pack_unpack_roundtrip(bits):
    rng = np.random.default_rng(0)
    q = rng.integers(0, 1 << bits, size=(64, 48), dtype=np.uint8)
    planes = packing.pack_codes(q, bits)
    assert planes.shape == (bits, 8, 48)
    np.testing.assert_array_equal(packing.unpack_codes(planes, bits), q)


def test_pack_fixed_vector():
    """Cross-language pin: rust/src/quant/packed.rs asserts the same bytes."""
    q = np.arange(16, dtype=np.uint8).reshape(16, 1) % 4  # 0,1,2,3,0,1,...
    planes = packing.pack_codes(q, 2)
    # bit-plane 0 (LSB): rows 0..7 -> 0,1,0,1,... => 0b10101010 = 0xAA
    assert planes[0, 0, 0] == 0xAA and planes[0, 1, 0] == 0xAA
    # bit-plane 1: rows 0..7 -> 0,0,1,1,... => 0b11001100 = 0xCC
    assert planes[1, 0, 0] == 0xCC and planes[1, 1, 0] == 0xCC


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(1, 4),
    rows=st.sampled_from([8, 32, 64, 128]),
    cols=st.integers(1, 40),
    seed=st.integers(0, 2**31),
)
def test_pack_roundtrip_prop(bits, rows, cols, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 1 << bits, size=(rows, cols), dtype=np.uint8)
    np.testing.assert_array_equal(packing.unpack_codes(packing.pack_codes(q, bits), bits), q)


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_rtn_quantize_dequantize_error(bits):
    """RTN reconstruction error must be bounded by half a quantization step."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=(128, 32)).astype(np.float32)
    codes, scales, zeros = packing.quantize_rtn(w, bits, group=32)
    w_hat = packing.dequantize(codes, scales, zeros, group=32)
    step = np.repeat(scales, 32, axis=0)
    # clamping can exceed half-step only at group extremes; allow a full step
    assert np.all(np.abs(w - w_hat) <= step + 1e-5)


def test_binarize_matches_eq4():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    bits01, alpha = packing.binarize(w)
    np.testing.assert_allclose(alpha, np.abs(w).sum(axis=0) / 64, rtol=1e-6)
    np.testing.assert_array_equal(bits01, (w >= 0).astype(np.uint8))
