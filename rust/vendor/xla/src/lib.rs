//! Offline stub of the `xla` PJRT bindings.
//!
//! Host-side [`Literal`] staging is fully functional (the
//! `runtime::literals` round-trip tests run against it), while client
//! construction reports unavailability: `PjRtClient::cpu()` returns an
//! error, so `Runtime::open*` fails with a clear message and every
//! PJRT-dependent path (integration tests, benches, `--pjrt` serving)
//! degrades gracefully instead of failing to link. Swap in the real
//! bindings by repointing the workspace `xla` dependency.
//!
//! All types here are `Send + Sync` (plain host data), matching the
//! `ExpertBackend: Sync` bound the expert-grouped dispatcher requires.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type; converts into `anyhow::Error` at call sites.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!("{what}: built against the offline xla stub (no PJRT plugin in this environment)"))
}

/// Element dtypes the workspace stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Native element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    const DTYPE: ElementType;
    fn read(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const DTYPE: ElementType = ElementType::F32;
    fn read(b: &[u8]) -> f32 {
        f32::from_ne_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const DTYPE: ElementType = ElementType::S32;
    fn read(b: &[u8]) -> i32 {
        i32::from_ne_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for u8 {
    const DTYPE: ElementType = ElementType::U8;
    fn read(b: &[u8]) -> u8 {
        b[0]
    }
}

/// Host-side literal: dtype + shape + raw bytes (or tuple elements).
#[derive(Clone, Debug)]
pub struct Literal {
    dtype: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Vec<Literal>,
    is_tuple: bool,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        dtype: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, XlaError> {
        let n: usize = dims.iter().product();
        if n * dtype.byte_size() != data.len() {
            return Err(XlaError(format!(
                "shape {dims:?} of {dtype:?} needs {} bytes, got {}",
                n * dtype.byte_size(),
                data.len()
            )));
        }
        Ok(Literal {
            dtype,
            dims: dims.to_vec(),
            bytes: data.to_vec(),
            tuple: Vec::new(),
            is_tuple: false,
        })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        if self.dtype != T::DTYPE {
            return Err(XlaError(format!(
                "literal is {:?}, requested {:?}",
                self.dtype,
                T::DTYPE
            )));
        }
        let sz = self.dtype.byte_size();
        Ok(self.bytes.chunks_exact(sz).map(T::read).collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        if self.is_tuple {
            Ok(self.tuple)
        } else {
            Err(XlaError("not a tuple literal".to_string()))
        }
    }
}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError(format!(
            "cannot parse {path}: the offline xla stub has no HLO parser"
        )))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
            .unwrap();
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<f32>().unwrap(), data);
        assert!(l.to_vec::<u8>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[3], &[1, 2]).is_err()
        );
    }

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn stub_types_are_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<Literal>();
        assert_sync::<PjRtClient>();
        assert_sync::<PjRtLoadedExecutable>();
    }
}
