//! Offline stand-in for the `anyhow` crate — the API-compatible subset
//! this workspace uses (`Error`, `Result`, `Context`, `anyhow!`,
//! `bail!`). The build environment has no crates.io access, so the error
//! type is vendored as a workspace path dependency; swapping back to the
//! real crate is a one-line change in `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// Boxed dynamic error with display-oriented context chaining.
///
/// Like the real `anyhow::Error`, this intentionally does **not**
/// implement `std::error::Error` itself, so the blanket
/// `From<E: std::error::Error>` conversion (what makes `?` work) stays
/// coherent.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Build an error from a display-able message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: message.to_string().into() }
    }

    /// Wrap a concrete error type.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// Prepend a context line to the message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { inner: format!("{context}: {}", self.inner).into() }
    }

    /// Innermost error in the source chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = &*self.inner;
        while let Some(src) = cur.source() {
            cur = src;
        }
        cur
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut src = self.inner.source();
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result`s of concrete error types.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_prepends() {
        let e = io_err().with_context(|| "opening x").unwrap_err();
        assert_eq!(e.to_string(), "opening x: disk on fire");
        let e = io_err().context("static ctx").unwrap_err();
        assert!(e.to_string().starts_with("static ctx: "));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn bails() -> Result<()> {
            bail!("nope {x}", x = 3);
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 3");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
