//! Grouped-batched dispatch ↔ per-token reference equivalence.
//!
//! `MoeModel::forward_opts` now routes every MoE layer through the
//! expert-grouped dispatcher (`moe::dispatch`). This suite pins it
//! against a local reimplementation of the historical row-at-a-time
//! forward: logits must agree within 1e-4 for fp and quantized models,
//! with `Pruner`, `RoutingStats`, `pruning_counter` and
//! `capture_moe_inputs` hooks all active — and the hooks themselves must
//! observe identical call counts and routing decisions.

use mcsharp::config::{ModelConfig, PmqConfig};
use mcsharp::moe::gating::{route, Route};
use mcsharp::moe::model::{ExpertProvider, ForwardOpts, MoeModel, Pruner};
use mcsharp::moe::{ExpertId, RoutingStats};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::tensor::{rmsnorm, Tensor2};

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "equiv-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 1,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

/// Keep-count depends only on call order, so the grouped path (which
/// consults the pruner in token-row order) must reproduce the reference
/// decision sequence exactly.
struct CyclePruner {
    calls: usize,
}

impl Pruner for CyclePruner {
    fn keep(&mut self, _layer: usize, _x: &[f32], r: &Route) -> usize {
        self.calls += 1;
        1 + self.calls % r.experts.len()
    }
}

/// Everything the hooks observed during one forward.
struct HookTrace {
    logits: Tensor2,
    stats: RoutingStats,
    counter: (u64, u64),
    capture: Vec<Vec<Vec<f32>>>,
    pruner_calls: usize,
}

/// The historical per-token forward (pre-dispatch semantics), expert
/// execution through the provider's row path only.
fn reference_forward(
    m: &MoeModel,
    provider: Option<&dyn ExpertProvider>,
    tokens: &[u16],
) -> HookTrace {
    let h = m.cfg.d_model;
    let t = tokens.len();
    let mut stats = RoutingStats::new(m.cfg.n_layers, m.cfg.n_experts);
    let mut counter = (0u64, 0u64);
    let mut capture: Vec<Vec<Vec<f32>>> = vec![Vec::new(); m.cfg.n_layers];
    let mut pruner = CyclePruner { calls: 0 };
    let mut x = Tensor2::zeros(t, h);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(m.embed.row(tok as usize));
    }
    let mut normed = Tensor2::zeros(t, h);
    for (l, block) in m.blocks.iter().enumerate() {
        for i in 0..t {
            rmsnorm(x.row(i), &block.attn_norm, normed.row_mut(i));
        }
        let attn_out = block.attn.forward(&normed, 0);
        x.add_assign(&attn_out);
        for i in 0..t {
            rmsnorm(x.row(i), &block.moe_norm, normed.row_mut(i));
        }
        for i in 0..t {
            let xin = normed.row(i).to_vec();
            capture[l].push(xin.clone());
            let r = route(&xin, &block.gate, m.cfg.top_k);
            let keep = pruner.keep(l, &xin, &r).clamp(1, r.experts.len());
            counter.0 += keep as u64;
            counter.1 += r.experts.len() as u64;
            let wsum: f32 = r.weights[..keep].iter().sum();
            let mut acc = vec![0.0f32; h];
            for rank in 0..keep {
                let e = r.experts[rank];
                let w = r.weights[rank] / wsum;
                stats.record(l, e, r.weights[rank]);
                match provider {
                    Some(p) => p.expert_ffn_acc(l, ExpertId::Routed(e), &xin, w, &mut acc),
                    None => block.experts[e].ffn_row_acc(&xin, w, &mut acc),
                }
            }
            for (s, shared) in block.shared.iter().enumerate() {
                match provider {
                    Some(p) => p.expert_ffn_acc(l, ExpertId::Shared(s), &xin, 1.0, &mut acc),
                    None => shared.ffn_row_acc(&xin, 1.0, &mut acc),
                }
            }
            let xr = x.row_mut(i);
            for (a, o) in xr.iter_mut().zip(&acc) {
                *a += o;
            }
            if l == 0 {
                stats.bump_tokens();
            }
        }
    }
    let mut logits = Tensor2::zeros(t, m.cfg.vocab_size);
    for i in 0..t {
        rmsnorm(x.row(i), &m.final_norm, normed.row_mut(i));
        let row = mcsharp::moe::attention::mat_vec(&m.lm_head, normed.row(i));
        logits.row_mut(i).copy_from_slice(&row);
    }
    HookTrace { logits, stats, counter, capture, pruner_calls: pruner.calls }
}

/// The production grouped path, all hooks active.
fn grouped_forward(
    m: &MoeModel,
    provider: Option<&dyn ExpertProvider>,
    tokens: &[u16],
) -> HookTrace {
    let mut stats = RoutingStats::new(m.cfg.n_layers, m.cfg.n_experts);
    let mut counter = (0u64, 0u64);
    let mut capture: Vec<Vec<Vec<f32>>> = vec![Vec::new(); m.cfg.n_layers];
    let mut pruner = CyclePruner { calls: 0 };
    let logits = {
        let mut opts = ForwardOpts {
            stats: Some(&mut stats),
            provider,
            pruner: Some(&mut pruner),
            pruning_counter: Some(&mut counter),
            capture_moe_inputs: Some(&mut capture),
        };
        m.forward_opts(tokens, &mut opts)
    };
    HookTrace { logits, stats, counter, capture, pruner_calls: pruner.calls }
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

fn assert_equivalent(got: &HookTrace, want: &HookTrace, what: &str) {
    close(&got.logits.data, &want.logits.data, 1e-4, &format!("{what} logits"));
    // hooks: identical counts and routing decisions, not just logits
    assert_eq!(got.pruner_calls, want.pruner_calls, "{what}: pruner call count");
    assert_eq!(got.counter, want.counter, "{what}: pruning counter");
    assert_eq!(got.stats.tokens, want.stats.tokens, "{what}: stats tokens");
    assert_eq!(got.stats.counts, want.stats.counts, "{what}: stats activation counts");
    for (i, (a, b)) in got.stats.weight_sums.iter().zip(&want.stats.weight_sums).enumerate() {
        assert!((a - b).abs() < 1e-4, "{what}: weight_sums[{i}] {a} vs {b}");
    }
    assert_eq!(got.capture.len(), want.capture.len());
    for (l, (ga, wa)) in got.capture.iter().zip(&want.capture).enumerate() {
        assert_eq!(ga.len(), wa.len(), "{what}: capture count layer {l}");
        for (i, (gx, wx)) in ga.iter().zip(wa).enumerate() {
            close(gx, wx, 1e-4, &format!("{what} capture l{l} row {i}"));
        }
    }
}

const TOKS: [u16; 12] = [1, 17, 30, 45, 8, 22, 50, 12, 40, 3, 60, 33];

#[test]
fn fp_grouped_matches_per_token_reference_with_all_hooks() {
    let m = MoeModel::new(&cfg(), 2024);
    let got = grouped_forward(&m, None, &TOKS);
    let want = reference_forward(&m, None, &TOKS);
    assert_equivalent(&got, &want, "fp");
    // every token-layer consulted the pruner exactly once
    assert_eq!(want.pruner_calls, TOKS.len() * 2);
}

#[test]
fn quantized_grouped_matches_per_token_reference_with_all_hooks() {
    let base = MoeModel::new(&cfg(), 2025);
    let alloc = vec![vec![2u8, 3, 1, 2], vec![3, 2, 2, 1]];
    let q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    // grouped path: QuantModel's batch override decodes each packed tile
    // once per token group; reference decodes per token via the row path
    let got = grouped_forward(&q.model, Some(&q), &TOKS);
    let want = reference_forward(&q.model, Some(&q), &TOKS);
    assert_equivalent(&got, &want, "quant");
}

#[test]
fn quantized_grouped_matches_without_pruning_hooks() {
    // hooks-off configuration (the common eval setup): logits only
    let base = MoeModel::new(&cfg(), 2026);
    let alloc = vec![vec![3u8; 4]; 2];
    let q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    let got = q
        .model
        .forward_opts(&TOKS, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
    // reference with a keep-everything pruner is the no-pruner forward
    let h = q.model.cfg.d_model;
    let t = TOKS.len();
    let mut x = Tensor2::zeros(t, h);
    for (i, &tok) in TOKS.iter().enumerate() {
        x.row_mut(i).copy_from_slice(q.model.embed.row(tok as usize));
    }
    let mut normed = Tensor2::zeros(t, h);
    for (l, block) in q.model.blocks.iter().enumerate() {
        for i in 0..t {
            rmsnorm(x.row(i), &block.attn_norm, normed.row_mut(i));
        }
        let attn_out = block.attn.forward(&normed, 0);
        x.add_assign(&attn_out);
        for i in 0..t {
            rmsnorm(x.row(i), &block.moe_norm, normed.row_mut(i));
        }
        for i in 0..t {
            let xin = normed.row(i).to_vec();
            let r = route(&xin, &block.gate, q.model.cfg.top_k);
            let mut acc = vec![0.0f32; h];
            for (rank, &e) in r.experts.iter().enumerate() {
                q.expert_ffn_acc(l, ExpertId::Routed(e), &xin, r.weights[rank], &mut acc);
            }
            for s in 0..block.shared.len() {
                q.expert_ffn_acc(l, ExpertId::Shared(s), &xin, 1.0, &mut acc);
            }
            let xr = x.row_mut(i);
            for (a, o) in xr.iter_mut().zip(&acc) {
                *a += o;
            }
        }
    }
    let mut want = Tensor2::zeros(t, q.model.cfg.vocab_size);
    for i in 0..t {
        rmsnorm(x.row(i), &q.model.final_norm, normed.row_mut(i));
        let row = mcsharp::moe::attention::mat_vec(&q.model.lm_head, normed.row(i));
        want.row_mut(i).copy_from_slice(&row);
    }
    close(&got.data, &want.data, 1e-4, "quant no-hooks logits");
}
