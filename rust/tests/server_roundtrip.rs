//! TCP server round-trip: the line protocol must return exactly the
//! tokens the engine produces for the same prompt.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

use mcsharp::backend::NativeBackend;
use mcsharp::config::ModelConfig;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::server;
use mcsharp::moe::MoeModel;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "srv-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 0,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

#[test]
fn tcp_roundtrip_matches_direct_generation() {
    let m = MoeModel::new(&tiny_cfg(), 200);
    // expected output straight from the engine
    let be = NativeBackend::fp(&m);
    let mut direct = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = direct.generate(&[1, 17, 30], 5).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            server::serve(listener, &engine, 4, Some(2)).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // ping first
        stream.write_all(b"PING\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");
        // two generation requests (server exits after 2)
        for _ in 0..2 {
            stream.write_all(b"GEN 5 1,17,30\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let got: Vec<u16> = line
                .trim()
                .strip_prefix("OK ")
                .unwrap()
                .split(',')
                .map(|t| t.parse().unwrap())
                .collect();
            assert_eq!(got, want);
        }
    });
}

#[test]
fn metrics_command_returns_json_snapshot() {
    let m = MoeModel::new(&tiny_cfg(), 202);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            server::serve(listener, &engine, 4, Some(1)).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        // generate, then scrape
        stream.write_all(b"GEN 4 1,17,30\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "{line}");
        line.clear();
        stream.write_all(b"METRICS\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let json = line.trim().strip_prefix("METRICS ").expect("prefix");
        let v = mcsharp::util::json::Value::parse(json).expect("valid json");
        assert_eq!(v.get("tokens_out").unwrap().as_usize().unwrap(), 4);
        assert_eq!(v.get("requests").unwrap().as_usize().unwrap(), 1);
        assert!(v.get("latency_p50_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("pruning_ratio").unwrap().as_f64().unwrap() == 0.0);
    });
}

#[test]
fn malformed_requests_get_err() {
    let m = MoeModel::new(&tiny_cfg(), 201);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            server::serve(listener, &engine, 4, Some(1)).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        stream.write_all(b"GEN notanumber 1,2\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{line}");
        line.clear();
        stream.write_all(b"BOGUS\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{line}");
        // finish with one good request so the server's quota drains
        line.clear();
        stream.write_all(b"GEN 2 1,5\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "{line}");
    });
}
