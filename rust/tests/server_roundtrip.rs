//! TCP server round-trip: the wire protocol must return exactly the
//! tokens the engine produces for the same prompt — including when N
//! clients hit the shared continuous-batching scheduler at once. v0
//! lines are exercised raw (byte-for-byte compatibility); v1 traffic
//! drives the shared [`Client`](mcsharp::coordinator::client::Client).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

use mcsharp::backend::NativeBackend;
use mcsharp::config::{ModelConfig, ServingConfig};
use mcsharp::coordinator::client::Client;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::server;
use mcsharp::moe::MoeModel;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "srv-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 0,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

/// Legacy v0 round-trip, raw bytes on purpose: the exact pre-v1 lines
/// must keep producing the exact pre-v1 responses.
#[test]
fn tcp_roundtrip_matches_direct_generation() {
    let m = MoeModel::new(&tiny_cfg(), 200);
    // expected output straight from the engine
    let be = NativeBackend::fp(&m);
    let mut direct = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = direct.generate(&[1, 17, 30], 5).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            server::serve(listener, &engine, 4, Some(2)).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // ping first
        stream.write_all(b"PING\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");
        // two generation requests (server exits after 2)
        for _ in 0..2 {
            stream.write_all(b"GEN 5 1,17,30\n").unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let got: Vec<u16> = line
                .trim()
                .strip_prefix("OK ")
                .unwrap()
                .split(',')
                .map(|t| t.parse().unwrap())
                .collect();
            assert_eq!(got, want);
        }
    });
}

#[test]
fn metrics_command_returns_json_snapshot() {
    let m = MoeModel::new(&tiny_cfg(), 202);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            server::serve(listener, &engine, 4, Some(1)).unwrap();
        });
        let mut client = Client::connect(addr).unwrap();
        // generate (v1 tagged), then scrape
        let out = client.gen(&[1, 17, 30], 4).unwrap();
        assert_eq!(out.tokens.len(), 7);
        assert!(out.latency_us >= out.queue_us, "latency includes queue wait");
        let v = client.metrics_value().unwrap();
        assert_eq!(v.get("tokens_out").unwrap().as_usize().unwrap(), 4);
        assert_eq!(v.get("requests").unwrap().as_usize().unwrap(), 1);
        assert!(v.get("latency_p50_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(v.get("queue_p50_us").unwrap().as_f64().unwrap() >= 0.0);
        assert!(v.get("pruning_ratio").unwrap().as_f64().unwrap() == 0.0);
    });
}

/// The serving-path acceptance test for cross-request continuous
/// batching: two clients connect at once and
///   (a) each gets exactly the single-client greedy reference tokens,
///   (b) the engine takes strictly fewer steps than the two requests
///       would sequentially (proof their sequences shared steps),
///   (c) an idle open connection (here: connected first, silent the
///       whole time) blocks nobody, and still gets METRICS/STATS
///       answers afterwards — with sane lifetime tps.
#[test]
fn concurrent_clients_share_engine_steps() {
    let m = MoeModel::new(&tiny_cfg(), 203);
    let be = NativeBackend::fp(&m);
    let prompts: [Vec<u16>; 2] = [vec![1, 17, 30], vec![1, 9, 22]];
    let mut want = Vec::new();
    let mut sequential_steps = 0u64;
    for p in &prompts {
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        want.push(eng.generate(p, 6).unwrap());
        sequential_steps += eng.metrics.steps;
    }

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            let sc = ServingConfig {
                max_batch: 2,
                // wide gather window: the engine waits for both requests
                // before its first step (a full batch short-circuits the
                // wait), so the step-sharing assertion is deterministic
                batch_window_us: 5_000_000,
                ..Default::default()
            };
            server::serve_with(listener, &engine, &sc, Some(2)).unwrap();
        });
        // (c) idle connection first — sends nothing while others work
        let idle = TcpStream::connect(addr).unwrap();
        let mut idle_reader = BufReader::new(idle.try_clone().unwrap());
        // two concurrent clients, each through the first-class Client
        let handles: Vec<_> = prompts
            .iter()
            .map(|p| {
                s.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    client.gen(p, 6).unwrap()
                })
            })
            .collect();
        let got: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // (a) token-for-token greedy reference
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.tokens, w, "served tokens diverged from single-client reference");
        }
        // (b) + lifetime metrics, scraped over the still-open idle conn
        let mut idle_out = idle.try_clone().unwrap();
        idle_out.write_all(b"METRICS\n").unwrap();
        let mut line = String::new();
        idle_reader.read_line(&mut line).unwrap();
        let json = line.trim().strip_prefix("METRICS ").expect("prefix");
        let v = mcsharp::util::json::Value::parse(json).expect("valid json");
        let steps = v.get("steps").unwrap().as_usize().unwrap() as u64;
        assert!(
            steps < sequential_steps,
            "no cross-request batching: {steps} engine steps vs {sequential_steps} sequential"
        );
        assert_eq!(v.get("tokens_out").unwrap().as_usize().unwrap(), 12);
        assert_eq!(v.get("requests").unwrap().as_usize().unwrap(), 2);
        assert!(v.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        // STATS carries the same lifetime tps plus percentile summaries
        line.clear();
        idle_out.write_all(b"STATS\n").unwrap();
        idle_reader.read_line(&mut line).unwrap();
        let field = |key: &str| -> f64 {
            line.split_whitespace()
                .find_map(|f| f.strip_prefix(key).and_then(|f| f.strip_prefix('=')))
                .unwrap_or_else(|| panic!("STATS must report {key}: {line}"))
                .parse()
                .unwrap()
        };
        assert!(field("tps") > 0.0, "lifetime tps insane: {line}");
        assert!(field("lat_p50_us") > 0.0, "latency summary missing: {line}");
        assert!(field("queue_p95_us") >= 0.0, "queue summary missing: {line}");
        // QUIT closes the idle connection server-side
        idle_out.write_all(b"QUIT\n").unwrap();
        line.clear();
        assert_eq!(idle_reader.read_line(&mut line).unwrap(), 0, "QUIT must close");
    });
}

#[test]
fn malformed_requests_get_err() {
    let m = MoeModel::new(&tiny_cfg(), 201);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
            server::serve(listener, &engine, 4, Some(1)).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        stream.write_all(b"GEN notanumber 1,2\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{line}");
        line.clear();
        stream.write_all(b"BOGUS\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{line}");
        // finish with one good request so the server's quota drains
        line.clear();
        stream.write_all(b"GEN 2 1,5\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "{line}");
    });
}
