//! End-to-end pipeline integration: train → calibrate → allocate (all
//! strategies) → quantize (GPTQ) → evaluate → OTP — the full MC# flow on
//! a small model, asserting the paper's *orderings* hold.

use mcsharp::config::{ModelConfig, OtpConfig, PmqConfig};
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::eval::{lm_suite, mc::score_suite, EvalOpts};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::otp::{train_otp, OtpPruner, RandomPruner};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::{TrainConfig, Trainer};
use mcsharp::util::rng::Rng;

fn small_cfg() -> ModelConfig {
    ModelConfig {
        name: "pipe-test".into(),
        family: "mixtral".into(),
        vocab_size: 512,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        n_experts: 6,
        top_k: 2,
        n_shared_experts: 0,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

#[test]
fn full_mc_sharp_pipeline() {
    // 1. pretrain briefly so experts specialize
    let cfg = small_cfg();
    let tc = TrainConfig { steps: 80, batch: 4, seq_len: 32, lr: 4e-3, ..Default::default() };
    let mut trainer = Trainer::new(&cfg, tc);
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    trainer.train(&corpus, true).unwrap();
    let base = trainer.model;

    // 2. calibrate
    let mut rng = Rng::new(11);
    let calib = corpus.batch(6, 32, &mut rng);
    let cal = calibrate(&base, &calib, 128);
    assert!(cal.stats.tokens > 0);

    // 3. ε table + PMQ allocation at 2-bit average
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc_pmq =
        strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let alloc_uni =
        strategies::allocation(Strategy::Uniform, &base, &cal, &eps, &pmq, 2.0, &mut rng);

    // 4. quantize with GPTQ
    let q_pmq = QuantModel::quantize(&base, &alloc_pmq, &pmq, &QuantMethod::Gptq(&cal.hessians));
    let q_uni = QuantModel::quantize(&base, &alloc_uni, &pmq, &QuantMethod::Gptq(&cal.hessians));
    assert!((q_pmq.avg_expert_bits() - 2.0).abs() < 0.1);
    // whole-model compression is diluted by fp16 embeddings on this toy
    // config; experts themselves must compress ≥ 3×
    assert!(q_pmq.nbytes() < base.nbytes_fp16() / 2, "compression < 2x");
    let expert_bytes: u64 = q_pmq.store.total_nbytes();
    let expert_fp16: u64 =
        (cfg.n_layers * cfg.n_experts * cfg.expert_params() * 2) as u64;
    assert!(expert_bytes * 3 < expert_fp16, "expert compression < 3x");

    // 5. perplexity ordering: fp ≤ pmq@2 and pmq not catastrophically
    //    worse; uniform-2bit ≥ pmq (the paper's central claim)
    let eval_seqs = corpus.batch(4, 32, &mut rng);
    let ppl_fp = base.perplexity(&eval_seqs, &mut ForwardOpts::default());
    let ppl_pmq = q_pmq
        .model
        .perplexity(&eval_seqs, &mut ForwardOpts { provider: Some(&q_pmq), ..Default::default() });
    let ppl_uni = q_uni
        .model
        .perplexity(&eval_seqs, &mut ForwardOpts { provider: Some(&q_uni), ..Default::default() });
    assert!(ppl_fp < ppl_pmq, "quantization must cost something: {ppl_fp} vs {ppl_pmq}");
    assert!(
        ppl_pmq <= ppl_uni * 1.10,
        "PMQ ({ppl_pmq:.2}) should not lose to uniform ({ppl_uni:.2})"
    );

    // 6. OTP training on the quantized model; beats random pruning at a
    //    comparable measured ratio
    let oc = OtpConfig { steps: 60, batch_tokens: 32, ..Default::default() };
    let rep = train_otp(&q_pmq, &calib, &oc, 0xF00D);
    let mut otp = OtpPruner { routers: rep.routers };
    let mut counter = (0u64, 0u64);
    let ppl_otp = q_pmq.model.perplexity(
        &eval_seqs,
        &mut ForwardOpts {
            provider: Some(&q_pmq),
            pruner: Some(&mut otp),
            pruning_counter: Some(&mut counter),
            ..Default::default()
        },
    );
    let otp_ratio = 1.0 - counter.0 as f64 / counter.1.max(1) as f64;
    let mut rnd = RandomPruner::new(otp_ratio.max(0.05), 3);
    let ppl_rnd = q_pmq.model.perplexity(
        &eval_seqs,
        &mut ForwardOpts {
            provider: Some(&q_pmq),
            pruner: Some(&mut rnd),
            ..Default::default()
        },
    );
    assert!(ppl_otp.is_finite() && ppl_rnd.is_finite());
    if otp_ratio > 0.03 {
        assert!(
            ppl_otp <= ppl_rnd * 1.05,
            "OTP ({ppl_otp:.2} @ {otp_ratio:.2}) should beat random ({ppl_rnd:.2})"
        );
    }
}

/// The full deployment path: quantize → write the packed checkpoint →
/// reload → train OTP on the *reloaded* model → serve through the
/// engine — outputs must match the never-serialized model exactly under
/// the same pruner (the `deploy_qckpt` example's invariant, as a test).
#[test]
fn qcheckpoint_deploys_identically_with_otp() {
    use mcsharp::backend::NativeBackend;
    use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
    use mcsharp::quant::qcheckpoint;

    let cfg = small_cfg();
    let tc = TrainConfig { steps: 60, batch: 4, seq_len: 32, lr: 4e-3, ..Default::default() };
    let mut trainer = Trainer::new(&cfg, tc);
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    trainer.train(&corpus, true).unwrap();
    let base = trainer.model;
    let mut rng = Rng::new(21);
    let calib = corpus.batch(6, 32, &mut rng);
    let cal = calibrate(&base, &calib, 128);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc = strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, 2.0, &mut rng);
    let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));

    let path = std::env::temp_dir()
        .join(format!("mcsharp-pipe-deploy-{}.bin", std::process::id()))
        .to_string_lossy()
        .into_owned();
    qcheckpoint::save(&q, &path).unwrap();
    let q2 = qcheckpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // OTP training must be reproducible on the reloaded weights
    let oc = OtpConfig { steps: 40, batch_tokens: 32, ..Default::default() };
    let rep_a = train_otp(&q, &calib, &oc, 0xD0E);
    let rep_b = train_otp(&q2, &calib, &oc, 0xD0E);

    // serve the same prompts through both engines with their pruners
    let be_a = NativeBackend::quant(&q);
    let be_b = NativeBackend::quant(&q2);
    let mut eng_a = DecodeEngine::new(
        EngineModel::Quant(&q),
        &be_a,
        Some(Box::new(OtpPruner { routers: rep_a.routers })),
    );
    let mut eng_b = DecodeEngine::new(
        EngineModel::Quant(&q2),
        &be_b,
        Some(Box::new(OtpPruner { routers: rep_b.routers })),
    );
    for seed in 0..4u16 {
        let prompt = vec![1, 30 + seed * 7, 100 + seed * 3, 60];
        let a = eng_a.generate(&prompt, 8).unwrap();
        let b = eng_b.generate(&prompt, 8).unwrap();
        assert_eq!(a, b, "deployment diverged for seed {seed}");
    }
    assert_eq!(
        eng_a.metrics.experts_kept, eng_b.metrics.experts_kept,
        "pruning decisions diverged across save/load"
    );
}

#[test]
fn suite_scores_degrade_monotonically_with_bits() {
    let cfg = small_cfg();
    let tc = TrainConfig { steps: 60, batch: 4, seq_len: 32, lr: 4e-3, ..Default::default() };
    let mut trainer = Trainer::new(&cfg, tc);
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    trainer.train(&corpus, true).unwrap();
    let base = trainer.model;
    let pmq = PmqConfig::default();
    let tasks = lm_suite::build(12, 0xAB);
    let (_, acc_fp) = score_suite(&base, &mut EvalOpts::default(), &tasks);
    let acc_at = |bits: u8| {
        let alloc = vec![vec![bits; cfg.n_experts]; cfg.n_layers];
        let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Rtn);
        let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
        let (_, acc) = score_suite(&q.model, &mut opts, &tasks);
        acc
    };
    let acc3 = acc_at(3);
    let acc1 = acc_at(1);
    // 3-bit stays close to fp; 1-bit falls behind 3-bit (paper Tables 2/4
    // shape). Tiny-suite noise tolerance: ±6 points.
    assert!(acc3 >= acc1 - 6.0, "3-bit {acc3} vs 1-bit {acc1}");
    assert!(acc_fp >= acc3 - 6.0, "fp {acc_fp} vs 3-bit {acc3}");
}
