//! Kernel-equivalence suite — the acceptance gate for the fused
//! dequant×matmul kernel layer (`quant::kernels`).
//!
//! For every bit-width in {1, 2, 3, 4}, group size in {16, 32, 64},
//! odd/awkward shapes (`d_out` not a multiple of the 8-lane vector
//! width, tiny and non-square matrices), and both matvec and batched
//! matmul entry points, three evaluations must agree to f32 accumulation
//! tolerance:
//!
//! 1. the SIMD path (whatever `active_isa()` picks on this host),
//! 2. the portable scalar path (pinned via `kernels::force_scalar`),
//! 3. the unfused reference: `dequantize()` then a dense accumulate.
//!
//! The AWQ `Scaled` variant (activation rescale folded into the kernel
//! prologue) and the accumulate contract (`y +=`, not `y =`) are
//! exercised through `QuantLinear`, i.e. the exact call path the serving
//! decode engine takes.

use mcsharp::quant::{kernels, BinaryMatrix, PackedMatrix, QuantLinear};
use mcsharp::quant::rtn::quantize_rtn;
use mcsharp::tensor::Tensor2;
use mcsharp::util::{prop, rng::Rng};

/// |a − b| within `tol`, scaled by magnitude (f32 accumulation order
/// differs between the FMA, scalar and reference paths).
fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}: element {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Random activation row with whole zero 8-chunks sprinkled in, so the
/// kernels' zero-skip branch is exercised alongside the dense path.
fn sparse_x(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    for c in 0..n / 8 {
        if rng.below(4) == 0 {
            x[c * 8..(c + 1) * 8].fill(0.0);
        }
    }
    x
}

/// Unfused reference: `y += x @ dequant(ql)` one token at a time.
fn reference_acc(ql: &QuantLinear, x: &[f32], t: usize, y: &mut [f32]) {
    let w = ql.dequantize();
    for ti in 0..t {
        let xr = &x[ti * w.rows..][..w.rows];
        let yr = &mut y[ti * w.cols..][..w.cols];
        for (r, &xv) in xr.iter().enumerate() {
            for o in 0..w.cols {
                yr[o] += xv * w.at(r, o);
            }
        }
    }
}

/// Run `ql` through matvec (t == 1) or matmul on both dispatch paths and
/// pin each against the unfused reference. `y` starts non-zero so the
/// accumulate contract is part of what is checked.
fn check_all_paths(ql: &QuantLinear, x: &[f32], t: usize, rng: &mut Rng, what: &str) {
    let d_out = ql.d_out();
    let y0: Vec<f32> = (0..t * d_out).map(|_| rng.normal()).collect();
    let mut want = y0.clone();
    reference_acc(ql, x, t, &mut want);

    let run = |ql: &QuantLinear| -> Vec<f32> {
        let mut y = y0.clone();
        if t == 1 {
            ql.matvec_acc(x, &mut y);
        } else {
            let xt = Tensor2::from_vec(t, ql.d_in(), x.to_vec());
            let mut yt = Tensor2::from_vec(t, d_out, y);
            ql.matmul_acc(&xt, &mut yt);
            y = yt.data;
        }
        y
    };

    let native = run(ql);
    assert_close(&native, &want, 1e-4, &format!("{what}: native vs reference"));
    let scalar = kernels::force_scalar(|| run(ql));
    assert_close(&scalar, &want, 1e-4, &format!("{what}: forced-scalar vs reference"));
    assert_close(&native, &scalar, 1e-4, &format!("{what}: native vs forced-scalar"));
}

fn packed_case(rng: &mut Rng, bits: u8, group: usize, t: usize) {
    let d_in = group * (1 + rng.below(3));
    let d_out = 1 + rng.below(40); // odd widths: scalar-tail coverage
    let w = Tensor2::randn(d_in, d_out, rng, 1.0);
    let (codes, scales, zeros) = quantize_rtn(&w, bits, group);
    let pm = PackedMatrix::from_codes(&codes, scales, zeros, d_in, d_out, bits, group);
    let x: Vec<f32> = (0..t).flat_map(|_| sparse_x(rng, d_in)).collect();
    check_all_paths(
        &QuantLinear::Packed(pm),
        &x,
        t,
        rng,
        &format!("packed b{bits} g{group} {d_in}x{d_out} t{t}"),
    );
}

#[test]
fn packed_matvec_all_bits_groups_shapes() {
    prop::for_all(901, 12, |rng, _| {
        for bits in 1..=4u8 {
            for &group in &[16usize, 32, 64] {
                packed_case(rng, bits, group, 1);
            }
        }
    });
}

#[test]
fn packed_matmul_all_bits_groups_shapes() {
    prop::for_all(902, 8, |rng, _| {
        for bits in 1..=4u8 {
            for &group in &[16usize, 32, 64] {
                let t = 2 + rng.below(7);
                packed_case(rng, bits, group, t);
            }
        }
    });
}

#[test]
fn binary_matvec_and_matmul() {
    prop::for_all(903, 15, |rng, _| {
        let d_in = 8 * (1 + rng.below(20));
        let d_out = 1 + rng.below(40);
        let w = Tensor2::randn(d_in, d_out, rng, 1.0);
        let bm = BinaryMatrix::binarize(&w);
        for t in [1usize, 1 + rng.below(8)] {
            let x: Vec<f32> = (0..t).flat_map(|_| sparse_x(rng, d_in)).collect();
            check_all_paths(
                &QuantLinear::Binary(bm.clone()),
                &x,
                t,
                rng,
                &format!("binary {d_in}x{d_out} t{t}"),
            );
        }
    });
}

#[test]
fn awq_scaled_prologue_folds_inv_s() {
    // Scaled stores codes of diag(s)·W and rescales activations by
    // inv_s in the kernel prologue; reference path dequantizes through
    // QuantLinear::dequantize (which folds inv_s back into the weights).
    prop::for_all(904, 10, |rng, _| {
        for &(bits, group) in &[(2u8, 16usize), (3, 32), (4, 64)] {
            let d_in = group * (1 + rng.below(2));
            let d_out = 1 + rng.below(32);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            // per-input-channel scales bounded away from 0
            let s: Vec<f32> = (0..d_in).map(|_| 0.5 + rng.f32() * 1.5).collect();
            let mut ws = w.clone();
            for r in 0..d_in {
                for v in ws.row_mut(r) {
                    *v *= s[r];
                }
            }
            let (codes, scales, zeros) = quantize_rtn(&ws, bits, group);
            let inner = PackedMatrix::from_codes(&codes, scales, zeros, d_in, d_out, bits, group);
            let inv_s: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
            let ql = QuantLinear::Scaled { inv_s, inner };
            for t in [1usize, 3] {
                let x: Vec<f32> = (0..t).flat_map(|_| sparse_x(rng, d_in)).collect();
                check_all_paths(&ql, &x, t, rng, &format!("scaled b{bits} g{group} t{t}"));
            }
        }
    });
}

#[test]
fn forced_scalar_dispatch_is_observable() {
    assert_eq!(
        kernels::force_scalar(kernels::active_isa),
        kernels::Isa::Scalar,
        "force_scalar must pin the scalar path"
    );
    if kernels::simd_available() {
        assert_eq!(kernels::active_isa(), kernels::Isa::Avx2Fma);
    } else {
        assert_eq!(kernels::active_isa(), kernels::Isa::Scalar);
    }
}

#[test]
fn expert_ffn_batch_matches_row_path() {
    // The scratch-arena FFN (pool slots + _sc call chain) must agree
    // with t independent row FFNs.
    use mcsharp::quant::QuantExpert;
    prop::for_all(905, 8, |rng, _| {
        let (h, f) = (32usize, 64usize);
        let mk = |rng: &mut Rng, d_in: usize, d_out: usize, bits: u8| -> QuantLinear {
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let (c, s, z) = quantize_rtn(&w, bits, 16);
            QuantLinear::Packed(PackedMatrix::from_codes(&c, s, z, d_in, d_out, bits, 16))
        };
        let bits = 2 + rng.below(3) as u8;
        let qe = QuantExpert {
            wg: mk(rng, h, f, bits),
            wu: mk(rng, h, f, bits),
            wd: mk(rng, f, h, bits),
            bits,
        };
        let t = 1 + rng.below(6);
        let x = Tensor2::randn(t, h, rng, 1.0);
        let mut batch = Tensor2::zeros(t, h);
        qe.ffn_batch_acc(&x, &mut batch);
        for ti in 0..t {
            let mut row = vec![0.0f32; h];
            qe.ffn_row_acc(x.row(ti), 1.0, &mut row);
            assert_close(&batch.data[ti * h..][..h], &row, 1e-3, "ffn batch vs row");
        }
        // weighted row path (exercises pool slot 2)
        let mut w1 = vec![0.0f32; h];
        let mut w2 = vec![0.0f32; h];
        qe.ffn_row_acc(x.row(0), 1.0, &mut w1);
        qe.ffn_row_acc(x.row(0), 0.25, &mut w2);
        let scaled: Vec<f32> = w1.iter().map(|v| v * 0.25).collect();
        assert_close(&w2, &scaled, 1e-4, "weighted ffn row");
    });
}
