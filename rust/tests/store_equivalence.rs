//! Store-equivalence suite: a `PagedStore` under a byte budget smaller
//! than the total packed experts must be **observationally identical** to
//! the all-resident store — bit-identical eval logits, bit-identical
//! served generations — while provably honoring its budget (peak
//! resident bytes) and actually paging (miss/evict counters move).
//!
//! This is the acceptance gate for the ExpertStore refactor: residency is
//! an implementation detail of `quant::store`, invisible to every
//! numerical result.

use mcsharp::backend::NativeBackend;
use mcsharp::config::{ModelConfig, PmqConfig};
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::moe::MoeModel;
use mcsharp::quant::qcheckpoint;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "store-eq".into(),
        family: "mixtral".into(),
        vocab_size: 96,
        d_model: 32,
        n_layers: 3,
        n_heads: 2,
        d_ff: 32,
        n_experts: 6,
        top_k: 2,
        n_shared_experts: 1,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

fn tmppath(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("mcsharp-store-eq-{name}-{}.q2", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Quantize a random model with a mixed allocation, save v2, and return
/// (resident reload, paged reload, budget).
fn resident_and_paged(
    seed: u64,
    name: &str,
    budget_frac: (u64, u64),
) -> (QuantModel, QuantModel, u64, String) {
    let base = MoeModel::new(&cfg(), seed);
    let alloc = vec![
        vec![2u8, 1, 3, 2, 2, 1],
        vec![3u8, 2, 1, 2, 3, 2],
        vec![2u8, 2, 2, 1, 1, 3],
    ];
    let mut q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    // non-uniform importance so the eviction tie-break has teeth
    let importance: Vec<Vec<f64>> = (0..3)
        .map(|l| (0..6).map(|e| ((l * 6 + e) as f64 * 0.37).sin().abs() + 0.01).collect())
        .collect();
    q.set_importance(importance);
    let path = tmppath(name);
    qcheckpoint::save(&q, &path).unwrap();
    let resident = qcheckpoint::load(&path).unwrap();
    let total = resident.store.total_nbytes();
    let budget = total * budget_frac.0 / budget_frac.1;
    assert!(budget < total, "test must run under memory pressure");
    let paged = qcheckpoint::load_paged(&path, budget).unwrap();
    (resident, paged, budget, path)
}

#[test]
fn eval_logits_bit_identical_under_tiny_budget() {
    let (resident, paged, budget, path) = resident_and_paged(310, "eval", (3, 5));
    let seqs: Vec<Vec<u16>> = (0..4)
        .map(|s| (0..20).map(|i| ((i * 7 + s * 13) % 90 + 1) as u16).collect())
        .collect();
    for toks in &seqs {
        let a = resident.model.forward_opts(
            toks,
            &mut ForwardOpts { provider: Some(&resident), ..Default::default() },
        );
        let b = paged.model.forward_opts(
            toks,
            &mut ForwardOpts { provider: Some(&paged), ..Default::default() },
        );
        assert_eq!(a.data, b.data, "paged eval diverged from resident");
    }
    // perplexity (f64 reduction over identical f32 logits) must match too
    let ppl_r = resident.model.perplexity(
        &seqs,
        &mut ForwardOpts { provider: Some(&resident), ..Default::default() },
    );
    let ppl_p = paged.model.perplexity(
        &seqs,
        &mut ForwardOpts { provider: Some(&paged), ..Default::default() },
    );
    assert_eq!(ppl_r.to_bits(), ppl_p.to_bits());
    let c = paged.store.counters();
    assert!(c.misses > 0, "budget below total must page: {c:?}");
    assert!(c.evictions > 0, "crossing layers under pressure must evict: {c:?}");
    assert!(c.hits > 0, "repeated routing must hit the cache: {c:?}");
    assert!(
        c.peak_resident_bytes <= budget,
        "budget {budget} violated: peak {}",
        c.peak_resident_bytes
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn served_generations_bit_identical_under_tiny_budget() {
    let (resident, paged, budget, path) = resident_and_paged(311, "serve", (3, 5));
    let be_r = NativeBackend::quant(&resident);
    let be_p = NativeBackend::quant(&paged);
    let mut eng_r = DecodeEngine::new(EngineModel::Quant(&resident), &be_r, None);
    let mut eng_p = DecodeEngine::new(EngineModel::Quant(&paged), &be_p, None);
    for s in 0..4u16 {
        let prompt = vec![1, 10 + s * 9, 40 + s * 5, 7];
        let a = eng_r.generate(&prompt, 8).unwrap();
        let b = eng_p.generate(&prompt, 8).unwrap();
        assert_eq!(a, b, "served generation diverged for seed {s}");
    }
    // identical dispatch accounting: the store must not change routing
    assert_eq!(eng_r.metrics.experts_kept, eng_p.metrics.experts_kept);
    assert_eq!(eng_r.metrics.routed_bytes, eng_p.metrics.routed_bytes);
    // the paged engine surfaced its gauges through the metrics
    let c = eng_p.metrics.cache.expect("paged engine exposes cache gauges");
    assert!(c.misses > 0);
    assert!(c.peak_resident_bytes <= budget);
    // resident engine reports a full cache and no paging
    let cr = eng_r.metrics.cache.expect("resident engine exposes cache gauges");
    assert_eq!(cr.resident_bytes, resident.store.total_nbytes());
    assert_eq!(cr.misses, 0);
    std::fs::remove_file(&path).ok();
}

/// Decode steps touch few experts per layer, so a serve-shaped workload
/// under a small budget should produce prefetch hits: the store learns
/// layer ℓ+1's hot experts from routing history and stages them while
/// layer ℓ executes.
#[test]
fn decode_workload_generates_prefetch_hits() {
    let (_resident, paged, _budget, path) = resident_and_paged(312, "prefetch", (1, 2));
    let be = NativeBackend::quant(&paged);
    let mut eng = DecodeEngine::new(EngineModel::Quant(&paged), &be, None);
    for s in 0..6u16 {
        let prompt = vec![1, 5 + s * 11, 3 + s * 7];
        eng.generate(&prompt, 10).unwrap();
    }
    let c = paged.store.counters();
    assert!(
        c.prefetch_hits > 0,
        "repeating decode routes should hit prefetched experts: {c:?}"
    );
    std::fs::remove_file(&path).ok();
}

/// OTP distillation reads experts through the same store handles — it
/// must produce identical routers on resident and paged models.
#[test]
fn otp_training_identical_across_stores() {
    use mcsharp::config::OtpConfig;
    use mcsharp::otp::train_otp;
    let (resident, paged, _budget, path) = resident_and_paged(313, "otp", (3, 5));
    let seqs: Vec<Vec<u16>> = (0..3)
        .map(|s| (0..16).map(|i| ((i * 11 + s * 17) % 90 + 1) as u16).collect())
        .collect();
    let oc = OtpConfig { steps: 30, batch_tokens: 24, ..Default::default() };
    let rep_r = train_otp(&resident, &seqs, &oc, 0xABC);
    let rep_p = train_otp(&paged, &seqs, &oc, 0xABC);
    for (a, b) in rep_r.curve.iter().zip(&rep_p.curve) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "mask ratio diverged");
        assert_eq!(a.2.to_bits(), b.2.to_bits(), "distill loss diverged");
    }
    std::fs::remove_file(&path).ok();
}
