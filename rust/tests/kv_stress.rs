//! Seeded multi-thread stress of the paged KV pool's refcount and
//! free-list invariants (ISSUE 9 satellite): N threads race
//! `lookup_prefix` / `append` / `register_progress` / `free_seq` on one
//! `Mutex<KvPool>` — the exact shape of the serving engine's admission,
//! prefill and retirement paths.
//!
//! What the run enforces:
//! * no page is double-freed and no refcount underflows — `release()`
//!   carries a `debug_assert!(rc > 0)` that aborts the worker thread,
//!   and every worker is joined;
//! * adopted/copy-on-write pages always read back the rows their tokens
//!   imply (spot-checked every few iterations);
//! * once every sequence is freed, every surviving page is owned by the
//!   prefix tree exactly once (`pages_in_use == tree_blocks × layers` —
//!   a leaked retain or a lost release breaks the equality);
//! * trimming the tree returns `pages_in_use` to the empty baseline.

use std::sync::{Arc, Mutex};

use mcsharp::moe::kv::{KvPool, SeqKv};
use mcsharp::util::rng::Rng;

const PAGE: usize = 4;
const WIDTH: usize = 8;
const LAYERS: usize = 2;
const THREADS: u64 = 8;
const ITERS: usize = 150;

/// Deterministic stand-in for prefill: the KV rows of position `pos`
/// are derived from `tokens[pos]` alone, so any two sequences (on any
/// threads) that share a token prefix produce bit-identical rows —
/// which is what makes cross-thread page adoption verifiable.
fn row_for(tok: u16, layer: usize) -> (Vec<f32>, Vec<f32>) {
    let base = tok as f32 + layer as f32 * 1000.0;
    let k: Vec<f32> = (0..WIDTH).map(|i| base + i as f32).collect();
    let v: Vec<f32> = (0..WIDTH).map(|i| -(base + i as f32)).collect();
    (k, v)
}

fn fill(pool: &mut KvPool, kv: &mut SeqKv, tokens: &[u16], from: usize) {
    for pos in from..tokens.len() {
        for l in 0..LAYERS {
            let (k, v) = row_for(tokens[pos], l);
            pool.append(&mut kv.layers[l], &k, &v);
        }
    }
}

/// Every cached position of every layer must read back the rows its
/// token implies — catches both a mis-adopted page and a copy-on-write
/// that copied the wrong rows or aliased a page another thread mutated.
fn verify(pool: &KvPool, kv: &SeqKv, tokens: &[u16]) {
    for (l, lk) in kv.layers.iter().enumerate() {
        for pos in 0..lk.len() {
            let (want_k, want_v) = row_for(tokens[pos], l);
            let (k, v) = pool.row(lk, pos);
            assert_eq!(k, &want_k[..], "layer {l} pos {pos}: K row corrupted");
            assert_eq!(v, &want_v[..], "layer {l} pos {pos}: V row corrupted");
        }
    }
}

#[test]
fn concurrent_lookup_register_free_preserves_invariants() {
    let pool = Arc::new(Mutex::new(KvPool::new(PAGE, WIDTH, LAYERS)));
    // Seed a 3-block shared prefix so every thread immediately races
    // over adoption of the same tree pages.
    let prefix: Vec<u16> = (100..100 + (3 * PAGE) as u16).collect();
    {
        let mut p = pool.lock().unwrap();
        let mut seq = SeqKv::new(LAYERS);
        fill(&mut p, &mut seq, &prefix, 0);
        p.register_progress(&mut seq, &prefix);
        p.free_seq(&mut seq);
    }
    let baseline = pool.lock().unwrap().pages_in_use();
    assert_eq!(baseline, 3 * LAYERS, "seed chain: one page per block per layer");

    let mut handles = Vec::new();
    for t in 0..THREADS {
        let pool = Arc::clone(&pool);
        let prefix = prefix.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x5EED_2026 ^ (t << 17));
            for it in 0..ITERS {
                // Prompt = some blocks of the shared prefix + a short
                // suffix from a tiny alphabet (identical blocks across
                // threads are likely → dedup/converge path runs hot).
                let keep = rng.below(3 * PAGE + 1);
                let suffix_len = 1 + rng.below(2 * PAGE);
                let mut tokens: Vec<u16> = prefix[..keep].to_vec();
                for _ in 0..suffix_len {
                    tokens.push(rng.below(6) as u16);
                }
                // admission: adopt whatever prefix the tree holds
                let mut seq = {
                    let mut p = pool.lock().unwrap();
                    let probed = p.probe_prefix(&tokens);
                    let seq = p.lookup_prefix(&tokens);
                    assert_eq!(
                        probed,
                        seq.shared_toks(),
                        "probe and lookup under one lock must agree"
                    );
                    seq
                };
                // prefill: append position by position, re-taking the
                // lock each time so other threads interleave mid-fill
                for pos in seq.len()..tokens.len() {
                    let mut p = pool.lock().unwrap();
                    for l in 0..LAYERS {
                        let (k, v) = row_for(tokens[pos], l);
                        p.append(&mut seq.layers[l], &k, &v);
                    }
                }
                // decode a couple of tokens, registering progress as
                // the engine does after each step
                for _ in 0..rng.below(3) {
                    let next = rng.below(6) as u16;
                    let mut p = pool.lock().unwrap();
                    for l in 0..LAYERS {
                        let (k, v) = row_for(next, l);
                        p.append(&mut seq.layers[l], &k, &v);
                    }
                    tokens.push(next);
                    p.register_progress(&mut seq, &tokens);
                }
                {
                    let mut p = pool.lock().unwrap();
                    p.register_progress(&mut seq, &tokens);
                    if it % 10 == 0 {
                        verify(&p, &seq, &tokens);
                    }
                    // retirement; the tree keeps its own references, so
                    // in-use pages can never drop below the seed chain
                    p.free_seq(&mut seq);
                    assert!(
                        p.pages_in_use() >= baseline,
                        "seed chain pages vanished while the tree holds them"
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("worker hit a refcount/free-list violation");
    }

    let mut p = pool.lock().unwrap();
    // Every sequence is freed: the only remaining owners are tree
    // blocks, holding exactly one page per layer each. A leaked retain
    // (page never released) or a lost release breaks this equality.
    let g = p.gauges();
    assert_eq!(
        p.pages_in_use(),
        g.tree_blocks as usize * LAYERS,
        "pages in use must be exactly the tree-held pages after all frees"
    );
    // The seeded chain must still be adoptable and hold uncorrupted
    // rows after the churn (no cap was set, so nothing was evicted).
    let mut probe = prefix.clone();
    probe.push(999);
    let mut seq = p.lookup_prefix(&probe);
    assert_eq!(seq.shared_toks(), 3 * PAGE, "seed chain lost during stress");
    verify(&p, &seq, &probe);
    p.free_seq(&mut seq);
    // Teardown: trim the whole tree away — every page returns to the
    // free list and the gauges read empty.
    p.set_page_cap(1);
    assert_eq!(p.pages_in_use(), 0, "trim must return every page to the free list");
    let g = p.gauges();
    assert_eq!(g.tree_blocks, 0);
    assert_eq!(g.kv_bytes, 0);
}
