//! PJRT ↔ native parity: the AOT-compiled Pallas kernels (via the xla
//! runtime) must agree with the pure-Rust fused implementations on the
//! same packed weights. Requires `make artifacts`.

use mcsharp::backend::{ExpertBackend, NativeBackend, PjrtBackend};
use mcsharp::config::{ModelConfig, PmqConfig};
use mcsharp::moe::MoeModel;
use mcsharp::otp::OtpRouter;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::runtime::literals::{f32_literal, to_f32, to_i32};
use mcsharp::runtime::Runtime;
use mcsharp::tensor::Tensor2;
use mcsharp::util::rng::Rng;

/// `None` only when this environment genuinely cannot run PJRT — the
/// artifacts were never built (`make artifacts`) or the build links the
/// offline xla stub. Any *other* `Runtime::open_default` error (corrupt
/// manifest, loader regression) still fails loudly so these parity
/// tests cannot go green vacuously.
fn runtime() -> Option<Runtime> {
    let manifest = mcsharp::config::repo_path("artifacts/manifest.json");
    if !std::path::Path::new(&manifest).exists() {
        eprintln!("skipping PJRT integration test: {manifest} missing (run `make artifacts`)");
        return None;
    }
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) if e.to_string().contains("offline xla stub") => {
            eprintln!("skipping PJRT integration test: {e}");
            None
        }
        Err(e) => panic!("artifacts present but runtime failed to open: {e}"),
    }
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn expert_ffn_parity_all_bitwidths() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::load("mix-tiny").unwrap();
    let base = MoeModel::new(&cfg, 123);
    // mixed allocation covering 1/2/3-bit experts
    let mut alloc = vec![vec![2u8; cfg.n_experts]; cfg.n_layers];
    alloc[0][0] = 1;
    alloc[0][1] = 3;
    alloc[0][2] = 2;
    let q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    let native = NativeBackend::quant(&q);
    let pjrt = PjrtBackend::new(&rt, &q, false).unwrap();
    let mut rng = Rng::new(7);
    for &(layer, expert) in &[(0usize, 0usize), (0, 1), (0, 2), (1, 4)] {
        for &t in &[1usize, 4, 16, 30] {
            let x = Tensor2::randn(t, cfg.d_model, &mut rng, 1.0);
            let a = native.expert_batch(layer, expert, &x).unwrap();
            let b = pjrt.expert_batch(layer, expert, &x).unwrap();
            close(&a.data, &b.data, 2e-3, &format!("expert l{layer}e{expert} t{t}"));
        }
    }
}

#[test]
fn gating_artifact_matches_native_route() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::load("mix-tiny").unwrap();
    let base = MoeModel::new(&cfg, 124);
    let mut rng = Rng::new(8);
    let t = 16usize;
    let x = Tensor2::randn(t, cfg.d_model, &mut rng, 1.0);
    let gate = &base.blocks[0].gate;
    let key = format!("mix-tiny_gating_topk_t{t}");
    let outs = rt
        .execute(
            &key,
            &[
                f32_literal(&x.data, &[t, cfg.d_model]).unwrap(),
                f32_literal(&gate.data, &[cfg.d_model, cfg.n_experts]).unwrap(),
            ],
        )
        .unwrap();
    let weights = to_f32(&outs[0]).unwrap();
    let idx = to_i32(&outs[1]).unwrap();
    for i in 0..t {
        let r = mcsharp::moe::route(x.row(i), gate, cfg.top_k);
        for k in 0..cfg.top_k {
            assert_eq!(idx[i * cfg.top_k + k] as usize, r.experts[k], "row {i} rank {k}");
            let w = weights[i * cfg.top_k + k];
            assert!((w - r.weights[k]).abs() < 1e-4, "row {i} rank {k}: {w} vs {}", r.weights[k]);
        }
    }
}

#[test]
fn otp_router_artifact_matches_rust_router() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::load("mix-tiny").unwrap();
    let mut rng = Rng::new(9);
    let router = OtpRouter::new(cfg.d_model, cfg.top_k, &mut rng);
    let t = 4usize;
    let k = cfg.top_k;
    let x = Tensor2::randn(t, cfg.d_model, &mut rng, 1.0);
    let gate_w: Vec<f32> = (0..t * k).map(|_| rng.f32()).collect();
    let noise: Vec<f32> = (0..t * k).map(|_| rng.gumbel()).collect();
    let tau = 1.3f32;
    let key = format!("mix-tiny_otp_router_t{t}");
    let outs = rt
        .execute(
            &key,
            &[
                f32_literal(&x.data, &[t, cfg.d_model]).unwrap(),
                f32_literal(&gate_w, &[t, k]).unwrap(),
                f32_literal(&router.fc1_w.data, &[cfg.d_model, k]).unwrap(),
                f32_literal(&router.fc1_b, &[k]).unwrap(),
                f32_literal(&router.fc2_w.data, &[2 * k, k]).unwrap(),
                f32_literal(&router.fc2_b, &[k]).unwrap(),
                f32_literal(&noise, &[t, k]).unwrap(),
                f32_literal(&[tau], &[1]).unwrap(),
            ],
        )
        .unwrap();
    let y = to_f32(&outs[0]).unwrap();
    let mask = to_f32(&outs[1]).unwrap();
    for i in 0..t {
        let f = router.forward_gumbel(
            x.row(i),
            &gate_w[i * k..(i + 1) * k],
            &noise[i * k..(i + 1) * k],
            tau,
        );
        close(&y[i * k..(i + 1) * k], &f.y, 1e-3, &format!("y row {i}"));
        close(&mask[i * k..(i + 1) * k], &f.mask, 1e-3, &format!("mask row {i}"));
    }
}

#[test]
fn manifest_group_matches_rust_constant() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.group, mcsharp::config::GROUP);
}

#[test]
fn oversize_batch_splits_across_buckets() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::load("mix-tiny").unwrap();
    let base = MoeModel::new(&cfg, 125);
    let alloc = vec![vec![2u8; cfg.n_experts]; cfg.n_layers];
    let q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    let native = NativeBackend::quant(&q);
    let pjrt = PjrtBackend::new(&rt, &q, false).unwrap();
    let mut rng = Rng::new(10);
    let x = Tensor2::randn(100, cfg.d_model, &mut rng, 1.0); // > max bucket 64
    let a = native.expert_batch(0, 0, &x).unwrap();
    let b = pjrt.expert_batch(0, 0, &x).unwrap();
    close(&a.data, &b.data, 2e-3, "oversize split");
}
