//! Paged-KV acceptance tests — the equivalence contract of the
//! chunked-prefill + prefix-sharing engine:
//!
//! * chunked prefill (any `--prefill-chunk`) is **token-identical** to
//!   token-at-a-time prefill, and reaches the first decode in fewer
//!   engine steps;
//! * two requests sharing a prompt prefix produce outputs identical to
//!   fully unshared runs, with `prefix_hit_toks > 0` and fewer total
//!   engine steps (observed over the wire via `METRICS`);
//! * a request diverging *inside* a shared block copy-on-writes: its
//!   own output matches a cold run and the donor's pages are untouched;
//! * the pool recycles freed pages through its free-list — capacity
//!   plateaus across distinct sequential requests.

use std::net::TcpListener;
use std::sync::Mutex;

use mcsharp::backend::NativeBackend;
use mcsharp::config::{ModelConfig, ServingConfig};
use mcsharp::coordinator::client::Client;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel, SeqState};
use mcsharp::coordinator::server;
use mcsharp::moe::MoeModel;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "kv-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 1,
        max_seq_len: 128,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

/// Chunked prefill must not change a single token: for every chunk
/// size, generations match the token-at-a-time (`chunk = 1`) engine
/// exactly — while the chunked engine reaches EOS in fewer steps.
#[test]
fn chunked_prefill_is_token_identical_to_token_at_a_time() {
    let m = MoeModel::new(&tiny_cfg(), 700);
    let be = NativeBackend::fp(&m);
    let prompts: [Vec<u16>; 3] = [
        (1..=20).collect(),            // long: many chunks
        vec![1, 17, 30, 45, 2],        // short: one chunk
        (1..=17).rev().collect(),      // page-misaligned length
    ];
    // reference: token-at-a-time prefill on a fresh engine per run
    let mut want = Vec::new();
    let mut serial_steps = 0u64;
    for p in &prompts {
        let mut eng =
            DecodeEngine::new(EngineModel::Fp(&m), &be, None).with_prefill_chunk(1);
        want.push(eng.generate(p, 6).unwrap());
        serial_steps += eng.metrics.steps;
    }
    for chunk in [2usize, 3, 16] {
        let mut chunked_steps = 0u64;
        for (p, w) in prompts.iter().zip(&want) {
            let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None)
                .with_prefill_chunk(chunk);
            let got = eng.generate(p, 6).unwrap();
            assert_eq!(&got, w, "chunk={chunk} diverged on prompt {p:?}");
            chunked_steps += eng.metrics.steps;
        }
        assert!(
            chunked_steps < serial_steps,
            "chunk={chunk} did not reduce steps: {chunked_steps} !< {serial_steps}"
        );
    }
}

/// Serving-path acceptance: request 2 shares request 1's prompt prefix.
/// Over the wire, both must return exactly what cold (unshared) engines
/// return, while `METRICS` shows `prefix_hit_toks > 0` and fewer total
/// engine steps than two cold runs.
#[test]
fn shared_prefix_matches_unshared_with_fewer_steps_via_metrics() {
    let m = MoeModel::new(&tiny_cfg(), 701);
    let be = NativeBackend::fp(&m);
    let system: Vec<u16> = (1..=9).collect(); // two full 4-blocks (usable 8)
    let p1: Vec<u16> = system.iter().copied().chain([20, 21]).collect();
    let p2: Vec<u16> = system.iter().copied().chain([40, 41]).collect();
    // cold references: fresh pool per prompt, same page/chunk shape
    let mut want = Vec::new();
    let mut cold_steps = 0u64;
    for p in [&p1, &p2] {
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None)
            .with_kv_page(4)
            .with_prefill_chunk(4);
        want.push(eng.generate(p, 5).unwrap());
        cold_steps += eng.metrics.steps;
    }
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sc = ServingConfig { kv_page: 4, prefill_chunk: 4, ..Default::default() };
    std::thread::scope(|s| {
        s.spawn(|| {
            let be = NativeBackend::fp(&m);
            let engine = Mutex::new(
                DecodeEngine::new(EngineModel::Fp(&m), &be, None)
                    .with_kv_page(sc.kv_page)
                    .with_prefill_chunk(sc.prefill_chunk),
            );
            server::serve_with(listener, &engine, &sc, Some(2)).unwrap();
        });
        let mut client = Client::connect(addr).unwrap();
        // sequential: p1's blocks are in the tree before p2 is admitted
        let g1 = client.gen(&p1, 5).unwrap();
        let g2 = client.gen(&p2, 5).unwrap();
        assert_eq!(g1.tokens, want[0], "warm-pool output diverged (request 1)");
        assert_eq!(g2.tokens, want[1], "shared-prefix output diverged (request 2)");
        let v = client.metrics_value().unwrap();
        let hits = v.get("prefix_hit_toks").unwrap().as_f64().unwrap();
        assert!(hits >= 8.0, "expected the 8-token system prefix adopted, got {hits}");
        let steps = v.get("steps").unwrap().as_f64().unwrap() as u64;
        assert!(
            steps < cold_steps,
            "prefix sharing did not save steps: {steps} !< {cold_steps}"
        );
        let pages = v.get("kv_pages").unwrap().as_f64().unwrap();
        assert!(pages > 0.0, "kv gauges must ride METRICS");
    });
}

/// Copy-on-write correctness: a prompt that diverges *inside* a shared
/// block adopts the partial page, then CoWs on its first append — its
/// generation matches a cold engine and the donor's cached prefix
/// still replays token-identically afterwards.
#[test]
fn divergence_inside_shared_block_cows_and_preserves_donor() {
    let m = MoeModel::new(&tiny_cfg(), 702);
    let be = NativeBackend::fp(&m);
    let p1: Vec<u16> = (1..=9).collect(); // blocks [1..4], [5..8], tail 9
    // shares block 1 fully and rows (5, 6) of block 2, diverges at
    // position 6 — the partial-adoption + CoW path
    let p2: Vec<u16> = vec![1, 2, 3, 4, 5, 6, 50];
    let cold = |p: &[u16]| {
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None)
            .with_kv_page(4)
            .with_prefill_chunk(4);
        eng.generate(p, 5).unwrap()
    };
    let (want1, want2) = (cold(&p1), cold(&p2));
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None)
        .with_kv_page(4)
        .with_prefill_chunk(4);
    let pool = eng.kv_pool();
    assert_eq!(eng.generate(&p1, 5).unwrap(), want1);
    let got2 = eng.generate(&p2, 5).unwrap();
    assert_eq!(got2, want2, "CoW run diverged from cold reference");
    let g = pool.lock().unwrap().gauges();
    // 4 full-block tokens + 2 partial rows adopted inside block 2
    assert!(g.prefix_hit_toks >= 6, "partial rows must count as prefix hits");
    assert!(g.cow_copies > 0, "divergent append inside a shared block must CoW");
    // donor pages untouched: replaying p1 still adopts and still matches
    assert_eq!(eng.generate(&p1, 5).unwrap(), want1, "donor prefix corrupted by CoW");
}

/// Free-list recycling at engine level: distinct sub-page prompts leave
/// nothing in the tree, so pages in use returns to zero after each
/// request and in-flight capacity plateaus — steady-state serving stops
/// allocating. Also pins the O(1) byte accounting to page granularity.
#[test]
fn pool_capacity_plateaus_across_distinct_requests() {
    let m = MoeModel::new(&tiny_cfg(), 703);
    let be = NativeBackend::fp(&m);
    // default 16-position pages: prompt(4) + generated(4) = 8 positions
    // fit one page per layer and never complete a block
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let pool = eng.kv_pool();
    let page_bytes = 2 * 16 * 32 * std::mem::size_of::<f32>() as u64;
    let mut inflight = Vec::new();
    for round in 0..3u16 {
        let prompt: Vec<u16> = (0..4).map(|t| 1 + t + round * 13).collect();
        let mut seq = SeqState::new(round as u64, prompt, 4, 2);
        seq.attach_prefix(&mut pool.lock().unwrap());
        while !seq.done() {
            let mut batch = [&mut seq];
            eng.step(&mut batch).unwrap();
        }
        let (in_use, bytes) = {
            let p = pool.lock().unwrap();
            (p.pages_in_use(), p.nbytes())
        };
        assert_eq!(in_use, 2, "one page per layer while live");
        assert_eq!(bytes, in_use as u64 * page_bytes, "bytes = pages x page-bytes");
        inflight.push(in_use);
        pool.lock().unwrap().free_seq(&mut seq.kv);
        assert_eq!(pool.lock().unwrap().pages_in_use(), 0, "round {round} leaked pages");
    }
    assert!(inflight.windows(2).all(|w| w[0] == w[1]), "capacity must plateau");
}
