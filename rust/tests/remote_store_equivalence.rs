//! Remote-store equivalence + sharding smoke suite: a `RemoteStore`
//! paging experts from shard servers over loopback must be
//! **observationally identical** to the all-resident and locally-paged
//! stores — bit-identical eval logits, bit-identical served generations
//! — while provably batching its wire traffic (one `FETCH` per layer
//! miss-set, never per-expert RPCs) and degrading shard death to `ERR`
//! on the affected requests instead of killing the engine.
//!
//! This is the acceptance gate for the multi-node expert sharding
//! refactor: *where* the packed bytes live (RAM, local file, another
//! node) is invisible to every numerical result.

use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mcsharp::backend::NativeBackend;
use mcsharp::config::{ModelConfig, PmqConfig, ServingConfig};
use mcsharp::coordinator::client::{Client, ClientError};
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::{protocol, server};
use mcsharp::moe::model::ForwardOpts;
use mcsharp::moe::MoeModel;
use mcsharp::quant::qcheckpoint::{self, ShardSource};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "remote-eq".into(),
        family: "mixtral".into(),
        vocab_size: 96,
        d_model: 32,
        n_layers: 3,
        n_heads: 2,
        d_ff: 32,
        n_experts: 6,
        top_k: 2,
        n_shared_experts: 1,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

fn tmppath(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("mcsharp-remote-eq-{name}-{}.q2", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Quantize a random model with a mixed allocation and save it as a v2
/// checkpoint (the seek-indexed format shards serve from).
fn save_checkpoint(seed: u64, name: &str) -> String {
    let base = MoeModel::new(&cfg(), seed);
    let alloc = vec![
        vec![2u8, 1, 3, 2, 2, 1],
        vec![3u8, 2, 1, 2, 3, 2],
        vec![2u8, 2, 2, 1, 1, 3],
    ];
    let mut q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    let importance: Vec<Vec<f64>> = (0..3)
        .map(|l| (0..6).map(|e| ((l * 6 + e) as f64 * 0.37).sin().abs() + 0.01).collect())
        .collect();
    q.set_importance(importance);
    let path = tmppath(name);
    qcheckpoint::save(&q, &path).unwrap();
    path
}

/// Spawn a real `serve_shard` on an ephemeral loopback port. The thread
/// is detached and lives for the remainder of the test process.
fn spawn_shard(path: &str, layers: Range<usize>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    spawn_shard_on(listener, path, layers);
    addr
}

fn spawn_shard_on(listener: TcpListener, path: &str, layers: Range<usize>) {
    let source = ShardSource::open(path, layers).unwrap();
    std::thread::spawn(move || {
        let _ = server::serve_shard(listener, &source, None);
    });
}

/// A shard we can kill mid-test: real `ShardSource` records, real
/// FETCH/REC grammar, plus an off switch that closes every socket and
/// stops the listener — indistinguishable from process death to the
/// coordinator on the other end.
struct MortalShard {
    addr: String,
    alive: Arc<AtomicBool>,
}

fn spawn_mortal_shard(path: &str, layers: Range<usize>) -> MortalShard {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let alive = Arc::new(AtomicBool::new(true));
    let source = Arc::new(ShardSource::open(path, layers).unwrap());
    let flag = alive.clone();
    std::thread::spawn(move || {
        listener.set_nonblocking(true).unwrap();
        loop {
            if !flag.load(Ordering::Acquire) {
                return; // listener drops: reconnects now refused
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let (src, f) = (source.clone(), flag.clone());
                    std::thread::spawn(move || {
                        let _ = mortal_conn(stream, &src, &f);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(_) => return,
            }
        }
    });
    MortalShard { addr, alive }
}

fn mortal_conn(
    stream: TcpStream,
    source: &ShardSource,
    alive: &AtomicBool,
) -> std::io::Result<()> {
    use std::io::{BufRead, BufReader, Write};
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(20)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        if !alive.load(Ordering::Acquire) {
            return Ok(()); // sockets drop here: the "kill"
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
        match protocol::parse_command(&line) {
            Ok(protocol::Command::Stats) => {
                let l = source.layers();
                write!(
                    out,
                    "STATS kind=shard layers={}..{} n_experts={} fetches=0\n",
                    l.start,
                    l.end,
                    source.n_experts()
                )?;
            }
            Ok(protocol::Command::Fetch(wf)) => {
                for &e in &wf.experts {
                    let span = source.record_span(wf.layer, e).unwrap();
                    out.write_all(
                        protocol::format_rec(wf.tag, wf.layer, e, span.len()).as_bytes(),
                    )?;
                    out.write_all(span)?;
                }
            }
            Ok(protocol::Command::Quit) => return Ok(()),
            _ => write!(out, "ERR msg=mortal shard: unsupported\n")?,
        }
    }
}

/// Eval logits and perplexity bit-identical across resident, paged and
/// remote stores — plus the batching proof: the first forward issues
/// exactly one demand `FETCH` per layer while fetching several experts
/// per layer (per-expert RPCs would make `fetch_rpcs == misses`).
#[test]
fn eval_logits_bit_identical_across_three_stores() {
    let path = save_checkpoint(410, "eval");
    let resident = qcheckpoint::load(&path).unwrap();
    let total = resident.store.total_nbytes();
    let paged = qcheckpoint::load_paged(&path, total * 3 / 5).unwrap();
    let shards = vec![spawn_shard(&path, 0..2), spawn_shard(&path, 2..3)];
    let remote = qcheckpoint::load_remote(&path, &shards, u64::MAX, 2_000).unwrap();

    let seqs: Vec<Vec<u16>> = (0..4)
        .map(|s| (0..24).map(|i| ((i * 7 + s * 13) % 90 + 1) as u16).collect())
        .collect();

    // first forward = the batching proof: no prefetch history yet, so
    // every record arrives via demand FETCHes — one per layer
    let a = resident.model.forward_opts(
        &seqs[0],
        &mut ForwardOpts { provider: Some(&resident), ..Default::default() },
    );
    let c = remote.model.forward_opts(
        &seqs[0],
        &mut ForwardOpts { provider: Some(&remote), ..Default::default() },
    );
    assert_eq!(a.data, c.data, "remote eval diverged from resident");
    let r = remote.store.remote_stats().expect("remote store reports fetch stats");
    let cc = remote.store.counters();
    assert_eq!(
        r.fetch_rpcs, 3,
        "each layer's routed miss-set must be ONE batched FETCH: {r:?}"
    );
    assert!(
        cc.misses > r.fetch_rpcs,
        "several experts per RPC (batched, not per-expert): {cc:?} vs {r:?}"
    );
    assert!(r.fetched_bytes > 0);
    assert_eq!((r.shards_up, r.shards_total), (2, 2));

    // rest of the suite: all three stores agree bit-for-bit
    for toks in &seqs {
        let a = resident.model.forward_opts(
            toks,
            &mut ForwardOpts { provider: Some(&resident), ..Default::default() },
        );
        let b = paged.model.forward_opts(
            toks,
            &mut ForwardOpts { provider: Some(&paged), ..Default::default() },
        );
        let c = remote.model.forward_opts(
            toks,
            &mut ForwardOpts { provider: Some(&remote), ..Default::default() },
        );
        assert_eq!(a.data, b.data, "paged eval diverged from resident");
        assert_eq!(a.data, c.data, "remote eval diverged from resident");
    }
    let ppl_r = resident.model.perplexity(
        &seqs,
        &mut ForwardOpts { provider: Some(&resident), ..Default::default() },
    );
    let ppl_m = remote.model.perplexity(
        &seqs,
        &mut ForwardOpts { provider: Some(&remote), ..Default::default() },
    );
    assert_eq!(ppl_r.to_bits(), ppl_m.to_bits());
    std::fs::remove_file(&path).ok();
}

/// Served generations bit-identical between a resident engine and a
/// remote engine running under a byte budget smaller than the total —
/// eviction and re-fetch over the wire must not change a single token.
#[test]
fn served_generations_bit_identical_under_budget() {
    let path = save_checkpoint(411, "serve");
    let resident = qcheckpoint::load(&path).unwrap();
    let total = resident.store.total_nbytes();
    let budget = total * 3 / 5;
    let shards = vec![spawn_shard(&path, 0..2), spawn_shard(&path, 2..3)];
    let remote = qcheckpoint::load_remote(&path, &shards, budget, 2_000).unwrap();

    let be_r = NativeBackend::quant(&resident);
    let be_m = NativeBackend::quant(&remote);
    let mut eng_r = DecodeEngine::new(EngineModel::Quant(&resident), &be_r, None);
    let mut eng_m = DecodeEngine::new(EngineModel::Quant(&remote), &be_m, None);
    for s in 0..4u16 {
        let prompt = vec![1, 10 + s * 9, 40 + s * 5, 7];
        let a = eng_r.generate(&prompt, 8).unwrap();
        let b = eng_m.generate(&prompt, 8).unwrap();
        assert_eq!(a, b, "remote-served generation diverged for seed {s}");
    }
    // identical dispatch accounting: the store must not change routing
    assert_eq!(eng_r.metrics.experts_kept, eng_m.metrics.experts_kept);
    assert_eq!(eng_r.metrics.routed_bytes, eng_m.metrics.routed_bytes);
    // the remote engine surfaced its gauges through the metrics
    let c = eng_m.metrics.cache.expect("remote engine exposes cache gauges");
    assert!(c.misses > 0, "budget below total must page: {c:?}");
    assert!(c.peak_resident_bytes <= budget, "budget {budget} violated: {c:?}");
    let r = eng_m.metrics.remote.expect("remote engine exposes fetch gauges");
    assert!(r.fetch_rpcs > 0 && r.fetched_bytes > 0, "{r:?}");
    std::fs::remove_file(&path).ok();
}

/// The CI sharding smoke test: coordinator + two shard servers over
/// loopback, driven end-to-end through the real wire protocol — served
/// tokens match a single-node resident engine, and the remote-fetch
/// gauges show up on `STATS` and `METRICS`.
#[test]
fn sharding_smoke_coordinator_plus_two_shards() {
    let path = save_checkpoint(412, "smoke");
    let resident = qcheckpoint::load(&path).unwrap();
    let prompt = vec![1u16, 23, 41, 7];
    let be_r = NativeBackend::quant(&resident);
    let mut eng_r = DecodeEngine::new(EngineModel::Quant(&resident), &be_r, None);
    let want = eng_r.generate(&prompt, 6).unwrap();

    let shards = vec![spawn_shard(&path, 0..2), spawn_shard(&path, 2..3)];
    let remote = qcheckpoint::load_remote(&path, &shards, u64::MAX, 2_000).unwrap();
    let be = NativeBackend::quant(&remote);
    let engine = Mutex::new(DecodeEngine::new(EngineModel::Quant(&remote), &be, None));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sc = ServingConfig::default();
    std::thread::scope(|s| {
        s.spawn(|| {
            server::serve_with(listener, &engine, &sc, Some(2)).unwrap();
        });
        let mut cl = Client::connect(addr).unwrap();
        let out = cl.gen(&prompt, 6).unwrap();
        assert_eq!(out.tokens, want, "sharded serving diverged from single-node");
        // remote-fetch observability on both scrape surfaces
        assert_eq!(cl.stats_field("shards_total").unwrap(), 2.0);
        assert_eq!(cl.stats_field("shards_up").unwrap(), 2.0);
        assert!(cl.stats_field("remote_fetch_rpcs").unwrap() > 0.0);
        assert!(cl.stats_field("remote_fetched_bytes").unwrap() > 0.0);
        let m = cl.metrics_value().unwrap();
        assert!(m.get("remote_fetch_rpcs").unwrap().as_f64().unwrap() > 0.0);
        assert!(m.get("shards_up").unwrap().as_f64().unwrap() == 2.0);
        let out2 = cl.gen(&prompt, 6).unwrap();
        assert_eq!(out2.tokens, want);
        cl.quit().unwrap();
    });
    std::fs::remove_file(&path).ok();
}

/// Killing a shard mid-stream degrades the routed requests to `ERR` —
/// the engine thread survives (the control plane keeps answering, and
/// after the shard restarts on the same address, generation resumes
/// bit-identically through lazy reconnection).
#[test]
fn shard_death_degrades_to_err_and_heals_on_restart() {
    let path = save_checkpoint(413, "kill");
    let shard_a = spawn_shard(&path, 0..2);
    let mortal = spawn_mortal_shard(&path, 2..3);
    let shards = vec![shard_a, mortal.addr.clone()];
    let remote = qcheckpoint::load_remote(&path, &shards, u64::MAX, 300).unwrap();
    let be = NativeBackend::quant(&remote);
    let engine = Mutex::new(DecodeEngine::new(EngineModel::Quant(&remote), &be, None));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sc = ServingConfig::default();
    std::thread::scope(|s| {
        s.spawn(|| {
            server::serve_with(listener, &engine, &sc, Some(2)).unwrap();
        });
        let mut cl = Client::connect(addr).unwrap();
        let prompt = vec![1u16, 30, 55, 9];
        let healthy = cl.gen(&prompt, 6).unwrap();

        // kill the layer-2 shard; new routed experts are now unfetchable
        mortal.alive.store(false, Ordering::Release);
        std::thread::sleep(Duration::from_millis(100)); // sockets drop
        remote.store.clear_cache(); // force the next request to fetch
        let t0 = Instant::now();
        let err = cl.gen(&prompt, 6).unwrap_err();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shard death must not stall the engine"
        );
        match err.downcast_ref::<ClientError>() {
            Some(ClientError::Rejected { msg, .. }) => {
                assert!(
                    msg.contains("expert fetch failed"),
                    "ERR should name the fetch failure: {msg}"
                );
            }
            other => panic!("expected a tagged ERR, got {other:?} ({err:#})"),
        }
        // the engine thread survived: the control plane still answers
        // and the gauges report the outage
        cl.ping().unwrap();
        assert_eq!(cl.stats_field("shards_up").unwrap(), 1.0);
        assert_eq!(cl.stats_field("shards_total").unwrap(), 2.0);

        // restart the shard on the SAME address: the next fetch lazily
        // reconnects and serving resumes bit-identically
        let listener = TcpListener::bind(&mortal.addr).unwrap();
        spawn_shard_on(listener, &path, 2..3);
        let back = cl.gen(&prompt, 6).unwrap();
        assert_eq!(back.tokens, healthy.tokens, "post-restart generation diverged");
        cl.quit().unwrap();
    });
    std::fs::remove_file(&path).ok();
}
