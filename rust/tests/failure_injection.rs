//! Failure-injection integration tests: every loading path must turn
//! corrupted or hostile inputs into `Err` (never panics, never silent
//! garbage), and runtime guardrails must hold under adversarial pruners,
//! degenerate batcher limits, and misbehaving expert shards (stalls,
//! connection drops, overload backpressure).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use mcsharp::backend::NativeBackend;
use mcsharp::config::{ModelConfig, PmqConfig, ServingConfig};
use mcsharp::coordinator::batcher::Batcher;
use mcsharp::coordinator::client::{Client, ClientError};
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::request::GenRequest;
use mcsharp::coordinator::scheduler::Scheduler;
use mcsharp::coordinator::{protocol, server};
use mcsharp::moe::gating::Route;
use mcsharp::moe::model::Pruner;
use mcsharp::moe::MoeModel;
use mcsharp::quant::qcheckpoint::{self, ShardSource};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::runtime::Runtime;
use mcsharp::util::json::Value;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "fail-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 0,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

fn tmpdir(name: &str) -> String {
    let d = std::env::temp_dir().join(format!("mcsharp-fail-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------- checkpoints

#[test]
fn truncated_checkpoint_is_an_error() {
    let dir = tmpdir("ckpt");
    let path = format!("{dir}/m.bin");
    let m = MoeModel::new(&tiny_cfg(), 1);
    m.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // cut the file in half — load must fail, not return a half-model
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(MoeModel::load(&path).is_err(), "truncated checkpoint loaded");
}

#[test]
fn garbage_checkpoint_is_an_error() {
    let dir = tmpdir("ckpt2");
    let path = format!("{dir}/m.bin");
    std::fs::write(&path, b"not a checkpoint at all").unwrap();
    assert!(MoeModel::load(&path).is_err());
}

#[test]
fn checkpoint_roundtrip_after_failure_paths_still_works() {
    let dir = tmpdir("ckpt3");
    let path = format!("{dir}/m.bin");
    let m = MoeModel::new(&tiny_cfg(), 2);
    m.save(&path).unwrap();
    let m2 = MoeModel::load(&path).unwrap();
    assert_eq!(m.cfg, m2.cfg);
    assert_eq!(m.embed.data, m2.embed.data);
}

// ------------------------------------------------------------------- configs

#[test]
fn malformed_config_json_is_an_error() {
    for bad in [
        "",                           // empty
        "{",                          // unbalanced
        "[1, 2, 3]",                  // wrong top-level type for a config
        "{\"name\": \"x\"}",          // missing required keys
        "{\"name\": 3, \"family\": \"f\"}", // wrong type
    ] {
        let parsed = Value::parse(bad);
        let cfg = parsed.and_then(|v| ModelConfig::from_json(&v));
        assert!(cfg.is_err(), "accepted malformed config: {bad:?}");
    }
}

#[test]
fn unknown_model_name_is_an_error() {
    assert!(ModelConfig::load("no-such-model").is_err());
}

// ------------------------------------------------------------------ artifacts

#[test]
fn missing_manifest_is_an_error() {
    let dir = tmpdir("noart");
    assert!(Runtime::open(&dir).is_err());
}

#[test]
fn corrupt_manifest_is_an_error() {
    let dir = tmpdir("badman");
    std::fs::write(format!("{dir}/manifest.json"), "{\"group\": \"not a number\"}").unwrap();
    assert!(Runtime::open(&dir).is_err());
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_panic() {
    // copy the real manifest but point one artifact at corrupted HLO text
    let real = mcsharp::config::repo_path("artifacts");
    let rt = match Runtime::open(&real) {
        Ok(rt) => rt,
        Err(_) => return, // artifacts not built in this environment — skip
    };
    let Some(key) = rt.manifest.artifacts.keys().next().cloned() else {
        return;
    };
    let dir = tmpdir("badhlo");
    std::fs::copy(
        format!("{real}/manifest.json"),
        format!("{dir}/manifest.json"),
    )
    .unwrap();
    for meta in rt.manifest.artifacts.values() {
        std::fs::write(format!("{}/{}", dir, meta.file), "HloModule garbage !!").unwrap();
    }
    let bad = Runtime::open(&dir).unwrap(); // manifest itself is fine
    assert!(bad.warmup(&key).is_err(), "corrupt HLO text compiled");
}

#[test]
fn unknown_artifact_key_is_an_error() {
    let real = mcsharp::config::repo_path("artifacts");
    if let Ok(rt) = Runtime::open(&real) {
        assert!(rt.meta("definitely/not/an/artifact").is_err());
        assert!(rt.warmup("definitely/not/an/artifact").is_err());
    }
}

// ----------------------------------------------------------- runtime guards

/// A hostile pruner that always answers 0 (and sometimes > k): the engine
/// must clamp to [1, k] so every token keeps at least one expert.
struct HostilePruner {
    calls: u64,
}

impl Pruner for HostilePruner {
    fn keep(&mut self, _layer: usize, _x: &[f32], route: &Route) -> usize {
        self.calls += 1;
        if self.calls % 2 == 0 {
            0
        } else {
            route.experts.len() + 7
        }
    }
}

#[test]
fn engine_clamps_hostile_pruner() {
    let m = MoeModel::new(&tiny_cfg(), 3);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(
        EngineModel::Fp(&m),
        &be,
        Some(Box::new(HostilePruner { calls: 0 })),
    );
    let out = eng.generate(&[1, 2, 3], 5).unwrap();
    assert_eq!(out.len(), 8);
    // kept experts stayed within [1, k] per token: totals bounded
    let steps = eng.metrics.experts_offered / tiny_cfg().top_k as u64 / 2; // layers
    assert!(eng.metrics.experts_kept >= steps, "some token kept zero experts");
    assert!(eng.metrics.experts_kept <= eng.metrics.experts_offered);
}

#[test]
fn batcher_zero_sized_limits_still_progress() {
    let m = MoeModel::new(&tiny_cfg(), 4);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    // max_batch 1, token budget 0: force-admission path must still drain
    let mut b = Batcher::new(1, 0);
    for i in 0..3 {
        b.submit(GenRequest::greedy(i, vec![1, 2], 2));
    }
    let results = b.run(&mut eng).unwrap();
    assert_eq!(results.len(), 3);
    assert!(results.iter().all(|r| r.tokens.len() == 4));
}

#[test]
fn empty_prompt_rejected_or_handled() {
    let m = MoeModel::new(&tiny_cfg(), 5);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    // an empty prompt has no conditioning token; engine treats position 0
    // as the first token — must not panic either way
    let mut b = Batcher::new(2, 64);
    b.submit(GenRequest::greedy(0, vec![1], 3));
    let results = b.run(&mut eng).unwrap();
    assert_eq!(results[0].tokens.len(), 4);
}

#[test]
fn out_of_vocab_token_does_not_corrupt_neighbours() {
    // tokens are u16; vocab is 64 — the embed lookup clamps/mods or the
    // model must error. Either way the *other* sequences in the batch
    // must be unaffected. We verify by comparing against solo runs.
    let m = MoeModel::new(&tiny_cfg(), 6);
    let be = NativeBackend::fp(&m);
    let clean = vec![1u16, 9, 3];
    let mut solo = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = solo.generate(&clean, 4).unwrap();
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let mut b = Batcher::new(2, 256);
    b.submit(GenRequest::greedy(0, clean.clone(), 4));
    b.submit(GenRequest::greedy(1, vec![1, 63, 2], 4)); // max valid id
    let mut results = b.run(&mut eng).unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results[0].tokens, want);
}

// ------------------------------------------------------------ expert shards

/// Quantize the tiny model and save a v2 (seek-indexed) checkpoint that
/// shard servers can serve records from.
fn quant_ckpt(name: &str, seed: u64) -> String {
    let m = MoeModel::new(&tiny_cfg(), seed);
    let alloc = vec![vec![2u8, 1, 3, 2], vec![3u8, 2, 1, 2]];
    let mut q = QuantModel::quantize(&m, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
    let importance: Vec<Vec<f64>> = (0..2)
        .map(|l| (0..4).map(|e| ((l * 4 + e) as f64 * 0.41).sin().abs() + 0.01).collect())
        .collect();
    q.set_importance(importance);
    let path = format!("{}/q.q2", tmpdir(name));
    qcheckpoint::save(&q, &path).unwrap();
    path
}

/// A shard that answers the connect-time `STATS` probe and then swallows
/// every `FETCH` without replying — the coordinator's per-fetch read
/// timeout is the only thing standing between a stall and a hung engine.
fn spawn_stalling_shard(layers: Range<usize>, n_experts: usize) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { return };
            let layers = layers.clone();
            std::thread::spawn(move || {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut out = stream;
                let mut line = String::new();
                loop {
                    line.clear();
                    if reader.read_line(&mut line).unwrap_or(0) == 0 {
                        return;
                    }
                    if line.starts_with("STATS") {
                        let _ = write!(
                            out,
                            "STATS kind=shard layers={}..{} n_experts={n_experts} fetches=0\n",
                            layers.start, layers.end
                        );
                    }
                    // FETCH: swallowed on purpose — never answered
                }
            });
        }
    });
    addr
}

/// A correct shard with an off switch: flipping `alive` closes every
/// connection and the listener, indistinguishable from process death.
struct KillableShard {
    addr: String,
    alive: Arc<AtomicBool>,
}

fn spawn_killable_shard(path: &str, layers: Range<usize>) -> KillableShard {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let alive = Arc::new(AtomicBool::new(true));
    let source = Arc::new(ShardSource::open(path, layers).unwrap());
    let flag = alive.clone();
    std::thread::spawn(move || {
        listener.set_nonblocking(true).unwrap();
        loop {
            if !flag.load(Ordering::Acquire) {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let (src, f) = (source.clone(), flag.clone());
                    std::thread::spawn(move || {
                        let _ = killable_conn(stream, &src, &f);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(_) => return,
            }
        }
    });
    KillableShard { addr, alive }
}

fn killable_conn(
    stream: TcpStream,
    source: &ShardSource,
    alive: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(20)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        if !alive.load(Ordering::Acquire) {
            return Ok(()); // socket drops here: the "kill"
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
        match protocol::parse_command(&line) {
            Ok(protocol::Command::Stats) => {
                let l = source.layers();
                write!(
                    out,
                    "STATS kind=shard layers={}..{} n_experts={} fetches=0\n",
                    l.start,
                    l.end,
                    source.n_experts()
                )?;
            }
            Ok(protocol::Command::Fetch(wf)) => {
                for &e in &wf.experts {
                    let span = source.record_span(wf.layer, e).unwrap();
                    out.write_all(
                        protocol::format_rec(wf.tag, wf.layer, e, span.len()).as_bytes(),
                    )?;
                    out.write_all(span)?;
                }
            }
            _ => write!(out, "ERR msg=unsupported\n")?,
        }
    }
}

/// A stalled expert fetch must degrade to a failed *request* within the
/// fetch timeout — never a hung engine. The loop survives: it still
/// accepts new work afterwards (a fatal engine error would flip the
/// scheduler to draining and reject submissions), and it exits cleanly
/// through shutdown instead of dying with an error.
#[test]
fn stalled_shard_fetch_times_out_and_loop_keeps_serving() {
    let path = quant_ckpt("stall", 40);
    let shard = spawn_stalling_shard(0..2, 4);
    let remote = qcheckpoint::load_remote(&path, &[shard], u64::MAX, 150).unwrap();
    let be = NativeBackend::quant(&remote);
    let engine = Mutex::new(DecodeEngine::new(EngineModel::Quant(&remote), &be, None));
    let sched = Scheduler::new(Batcher::new(2, 256));
    std::thread::scope(|s| {
        let loop_thread = s.spawn(|| sched.run_engine(&engine));
        let t0 = Instant::now();
        let rx = sched.submit(GenRequest::greedy(0, vec![1, 2, 3], 4)).unwrap();
        assert!(rx.recv().is_err(), "stalled fetch must fail the request, not hang");
        assert!(t0.elapsed() < Duration::from_secs(10), "degradation must be prompt");
        // still accepting: the outage was contained, not fatal
        let rx2 = sched.submit(GenRequest::greedy(1, vec![1, 5, 2], 4)).unwrap();
        assert!(rx2.recv().is_err(), "shard is still stalled; request must fail");
        sched.shutdown();
        let served = loop_thread
            .join()
            .unwrap()
            .expect("engine loop must exit cleanly, not die");
        assert_eq!(served, 0);
    });
}

/// A dropped shard connection fails only the sequences that *need* a
/// fetch: a prompt whose routed experts are already cache-resident keeps
/// generating bit-identically with the shard dead, while a cold cache
/// surfaces the recoverable `FetchUnavailable` classification.
#[test]
fn shard_connection_drop_fails_only_uncached_sequences() {
    let path = quant_ckpt("drop", 41);
    let shard = spawn_killable_shard(&path, 0..2);
    let remote =
        qcheckpoint::load_remote(&path, &[shard.addr.clone()], u64::MAX, 300).unwrap();
    let be = NativeBackend::quant(&remote);
    let mut eng = DecodeEngine::new(EngineModel::Quant(&remote), &be, None);
    let g1 = eng.generate(&[1, 7, 3], 6).unwrap();

    shard.alive.store(false, Ordering::Release);
    std::thread::sleep(Duration::from_millis(80)); // sockets drop
    // same prompt ⇒ same routes ⇒ all hits: generation is unaffected
    let g2 = eng.generate(&[1, 7, 3], 6).unwrap();
    assert_eq!(g1, g2, "cache-resident sequence must not notice the dead shard");

    // force residency misses: now the drop is a recoverable fetch error
    remote.store.clear_cache();
    let err = eng.generate(&[1, 7, 3], 6).unwrap_err();
    assert!(
        mcsharp::quant::remote::is_fetch_unavailable(&err),
        "shard death must classify as FetchUnavailable, got: {err:#}"
    );
}

/// `gen_with_retry` against a real `max_queue = 1` server: with one
/// sequence wedged in the engine (the test holds the engine mutex) and
/// one filling the queue, a plain `gen` is refused with `BUSY`, while
/// `gen_with_retry` rides the backoff out and completes — strictly after
/// the engine is released.
#[test]
fn gen_with_retry_waits_out_busy_queue() {
    let m = MoeModel::new(&tiny_cfg(), 42);
    let be = NativeBackend::fp(&m);
    let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sc = ServingConfig { max_batch: 1, max_queue: 1, ..Default::default() };
    std::thread::scope(|s| {
        s.spawn(|| {
            server::serve_with(listener, &engine, &sc, Some(4)).unwrap();
        });
        let mut a = Client::connect(addr).unwrap();
        // warm-up round trip: proves the engine loop is past its startup
        // engine-lock and idle, so the wedge below cannot block startup
        a.gen(&[1, 2], 1).unwrap();
        // wedge the engine: admission keeps running (scheduler lock), but
        // no step can complete until we let go
        let guard = engine.lock().unwrap();
        let t0 = a.submit(&[1, 5, 9], 4).unwrap(); // admitted, then wedged
        std::thread::sleep(Duration::from_millis(80));
        let t1 = a.submit(&[1, 6, 9], 4).unwrap(); // fills max_queue = 1
        std::thread::sleep(Duration::from_millis(80));

        // queue is provably full: a plain gen bounces with BUSY
        let mut b = Client::connect(addr).unwrap();
        let err = b.gen(&[1, 7, 9], 4).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ClientError>(), Some(ClientError::Busy { .. })),
            "expected BUSY against a full queue, got: {err:#}"
        );

        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let retry = s.spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            tx.send(()).unwrap();
            let out = c.gen_with_retry(&[1, 7, 9], 4, Duration::from_secs(20)).unwrap();
            let done = Instant::now();
            c.quit().unwrap();
            (out, done)
        });
        rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(60)); // a few BUSY rounds
        let released = Instant::now();
        drop(guard);

        let (out, done) = retry.join().unwrap();
        assert!(done >= released, "retry cannot succeed while the engine is wedged");
        assert_eq!(out.tokens.len(), 7, "retried request must complete normally");
        // the wedged and queued requests drained too
        let got = a.collect_tags(&[t0, t1]).unwrap();
        assert_eq!(got[&t0].tokens.len(), 7);
        assert_eq!(got[&t1].tokens.len(), 7);
    });
}
