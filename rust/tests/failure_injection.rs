//! Failure-injection integration tests: every loading path must turn
//! corrupted or hostile inputs into `Err` (never panics, never silent
//! garbage), and runtime guardrails must hold under adversarial pruners
//! and degenerate batcher limits.

use mcsharp::backend::NativeBackend;
use mcsharp::config::ModelConfig;
use mcsharp::coordinator::batcher::Batcher;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::request::GenRequest;
use mcsharp::moe::gating::Route;
use mcsharp::moe::model::Pruner;
use mcsharp::moe::MoeModel;
use mcsharp::runtime::Runtime;
use mcsharp::util::json::Value;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "fail-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 0,
        max_seq_len: 64,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

fn tmpdir(name: &str) -> String {
    let d = std::env::temp_dir().join(format!("mcsharp-fail-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------- checkpoints

#[test]
fn truncated_checkpoint_is_an_error() {
    let dir = tmpdir("ckpt");
    let path = format!("{dir}/m.bin");
    let m = MoeModel::new(&tiny_cfg(), 1);
    m.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // cut the file in half — load must fail, not return a half-model
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(MoeModel::load(&path).is_err(), "truncated checkpoint loaded");
}

#[test]
fn garbage_checkpoint_is_an_error() {
    let dir = tmpdir("ckpt2");
    let path = format!("{dir}/m.bin");
    std::fs::write(&path, b"not a checkpoint at all").unwrap();
    assert!(MoeModel::load(&path).is_err());
}

#[test]
fn checkpoint_roundtrip_after_failure_paths_still_works() {
    let dir = tmpdir("ckpt3");
    let path = format!("{dir}/m.bin");
    let m = MoeModel::new(&tiny_cfg(), 2);
    m.save(&path).unwrap();
    let m2 = MoeModel::load(&path).unwrap();
    assert_eq!(m.cfg, m2.cfg);
    assert_eq!(m.embed.data, m2.embed.data);
}

// ------------------------------------------------------------------- configs

#[test]
fn malformed_config_json_is_an_error() {
    for bad in [
        "",                           // empty
        "{",                          // unbalanced
        "[1, 2, 3]",                  // wrong top-level type for a config
        "{\"name\": \"x\"}",          // missing required keys
        "{\"name\": 3, \"family\": \"f\"}", // wrong type
    ] {
        let parsed = Value::parse(bad);
        let cfg = parsed.and_then(|v| ModelConfig::from_json(&v));
        assert!(cfg.is_err(), "accepted malformed config: {bad:?}");
    }
}

#[test]
fn unknown_model_name_is_an_error() {
    assert!(ModelConfig::load("no-such-model").is_err());
}

// ------------------------------------------------------------------ artifacts

#[test]
fn missing_manifest_is_an_error() {
    let dir = tmpdir("noart");
    assert!(Runtime::open(&dir).is_err());
}

#[test]
fn corrupt_manifest_is_an_error() {
    let dir = tmpdir("badman");
    std::fs::write(format!("{dir}/manifest.json"), "{\"group\": \"not a number\"}").unwrap();
    assert!(Runtime::open(&dir).is_err());
}

#[test]
fn corrupt_hlo_text_fails_at_compile_not_panic() {
    // copy the real manifest but point one artifact at corrupted HLO text
    let real = mcsharp::config::repo_path("artifacts");
    let rt = match Runtime::open(&real) {
        Ok(rt) => rt,
        Err(_) => return, // artifacts not built in this environment — skip
    };
    let Some(key) = rt.manifest.artifacts.keys().next().cloned() else {
        return;
    };
    let dir = tmpdir("badhlo");
    std::fs::copy(
        format!("{real}/manifest.json"),
        format!("{dir}/manifest.json"),
    )
    .unwrap();
    for meta in rt.manifest.artifacts.values() {
        std::fs::write(format!("{}/{}", dir, meta.file), "HloModule garbage !!").unwrap();
    }
    let bad = Runtime::open(&dir).unwrap(); // manifest itself is fine
    assert!(bad.warmup(&key).is_err(), "corrupt HLO text compiled");
}

#[test]
fn unknown_artifact_key_is_an_error() {
    let real = mcsharp::config::repo_path("artifacts");
    if let Ok(rt) = Runtime::open(&real) {
        assert!(rt.meta("definitely/not/an/artifact").is_err());
        assert!(rt.warmup("definitely/not/an/artifact").is_err());
    }
}

// ----------------------------------------------------------- runtime guards

/// A hostile pruner that always answers 0 (and sometimes > k): the engine
/// must clamp to [1, k] so every token keeps at least one expert.
struct HostilePruner {
    calls: u64,
}

impl Pruner for HostilePruner {
    fn keep(&mut self, _layer: usize, _x: &[f32], route: &Route) -> usize {
        self.calls += 1;
        if self.calls % 2 == 0 {
            0
        } else {
            route.experts.len() + 7
        }
    }
}

#[test]
fn engine_clamps_hostile_pruner() {
    let m = MoeModel::new(&tiny_cfg(), 3);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(
        EngineModel::Fp(&m),
        &be,
        Some(Box::new(HostilePruner { calls: 0 })),
    );
    let out = eng.generate(&[1, 2, 3], 5).unwrap();
    assert_eq!(out.len(), 8);
    // kept experts stayed within [1, k] per token: totals bounded
    let steps = eng.metrics.experts_offered / tiny_cfg().top_k as u64 / 2; // layers
    assert!(eng.metrics.experts_kept >= steps, "some token kept zero experts");
    assert!(eng.metrics.experts_kept <= eng.metrics.experts_offered);
}

#[test]
fn batcher_zero_sized_limits_still_progress() {
    let m = MoeModel::new(&tiny_cfg(), 4);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    // max_batch 1, token budget 0: force-admission path must still drain
    let mut b = Batcher::new(1, 0);
    for i in 0..3 {
        b.submit(GenRequest::greedy(i, vec![1, 2], 2));
    }
    let results = b.run(&mut eng).unwrap();
    assert_eq!(results.len(), 3);
    assert!(results.iter().all(|r| r.tokens.len() == 4));
}

#[test]
fn empty_prompt_rejected_or_handled() {
    let m = MoeModel::new(&tiny_cfg(), 5);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    // an empty prompt has no conditioning token; engine treats position 0
    // as the first token — must not panic either way
    let mut b = Batcher::new(2, 64);
    b.submit(GenRequest::greedy(0, vec![1], 3));
    let results = b.run(&mut eng).unwrap();
    assert_eq!(results[0].tokens.len(), 4);
}

#[test]
fn out_of_vocab_token_does_not_corrupt_neighbours() {
    // tokens are u16; vocab is 64 — the embed lookup clamps/mods or the
    // model must error. Either way the *other* sequences in the batch
    // must be unaffected. We verify by comparing against solo runs.
    let m = MoeModel::new(&tiny_cfg(), 6);
    let be = NativeBackend::fp(&m);
    let clean = vec![1u16, 9, 3];
    let mut solo = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = solo.generate(&clean, 4).unwrap();
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let mut b = Batcher::new(2, 256);
    b.submit(GenRequest::greedy(0, clean.clone(), 4));
    b.submit(GenRequest::greedy(1, vec![1, 63, 2], 4)); // max valid id
    let mut results = b.run(&mut eng).unwrap();
    results.sort_by_key(|r| r.id);
    assert_eq!(results[0].tokens, want);
}
