//! Wire-protocol v1 acceptance tests, driven end-to-end over TCP
//! through the first-class [`Client`]:
//!
//! * one connection pipelining N `GEN`s gets token-identical greedy
//!   results to serial submission with strictly fewer engine steps;
//! * a saturated admission queue answers `BUSY` immediately while
//!   in-flight requests complete;
//! * `stream=1` emits one `TOK` per generated token ahead of the
//!   terminal `OK`;
//! * v0 and v1 traffic interleave on one connection, v0 byte-identical
//!   to the legacy protocol;
//! * malformed / oversized / partial lines produce `ERR` and leave the
//!   connection usable (never a hang, panic, or silent drop);
//! * `TRACE` dumps the span ring of a served `GEN` as valid JSON lines,
//!   `last=` truncation and the ring capacity both bound the dump;
//! * the `--trace-out` artifact is valid Chrome trace_event JSON whose
//!   request spans temporally contain the engine's step-phase spans.

use std::net::TcpListener;
use std::sync::Mutex;

use mcsharp::backend::NativeBackend;
use mcsharp::config::{ModelConfig, ServingConfig};
use mcsharp::coordinator::client::{Client, GenOpts};
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::protocol::Response;
use mcsharp::coordinator::server;
use mcsharp::moe::MoeModel;
use mcsharp::util::json::Value;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "proto-test".into(),
        family: "mixtral".into(),
        vocab_size: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        n_experts: 4,
        top_k: 2,
        n_shared_experts: 0,
        // roomy: the backpressure test keeps one long sequence decoding
        // while shorter requests probe the queue bound
        max_seq_len: 256,
        rope_theta: 10_000.0,
        modalities: 1,
        buckets: vec![4],
    }
}

fn serve_on<'m>(
    s: &'m std::thread::Scope<'m, '_>,
    m: &'m MoeModel,
    sc: ServingConfig,
    max_requests: Option<usize>,
) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    s.spawn(move || {
        let be = NativeBackend::fp(m);
        let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(m), &be, None));
        server::serve_with(listener, &engine, &sc, max_requests).unwrap();
    });
    addr
}

/// THE tentpole acceptance test: a single connection pipelines N
/// requests — all submitted before any response is read — and receives
/// token-identical greedy results to serial submission, with strictly
/// fewer engine steps (proof the one connection's requests shared the
/// continuous batch, which the old lockstep reader could never do).
#[test]
fn single_connection_pipelining_matches_serial_with_fewer_steps() {
    let m = MoeModel::new(&tiny_cfg(), 300);
    let be = NativeBackend::fp(&m);
    let prompts: [Vec<u16>; 3] = [vec![1, 17, 30], vec![1, 9, 22], vec![1, 40, 2]];
    let mut want = Vec::new();
    let mut serial_steps = 0u64;
    for p in &prompts {
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        want.push(eng.generate(p, 6).unwrap());
        serial_steps += eng.metrics.steps;
    }
    std::thread::scope(|s| {
        let sc = ServingConfig {
            max_batch: 3,
            // wide gather window: the engine waits for the full batch
            // before its first step (a full batch short-circuits the
            // wait), so the step-sharing assertion is deterministic
            batch_window_us: 5_000_000,
            ..Default::default()
        };
        let addr = serve_on(s, &m, sc, Some(3));
        let mut client = Client::connect(addr).unwrap();
        let reqs: Vec<(Vec<u16>, usize)> =
            prompts.iter().map(|p| (p.clone(), 6)).collect();
        let got = client.gen_pipelined(&reqs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.tokens, w, "pipelined tokens diverged from serial reference");
        }
        let steps = client.stats_field("steps").unwrap() as u64;
        assert!(
            steps < serial_steps,
            "pipelined requests did not share steps: {steps} !< {serial_steps}"
        );
        // the wire surfaced the measured latencies (satellite: GenResult
        // latency/queue no longer dropped on the wire)
        for g in &got {
            assert!(g.latency_us > 0, "latency_us must ride the OK line");
            assert!(g.latency_us >= g.queue_us);
        }
    });
}

/// Backpressure acceptance: with `max_batch=1 max_queue=1`, a third
/// concurrent request is answered `BUSY` immediately — before the
/// in-flight request finishes — and everything admitted still completes.
#[test]
fn saturated_queue_answers_busy_while_inflight_completes() {
    let m = MoeModel::new(&tiny_cfg(), 301);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    // 200 decode steps ≈ a multi-millisecond in-flight window even on a
    // fast core — the BUSY probes below land well inside it
    let long_want = eng.generate(&[1, 17, 30], 200).unwrap();
    let short_want = eng.generate(&[1, 9, 22], 3).unwrap();
    std::thread::scope(|s| {
        let sc = ServingConfig {
            max_batch: 1, // one active sequence ⇒ the second stays queued
            max_queue: 1, // one queued sequence ⇒ the third is refused
            ..Default::default()
        };
        let addr = serve_on(s, &m, sc, Some(2));
        let mut client = Client::connect(addr).unwrap();
        // request 1: long and streaming — the first TOK proves it is
        // admitted and decoding, so the queue-depth math below is exact
        let t1 = client
            .submit_opts(&[1, 17, 30], 200, GenOpts { stream: true, ..Default::default() })
            .unwrap();
        match client.recv_response().unwrap() {
            Response::Tok { tag, .. } => assert_eq!(tag, t1),
            other => panic!("expected first TOK, got {other:?}"),
        }
        // request 2 fills the queue; request 3 must bounce
        let t2 = client.submit(&[1, 9, 22], 3).unwrap();
        let t3 = client.submit(&[1, 40, 2], 3).unwrap();
        let mut busy_at = None;
        let mut ok1 = None;
        let mut ok2 = None;
        let mut order = 0usize;
        while ok1.is_none() || ok2.is_none() || busy_at.is_none() {
            match client.recv_response().unwrap() {
                Response::Tok { tag, .. } => assert_eq!(tag, t1),
                Response::Busy { tag } => {
                    assert_eq!(tag, t3, "only the over-cap request may bounce");
                    busy_at = Some(order);
                }
                Response::Ok { tag: Some(tag), tokens, .. } => {
                    if tag == t1 {
                        ok1 = Some((order, tokens));
                    } else {
                        assert_eq!(tag, t2);
                        ok2 = Some(tokens);
                    }
                }
                other => panic!("unexpected response: {other:?}"),
            }
            order += 1;
        }
        let (ok1_at, ok1_tokens) = ok1.unwrap();
        assert!(
            busy_at.unwrap() < ok1_at,
            "BUSY must be immediate, not queued behind the in-flight OK"
        );
        // in-flight and queued requests both completed, token-exact
        assert_eq!(ok1_tokens, long_want);
        assert_eq!(ok2.unwrap(), short_want);
    });
}

/// `stream=1`: one `TOK` per generated token, in decode order, whose
/// concatenation equals the terminal `OK`'s generated tail — and the
/// streamed result is token-identical to a non-streamed run.
#[test]
fn streaming_emits_tok_per_token_before_ok() {
    let m = MoeModel::new(&tiny_cfg(), 302);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = eng.generate(&[1, 17, 30], 5).unwrap();
    std::thread::scope(|s| {
        let addr = serve_on(s, &m, ServingConfig::default(), Some(1));
        let mut client = Client::connect(addr).unwrap();
        let mut streamed = Vec::new();
        let out = client.gen_stream(&[1, 17, 30], 5, |t| streamed.push(t)).unwrap();
        assert_eq!(out.tokens, want);
        assert_eq!(streamed.len(), 5, "one TOK per generated token");
        assert_eq!(&out.tokens[3..], &streamed[..], "TOK stream must equal the OK tail");
    });
}

/// v0 and v1 interleave on one connection: the legacy positional `GEN`
/// still answers the legacy untagged `OK`, tagged requests answer
/// tagged, and control lines work throughout.
#[test]
fn v0_and_v1_mixed_traffic_one_connection() {
    let m = MoeModel::new(&tiny_cfg(), 303);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = eng.generate(&[1, 17, 30], 4).unwrap();
    std::thread::scope(|s| {
        let addr = serve_on(s, &m, ServingConfig::default(), Some(3));
        let mut client = Client::connect(addr).unwrap();
        client.ping().unwrap();
        // legacy v0 line, lockstep: untagged OK with the same tokens
        client.send_raw("GEN 4 1,17,30").unwrap();
        match client.recv_response().unwrap() {
            Response::Ok { tag: None, tokens, .. } => assert_eq!(tokens, want),
            other => panic!("v0 GEN must answer untagged OK, got {other:?}"),
        }
        // tagged v1 on the same connection: same tokens, tagged + timed
        let out = client.gen(&[1, 17, 30], 4).unwrap();
        assert_eq!(out.tokens, want);
        // v0 again after v1 — the dialects share one parser and one
        // scheduler, nothing latched
        client.send_raw("GEN 4 1,17,30").unwrap();
        match client.recv_response().unwrap() {
            Response::Ok { tag: None, tokens, .. } => assert_eq!(tokens, want),
            other => panic!("v0 after v1 must still answer untagged OK, got {other:?}"),
        }
        client.ping().unwrap();
    });
}

/// Protocol robustness over the wire: every malformed line is answered
/// with `ERR` (tagged when the tag was parseable? — no: a line that
/// fails to parse has no trustworthy tag, so ERR is untagged), the
/// oversized line is bounded and discarded, and the connection keeps
/// working afterwards.
#[test]
fn malformed_and_oversized_lines_answer_err_and_stay_usable() {
    let m = MoeModel::new(&tiny_cfg(), 304);
    let be = NativeBackend::fp(&m);
    let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
    let want = eng.generate(&[1, 5], 2).unwrap();
    std::thread::scope(|s| {
        let addr = serve_on(s, &m, ServingConfig::default(), Some(1));
        let mut client = Client::connect(addr).unwrap();
        let bad_lines = [
            "BOGUS".to_string(),
            "GEN".to_string(),
            "GEN notanumber 1,2".to_string(),
            "GEN 4".to_string(),
            "GEN 4 1,,2".to_string(),
            "GEN id=1 max_new=4".to_string(),           // v1 missing toks
            "GEN max_new=4 toks=1,2".to_string(),       // v1 missing id
            "GEN id=1 max_new=4 toks=".to_string(),     // empty token list
            "GEN id=1 id=2 max_new=4 toks=1".to_string(),
            "GEN id=1 max_new=4 stream=9 toks=1".to_string(),
            "GEN id=1 max_new=".to_string(),            // truncated/partial line
            // oversized: a single line past MAX_LINE_BYTES must be
            // bounded, discarded, and answered ERR
            format!("GEN 4 {}", "1,".repeat(200 * 1024)),
        ];
        for line in &bad_lines {
            client.send_raw(line).unwrap();
            match client.recv_response().unwrap() {
                Response::Err { msg, .. } => {
                    assert!(!msg.is_empty(), "ERR must carry a reason for {line:?}")
                }
                other => panic!("{:?} must answer ERR, got {other:?}", &line[..line.len().min(60)]),
            }
        }
        // a malformed v1 GEN whose id= parsed keeps its tag on the ERR,
        // so a pipelined client can mark that tag terminal
        client.send_raw("GEN id=77 max_new=4 toks=1,,2").unwrap();
        match client.recv_response().unwrap() {
            Response::Err { tag: Some(77), .. } => {}
            other => panic!("salvageable id must answer tagged ERR, got {other:?}"),
        }
        // the connection survived all of it
        let out = client.gen(&[1, 5], 2).unwrap();
        assert_eq!(out.tokens, want);
    });
}

/// `TRACE` over the wire: a served `GEN` leaves spans in the ring, the
/// dump is one valid JSON object per line with the full span schema,
/// `last=` truncates to the newest spans, and a deliberately tiny ring
/// (capacity 8, far below the ~11 spans a single step + retire records)
/// proves overwrite-oldest capping end to end.
#[test]
fn trace_dump_roundtrips_spans_and_honors_ring_cap() {
    let m = MoeModel::new(&tiny_cfg(), 305);
    let be = NativeBackend::fp(&m);
    let engine = Mutex::new(
        DecodeEngine::new(EngineModel::Fp(&m), &be, None).with_trace_capacity(8),
    );
    let sc = ServingConfig::default();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| server::serve_with(listener, &engine, &sc, Some(1)).unwrap());
        let mut client = Client::connect(addr).unwrap();
        client.gen(&[1, 17, 30], 4).unwrap();
        let spans = client.trace(None).unwrap();
        assert!(!spans.is_empty(), "a served GEN must leave spans in the ring");
        assert!(spans.len() <= 8, "ring cap 8 must bound the dump, got {}", spans.len());
        let mut kinds = Vec::new();
        for line in &spans {
            let v = Value::parse(line)
                .unwrap_or_else(|e| panic!("span line must be valid JSON, got {line:?}: {e}"));
            kinds.push(v.get("kind").unwrap().as_str().unwrap().to_string());
            for key in ["id", "t_start_us", "dur_us", "a", "b"] {
                v.get(key).unwrap().as_f64().unwrap();
            }
        }
        // retire records the request lifecycle last, so the newest 8
        // spans always hold the final step and the request record
        assert!(kinds.iter().any(|k| k == "request"), "no request span in {kinds:?}");
        assert!(kinds.iter().any(|k| k == "decode-step"), "no step span in {kinds:?}");
        // last= keeps only the newest n spans; the engine is idle
        // between the two dumps, so the tail matches exactly
        let last2 = client.trace(Some(2)).unwrap();
        assert_eq!(last2.len(), 2);
        assert_eq!(&spans[spans.len() - 2..], &last2[..]);
    });
}

/// The `--trace-out` shutdown artifact: after serving a `GEN`, the
/// engine's span snapshot written through `trace::write_chrome` is
/// valid Chrome trace_event JSON (`chrome://tracing` loadable) — a
/// `traceEvents` array of complete (`ph:"X"`) events where the served
/// request's span temporally contains the engine's step-phase spans.
#[test]
fn trace_out_writes_chrome_trace_event_json_with_nested_spans() {
    let m = MoeModel::new(&tiny_cfg(), 306);
    let be = NativeBackend::fp(&m);
    let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
    let sc = ServingConfig::default();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| server::serve_with(listener, &engine, &sc, Some(1)).unwrap());
        let mut client = Client::connect(addr).unwrap();
        client.gen(&[1, 17, 30], 4).unwrap();
    });
    // server joined at scope exit; this is the same dump `mcsharp serve
    // --trace-out` performs at shutdown
    let path = std::env::temp_dir().join(format!("mcsharp_trace_{}.json", std::process::id()));
    let path_str = path.to_str().unwrap();
    let spans = engine.lock().unwrap().trace.snapshot(None);
    mcsharp::trace::write_chrome(path_str, &spans).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let doc = Value::parse(&text).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "trace file must carry the served request's events");
    let window = |ev: &Value| -> (f64, f64) {
        assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "X", "complete events only");
        (ev.get("ts").unwrap().as_f64().unwrap(), ev.get("dur").unwrap().as_f64().unwrap())
    };
    let req = events
        .iter()
        .find(|ev| ev.get("name").unwrap().as_str().unwrap() == "request")
        .expect("no request event in the trace file");
    let (req_ts, req_dur) = window(req);
    // request-scope events sit on their own per-request track
    assert!(req.get("tid").unwrap().as_f64().unwrap() >= 2.0);
    let step = events
        .iter()
        .find(|ev| ev.get("name").unwrap().as_str().unwrap() == "decode-step")
        .expect("no decode-step event in the trace file");
    let (step_ts, step_dur) = window(step);
    assert_eq!(step.get("tid").unwrap().as_f64().unwrap(), 1.0, "engine track");
    // nesting: every step serving this lone request falls inside its
    // request window (+2µs slack for independent µs floor-rounding)
    assert!(step_ts >= req_ts, "step starts before its request: {step_ts} < {req_ts}");
    assert!(
        step_ts + step_dur <= req_ts + req_dur + 2.0,
        "step outlives its request: {} > {}",
        step_ts + step_dur,
        req_ts + req_dur
    );
}
