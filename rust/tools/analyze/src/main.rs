//! CLI for `mcsharp-analyze`.
//!
//! ```text
//! cargo run -p mcsharp-analyze --bin analyze [-- ROOT] [--inventory PATH | --no-inventory]
//! ```
//!
//! Defaults (run from the repo root, as CI does): `ROOT = rust/src`,
//! inventory = `ANALYSIS.md`. Findings go to stdout one per line; the
//! summary goes to stderr. Exit 0 when clean, 1 on any finding, 2 on a
//! missing source root. `tools/analyze_mirror.py` is the toolchain-free
//! mirror with the identical interface.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut inventory: Option<PathBuf> = Some(PathBuf::from("ANALYSIS.md"));
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--no-inventory" => inventory = None,
            "--inventory" => match args.next() {
                Some(p) => inventory = Some(PathBuf::from(p)),
                None => {
                    eprintln!("analyze: --inventory needs a path");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                eprintln!("usage: analyze [ROOT] [--inventory PATH | --no-inventory]");
                return ExitCode::SUCCESS;
            }
            // first positional wins, matching the mirror
            _ => {
                if root.is_none() {
                    root = Some(PathBuf::from(a));
                }
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from("rust/src"));
    if !root.is_dir() {
        eprintln!("analyze: source root {} not found", root.display());
        return ExitCode::from(2);
    }
    let findings = mcsharp_analyze::run_all(&root, inventory.as_deref());
    for f in &findings {
        println!("{f}");
    }
    eprintln!("analyze: {} finding(s) over 6 passes", findings.len());
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
