//! `mcsharp-analyze` — repo-native static analysis for the `mcsharp`
//! serving stack. Six passes over `rust/src/` enforce the invariants
//! the type system cannot:
//!
//! 1. **lock-order** — mutexes are acquired in the declared hierarchy
//!    `scheduler → engine → pool → store` (deadlock freedom), and no
//!    blocking I/O call runs while a classified lock is held.
//! 2. **hot-path** — functions marked `// analyze: hot-path` never
//!    allocate (`Vec::new`, `vec!`, `.to_vec()`, `.collect()`,
//!    `.clone()`, `Box::new`, `String` construction, `format!`); each
//!    deliberate exception carries `// analyze: allow(alloc): <why>`.
//! 3. **unsafe-audit** — every `unsafe` block/impl has an adjacent
//!    `// SAFETY:` comment, every `unsafe fn` a `# Safety` doc, and the
//!    per-file site counts match the checked-in inventory table in
//!    `ANALYSIS.md` (drift or stale rows are findings).
//! 4. **protocol-point** — wire-framing string literals (`OK id=`,
//!    `BUSY id=`, `FETCH `, …) appear only in
//!    `coordinator/protocol.rs`, the single parse/format point.
//! 5. **gauge-staleness** — every `Metrics` field marked
//!    `// analyze: gauge` is re-assigned inside `DecodeEngine::step`,
//!    so `STATS`/`METRICS` can never silently publish stale gauges.
//! 6. **trace-guard** — a `SpanGuard` records its span when dropped, so
//!    `let _ = ..span(..)` (immediate drop) records a zero-length span
//!    and measures nothing; the guard must be bound to a named variable
//!    (or waived with `// analyze: allow(trace-guard): <why>`).
//!
//! The analysis is a hand-rolled lexer plus token-stream walks — no
//! external parser crates (this build environment has no crates.io
//! access), no type information, per-function scope only. `#[cfg(test)]`
//! regions are exempt. `tools/analyze_mirror.py` at the repo root is a
//! line-for-line Python mirror that runs without a Rust toolchain; any
//! behavioural change must land in both.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

// --------------------------------------------------------------- lexer

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Comment,
    Str,
    Char,
    Lifetime,
    Ident,
    Num,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    fn is(&self, kind: Kind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

/// Tokenize Rust source: comments, string/char/lifetime literals,
/// identifiers, numbers, single-char punctuation. Enough fidelity for
/// token-stream analysis; not a full grammar.
pub fn lex(src: &str) -> Vec<Tok> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let span = |a: usize, b: usize| cs[a..b.min(n)].iter().collect::<String>();
    let mut toks = Vec::new();
    let (mut i, mut line) = (0usize, 1usize);
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let mut j = i;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Comment, text: span(i, j), line });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let (mut depth, mut j, start) = (1usize, i + 2, line);
            while j < n && depth > 0 {
                if cs[j] == '/' && j + 1 < n && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && j + 1 < n && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(Tok { kind: Kind::Comment, text: span(i, j), line: start });
            i = j;
            continue;
        }
        // raw / byte-raw strings: r"..", r#".."#, br".."
        if c == 'r' || (c == 'b' && i + 1 < n && cs[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let h0 = j;
            while j < n && cs[j] == '#' {
                j += 1;
            }
            let hashes = j - h0;
            if j < n && cs[j] == '"' {
                let start = line;
                j += 1;
                loop {
                    if j >= n {
                        break;
                    }
                    if cs[j] == '"' {
                        let mut k = j + 1;
                        while k < n && k - j - 1 < hashes && cs[k] == '#' {
                            k += 1;
                        }
                        if k - j - 1 == hashes {
                            j = k;
                            break;
                        }
                    }
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                toks.push(Tok { kind: Kind::Str, text: span(i, j), line: start });
                i = j;
                continue;
            }
            // not a raw string opener — fall through to the ident arm
        }
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '"' {
                    j += 1;
                    break;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Str, text: span(i, j), line });
            i = j;
            continue;
        }
        if c == '\'' {
            // lifetime ('a) vs char literal ('x', '\n', '\'')
            if i + 1 < n && (cs[i + 1].is_ascii_alphabetic() || cs[i + 1] == '_') {
                let mut j = i + 2;
                while j < n && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                if j >= n || cs[j] != '\'' {
                    toks.push(Tok { kind: Kind::Lifetime, text: span(i, j), line });
                    i = j;
                    continue;
                }
            }
            let mut j = i + 1;
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '\'' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Char, text: span(i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_alphabetic() || c == '_' {
            let mut j = i + 1;
            while j < n && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: span(i, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && (cs[j].is_ascii_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Num, text: span(i, j), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

/// Drop `#[cfg(test)] <item> { .. }` regions — tests are exempt from
/// every pass (they may hold wire literals, allocate, and take locks in
/// arbitrary orders on purpose).
fn strip_tests(toks: Vec<Tok>) -> Vec<Tok> {
    let mut out = Vec::with_capacity(toks.len());
    let n = toks.len();
    let mut i = 0;
    while i < n {
        let is_cfg_test = toks[i].is(Kind::Punct, "#")
            && i + 6 < n
            && toks[i + 1].is(Kind::Punct, "[")
            && toks[i + 2].is(Kind::Ident, "cfg")
            && toks[i + 3].is(Kind::Punct, "(")
            && toks[i + 4].is(Kind::Ident, "test")
            && toks[i + 5].is(Kind::Punct, ")")
            && toks[i + 6].is(Kind::Punct, "]");
        if is_cfg_test {
            let mut j = i + 7;
            while j < n && !toks[j].is(Kind::Punct, "{") {
                if toks[j].is(Kind::Punct, ";") {
                    break; // cfg(test) on a bodiless item
                }
                j += 1;
            }
            if j < n && toks[j].is(Kind::Punct, "{") {
                let mut depth = 0i64;
                while j < n {
                    if toks[j].is(Kind::Punct, "{") {
                        depth += 1;
                    } else if toks[j].is(Kind::Punct, "}") {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
            }
            i = j + 1;
            continue;
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

/// One lexed source file: raw lines (for comment-adjacency checks),
/// the full token stream, and the comment-free code stream — both with
/// `#[cfg(test)]` regions removed.
pub struct SrcFile {
    pub rel: String,
    lines: Vec<String>,
    toks: Vec<Tok>,
    code: Vec<Tok>,
}

impl SrcFile {
    pub fn new(rel: &str, text: &str) -> SrcFile {
        let toks = strip_tests(lex(text));
        let code = toks.iter().filter(|t| t.kind != Kind::Comment).cloned().collect();
        SrcFile {
            rel: rel.replace('\\', "/"),
            lines: text.split('\n').map(str::to_string).collect(),
            toks,
            code,
        }
    }

    fn line(&self, ln: usize) -> &str {
        if (1..=self.lines.len()).contains(&ln) {
            &self.lines[ln - 1]
        } else {
            ""
        }
    }
}

#[derive(Debug)]
pub struct Finding {
    pub pass: &'static str,
    pub rel: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}:{}: {}", self.pass, self.rel, self.line, self.msg)
    }
}

// ---------------------------------------------------- function extraction

struct FnItem<'a> {
    name: String,
    line: usize,
    body: &'a [Tok],
    sfile: &'a SrcFile,
}

/// Every `fn name(..) { .. }` with a body in the code stream.
fn functions(sfile: &SrcFile) -> Vec<FnItem<'_>> {
    let toks = &sfile.code;
    let n = toks.len();
    let mut fns = Vec::new();
    let mut i = 0;
    while i < n {
        if toks[i].is(Kind::Ident, "fn") && i + 1 < n && toks[i + 1].kind == Kind::Ident {
            let (name, fline) = (toks[i + 1].text.clone(), toks[i].line);
            let mut j = i + 2;
            let mut paren = 0i64;
            let mut body: Option<(usize, usize)> = None;
            while j < n {
                let t = &toks[j];
                if t.kind == Kind::Punct {
                    match t.text.as_str() {
                        "(" => paren += 1,
                        ")" => paren -= 1,
                        ";" if paren == 0 => break, // trait method without a body
                        "{" if paren == 0 => {
                            let mut depth = 0i64;
                            let mut k = j;
                            while k < n {
                                if toks[k].is(Kind::Punct, "{") {
                                    depth += 1;
                                } else if toks[k].is(Kind::Punct, "}") {
                                    depth -= 1;
                                    if depth == 0 {
                                        break;
                                    }
                                }
                                k += 1;
                            }
                            body = Some((j, (k + 1).min(n)));
                            j = k;
                            break;
                        }
                        _ => {}
                    }
                }
                j += 1;
            }
            if let Some((a, b)) = body {
                fns.push(FnItem { name, line: fline, body: &toks[a..b], sfile });
                i = j + 1;
                continue;
            }
        }
        i += 1;
    }
    fns
}

/// Comment/attribute/blank lines immediately above a declaration line —
/// where `// analyze: ...` markers and `/// # Safety` docs live.
fn header_block(sfile: &SrcFile, fn_line: usize) -> Vec<String> {
    let mut block = Vec::new();
    let mut ln = fn_line.saturating_sub(1);
    while ln >= 1 {
        let s = sfile.line(ln).trim().to_string();
        if s.is_empty() || s.starts_with("//") || s.starts_with("#[") {
            block.push(s);
            ln -= 1;
        } else {
            break;
        }
    }
    block
}

fn has_waiver(sfile: &SrcFile, line: usize, tag: &str) -> bool {
    let marker = format!("analyze: allow({tag})");
    for ln in [line, line.saturating_sub(1), line.saturating_sub(2)] {
        if ln >= 1 && sfile.line(ln).contains(&marker) {
            return true;
        }
    }
    false
}

fn fn_waiver(fnc: &FnItem<'_>, tag: &str) -> bool {
    let marker = format!("analyze: allow({tag})");
    header_block(fnc.sfile, fnc.line).iter().any(|s| s.contains(&marker))
}

// ----------------------------------------------------------- pass 1: locks

fn rank(cls: &str) -> u8 {
    match cls {
        "scheduler" => 0,
        "engine" => 1,
        "pool" => 2,
        "store" => 3,
        _ => unreachable!("unknown lock class {cls}"),
    }
}

const IO_IDENTS: [&str; 11] = [
    "read_command_line",
    "read_line",
    "write_all",
    "read_exact",
    "read_to_end",
    "read_to_string",
    "flush",
    "connect",
    "connect_timeout",
    "accept",
    "sleep",
];

/// Map a `.lock()` receiver to its hierarchy class, or `None` for
/// unranked mutexes (log sinks, test plumbing).
fn classify_lock(recv: &str, rel: &str) -> Option<&'static str> {
    if recv.contains("pool") {
        return Some("pool");
    }
    if recv == "inner" {
        if rel.ends_with("coordinator/scheduler.rs") {
            return Some("scheduler");
        }
        if rel.ends_with("quant/store.rs") || rel.ends_with("quant/remote.rs") {
            return Some("store");
        }
        return None;
    }
    if recv == "eng" || recv == "engine" {
        return Some("engine");
    }
    None
}

fn pass_lock_order(files: &[SrcFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for sf in files {
        for fnc in functions(sf) {
            check_fn_locks(&fnc, &mut findings);
        }
    }
    findings
}

enum Binding {
    Named(String),
    Anon,
    Temp,
}

fn check_fn_locks(fnc: &FnItem<'_>, findings: &mut Vec<Finding>) {
    let toks = fnc.body;
    let n = toks.len();
    // (class, let-bound name, brace depth at acquisition)
    let mut held: Vec<(&'static str, Option<String>, i64)> = Vec::new();
    let mut depth = 0i64;
    let mut stmt_start = 0usize;
    let mut i = 0;
    while i < n {
        let t = &toks[i];
        if t.is(Kind::Punct, "{") {
            depth += 1;
            stmt_start = i + 1;
        } else if t.is(Kind::Punct, "}") {
            depth -= 1;
            held.retain(|h| h.2 <= depth);
            stmt_start = i + 1;
        } else if t.is(Kind::Punct, ";") {
            stmt_start = i + 1;
        } else if t.is(Kind::Ident, "drop")
            && i + 2 < n
            && toks[i + 1].is(Kind::Punct, "(")
            && toks[i + 2].kind == Kind::Ident
        {
            let name = toks[i + 2].text.as_str();
            held.retain(|h| h.1.as_deref() != Some(name));
        } else if t.is(Kind::Punct, ".")
            && i + 3 < n
            && toks[i + 1].is(Kind::Ident, "lock")
            && toks[i + 2].is(Kind::Punct, "(")
            && toks[i + 3].is(Kind::Punct, ")")
        {
            let recv = receiver_before(toks, i);
            if let Some(cls) = classify_lock(&recv, &fnc.sfile.rel) {
                let r = rank(cls);
                for (hcls, _, _) in &held {
                    if rank(hcls) >= r
                        && !(has_waiver(fnc.sfile, t.line, "lock-order")
                            || fn_waiver(fnc, "lock-order"))
                    {
                        findings.push(Finding {
                            pass: "lock-order",
                            rel: fnc.sfile.rel.clone(),
                            line: t.line,
                            msg: format!(
                                "acquires `{cls}` lock while holding `{hcls}` \
                                 (declared order: scheduler -> engine -> pool -> store) in fn {}",
                                fnc.name
                            ),
                        });
                    }
                }
                // bound to a let-guard? held until scope end / drop()
                match let_binding(toks, stmt_start, i) {
                    Binding::Named(name) => held.push((cls, Some(name), depth)),
                    Binding::Anon => held.push((cls, None, depth)),
                    Binding::Temp => {}
                }
            }
            i += 4;
            continue;
        } else if t.kind == Kind::Ident && IO_IDENTS.contains(&t.text.as_str()) && !held.is_empty()
        {
            if !(has_waiver(fnc.sfile, t.line, "lock-across-io")
                || fn_waiver(fnc, "lock-across-io"))
            {
                let hcls = held.last().unwrap().0;
                findings.push(Finding {
                    pass: "lock-order",
                    rel: fnc.sfile.rel.clone(),
                    line: t.line,
                    msg: format!(
                        "blocking call `{}` while holding `{hcls}` lock in fn {}",
                        t.text, fnc.name
                    ),
                });
            }
        }
        i += 1;
    }
}

/// Identifier naming the receiver of `.lock()`: the ident before the
/// dot, or — when the receiver is a call like `kv_pool()` — the method
/// name before its parens.
fn receiver_before(toks: &[Tok], dot_i: usize) -> String {
    let mut j = dot_i as i64 - 1;
    if j >= 0 && toks[j as usize].is(Kind::Punct, ")") {
        let mut depth = 0i64;
        while j >= 0 {
            if toks[j as usize].text == ")" {
                depth += 1;
            } else if toks[j as usize].text == "(" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j -= 1;
        }
        j -= 1;
    }
    if j >= 0 && toks[j as usize].kind == Kind::Ident {
        return toks[j as usize].text.clone();
    }
    String::new()
}

/// `let [mut] name = ..lock()..` => Named; `let (a, b) = ..` => Anon
/// (scope-held, anonymous); no `let` => Temp (statement temporary).
fn let_binding(toks: &[Tok], stmt_start: usize, lock_i: usize) -> Binding {
    for j in stmt_start..lock_i {
        if toks[j].is(Kind::Ident, "let") {
            let mut k = j + 1;
            if k < lock_i && toks[k].is(Kind::Ident, "mut") {
                k += 1;
            }
            if k < lock_i && toks[k].kind == Kind::Ident {
                return Binding::Named(toks[k].text.clone());
            }
            return Binding::Anon;
        }
    }
    Binding::Temp
}

// -------------------------------------------------------- pass 2: hot path

const DENIED_METHODS: [&str; 6] = ["to_vec", "collect", "clone", "cloned", "to_owned", "to_string"];
const DENIED_CTORS: [&str; 3] = ["Vec", "String", "Box"];
const DENIED_CTOR_FNS: [&str; 3] = ["new", "with_capacity", "from"];

fn is_hot_path(fnc: &FnItem<'_>) -> bool {
    header_block(fnc.sfile, fnc.line).iter().any(|s| s.contains("analyze: hot-path"))
}

fn pass_hot_path(files: &[SrcFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for sf in files {
        for fnc in functions(sf) {
            if is_hot_path(&fnc) {
                check_hot_fn(&fnc, &mut findings);
            }
        }
    }
    findings
}

fn check_hot_fn(fnc: &FnItem<'_>, findings: &mut Vec<Finding>) {
    let toks = fnc.body;
    let n = toks.len();
    let flag = |t: &Tok, what: String, findings: &mut Vec<Finding>| {
        if !has_waiver(fnc.sfile, t.line, "alloc") {
            findings.push(Finding {
                pass: "hot-path",
                rel: fnc.sfile.rel.clone(),
                line: t.line,
                msg: format!(
                    "allocation `{what}` in hot-path fn {} \
                     (scratch-arena contract; waive with `// analyze: allow(alloc): <why>`)",
                    fnc.name
                ),
            });
        }
    };
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        if (t.text == "vec" || t.text == "format") && i + 1 < n && toks[i + 1].text == "!" {
            flag(t, format!("{}!", t.text), findings);
        } else if DENIED_CTORS.contains(&t.text.as_str())
            && i + 3 < n
            && toks[i + 1].text == ":"
            && toks[i + 2].text == ":"
            && toks[i + 3].kind == Kind::Ident
            && DENIED_CTOR_FNS.contains(&toks[i + 3].text.as_str())
        {
            flag(t, format!("{}::{}", t.text, toks[i + 3].text), findings);
        } else if DENIED_METHODS.contains(&t.text.as_str())
            && i >= 1
            && toks[i - 1].text == "."
            && i + 1 < n
            && toks[i + 1].text == "("
        {
            flag(t, format!(".{}()", t.text), findings);
        }
    }
}

// ---------------------------------------------------- pass 3: unsafe audit

const STMT_ENDERS: &[char] = &[';', '{', '}', ','];

#[derive(Clone, Copy, PartialEq, Eq)]
enum UnsafeKind {
    Fn,
    Impl,
    Block,
}

/// Every `unsafe fn` / `unsafe impl` / `unsafe {}` site outside tests.
fn unsafe_sites(sfile: &SrcFile) -> Vec<(UnsafeKind, usize)> {
    let toks = &sfile.code;
    let mut sites = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.is(Kind::Ident, "unsafe") {
            let kind = match toks.get(i + 1) {
                Some(nxt) if nxt.is(Kind::Ident, "impl") => UnsafeKind::Impl,
                Some(nxt) if nxt.is(Kind::Ident, "fn") => UnsafeKind::Fn,
                _ => UnsafeKind::Block,
            };
            sites.push((kind, t.line));
        }
    }
    sites
}

/// An `unsafe {}` block (or `unsafe impl`) is justified when a
/// `// SAFETY:` comment sits on the same line or directly above it —
/// scanning up through comment lines and the continuation lines of the
/// same statement, stopping at any line that ends a prior statement.
fn block_justified(sfile: &SrcFile, line: usize) -> bool {
    if sfile.line(line).contains("SAFETY:") {
        return true;
    }
    let mut ln = line.saturating_sub(1);
    while ln >= 1 {
        let s = sfile.line(ln).trim().to_string();
        if s.starts_with("//") {
            if s.contains("SAFETY:") {
                return true;
            }
            ln -= 1;
            continue;
        }
        if s.is_empty() {
            return false;
        }
        if s.ends_with(STMT_ENDERS) {
            return false; // crossed a statement boundary with no comment
        }
        ln -= 1; // continuation line of the same statement
    }
    false
}

/// An `unsafe fn` is justified by a `# Safety` doc section (or a SAFETY
/// note) in its header block.
fn fn_justified(sfile: &SrcFile, line: usize) -> bool {
    header_block(sfile, line).iter().any(|s| s.contains("SAFETY") || s.contains("# Safety"))
        || sfile.line(line).contains("SAFETY:")
}

fn pass_unsafe(files: &[SrcFile], inventory_text: Option<&str>) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut counts: BTreeMap<String, (u32, u32, u32)> = BTreeMap::new();
    for sf in files {
        let mut c = (0u32, 0u32, 0u32);
        for (kind, line) in unsafe_sites(sf) {
            let (ok, word) = match kind {
                UnsafeKind::Fn => {
                    c.0 += 1;
                    (fn_justified(sf, line), "fn")
                }
                UnsafeKind::Impl => {
                    c.1 += 1;
                    (block_justified(sf, line), "impl")
                }
                UnsafeKind::Block => {
                    c.2 += 1;
                    (block_justified(sf, line), "block")
                }
            };
            if !ok {
                findings.push(Finding {
                    pass: "unsafe-audit",
                    rel: sf.rel.clone(),
                    line,
                    msg: format!("unsafe {word} without an adjacent `// SAFETY:` justification"),
                });
            }
        }
        if c != (0, 0, 0) {
            counts.insert(sf.rel.clone(), c);
        }
    }
    let Some(text) = inventory_text else {
        return findings;
    };
    let inv = parse_inventory(text);
    for (rel, c) in &counts {
        match inv.get(rel) {
            None => findings.push(Finding {
                pass: "unsafe-audit",
                rel: rel.clone(),
                line: 0,
                msg: format!(
                    "unsafe code not in the ANALYSIS.md inventory (fns={} impls={} blocks={})",
                    c.0, c.1, c.2
                ),
            }),
            Some(want) if want != c => findings.push(Finding {
                pass: "unsafe-audit",
                rel: rel.clone(),
                line: 0,
                msg: format!(
                    "inventory drift: ANALYSIS.md says fns={} impls={} blocks={}, \
                     tree has fns={} impls={} blocks={}",
                    want.0, want.1, want.2, c.0, c.1, c.2
                ),
            }),
            Some(_) => {}
        }
    }
    for rel in inv.keys() {
        if !counts.contains_key(rel) {
            findings.push(Finding {
                pass: "unsafe-audit",
                rel: rel.clone(),
                line: 0,
                msg: "stale inventory row: file has no unsafe code (or no longer exists)".into(),
            });
        }
    }
    findings
}

/// Rows shaped `` | `path` | fns | impls | blocks | `` anywhere in the
/// inventory document.
pub fn parse_inventory(text: &str) -> BTreeMap<String, (u32, u32, u32)> {
    let mut inv = BTreeMap::new();
    for line in text.split('\n') {
        let cells: Vec<&str> = line.split('|').collect();
        if !line.starts_with('|') || cells.len() < 5 {
            continue;
        }
        let path = cells[1].trim();
        if path.len() < 3 || !path.starts_with('`') || !path.ends_with('`') {
            continue;
        }
        let nums: Vec<Option<u32>> =
            cells[2..5].iter().map(|c| c.trim().parse::<u32>().ok()).collect();
        if let [Some(a), Some(b), Some(c)] = nums[..] {
            inv.insert(path.trim_matches('`').to_string(), (a, b, c));
        }
    }
    inv
}

// ------------------------------------------------- pass 4: protocol point

const WIRE_PATTERNS: [&str; 8] =
    ["OK id=", "ERR id=", "REC id=", "TOK id=", "BUSY id=", "GEN id=", "FETCH ", "TRACE "];

fn pass_protocol(files: &[SrcFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for sf in files {
        if sf.rel.ends_with("coordinator/protocol.rs") {
            continue;
        }
        for t in &sf.toks {
            if t.kind != Kind::Str {
                continue;
            }
            let body = t
                .text
                .trim_start_matches(&['b', 'r', '#'][..])
                .trim_start_matches('"');
            for pat in WIRE_PATTERNS {
                // wire frames are whole lines: only a literal that BEGINS
                // with a tag is framing (error text mentioning FETCH is not)
                if body.starts_with(pat) {
                    findings.push(Finding {
                        pass: "protocol-point",
                        rel: sf.rel.clone(),
                        line: t.line,
                        msg: format!(
                            "wire literal \"{pat}..\" outside coordinator/protocol.rs \
                             (all framing goes through protocol::format_*/parse_*)"
                        ),
                    });
                    break;
                }
            }
        }
    }
    findings
}

// ------------------------------------------------ pass 5: gauge staleness

/// Fields of `struct Metrics` whose preceding comment carries
/// `analyze: gauge`.
fn gauge_fields(sf: &SrcFile) -> Vec<(String, usize)> {
    let toks = &sf.code;
    let n = toks.len();
    let mut fields = Vec::new();
    for i in 0..n {
        if toks[i].is(Kind::Ident, "struct") && i + 1 < n && toks[i + 1].text == "Metrics" {
            let mut j = i + 2;
            while j < n && !toks[j].is(Kind::Punct, "{") {
                j += 1;
            }
            let mut depth = 0i64;
            while j < n {
                let tj = &toks[j];
                if tj.is(Kind::Punct, "{") {
                    depth += 1;
                } else if tj.is(Kind::Punct, "}") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if depth == 1
                    && tj.kind == Kind::Ident
                    && j + 2 < n
                    && toks[j + 1].text == ":"
                    && toks[j + 2].text != ":"
                {
                    let block = header_block(sf, tj.line);
                    if block.iter().any(|s| s.contains("analyze: gauge")) {
                        fields.push((tj.text.clone(), tj.line));
                    }
                }
                j += 1;
            }
            break;
        }
    }
    fields
}

fn pass_gauges(files: &[SrcFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let Some(metrics) = files.iter().find(|f| f.rel.ends_with("coordinator/metrics.rs")) else {
        return findings;
    };
    let Some(engine) = files.iter().find(|f| f.rel.ends_with("coordinator/engine.rs")) else {
        return findings;
    };
    let fields = gauge_fields(metrics);
    if fields.is_empty() {
        findings.push(Finding {
            pass: "gauge-staleness",
            rel: metrics.rel.clone(),
            line: 0,
            msg: "no Metrics field carries an `// analyze: gauge` marker — \
                  the staleness contract has rotted"
                .into(),
        });
        return findings;
    }
    let fns = functions(engine);
    let Some(step) = fns.iter().find(|f| f.name == "step") else {
        findings.push(Finding {
            pass: "gauge-staleness",
            rel: engine.rel.clone(),
            line: 0,
            msg: "DecodeEngine::step not found".into(),
        });
        return findings;
    };
    for (field, fline) in fields {
        if !assigns_metrics_field(step.body, &field) {
            findings.push(Finding {
                pass: "gauge-staleness",
                rel: metrics.rel.clone(),
                line: fline,
                msg: format!(
                    "gauge field `{field}` is never refreshed inside DecodeEngine::step \
                     (the per-step loop must republish it)"
                ),
            });
        }
    }
    findings
}

/// `metrics.<field> = ...` (assignment, not `==`) anywhere in the body.
fn assigns_metrics_field(toks: &[Tok], field: &str) -> bool {
    let n = toks.len();
    for i in 0..n.saturating_sub(3) {
        if toks[i].is(Kind::Ident, "metrics")
            && toks[i + 1].text == "."
            && toks[i + 2].is(Kind::Ident, field)
            && toks[i + 3].text == "="
            && (i + 4 >= n || toks[i + 4].text != "=")
        {
            return true;
        }
    }
    false
}

// -------------------------------------------------- pass 6: trace guard

fn pass_trace_guard(files: &[SrcFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for sf in files {
        for fnc in functions(sf) {
            check_fn_trace_guard(&fnc, &mut findings);
        }
    }
    findings
}

/// `let _ = <expr containing .span( or SpanGuard>;` — the guard drops at
/// the end of the statement, so the recorded span is zero-length and the
/// timing is silently lost.
fn check_fn_trace_guard(fnc: &FnItem<'_>, findings: &mut Vec<Finding>) {
    let toks = fnc.body;
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if toks[i].is(Kind::Ident, "let")
            && i + 2 < n
            && toks[i + 1].is(Kind::Ident, "_")
            && toks[i + 2].is(Kind::Punct, "=")
        {
            let let_line = toks[i].line;
            let mut j = i + 3;
            let mut guardish = false;
            while j < n && !toks[j].is(Kind::Punct, ";") {
                let t = &toks[j];
                if t.kind == Kind::Ident
                    && ((t.text == "span" && j + 1 < n && toks[j + 1].is(Kind::Punct, "("))
                        || t.text == "SpanGuard")
                {
                    guardish = true;
                }
                j += 1;
            }
            if guardish
                && !(has_waiver(fnc.sfile, let_line, "trace-guard")
                    || fn_waiver(fnc, "trace-guard"))
            {
                findings.push(Finding {
                    pass: "trace-guard",
                    rel: fnc.sfile.rel.clone(),
                    line: let_line,
                    msg: format!(
                        "`let _ = ..span(..)` drops the SpanGuard immediately — the span \
                         records zero length and measures nothing; bind a named guard in fn {} \
                         (waive with `// analyze: allow(trace-guard): <why>`)",
                        fnc.name
                    ),
                });
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

// ----------------------------------------------------------------- driver

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    // a directory's own files come before its subdirectories' files,
    // matching the mirror's os.walk order
    for p in &entries {
        if p.is_file() && p.extension().is_some_and(|e| e == "rs") {
            out.push(p.clone());
        }
    }
    for p in &entries {
        if p.is_dir() {
            collect_rs(p, out);
        }
    }
}

/// Lex every `.rs` file under `root`; `rel` paths are reported relative
/// to `root`'s grandparent (so `rust/src/...` from the repo root).
pub fn load_tree(root: &Path) -> Vec<SrcFile> {
    let base = root.parent().and_then(Path::parent).unwrap_or_else(|| Path::new(""));
    let mut paths = Vec::new();
    collect_rs(root, &mut paths);
    paths
        .iter()
        .filter_map(|p| {
            let rel = p.strip_prefix(base).unwrap_or(p).to_string_lossy().into_owned();
            fs::read_to_string(p).ok().map(|text| SrcFile::new(&rel, &text))
        })
        .collect()
}

/// Run all six passes over pre-lexed files (fixture tests call this
/// with synthetic `rel` names).
pub fn run_passes(files: &[SrcFile], inventory_text: Option<&str>) -> Vec<Finding> {
    let mut findings = Vec::new();
    findings.extend(pass_lock_order(files));
    findings.extend(pass_hot_path(files));
    findings.extend(pass_unsafe(files, inventory_text));
    findings.extend(pass_protocol(files));
    findings.extend(pass_gauges(files));
    findings.extend(pass_trace_guard(files));
    findings
}

/// Run all six passes over the tree at `root`, checking the unsafe
/// inventory in `inventory` when it exists.
pub fn run_all(root: &Path, inventory: Option<&Path>) -> Vec<Finding> {
    let files = load_tree(root);
    let inv_text = inventory.and_then(|p| fs::read_to_string(p).ok());
    run_passes(&files, inv_text.as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_kinds_and_lines() {
        let toks = lex("fn a() {\n  let s = \"x\"; // hi\n}\n");
        let kinds: Vec<Kind> = toks.iter().map(|t| t.kind).collect();
        assert_eq!(
            kinds,
            vec![
                Kind::Ident, // fn
                Kind::Ident, // a
                Kind::Punct,
                Kind::Punct,
                Kind::Punct, // {
                Kind::Ident, // let
                Kind::Ident, // s
                Kind::Punct, // =
                Kind::Str,
                Kind::Punct, // ;
                Kind::Comment,
                Kind::Punct, // }
            ]
        );
        assert_eq!(toks[8].line, 2);
        assert_eq!(toks[10].text, "// hi");
    }

    #[test]
    fn lexer_raw_strings_and_lifetimes() {
        let toks = lex("r#\"a \"quote\" b\"# b\"bytes\" 'a 'x' rp");
        assert_eq!(toks[0].kind, Kind::Str);
        assert_eq!(toks[0].text, "r#\"a \"quote\" b\"#");
        assert_eq!(toks[1].kind, Kind::Str);
        assert_eq!(toks[2].kind, Kind::Lifetime);
        assert_eq!(toks[3].kind, Kind::Char);
        assert!(toks[4].is(Kind::Ident, "rp"), "r-prefixed ident is not a raw string");
    }

    #[test]
    fn cfg_test_regions_are_stripped() {
        let sf = SrcFile::new(
            "x.rs",
            "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn gone() { let v = vec![1]; }\n}\n",
        );
        let names: Vec<String> = functions(&sf).iter().map(|f| f.name.clone()).collect();
        assert_eq!(names, vec!["live"]);
    }

    #[test]
    fn inventory_rows_parse() {
        let inv = parse_inventory(
            "| file | fns | impls | blocks |\n\
             |---|---|---|---|\n\
             | `rust/src/a.rs` | 1 | 2 | 3 |\n\
             not a row | `x` | 1 | 1 | 1 |\n",
        );
        assert_eq!(inv.len(), 1);
        assert_eq!(inv["rust/src/a.rs"], (1, 2, 3));
    }
}
