//! Trace-guard pass fixture (clean): a named guard that lives across
//! the timed work, a waived deliberate drop, and an innocent `let _`
//! that has nothing to do with spans. Never compiled — lexed only.

pub fn step_with_named_guard(tracer: &Tracer) {
    let _step = tracer.span(SpanKind::DecodeStep, 0);
    expensive_work();
}

pub fn probe_enabled(tracer: &Tracer) {
    // analyze: allow(trace-guard): probing that span() compiles is the point
    let _ = tracer.span(SpanKind::Route, 1);
}

pub fn unrelated_discard() {
    let _ = compute();
}

fn compute() -> u64 {
    7
}

fn expensive_work() {}
