//! Unsafe-audit pass fixture (clean): every site carries its
//! justification — `// SAFETY:` on impls and blocks, a `# Safety` doc
//! section on unsafe fns. Never compiled — lexed only.

pub struct SharedTable {
    ptr: *const f32,
    len: usize,
}

// SAFETY: the pointer refers to an immutable 'static mapping that is
// never mutated after initialization, so concurrent reads are safe.
unsafe impl Sync for SharedTable {}

/// Reads one element without a bounds check.
///
/// # Safety
/// `i` must be less than `t.len` and the mapping must outlive the call.
pub unsafe fn get_unchecked(t: &SharedTable, i: usize) -> f32 {
    // SAFETY: the caller upholds the index bound per this fn's contract.
    unsafe { *t.ptr.add(i) }
}
