//! Gauge-staleness pass fixture (clean): `step` republishes every
//! marked gauge each call. Never compiled — lexed only.

pub struct DecodeEngine {
    pub metrics: super::metrics::Metrics,
}

impl DecodeEngine {
    pub fn step(&mut self, live_pages: u64) {
        self.metrics.steps += 1;
        self.metrics.kv_pages = live_pages;
    }
}
