//! Gauge-staleness pass fixture (clean): the marked gauge is refreshed
//! by `step` in the sibling engine fixture. Never compiled — lexed only.

pub struct Metrics {
    /// Pages currently owned by live sequences or the prefix tree.
    // analyze: gauge
    pub kv_pages: u64,
    /// Monotone counter — not a gauge, not checked.
    pub steps: u64,
}
