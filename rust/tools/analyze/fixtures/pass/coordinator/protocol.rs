//! Protocol-point pass fixture (clean): wire framing literals are legal
//! here — this path is the single parse/format point the pass protects.
//! Never compiled — lexed only.

pub fn format_ok(id: u64) -> String {
    format!("OK id={id}\n")
}

pub fn format_busy(id: u64) -> String {
    format!("BUSY id={id} retry=1\n")
}

pub fn format_fetch(eid: u32) -> String {
    format!("FETCH {eid}\n")
}
