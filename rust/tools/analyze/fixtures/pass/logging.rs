//! Protocol-point pass fixture (clean, outside protocol.rs): literals
//! that merely MENTION a wire tag mid-string are prose, not framing —
//! only a literal that begins with a tag is a frame. Never compiled.

pub fn fetch_error(code: u32) -> String {
    format!("shard rejected FETCH request: code {code}")
}

pub fn busy_hint() -> &'static str {
    "server replied BUSY id=<tag>; retry with backoff"
}
