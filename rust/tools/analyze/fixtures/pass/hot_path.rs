//! Hot-path pass fixture (clean): a marked function that only writes
//! into caller scratch, a waived one-time copy, and an unmarked helper
//! that allocates freely. Never compiled — lexed only.

// analyze: hot-path
pub fn dot(a: &[f32], b: &[f32], acc: &mut f32) {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    *acc = s;
}

// analyze: hot-path
pub fn warm(src: &[f32], scratch: &mut Vec<f32>) {
    if scratch.is_empty() {
        // analyze: allow(alloc): one-time warmup copy, not per token
        *scratch = src.to_vec();
    }
    for v in scratch.iter_mut() {
        *v *= 2.0;
    }
}

pub fn setup(n: usize) -> Vec<f32> {
    // unmarked: setup-time code may allocate
    vec![0.0; n]
}
