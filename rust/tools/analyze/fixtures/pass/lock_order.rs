//! Lock-order pass fixture (clean): acquisitions follow the declared
//! hierarchy, guards drop before lower-ranked locks are retaken, and
//! blocking I/O only runs lock-free. Never compiled — lexed only.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;

pub struct Engine;
pub struct Pool;

pub fn good_order(eng: &Mutex<Engine>, pool: &Mutex<Pool>) {
    let e = eng.lock().unwrap();
    let p = pool.lock().unwrap();
    drop(p);
    drop(e);
}

pub fn reacquire_after_drop(pool: &Mutex<Pool>, eng: &Mutex<Engine>) {
    let p = pool.lock().unwrap();
    drop(p);
    let e = eng.lock().unwrap();
    drop(e);
}

pub fn scoped_release(pool: &Mutex<Pool>, eng: &Mutex<Engine>) {
    {
        let p = pool.lock().unwrap();
        let _ = &*p;
    }
    let e = eng.lock().unwrap();
    drop(e);
}

pub fn statement_temporary(eng: &Mutex<Engine>, sock: &mut TcpStream) {
    // the guard is a statement temporary: it cannot outlive this line
    eng.lock().unwrap();
    sock.write_all(b"ok").unwrap();
}

pub fn waived_inversion(pool: &Mutex<Pool>, eng: &Mutex<Engine>) {
    let p = pool.lock().unwrap();
    // analyze: allow(lock-order): startup-only path, both locks private
    let e = eng.lock().unwrap();
    drop(e);
    drop(p);
}
