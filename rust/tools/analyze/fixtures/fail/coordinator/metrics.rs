//! Gauge-staleness pass fixture (seeded violation): `kv_pages` is
//! marked as a gauge but the sibling engine fixture's `step` never
//! refreshes it. Never compiled — lexed only.

pub struct Metrics {
    /// Pages currently owned by live sequences or the prefix tree.
    // analyze: gauge
    pub kv_pages: u64,
    /// Monotone counter — not a gauge, not checked.
    pub steps: u64,
}
