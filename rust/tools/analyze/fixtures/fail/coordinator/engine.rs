//! Gauge-staleness pass fixture (seeded violation, with metrics.rs):
//! `step` bumps a counter but never republishes the marked gauge.
//! Never compiled — lexed only.

pub struct DecodeEngine {
    pub metrics: super::metrics::Metrics,
}

impl DecodeEngine {
    pub fn step(&mut self) {
        self.metrics.steps += 1;
    }
}
