//! Hot-path pass fixture (seeded violations): a marked function that
//! allocates three different ways. Never compiled — lexed only.

// analyze: hot-path
pub fn softmax_slow(x: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    let exps: Vec<f32> = x.iter().map(|v| v.exp()).collect();
    let denom: f32 = exps.iter().sum();
    for e in &exps {
        out.push(e / denom);
    }
    let _scale = vec![denom; x.len()];
    out
}
