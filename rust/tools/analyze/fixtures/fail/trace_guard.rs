//! Trace-guard pass fixture (seeded violation): a SpanGuard bound to
//! `_` drops before the work it was meant to time. Never compiled —
//! lexed only.

pub fn step_with_dropped_guard(tracer: &Tracer) {
    let _ = tracer.span(SpanKind::DecodeStep, 0);
    expensive_work();
}

fn expensive_work() {}
