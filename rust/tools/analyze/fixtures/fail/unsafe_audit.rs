//! Unsafe-audit pass fixture (seeded violations): an impl, a fn and a
//! block, all without justification. Never compiled — lexed only.

pub struct RawView {
    ptr: *const f32,
    len: usize,
}

unsafe impl Send for RawView {}

pub unsafe fn read_first(v: &RawView) -> f32 {
    *v.ptr
}

pub fn peek(v: &RawView) -> f32 {
    let x = unsafe { *v.ptr.add(v.len - 1) };
    x
}
