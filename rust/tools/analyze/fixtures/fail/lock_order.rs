//! Lock-order pass fixture (seeded violations): one hierarchy
//! inversion, one blocking write under a held lock. Never compiled.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;

pub struct Engine;
pub struct Pool;

pub fn bad_order(pool: &Mutex<Pool>, eng: &Mutex<Engine>) {
    let p = pool.lock().unwrap();
    let e = eng.lock().unwrap();
    drop(e);
    drop(p);
}

pub fn io_under_lock(eng: &Mutex<Engine>, sock: &mut TcpStream) {
    let e = eng.lock().unwrap();
    sock.write_all(b"tick").unwrap();
    drop(e);
}
