//! Protocol-point pass fixture (seeded violations): hand-rolled wire
//! frames outside coordinator/protocol.rs. Never compiled — lexed only.

pub fn handroll_busy(id: u64) -> String {
    let mut s = String::from("BUSY id=");
    s.push_str(&id.to_string());
    s.push('\n');
    s
}

pub fn handroll_fetch(eid: u32) -> Vec<u8> {
    format!("FETCH {eid}\n").into_bytes()
}
