//! End-to-end checks of the six analysis passes against the seeded
//! fixture trees, plus the gate the CI `analysis` job relies on: the
//! real `rust/src/` tree must be clean against the `ANALYSIS.md`
//! inventory.

use std::path::{Path, PathBuf};

use mcsharp_analyze::{load_tree, run_all, run_passes, Finding};

fn fixture_dir(which: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(which)
}

fn by_pass<'a>(findings: &'a [Finding], pass: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.pass == pass).collect()
}

fn render(findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&format!("  {f}\n"));
    }
    s
}

#[test]
fn fail_fixtures_trip_every_pass() {
    let findings = run_all(&fixture_dir("fail"), None);

    let lock = by_pass(&findings, "lock-order");
    assert_eq!(lock.len(), 2, "lock-order findings:\n{}", render(&findings));
    assert!(
        lock.iter().any(|f| {
            f.rel.ends_with("fail/lock_order.rs")
                && f.msg.contains("acquires `engine` lock while holding `pool`")
                && f.msg.contains("fn bad_order")
        }),
        "missing the hierarchy-inversion finding:\n{}",
        render(&findings)
    );
    assert!(
        lock.iter().any(|f| {
            f.msg.contains("blocking call `write_all` while holding `engine` lock")
                && f.msg.contains("fn io_under_lock")
        }),
        "missing the lock-across-io finding:\n{}",
        render(&findings)
    );

    let hot = by_pass(&findings, "hot-path");
    assert_eq!(hot.len(), 3, "hot-path findings:\n{}", render(&findings));
    for what in ["`Vec::new`", "`.collect()`", "`vec!`"] {
        assert!(
            hot.iter().any(|f| f.msg.contains(what) && f.msg.contains("fn softmax_slow")),
            "missing hot-path finding for {what}:\n{}",
            render(&findings)
        );
    }

    let uns = by_pass(&findings, "unsafe-audit");
    assert_eq!(uns.len(), 3, "unsafe-audit findings:\n{}", render(&findings));
    for word in ["unsafe impl", "unsafe fn", "unsafe block"] {
        assert!(
            uns.iter().any(|f| f.rel.ends_with("fail/unsafe_audit.rs")
                && f.msg.contains(word)
                && f.msg.contains("without an adjacent")),
            "missing unjustified `{word}` finding:\n{}",
            render(&findings)
        );
    }

    let wire = by_pass(&findings, "protocol-point");
    assert_eq!(wire.len(), 2, "protocol-point findings:\n{}", render(&findings));
    for pat in ["BUSY id=", "FETCH "] {
        assert!(
            wire.iter().any(|f| f.rel.ends_with("fail/wire_literals.rs")
                && f.msg.contains(&format!("\"{pat}..\""))),
            "missing wire-literal finding for {pat:?}:\n{}",
            render(&findings)
        );
    }

    let gauge = by_pass(&findings, "gauge-staleness");
    assert_eq!(gauge.len(), 1, "gauge findings:\n{}", render(&findings));
    assert!(
        gauge[0].rel.ends_with("coordinator/metrics.rs")
            && gauge[0].msg.contains("`kv_pages` is never refreshed"),
        "wrong gauge finding:\n{}",
        render(&findings)
    );

    let guard = by_pass(&findings, "trace-guard");
    assert_eq!(guard.len(), 1, "trace-guard findings:\n{}", render(&findings));
    assert!(
        guard[0].rel.ends_with("fail/trace_guard.rs")
            && guard[0].msg.contains("drops the SpanGuard immediately")
            && guard[0].msg.contains("fn step_with_dropped_guard"),
        "wrong trace-guard finding:\n{}",
        render(&findings)
    );

    assert_eq!(findings.len(), 12, "unexpected extra findings:\n{}", render(&findings));
}

#[test]
fn pass_fixtures_are_clean() {
    let findings = run_all(&fixture_dir("pass"), None);
    assert!(
        findings.is_empty(),
        "pass fixtures must produce zero findings:\n{}",
        render(&findings)
    );
}

#[test]
fn inventory_drift_and_stale_rows_are_caught() {
    let files = load_tree(&fixture_dir("pass"));

    let good = "| `fixtures/pass/unsafe_audit.rs` | 1 | 1 | 1 |\n";
    let findings = run_passes(&files, Some(good));
    assert!(findings.is_empty(), "accurate inventory must be clean:\n{}", render(&findings));

    let bad = "| `fixtures/pass/unsafe_audit.rs` | 9 | 9 | 9 |\n\
               | `fixtures/pass/gone.rs` | 1 | 0 | 0 |\n";
    let findings = run_passes(&files, Some(bad));
    assert_eq!(findings.len(), 2, "drift + stale expected:\n{}", render(&findings));
    assert!(findings.iter().any(|f| f.msg.contains("inventory drift")
        && f.msg.contains("says fns=9 impls=9 blocks=9")
        && f.msg.contains("tree has fns=1 impls=1 blocks=1")));
    assert!(findings
        .iter()
        .any(|f| f.rel == "fixtures/pass/gone.rs" && f.msg.contains("stale inventory row")));

    // no inventory row at all for a file with unsafe code is a finding
    let findings = run_passes(&files, Some("| `fixtures/pass/other.rs` | 0 | 0 | 1 |\n"));
    assert!(findings.iter().any(|f| f.rel.ends_with("unsafe_audit.rs")
        && f.msg.contains("not in the ANALYSIS.md inventory")));
}

#[test]
fn real_tree_is_clean_against_checked_in_inventory() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..");
    let findings = run_all(&repo.join("rust/src"), Some(&repo.join("ANALYSIS.md")));
    assert!(
        findings.is_empty(),
        "rust/src must satisfy all six passes (fix the code, add a waiver \
         with a reason, or update the ANALYSIS.md inventory):\n{}",
        render(&findings)
    );
}
