//! Table 5 reproduction: params / activated params / quality / speedup
//! for 16-bit vs Uni-2 vs PMQ vs PMQ+OTP, on the LLM- and VLM-analogs.
//!
//! "Speedup" is reported two ways: measured single-core decode wallclock
//! (this testbed is compute-bound, unlike the paper's GPUs) and the
//! memory-roofline ratio (bytes moved — the quantity that actually
//! produces the paper's 1.6–2.0×; see DESIGN.md §3).

#[path = "common.rs"]
mod common;

use std::time::Instant;

use mcsharp::backend::NativeBackend;
use mcsharp::coordinator::batcher::Batcher;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::request::GenRequest;
use mcsharp::config::OtpConfig;
use mcsharp::eval::{lm_suite, mc::score_suite, EvalOpts};
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::Strategy;
use mcsharp::profile::{Deployment, A100_80G};
use mcsharp::util::bench::Table;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

fn decode_wall(eng: &mut DecodeEngine, corpus: &mcsharp::data::Corpus) -> f64 {
    let mut rng = Rng::new(0x7ab5);
    let mut b = Batcher::new(4, 2048);
    for i in 0..8 {
        b.submit(GenRequest::greedy(i, corpus.sample(12, &mut rng), 12));
    }
    let t0 = Instant::now();
    b.run(eng).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    eng.metrics.tokens_out as f64 / dt
}

fn main() {
    println!("== Table 5: memory / activated params / quality / speedup ==\n");
    for model in ["mix-tiny", "dsvl-s"] {
        println!("--- {model} ---");
        let s = common::setup(model);
        let items = 12;
        let tasks = lm_suite::build(items, 0x7AB5);
        let mut t = Table::new(&[
            "config", "bits", "eval%", "params", "act/tok", "meas tok/s", "roofline x",
        ]);
        // fp16 row
        let (_, acc_fp) = score_suite(&s.base, &mut EvalOpts::default(), &tasks);
        let dep_fp = Deployment::fp16(&s.base.cfg, 1.0);
        let be_fp = NativeBackend::fp(&s.base);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&s.base), &be_fp, None);
        let tps_fp = decode_wall(&mut eng, &s.corpus);
        let lat_fp = dep_fp.decode_latency_s(&A100_80G);
        t.row(vec![
            "16-bit".into(),
            "16.00".into(),
            format!("{acc_fp:.1}"),
            human_bytes(s.base.nbytes_fp16()),
            human_bytes(dep_fp.act_bytes_per_token),
            format!("{tps_fp:.0}"),
            "1.00x".into(),
        ]);
        for (name, strat, otp) in [
            ("Uni-2", Strategy::Uniform, false),
            ("PMQ", Strategy::Pmq, false),
            ("PMQ+OTP", Strategy::Pmq, true),
        ] {
            let q = s.quantize(strat, 2.0, 0x7AB5);
            let routers = if otp {
                let oc = OtpConfig { steps: 150, ..Default::default() };
                Some(train_otp(&q, &s.calib_seqs, &oc, 0x7AB5).routers)
            } else {
                None
            };
            // quality
            let mut counter = (0u64, 0u64);
            let mut pruner = routers.clone().map(|r| OtpPruner { routers: r });
            let mut opts = EvalOpts {
                provider: Some(&q),
                pruner: pruner.as_mut().map(|p| p as &mut dyn mcsharp::moe::Pruner),
                pruning_counter: Some(&mut counter),
            };
            let (_, acc) = score_suite(&q.model, &mut opts, &tasks);
            let keep = if counter.1 > 0 {
                counter.0 as f64 / counter.1 as f64
            } else {
                1.0
            };
            // measured decode
            let be = NativeBackend::quant(&q);
            let pr = routers.clone().map(|r| {
                Box::new(OtpPruner { routers: r }) as Box<dyn mcsharp::moe::Pruner>
            });
            let mut eng = DecodeEngine::new(EngineModel::Quant(&q), &be, pr);
            let tps = decode_wall(&mut eng, &s.corpus);
            // roofline
            let dep = Deployment::quantized(&q, keep, 1.0);
            let speed = lat_fp / dep.decode_latency_s(&A100_80G);
            t.row(vec![
                name.into(),
                format!("{:.2}", q.avg_model_bits()),
                format!("{acc:.1}"),
                human_bytes(q.nbytes()),
                human_bytes(dep.act_bytes_per_token),
                format!("{tps:.0}"),
                format!("{speed:.2}x"),
            ]);
        }
        t.print();
        println!();
    }
    println!("paper shape: PMQ ≈ Uni memory but much better eval%; OTP cuts act/tok");
    println!("further with ~1% quality cost; roofline speedup lands in the 1.6–2x band");
    println!("once embeddings/attention are the remaining fp16 bytes.");
}
