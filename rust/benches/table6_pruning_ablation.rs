//! Table 6 reproduction: the MC# combination ablation.
//! Mixtral-analog: PMQ@2 / PMQ@1.7 / PMQ+ODP / PMQ+OTP (PPL ↓).
//! VLM-analog: PMQ@2 / PMQ@1.6 / PMQ+random / PMQ+OTP (score ↑).
//! Shape: OTP reaches a *higher* pruning ratio than ODP at *better*
//! quality; random pruning at a similar ratio is much worse; keeping
//! bits at 2 and pruning dynamically beats quantizing down to ~1.6.

#[path = "common.rs"]
mod common;

use mcsharp::config::OtpConfig;
use mcsharp::eval::vlm_suite::score_vlm;
use mcsharp::eval::EvalOpts;
use mcsharp::moe::model::ForwardOpts;
use mcsharp::moe::Pruner;
use mcsharp::otp::{train_otp, OdpPruner, OtpPruner, RandomPruner};
use mcsharp::pmq::Strategy;
use mcsharp::util::bench::Table;

fn main() {
    println!("== Table 6: PMQ × dynamic-pruning ablation ==\n");

    // ---------------- Mixtral-analog (PPL) ----------------
    let s = common::setup("mix-tiny");
    let q2 = s.quantize(Strategy::Pmq, 2.0, 0x7AB6);
    let q17 = s.quantize(Strategy::Pmq, 1.7, 0x7AB6);
    let mut t = Table::new(&["method", "bits", "pruning %", "PPL"]);
    t.row(vec!["PMQ".into(), fmt_bits(&q2), "0.0".into(), format!("{:.3}", s.ppl(&q2))]);
    t.row(vec!["PMQ".into(), fmt_bits(&q17), "0.0".into(), format!("{:.3}", s.ppl(&q17))]);
    // ODP (rule-based, Eq. 5)
    {
        let mut odp = OdpPruner::calibrate(&q2.model, &s.calib_seqs);
        let (ppl, ratio) = ppl_with(&s, &q2, &mut odp);
        t.row(vec![
            "PMQ+ODP".into(),
            fmt_bits(&q2),
            format!("{:.1}", 100.0 * ratio),
            format!("{ppl:.3}"),
        ]);
    }
    // OTP (learnable)
    {
        let oc = OtpConfig { steps: 200, ..Default::default() };
        let rep = train_otp(&q2, &s.calib_seqs, &oc, 0x7AB6D);
        let mut otp = OtpPruner { routers: rep.routers };
        let (ppl, ratio) = ppl_with(&s, &q2, &mut otp);
        t.row(vec![
            "PMQ+OTP".into(),
            fmt_bits(&q2),
            format!("{:.1}", 100.0 * ratio),
            format!("{ppl:.3}"),
        ]);
    }
    println!("--- mix-tiny (WikiText2-analog PPL ↓) ---");
    t.print();

    // ---------------- VLM-analog (score) ----------------
    let s2 = common::setup("dsvl-s");
    let q2v = s2.quantize(Strategy::Pmq, 2.0, 0x7AB6);
    let q16v = s2.quantize(Strategy::Pmq, 1.6, 0x7AB6);
    let items = 10;
    let mut t2 = Table::new(&["method", "bits", "pruning %", "Score"]);
    let base_row = |q: &mcsharp::quant::QuantModel, t2: &mut Table| {
        let mut opts = EvalOpts { provider: Some(q), ..Default::default() };
        let r = score_vlm(&q.model, &mut opts, items, 0x7AB60);
        t2.row(vec!["PMQ".into(), fmt_bits(q), "0.0".into(), format!("{:.2}", r.avg)]);
    };
    base_row(&q2v, &mut t2);
    base_row(&q16v, &mut t2);
    // learnable OTP first, so random can match its measured ratio
    let oc = OtpConfig { steps: 200, ..Default::default() };
    let rep = train_otp(&q2v, &s2.calib_seqs, &oc, 0x7AB6E);
    let mut otp = OtpPruner { routers: rep.routers };
    let (score_otp, ratio_otp) = score_with(&s2, &q2v, &mut otp, items);
    let mut rnd = RandomPruner::new(ratio_otp.max(0.05), 0x7AB6F);
    let (score_rnd, ratio_rnd) = score_with(&s2, &q2v, &mut rnd, items);
    t2.row(vec![
        "PMQ+random".into(),
        fmt_bits(&q2v),
        format!("{:.1}", 100.0 * ratio_rnd),
        format!("{score_rnd:.2}"),
    ]);
    t2.row(vec![
        "PMQ+OTP".into(),
        fmt_bits(&q2v),
        format!("{:.1}", 100.0 * ratio_otp),
        format!("{score_otp:.2}"),
    ]);
    println!("\n--- dsvl-s (multimodal avg score ↑) ---");
    t2.print();
    println!("\npaper shape: OTP > ODP (higher ratio, better PPL); OTP ≫ random at");
    println!("matched ratio; PMQ@2+OTP beats quantizing down to ~1.6 bits.");
}

fn fmt_bits(q: &mcsharp::quant::QuantModel) -> String {
    format!("{:.2}", q.avg_model_bits())
}

fn ppl_with(s: &common::Setup, q: &mcsharp::quant::QuantModel, p: &mut dyn Pruner) -> (f64, f64) {
    let mut counter = (0u64, 0u64);
    let ppl = q.model.perplexity(
        &s.eval_seqs,
        &mut ForwardOpts {
            provider: Some(q),
            pruner: Some(p),
            pruning_counter: Some(&mut counter),
            ..Default::default()
        },
    );
    (ppl, 1.0 - counter.0 as f64 / counter.1.max(1) as f64)
}

fn score_with(
    s: &common::Setup,
    q: &mcsharp::quant::QuantModel,
    p: &mut dyn Pruner,
    items: usize,
) -> (f64, f64) {
    let _ = s;
    let mut counter = (0u64, 0u64);
    let mut opts = EvalOpts {
        provider: Some(q),
        pruner: Some(p),
        pruning_counter: Some(&mut counter),
    };
    let r = score_vlm(&q.model, &mut opts, items, 0x7AB60);
    (r.avg, 1.0 - counter.0 as f64 / counter.1.max(1) as f64)
}
