//! Fig. 11/12 reproduction: the Pareto frontier. PMQ's (bits, quality)
//! curve must dominate a cloud of random mixed-precision configurations
//! on both the LLM-analog (PPL) and the VLM-analog (suite average); the
//! VLM curve should be visibly flatter (Fig. 12's observation).

#[path = "common.rs"]
mod common;

use mcsharp::eval::vlm_suite::score_vlm;
use mcsharp::eval::EvalOpts;
use mcsharp::pmq::Strategy;

fn main() {
    let bit_grid = [1.5f64, 1.75, 2.0, 2.25, 2.5];
    let n_random = std::env::var("PARETO_RANDOM").ok().and_then(|v| v.parse().ok()).unwrap_or(8);

    println!("== Fig. 11: mix-tiny Pareto (bits vs PPL, lower better) ==");
    let s = common::setup("mix-tiny");
    println!("series,bits,ppl");
    let mut pmq_pts = Vec::new();
    for &b in &bit_grid {
        let q = s.quantize(Strategy::Pmq, b, 0xFA12);
        let p = s.ppl(&q);
        pmq_pts.push((b, p));
        println!("PMQ,{b:.2},{p:.3}");
    }
    let mut dominated = 0;
    let mut total = 0;
    for i in 0..n_random {
        for &b in &bit_grid {
            let q = s.quantize(Strategy::Random, b, 0x9999 + i as u64);
            let p = s.ppl(&q);
            println!("random,{b:.2},{p:.3}");
            total += 1;
            // a random point is dominated if some PMQ point has ≤ bits and ≤ ppl
            if pmq_pts.iter().any(|&(pb, pp)| pb <= b + 1e-9 && pp <= p + 1e-9) {
                dominated += 1;
            }
        }
    }
    println!("PMQ dominates {dominated}/{total} random configs\n");

    println!("== Fig. 12: dsvl-s Pareto (bits vs VLM avg, higher better) ==");
    let s2 = common::setup("dsvl-s");
    let items = 8;
    println!("series,bits,score");
    let mut pmq2 = Vec::new();
    for &b in &[1.5f64, 2.0, 2.5] {
        let q = s2.quantize(Strategy::Pmq, b, 0xFA12);
        let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
        let r = score_vlm(&q.model, &mut opts, items, 0xFA10);
        pmq2.push((b, r.avg));
        println!("PMQ,{b:.2},{:.2}", r.avg);
    }
    for i in 0..n_random.min(4) {
        for &b in &[1.5f64, 2.0, 2.5] {
            let q = s2.quantize(Strategy::Random, b, 0x8888 + i as u64);
            let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
            let r = score_vlm(&q.model, &mut opts, items, 0xFA10);
            println!("random,{b:.2},{:.2}", r.avg);
        }
    }
    // flatness: relative quality span of the PMQ curve
    let llm_span = (pmq_pts.last().unwrap().1 - pmq_pts[0].1).abs() / pmq_pts.last().unwrap().1;
    let vlm_span = (pmq2.last().unwrap().1 - pmq2[0].1).abs() / pmq2.last().unwrap().1.max(1e-9);
    println!("\ncurve spans (rel): LLM-ppl {llm_span:.3} vs VLM-score {vlm_span:.3}");
    println!("paper shape: PMQ traces the frontier; VLM curve flatter.");
}
