//! Fig. 9/10 reproduction: the allocation-metric ablation. Fig. 9 plots
//! WikiText2-PPL-vs-bits for Mixtral; Fig. 10 plots VLM suite average for
//! DeepSeek-VL2-S. Shape: PMQ at/near the best curve at every bit point
//! with its edge concentrated below 2 bits; single-factor metrics
//! (weights-only, frequency-only) and Hessian trail.
//!
//! Both evaluations are deliberately larger than the other benches' (16
//! held-out sequences for PPL, 32 items/task for the suite): strategy
//! gaps at matched average bits are fractions of a PPL point on a tiny
//! model, so a small eval set is noise-dominated.

#[path = "common.rs"]
mod common;

use mcsharp::eval::vlm_suite::score_vlm;
use mcsharp::eval::EvalOpts;
use mcsharp::moe::model::ForwardOpts;
use mcsharp::pmq::Strategy;
use mcsharp::util::bench::Table;
use mcsharp::util::rng::Rng;

const STRATS: [Strategy; 5] = [
    Strategy::WeightsOnly,
    Strategy::FrequencyOnly,
    Strategy::Hessian,
    Strategy::FNorm,
    Strategy::Pmq,
];

fn main() {
    let bits = [2.5f64, 2.25, 2.0, 1.75, 1.5];

    println!("== Fig. 9: Mixtral-analog PPL vs avg bits per strategy ==\n");
    let s = common::setup("mix-tiny");
    // larger held-out set than Setup::eval_seqs — see module doc
    let mut rng = Rng::new(0xF9EA);
    let eval = s.corpus.batch(16, 64, &mut rng);
    let ppl = |q: &mcsharp::quant::QuantModel| -> f64 {
        q.model
            .perplexity(&eval, &mut ForwardOpts { provider: Some(q), ..Default::default() })
    };
    let mut t = Table::new(&["strategy", "2.50", "2.25", "2.00", "1.75", "1.50"]);
    let mut low_bit: Vec<(Strategy, f64)> = Vec::new();
    for strat in STRATS {
        let mut cells = vec![strat.name().to_string()];
        for &b in &bits {
            let q = s.quantize(strat, b, 0xF19);
            let p = ppl(&q);
            if b == 1.5 {
                low_bit.push((strat, p));
            }
            cells.push(format!("{p:.2}"));
        }
        t.row(cells);
    }
    let fp = s.base.perplexity(&eval, &mut ForwardOpts::default());
    t.row(vec![
        "fp16".into(),
        format!("{fp:.2}"),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
    ]);
    t.print();
    let pmq_low = low_bit.iter().find(|(st, _)| *st == Strategy::Pmq).unwrap().1;
    let best_other = low_bit
        .iter()
        .filter(|(st, _)| *st != Strategy::Pmq)
        .map(|&(_, p)| p)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nfig9 @1.5 bits: PMQ {pmq_low:.2} vs best single-factor {best_other:.2} — {}",
        if pmq_low <= best_other * 1.02 { "PMQ at/near the frontier" } else { "PMQ behind (investigate)" }
    );

    println!("\n== Fig. 10: dsvl-s VLM-suite avg vs avg bits per strategy ==\n");
    let s2 = common::setup("dsvl-s");
    let items = std::env::var("BENCH_ITEMS").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let mut t2 = Table::new(&["strategy", "2.50", "2.00", "1.50"]);
    let mut low_vlm: Vec<(Strategy, f64)> = Vec::new();
    for strat in STRATS {
        let mut cells = vec![strat.name().to_string()];
        for &b in &[2.5f64, 2.0, 1.5] {
            let q = s2.quantize(strat, b, 0xF19);
            let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
            let r = score_vlm(&q.model, &mut opts, items, 0xF10);
            if b == 1.5 {
                low_vlm.push((strat, r.avg));
            }
            cells.push(format!("{:.1}", r.avg));
        }
        t2.row(cells);
    }
    let fp_vlm = score_vlm(&s2.base, &mut EvalOpts::default(), items, 0xF10);
    t2.row(vec!["fp16".into(), format!("{:.1}", fp_vlm.avg), "".into(), "".into()]);
    t2.print();
    let pmq_v = low_vlm.iter().find(|(st, _)| *st == Strategy::Pmq).unwrap().1;
    let best_other_v = low_vlm
        .iter()
        .filter(|(st, _)| *st != Strategy::Pmq)
        .map(|&(_, sc)| sc)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nfig10 @1.5 bits: PMQ {pmq_v:.1} vs best single-factor {best_other_v:.1} — {}",
        if pmq_v >= best_other_v - 1.0 { "PMQ at/near the frontier" } else { "PMQ behind (investigate)" }
    );
    println!("\npaper shape: PMQ at/near the best curve everywhere, edge <2 bits;");
    println!("single-factor metrics and Hessian trail (exact orderings vary with");
    println!("the tiny-model noise floor — the paper's 46-point gaps need 47B params).");
}
