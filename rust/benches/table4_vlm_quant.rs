//! Table 4 reproduction: quantized DeepSeek-VL2-analogs (T/S/L) on the
//! 6-task multimodal suite. Shape: Uni-2bit collapses (catastrophically
//! on the tiny model); PMQ > Hessian at every bit point; bigger models
//! lose less at the same bits.

#[path = "common.rs"]
mod common;

use mcsharp::eval::vlm_suite::{score_vlm, TASKS};
use mcsharp::eval::EvalOpts;
use mcsharp::pmq::Strategy;
use mcsharp::util::bench::Table;

fn main() {
    println!("== Table 4: DeepSeek-VL2-analog multimodal suite ==\n");
    let items = std::env::var("BENCH_ITEMS").ok().and_then(|v| v.parse().ok()).unwrap_or(10);
    for model in ["dsvl-t", "dsvl-s", "dsvl-l"] {
        println!("--- {model} ---");
        let s = common::setup(model);
        let mut header = vec!["Method".to_string(), "Bits".to_string()];
        header.extend(TASKS.iter().map(|t| t.to_string()));
        header.push("Avg.%".into());
        let hdr: Vec<&str> = header.iter().map(|x| x.as_str()).collect();
        let mut table = Table::new(&hdr);
        let fp = score_vlm(&s.base, &mut EvalOpts::default(), items, 0x7AB1E4);
        push(&mut table, "fp16", 16.0, &fp.scores, fp.avg);
        let mut run = |name: &str, strat: Strategy, bits: f64| {
            let q = s.quantize(strat, bits, 0x7AB1E4);
            let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
            let r = score_vlm(&q.model, &mut opts, items, 0x7AB1E4);
            push(&mut table, name, q.avg_model_bits(), &r.scores, r.avg);
        };
        run("Uni", Strategy::Uniform, 3.0);
        run("Uni", Strategy::Uniform, 2.0);
        for &b in &[2.5, 2.0, 1.57] {
            run("Hessian", Strategy::Hessian, b);
        }
        for &b in &[2.5, 2.0, 1.57] {
            run("PMQ", Strategy::Pmq, b);
        }
        table.print();
        println!();
    }
    println!("paper shape: PMQ > Hessian at same bits; larger model = smaller drop.");
}

fn push(table: &mut Table, name: &str, bits: f64, scores: &[(String, f64)], avg: f64) {
    let mut cells = vec![name.to_string(), format!("{bits:.2}")];
    cells.extend(scores.iter().map(|(_, v)| format!("{v:.1}")));
    cells.push(format!("{avg:.2}"));
    table.row(cells);
}
