//! Table 7 reproduction: challenging benchmarks (GSM8K-analog,
//! HumanEval-analog pass@10, NIAH-analog long-context retrieval) across
//! quantization methods. Shape: hard tasks degrade first; Uniform-2bit
//! scores ~0; PMQ keeps NIAH intact and stays ahead of BSP/Hessian;
//! PMQ+OTP costs ≈nothing on top.

#[path = "common.rs"]
mod common;

use mcsharp::backend::NativeBackend;
use mcsharp::config::{repo_path, ModelConfig, OtpConfig, PmqConfig};
use mcsharp::coordinator::engine::EngineModel;
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::eval::hard_suite::score_hard;
use mcsharp::moe::MoeModel;
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::{calibrate, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::train::{TrainConfig, Trainer};
use mcsharp::util::bench::Table;
use mcsharp::util::rng::Rng;

/// The hard tasks need digit / NEEDLE / QUERY tokens, which only the
/// MATH-analog corpus emits — a model pretrained purely on the general
/// corpus floors at 0 on them *at fp16* (capability, not compression).
/// Table 7 therefore uses a mix-tiny pretrained on an alternating
/// General+Math curriculum (cached like the other checkpoints), with
/// calibration/eval sets blended the same way.
fn blended_setup() -> common::Setup {
    let cfg = ModelConfig::load("mix-tiny").expect("config");
    let path = repo_path("checkpoints/mix-tiny-blend-s1500.bin");
    let base = match MoeModel::load(&path) {
        Ok(m) if m.cfg == cfg => m,
        _ => {
            let tc = TrainConfig { steps: 1500, ..Default::default() };
            let mut t = Trainer::new(&cfg, tc);
            let gen = Corpus::new(CorpusKind::General, 0xDA7A);
            let math = Corpus::new(CorpusKind::Math, 0xDA7A);
            println!("(pretraining blended mix-tiny, 1500 steps...)");
            for i in 0..1500 {
                t.step(if i % 2 == 0 { &gen } else { &math });
            }
            t.model.save(&path).expect("save");
            t.model
        }
    };
    let gen = Corpus::new(CorpusKind::General, 0xDA7A);
    let math = Corpus::new(CorpusKind::Math, 0xDA7A);
    let mut rng = Rng::new(0xBE7C);
    let mut calib_seqs = gen.batch(4, 64, &mut rng);
    calib_seqs.extend(math.batch(4, 64, &mut rng));
    let cal = calibrate(&base, &calib_seqs, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let mut eval_seqs = gen.batch(2, 48, &mut rng);
    eval_seqs.extend(math.batch(2, 48, &mut rng));
    common::Setup { base, cal, eps, pmq, corpus: gen, eval_seqs, calib_seqs }
}

fn main() {
    println!("== Table 7: GSM8K~ / HumanEval~(p@10) / NIAH~ ==\n");
    let s = blended_setup();
    let n = std::env::var("BENCH_ITEMS").ok().and_then(|v| v.parse().ok()).unwrap_or(24);
    let ctx = 48;
    let mut t = Table::new(&["method", "bits", "GSM8K~", "HumanEval~", "NIAH~"]);

    // fp16
    {
        let be = NativeBackend::fp(&s.base);
        let sc = score_hard(EngineModel::Fp(&s.base), &be, None, n, ctx, 0x7AB7);
        t.row(vec![
            "fp16".into(),
            "16.00".into(),
            format!("{:.1}", sc.gsm),
            format!("{:.1}", sc.humaneval_p10),
            format!("{:.1}", sc.niah),
        ]);
    }
    let mut run = |name: &str, strat: Strategy, bits: f64, otp: bool| {
        let q = s.quantize(strat, bits, 0x7AB7);
        let be = NativeBackend::quant(&q);
        let pruner = if otp {
            let oc = OtpConfig { steps: 150, ..Default::default() };
            let rep = train_otp(&q, &s.calib_seqs, &oc, 0x7AB7D);
            Some(Box::new(OtpPruner { routers: rep.routers }) as Box<dyn mcsharp::moe::Pruner>)
        } else {
            None
        };
        let sc = score_hard(EngineModel::Quant(&q), &be, pruner, n, ctx, 0x7AB7);
        t.row(vec![
            name.into(),
            format!("{:.2}", q.avg_model_bits()),
            format!("{:.1}", sc.gsm),
            format!("{:.1}", sc.humaneval_p10),
            format!("{:.1}", sc.niah),
        ]);
    };
    run("Uniform", Strategy::Uniform, 3.0, false);
    run("Uniform", Strategy::Uniform, 2.0, false);
    run("BSP", Strategy::BspLike, 2.5, false);
    run("Hessian", Strategy::Hessian, 2.5, false);
    run("Hessian", Strategy::Hessian, 2.0, false);
    run("PMQ", Strategy::Pmq, 2.5, false);
    run("PMQ+OTP", Strategy::Pmq, 2.5, true);
    run("PMQ", Strategy::Pmq, 2.0, false);
    run("PMQ+OTP", Strategy::Pmq, 2.0, true);
    t.print();
    println!("\ntestbed honesty: fp16 itself sits near the per-digit chance floor");
    println!("(~10%) on these generation tasks — a 3.5M-param model has marginal");
    println!("arithmetic/retrieval capability, so method orderings here are noise.");
    println!("The transferable Table 7 claim (hard tasks degrade before MC tasks)");
    println!("is visible against §T2: the MC suite moves ≤2% under 2-bit");
    println!("compression while these tasks sit at/near floor at every width.");
}
