//! Ablation: Gumbel-Softmax temperature schedule for OTP training
//! (Eq. 13 — "as τ→0 the predicted value approaches one-hot"). Compares
//! the annealed default (4 → 0.5) against fixed-high, fixed-low, and a
//! no-anneal mid temperature, at λ=1, reporting the learned pruning
//! ratio and post-pruning PPL.
//!
//! Expected shape: annealing explores early (high τ ⇒ soft masks, stable
//! gradients) and commits late (low τ ⇒ near-one-hot), reaching an equal
//! or better ratio/PPL trade-off than any fixed temperature; fixed-low
//! risks premature collapse, fixed-high never sharpens.

#[path = "common.rs"]
mod common;

use mcsharp::config::OtpConfig;
use mcsharp::moe::model::ForwardOpts;
use mcsharp::moe::Pruner;
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::Strategy;
use mcsharp::util::bench::Table;

fn main() {
    println!("== Ablation: OTP Gumbel-Softmax temperature schedule ==\n");
    let s = common::setup("mix-tiny");
    let q = s.quantize(Strategy::Pmq, 2.0, 0xAB3C);
    let ppl_unpruned = s.ppl(&q);
    println!("PMQ@2.0 unpruned PPL {ppl_unpruned:.3}\n");

    let schedules: &[(&str, f32, f32)] = &[
        ("anneal 4→0.5", 4.0, 0.5),
        ("fixed 4.0", 4.0, 4.0),
        ("fixed 1.0", 1.0, 1.0),
        ("fixed 0.2", 0.2, 0.2),
    ];
    let mut t = Table::new(&["schedule", "trained mask %", "eval pruned %", "PPL"]);
    for &(name, t0, t1) in schedules {
        let oc = OtpConfig { tau_start: t0, tau_end: t1, steps: 200, ..Default::default() };
        let rep = train_otp(&q, &s.calib_seqs, &oc, 0xAB3D);
        let trained_ratio = rep.curve.last().map(|c| c.1).unwrap_or(0.0);
        let mut pruner = OtpPruner { routers: rep.routers };
        let mut counter = (0u64, 0u64);
        let ppl = q.model.perplexity(
            &s.eval_seqs,
            &mut ForwardOpts {
                provider: Some(&q),
                pruner: Some(&mut pruner as &mut dyn Pruner),
                pruning_counter: Some(&mut counter),
                ..Default::default()
            },
        );
        let eval_ratio = 1.0 - counter.0 as f64 / counter.1.max(1) as f64;
        t.row(vec![
            name.into(),
            format!("{:.1}", 100.0 * trained_ratio),
            format!("{:.1}", 100.0 * eval_ratio),
            format!("{ppl:.3}"),
        ]);
    }
    t.print();
    println!("\nshape: the annealed schedule matches or beats fixed temperatures on");
    println!("the (pruning ratio, PPL) trade-off; fixed-high stays soft in training");
    println!("(trained%≠eval%), fixed-low can lock in early masks.");
}
