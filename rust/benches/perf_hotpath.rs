//! §Perf instrument: microbenchmarks of every hot path, used for the
//! optimization pass (EXPERIMENTS.md §Perf). Not a paper table — this is
//! the profiler for L3 (native kernels, engine step, batcher overhead)
//! plus the PJRT call path, and prints the L1 VMEM/MXU structure
//! estimates for the Pallas kernels.

#[path = "common.rs"]
mod common;

use std::time::Duration;

use mcsharp::backend::{ExpertBackend, NativeBackend, PjrtBackend};
use mcsharp::coordinator::client::Client;
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel, SeqState};
use mcsharp::moe::model::{ExpertId, ExpertProvider, ForwardOpts};
use mcsharp::pmq::Strategy;
use mcsharp::profile::dequant_matmul_estimate;
use mcsharp::quant::qlinear::QuantLinear;
use mcsharp::quant::qmodel::{QuantExpert, QuantModel};
use mcsharp::quant::{binary::BinaryMatrix, kernels, packed::PackedMatrix, rtn};
use mcsharp::runtime::Runtime;
use mcsharp::tensor::Tensor2;
use mcsharp::util::bench::{report, time, Stats};
use mcsharp::util::json::{self, Value};
use mcsharp::util::rng::Rng;

/// Forces the degenerate per-token path through the same dispatcher: the
/// default `expert_ffn_batch_acc` loops this row method, re-decoding
/// every packed tile per token — the pre-refactor eval behaviour.
struct RowOnly<'a>(&'a QuantModel);

impl ExpertProvider for RowOnly<'_> {
    fn expert_ffn_acc(&self, layer: usize, id: ExpertId, x: &[f32], w: f32, out: &mut [f32]) {
        self.0.expert_ffn_acc(layer, id, x, w, out);
    }
}

fn main() {
    // `cargo bench --bench perf_hotpath -- --smoke`: CI's bench-rot
    // gate — compile everything, run each synthetic section for ~one
    // iteration, and skip the sections that pretrain zoo models.
    let smoke = std::env::args().any(|a| a == "--smoke");
    // `--json`: additionally write the kernel-section rows to
    // BENCH_perf_hotpath.json at the repo root (machine-readable bench
    // trajectory; CI uploads it as an artifact).
    let json_out = std::env::args().any(|a| a == "--json");
    let budget = if smoke { Duration::from_millis(2) } else { Duration::from_millis(300) };
    let mut rng = Rng::new(0x9E2F);
    let (h, f) = (128usize, 256usize);
    let w = Tensor2::randn(h, f, &mut rng, 1.0);
    let x: Vec<f32> = (0..h).map(|_| rng.normal()).collect();

    println!("== matvec kernels (one [128]x[128,256] matvec) ==");
    {
        let mut y = vec![0.0f32; f];
        let s = time(budget, 20_000, || {
            y.fill(0.0);
            for (r, &xr) in x.iter().enumerate() {
                mcsharp::tensor::axpy(xr, w.row(r), &mut y);
            }
            std::hint::black_box(&y);
        });
        report("matvec f32", &s);
    }
    for bits in [2u8, 3] {
        let (c, sc, z) = rtn::quantize_rtn(&w, bits, 32);
        let pm = PackedMatrix::from_codes(&c, sc, z, h, f, bits, 32);
        let mut y = vec![0.0f32; f];
        let s = time(budget, 20_000, || {
            y.fill(0.0);
            pm.matvec_fused(&x, &mut y);
            std::hint::black_box(&y);
        });
        report(&format!("matvec packed {bits}-bit (fused dequant)"), &s);
    }
    {
        let bm = BinaryMatrix::binarize(&w);
        let mut y = vec![0.0f32; f];
        let s = time(budget, 20_000, || {
            y.fill(0.0);
            bm.matvec_fused(&x, &mut y);
            std::hint::black_box(&y);
        });
        report("matvec binary 1-bit (Eq. 9)", &s);
    }

    // The acceptance rows for the kernel-layer refactor (EXPERIMENTS.md
    // §Kernels): per bit-width, (a) unfused — dequantize the whole matrix
    // then dense-accumulate, the pre-kernel baseline shape — vs (b) the
    // fused kernel on the scalar path (`force_scalar`) vs (c) the fused
    // kernel on the SIMD path (host permitting). Fused must beat unfused
    // on every row — asserted here, so the CI bench-smoke run *is* the
    // perf gate. 1-bit rows run the binary Eq. 9 kernel.
    println!("\n== fused dequant x matmul kernels: unfused vs fused-scalar vs fused-SIMD ==");
    let (kernel_rows, host_simd) = {
        let simd = kernels::simd_available();
        println!("  host SIMD path: {}", if simd { "avx2+fma" } else { "(none — scalar only)" });
        let t_mm = 16usize;
        let xb = Tensor2::randn(t_mm, h, &mut rng, 1.0);
        let mut rows: Vec<Value> = Vec::new();
        for bits in [1u8, 2, 3, 4] {
            let ql = if bits == 1 {
                QuantLinear::Binary(BinaryMatrix::binarize(&w))
            } else {
                let (c, sc, z) = rtn::quantize_rtn(&w, bits, 32);
                QuantLinear::Packed(PackedMatrix::from_codes(&c, sc, z, h, f, bits, 32))
            };
            let mut bench_op = |op: &str, t: usize, x_op: &[f32]| {
                let mut y = vec![0.0f32; t * f];
                let unfused = time(budget, 20_000, || {
                    y.fill(0.0);
                    let wd = ql.dequantize();
                    for ti in 0..t {
                        let yr = &mut y[ti * f..][..f];
                        for (r, &xr) in x_op[ti * h..][..h].iter().enumerate() {
                            if xr != 0.0 {
                                mcsharp::tensor::axpy(xr, wd.row(r), yr);
                            }
                        }
                    }
                    std::hint::black_box(&y);
                });
                let run_fused = |y: &mut Vec<f32>| {
                    y.fill(0.0);
                    if t == 1 {
                        ql.matvec_acc(x_op, y);
                    } else {
                        let xt = Tensor2::from_vec(t, h, x_op.to_vec());
                        let mut yt = Tensor2::from_vec(t, f, std::mem::take(y));
                        ql.matmul_acc(&xt, &mut yt);
                        *y = yt.data;
                    }
                    std::hint::black_box(&y);
                };
                let scalar =
                    kernels::force_scalar(|| time(budget, 20_000, || run_fused(&mut y)));
                let simd_stats = simd.then(|| time(budget, 20_000, || run_fused(&mut y)));
                report(&format!("{op} {bits}-bit unfused (dequant+dense)"), &unfused);
                report(&format!("{op} {bits}-bit fused scalar"), &scalar);
                if let Some(s) = &simd_stats {
                    report(&format!("{op} {bits}-bit fused simd"), s);
                }
                let fused_best =
                    simd_stats.as_ref().map_or(scalar.p50_ns, |s| s.p50_ns.min(scalar.p50_ns));
                assert!(
                    fused_best < unfused.p50_ns,
                    "fused kernel must beat unfused dequant+matmul ({op}, {bits}-bit): \
                     {fused_best} ns !< {} ns",
                    unfused.p50_ns
                );
                let row_json = |st: &Stats| {
                    json::obj(vec![
                        ("mean_ns", json::num(st.mean_ns)),
                        ("p50_ns", json::num(st.p50_ns)),
                        ("p95_ns", json::num(st.p95_ns)),
                        ("iters", json::num(st.iters as f64)),
                    ])
                };
                let (simd_json, simd_speedup) = match &simd_stats {
                    Some(st) => (row_json(st), json::num(scalar.p50_ns / st.p50_ns)),
                    None => (Value::Null, Value::Null),
                };
                rows.push(json::obj(vec![
                    ("op", json::s(op)),
                    ("bits", json::num(bits as f64)),
                    ("tokens", json::num(t as f64)),
                    ("unfused", row_json(&unfused)),
                    ("fused_scalar", row_json(&scalar)),
                    ("fused_simd", simd_json),
                    (
                        "speedup_fused_vs_unfused",
                        json::num(unfused.p50_ns / fused_best),
                    ),
                    ("speedup_simd_vs_scalar", simd_speedup),
                ]));
            };
            bench_op("matvec", 1, &x);
            bench_op("matmul", t_mm, &xb.data);
        }
        (rows, simd)
    };
    std::hint::black_box(&kernel_rows);

    // The acceptance metric for the expert-grouped dispatch refactor
    // (EXPERIMENTS.md §Perf): one packed expert over a G-row token group,
    // per-token (G tile decodes) vs grouped (1 tile decode).
    println!("\n== grouped vs per-token quant expert (2-bit [128->256->128], G-row group) ==");
    {
        let pack = |w: &Tensor2| {
            let (c, sc, z) = rtn::quantize_rtn(w, 2, 32);
            QuantLinear::Packed(PackedMatrix::from_codes(&c, sc, z, w.rows, w.cols, 2, 32))
        };
        let qe = QuantExpert {
            wg: pack(&Tensor2::randn(h, f, &mut rng, 1.0)),
            wu: pack(&Tensor2::randn(h, f, &mut rng, 1.0)),
            wd: pack(&Tensor2::randn(f, h, &mut rng, 1.0)),
            bits: 2,
        };
        for g in [1usize, 2, 4, 8, 16] {
            let xb = Tensor2::randn(g, h, &mut rng, 1.0);
            let mut out = Tensor2::zeros(g, h);
            let st = time(budget, 5_000, || {
                out.data.fill(0.0);
                for i in 0..g {
                    qe.ffn_row_acc(xb.row(i), 1.0, out.row_mut(i));
                }
                std::hint::black_box(&out);
            });
            report(&format!("per-token x{g} (decode {g}x)"), &st);
            let st = time(budget, 5_000, || {
                out.data.fill(0.0);
                qe.ffn_batch_acc(&xb, &mut out);
                std::hint::black_box(&out);
            });
            report(&format!("grouped   x{g} (decode 1x)"), &st);
        }
    }

    // The deployment half of the refactor (EXPERIMENTS.md §Memory): the
    // same decode workload over an all-resident store vs a PagedStore at
    // half the packed bytes — the paged row pays the paging I/O, the
    // counters show the cache behaviour. Random-init model: no training,
    // so this section also runs in the CI smoke gate.
    println!("\n== expert store: resident vs paged decode (random model, 50% budget) ==");
    {
        let cfg = mcsharp::config::ModelConfig {
            name: "perf-store".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            n_experts: 8,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let base = mcsharp::moe::MoeModel::new(&cfg, 0xA11CE);
        let alloc = vec![vec![2u8; cfg.n_experts]; cfg.n_layers];
        let qs = QuantModel::quantize(
            &base,
            &alloc,
            &mcsharp::config::PmqConfig::default(),
            &mcsharp::quant::qmodel::QuantMethod::Rtn,
        );
        let path = std::env::temp_dir()
            .join(format!("mcsharp-perf-store-{}.q2", std::process::id()))
            .to_string_lossy()
            .into_owned();
        mcsharp::quant::qcheckpoint::save(&qs, &path).unwrap();
        let resident = mcsharp::quant::qcheckpoint::load(&path).unwrap();
        let paged = mcsharp::quant::qcheckpoint::load_paged(
            &path,
            resident.store.total_nbytes() / 2,
        )
        .unwrap();
        let run = |q: &QuantModel, label: &str| {
            let be = NativeBackend::quant(q);
            let mut eng = DecodeEngine::new(EngineModel::Quant(q), &be, None);
            let mut seqs: Vec<SeqState> =
                (0..4).map(|i| SeqState::new(i, vec![1, 9, 17], 1_000_000, cfg.n_layers)).collect();
            let st = time(budget, 2_000, || {
                let mut batch: Vec<&mut SeqState> = seqs.iter_mut().collect();
                eng.step(&mut batch).unwrap();
            });
            report(label, &st);
        };
        run(&resident, "engine.step resident store (4 seqs)");
        run(&paged, "engine.step paged @50%     (4 seqs)");
        let c = paged.store.counters();
        println!(
            "paged counters: hits {} misses {} evictions {} prefetch-hits {} peak {} B (budget {} B)",
            c.hits,
            c.misses,
            c.evictions,
            c.prefetch_hits,
            c.peak_resident_bytes,
            paged.store.budget_bytes().unwrap_or(0)
        );
        std::fs::remove_file(&path).ok();
    }

    // Sharded residency (EXPERIMENTS.md §Sharding): the same decode
    // workload with the experts paged over the wire from two loopback
    // shard servers. The remote row pays one batched FETCH per layer
    // miss-set; the gauges quantify the wire traffic. Random-init model,
    // so this section runs in the CI smoke gate, and its block rides the
    // --json artifact.
    println!("\n== expert store: remote decode (coordinator + 2 loopback shards, 50% budget) ==");
    let sharding_row = {
        let cfg = mcsharp::config::ModelConfig {
            name: "perf-shard".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            n_experts: 8,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let base = mcsharp::moe::MoeModel::new(&cfg, 0x5A4D);
        let alloc = vec![vec![2u8; cfg.n_experts]; cfg.n_layers];
        let qs = QuantModel::quantize(
            &base,
            &alloc,
            &mcsharp::config::PmqConfig::default(),
            &mcsharp::quant::qmodel::QuantMethod::Rtn,
        );
        let path = std::env::temp_dir()
            .join(format!("mcsharp-perf-shard-{}.q2", std::process::id()))
            .to_string_lossy()
            .into_owned();
        mcsharp::quant::qcheckpoint::save(&qs, &path).unwrap();
        let resident = mcsharp::quant::qcheckpoint::load(&path).unwrap();
        let spawn_shard = |layers: std::ops::Range<usize>| {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let source =
                mcsharp::quant::qcheckpoint::ShardSource::open(&path, layers).unwrap();
            std::thread::spawn(move || {
                let _ = mcsharp::coordinator::server::serve_shard(listener, &source, None);
            });
            addr
        };
        let shards = vec![spawn_shard(0..1), spawn_shard(1..2)];
        let budget_bytes = resident.store.total_nbytes() / 2;
        let remote =
            mcsharp::quant::qcheckpoint::load_remote(&path, &shards, budget_bytes, 2_000)
                .unwrap();
        let run = |q: &QuantModel, label: &str| {
            let be = NativeBackend::quant(q);
            let mut eng = DecodeEngine::new(EngineModel::Quant(q), &be, None);
            let mut seqs: Vec<SeqState> =
                (0..4).map(|i| SeqState::new(i, vec![1, 9, 17], 1_000_000, cfg.n_layers)).collect();
            let st = time(budget, 2_000, || {
                let mut batch: Vec<&mut SeqState> = seqs.iter_mut().collect();
                eng.step(&mut batch).unwrap();
            });
            report(label, &st);
            st
        };
        let st_res = run(&resident, "engine.step resident store (4 seqs)");
        let st_rem = run(&remote, "engine.step remote @50%    (4 seqs)");
        let r = remote.store.remote_stats().expect("remote store reports fetch stats");
        println!(
            "remote gauges: fetch_rpcs {} prefetch_rpcs {} fetched {} B fetch_p95 {} us shards {}/{}",
            r.fetch_rpcs, r.prefetch_rpcs, r.fetched_bytes, r.fetch_p95_us, r.shards_up, r.shards_total
        );
        std::fs::remove_file(&path).ok();
        let row_json = |st: &Stats| {
            json::obj(vec![
                ("mean_ns", json::num(st.mean_ns)),
                ("p50_ns", json::num(st.p50_ns)),
                ("p95_ns", json::num(st.p95_ns)),
                ("iters", json::num(st.iters as f64)),
            ])
        };
        json::obj(vec![
            ("op", json::s("engine_step_4seq")),
            ("shards", json::num(2.0)),
            ("budget_frac", json::num(0.5)),
            ("resident", row_json(&st_res)),
            ("remote", row_json(&st_rem)),
            ("remote_fetch_rpcs", json::num(r.fetch_rpcs as f64)),
            ("remote_prefetch_rpcs", json::num(r.prefetch_rpcs as f64)),
            ("remote_fetched_bytes", json::num(r.fetched_bytes as f64)),
            ("remote_fetch_p95_us", json::num(r.fetch_p95_us as f64)),
        ])
    };

    // Serving-side acceptance rows for the serve path (EXPERIMENTS.md
    // §Serving), all driven through the first-class protocol-v1 Client:
    // (a) the same TCP server under 1 vs 8 concurrent clients (cross-
    // request continuous batching), and (b) ONE connection submitting
    // the same workload serially (lockstep, the old protocol's ceiling)
    // vs pipelined (tagged v1, all requests in flight at once). The
    // printed steps count is the structural proof (fewer steps per
    // generated token), tok/s is the testbed-specific realization.
    // Random-init model: no pretraining, so this section runs in the CI
    // smoke gate.
    println!("\n== serving throughput: shared scheduler, protocol v1 ==");
    {
        let cfg = mcsharp::config::ModelConfig {
            name: "perf-serve".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            n_experts: 8,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let base = mcsharp::moe::MoeModel::new(&cfg, 0x5E21E);
        let (reqs_per_client, max_new) = if smoke { (2usize, 4usize) } else { (8, 16) };
        // no gather window anywhere: every row runs the identical
        // config, so speedups come purely from requests overlapping in
        // the shared active set (a window would tax the serial rows'
        // idle→busy transitions and bias the comparison)
        let sc = mcsharp::config::ServingConfig { max_batch: 8, ..Default::default() };
        // one serve_with run over a fresh engine; `drive` does the
        // client work; returns (wall seconds, lifetime engine steps)
        let run = |total: usize, drive: &(dyn Fn(std::net::SocketAddr) + Sync)| -> (f64, u64) {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let steps = std::sync::atomic::AtomicU64::new(0);
            let t0 = std::time::Instant::now();
            std::thread::scope(|s| {
                s.spawn(|| {
                    let be = NativeBackend::fp(&base);
                    let engine = std::sync::Mutex::new(DecodeEngine::new(
                        EngineModel::Fp(&base),
                        &be,
                        None,
                    ));
                    mcsharp::coordinator::server::serve_with(listener, &engine, &sc, Some(total))
                        .unwrap();
                    let eng = engine.lock().unwrap();
                    steps.store(eng.metrics.steps, std::sync::atomic::Ordering::Relaxed);
                });
                drive(addr);
            });
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            (dt, steps.load(std::sync::atomic::Ordering::Relaxed))
        };
        fn prompt(c: usize, r: usize) -> Vec<u16> {
            vec![1u16, (2 + c) as u16, (3 + r) as u16]
        }
        // (a) concurrent clients, each lockstep — batching is
        // cross-connection
        for clients in [1usize, 8] {
            let total = clients * reqs_per_client;
            let (dt, steps) = run(total, &|addr| {
                std::thread::scope(|cs| {
                    for c in 0..clients {
                        cs.spawn(move || {
                            let mut client = Client::connect(addr).unwrap();
                            for r in 0..reqs_per_client {
                                let out = client.gen(&prompt(c, r), max_new).unwrap();
                                assert_eq!(out.tokens.len(), 3 + max_new);
                            }
                        });
                    }
                });
            });
            println!(
                "  {clients} client(s) x {reqs_per_client} reqs x {max_new} new tokens (lockstep): \
                 {:8.1} tok/s over {:3} engine steps",
                (total * max_new) as f64 / dt,
                steps,
            );
        }
        // (b) ONE connection, serial vs pipelined — the protocol-v1
        // acceptance row: tagged responses let a single client keep
        // every request in flight, so its requests batch against each
        // other (the CI bench-smoke gate exercises this v1 path on
        // every PR)
        let total = reqs_per_client * 4;
        let reqs: Vec<(Vec<u16>, usize)> =
            (0..total).map(|r| (prompt(r % 5, r / 5), max_new)).collect();
        let (dt_serial, steps_serial) = run(total, &|addr| {
            let mut client = Client::connect(addr).unwrap();
            for (p, n) in &reqs {
                let out = client.gen(p, *n).unwrap();
                assert_eq!(out.tokens.len(), p.len() + n);
            }
        });
        let (dt_pipe, steps_pipe) = run(total, &|addr| {
            let mut client = Client::connect(addr).unwrap();
            let outs = client.gen_pipelined(&reqs).unwrap();
            assert_eq!(outs.len(), reqs.len());
        });
        println!(
            "  1 conn x {total} reqs x {max_new} new tokens serial   : {:8.1} tok/s over {:3} engine steps",
            (total * max_new) as f64 / dt_serial,
            steps_serial,
        );
        println!(
            "  1 conn x {total} reqs x {max_new} new tokens pipelined: {:8.1} tok/s over {:3} engine steps",
            (total * max_new) as f64 / dt_pipe,
            steps_pipe,
        );
        assert!(
            steps_pipe < steps_serial,
            "pipelining one connection must share engine steps: {steps_pipe} !< {steps_serial}"
        );
    }

    // Observability overhead row (EXPERIMENTS.md §Observability): the
    // identical 4-sequence decode step with the span ring recording
    // (the always-on default) vs an engine constructed under
    // MCSHARP_TRACE_OFF=1 (the Tracer reads the variable once, at
    // construction). The only delta between the rows is the ring
    // writes, guard drops, and phase-histogram records — so this row
    // IS the tracing tax, asserted under 3% at p50 (best of 3 runs
    // each). Random-init model: the CI bench-smoke run gates tracing
    // overhead on every PR.
    println!("\n== tracing overhead: span ring on vs MCSHARP_TRACE_OFF=1 ==");
    let trace_row = {
        let cfg = mcsharp::config::ModelConfig {
            name: "perf-trace".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            n_experts: 8,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let base = mcsharp::moe::MoeModel::new(&cfg, 0x7ACE);
        let be = NativeBackend::fp(&base);
        // best-of-3: the delta under test is nanoseconds per step, so
        // take the quietest run of each row rather than one sample
        let bench = |label: &str| -> Stats {
            let mut best: Option<Stats> = None;
            for _ in 0..3 {
                let mut eng = DecodeEngine::new(EngineModel::Fp(&base), &be, None);
                let mut seqs: Vec<SeqState> = (0..4)
                    .map(|i| SeqState::new(i, vec![1, 9, 17], 1_000_000, cfg.n_layers))
                    .collect();
                let st = time(budget, 2_000, || {
                    let mut batch: Vec<&mut SeqState> = seqs.iter_mut().collect();
                    eng.step(&mut batch).unwrap();
                });
                if best.as_ref().map_or(true, |b| st.p50_ns < b.p50_ns) {
                    best = Some(st);
                }
            }
            let st = best.unwrap();
            report(label, &st);
            st
        };
        let traced = bench("engine.step traced    (4 seqs, best of 3)");
        std::env::set_var("MCSHARP_TRACE_OFF", "1");
        let untraced = bench("engine.step trace-off (4 seqs, best of 3)");
        std::env::remove_var("MCSHARP_TRACE_OFF");
        let overhead = traced.p50_ns / untraced.p50_ns - 1.0;
        println!("  tracing overhead at p50: {:+.2}%", overhead * 100.0);
        assert!(
            overhead < 0.03,
            "span-ring tracing must cost under 3% of a decode step: {:.2}% over",
            overhead * 100.0
        );
        let row_json = |st: &Stats| {
            json::obj(vec![
                ("mean_ns", json::num(st.mean_ns)),
                ("p50_ns", json::num(st.p50_ns)),
                ("p95_ns", json::num(st.p95_ns)),
                ("iters", json::num(st.iters as f64)),
            ])
        };
        json::obj(vec![
            ("op", json::s("engine_step_4seq")),
            ("ring_cap", json::num(4096.0)),
            ("traced", row_json(&traced)),
            ("trace_off", row_json(&untraced)),
            ("overhead_frac_p50", json::num(overhead)),
        ])
    };

    // Acceptance rows for the paged-KV engine (EXPERIMENTS.md §KV):
    // (a) prompt ingestion token-at-a-time (`--prefill-chunk 1`, the
    // pre-paging engine's shape) vs chunked through the blocked-matmul
    // attention path — chunked must win; (b) a warm shared prefix must
    // reach the first decode in fewer engine steps than a cold prompt.
    // Both asserted, so the CI bench-smoke run gates the prefill path.
    println!("\n== chunked prefill + prefix sharing (paged KV engine) ==");
    let prefill_rows = {
        let cfg = mcsharp::config::ModelConfig {
            name: "perf-prefill".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            n_experts: 8,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let base = mcsharp::moe::MoeModel::new(&cfg, 0xC0FFE);
        let be = NativeBackend::fp(&base);
        let prompt_len = 32usize;
        // fresh engine (fresh pool) per iteration, distinct leading
        // tokens per iteration: every sample is a genuinely cold prefill
        let mut bench_chunk = |chunk: usize| {
            let mut it = 0u16;
            time(budget, 500, || {
                it = it.wrapping_add(1);
                let mut p: Vec<u16> = (1..=prompt_len as u16).collect();
                p[0] = 1 + it % 61;
                p[1] = 1 + (it / 61) % 61;
                let mut eng = DecodeEngine::new(EngineModel::Fp(&base), &be, None)
                    .with_prefill_chunk(chunk);
                std::hint::black_box(eng.generate(&p, 2).unwrap());
            })
        };
        let tat = bench_chunk(1);
        let chunked = bench_chunk(16);
        report("cold prefill 32-tok prompt, chunk=1  (token-at-a-time)", &tat);
        report("cold prefill 32-tok prompt, chunk=16 (blocked matmul)", &chunked);
        assert!(
            chunked.p50_ns < tat.p50_ns,
            "chunked prefill must beat token-at-a-time: {} ns !< {} ns",
            chunked.p50_ns,
            tat.p50_ns
        );
        // (b) warm vs cold shared prefix: same 32-token system prefix,
        // different tails — the second request adopts both full blocks
        // and skips their prefill steps entirely
        let sys: Vec<u16> = (1..=32).collect();
        let pa: Vec<u16> = sys.iter().copied().chain([40, 41]).collect();
        let pb: Vec<u16> = sys.iter().copied().chain([50, 51]).collect();
        let mut eng = DecodeEngine::new(EngineModel::Fp(&base), &be, None);
        std::hint::black_box(eng.generate(&pa, 4).unwrap());
        let cold_steps = eng.metrics.steps;
        std::hint::black_box(eng.generate(&pb, 4).unwrap());
        let warm_steps = eng.metrics.steps - cold_steps;
        let g = eng.kv_pool().lock().unwrap().gauges();
        println!(
            "  shared 32-tok prefix: cold {cold_steps} steps -> warm {warm_steps} steps \
             (prefix-hit tokens {}, cow copies {})",
            g.prefix_hit_toks, g.cow_copies
        );
        assert!(
            warm_steps < cold_steps,
            "warm shared prefix must save engine steps: {warm_steps} !< {cold_steps}"
        );
        assert!(g.prefix_hit_toks >= 32, "both full blocks must be adopted");
        let row_json = |st: &Stats| {
            json::obj(vec![
                ("mean_ns", json::num(st.mean_ns)),
                ("p50_ns", json::num(st.p50_ns)),
                ("p95_ns", json::num(st.p95_ns)),
                ("iters", json::num(st.iters as f64)),
            ])
        };
        vec![
            json::obj(vec![
                ("op", json::s("cold_prefill")),
                ("prompt_toks", json::num(prompt_len as f64)),
                ("chunk1", row_json(&tat)),
                ("chunk16", row_json(&chunked)),
                ("speedup_chunked", json::num(tat.p50_ns / chunked.p50_ns)),
            ]),
            json::obj(vec![
                ("op", json::s("warm_prefix")),
                ("shared_toks", json::num(32.0)),
                ("cold_steps", json::num(cold_steps as f64)),
                ("warm_steps", json::num(warm_steps as f64)),
                ("prefix_hit_toks", json::num(g.prefix_hit_toks as f64)),
            ]),
        ]
    };

    if json_out {
        let doc = json::obj(vec![
            ("bench", json::s("perf_hotpath")),
            ("section", json::s("kernels")),
            ("harness", json::s("cargo-bench")),
            ("smoke", Value::Bool(smoke)),
            ("host_isa", json::s(if host_simd { "avx2+fma" } else { "scalar" })),
            (
                "shape",
                json::obj(vec![
                    ("d_in", json::num(h as f64)),
                    ("d_out", json::num(f as f64)),
                    ("t_matmul", json::num(16.0)),
                    ("group", json::num(32.0)),
                ]),
            ),
            ("rows", Value::Arr(kernel_rows.clone())),
            ("prefill", Value::Arr(prefill_rows.clone())),
            ("sharding", sharding_row.clone()),
            ("trace", trace_row.clone()),
        ]);
        let path = mcsharp::config::repo_path("BENCH_perf_hotpath.json");
        std::fs::write(&path, doc.to_json()).expect("write BENCH json");
        println!("  wrote {path}");
    }
    std::hint::black_box((&prefill_rows, &sharding_row, &trace_row));

    if smoke {
        println!("\n(--smoke: skipping pretrained-model and PJRT sections)");
        print_l1_estimates();
        return;
    }

    let s = common::setup("mix-tiny");
    let q = s.quantize(Strategy::Pmq, 2.0, 0x9E2F);
    // End-to-end form of the same comparison: quantized perplexity eval
    // through forward_opts, per-token provider vs grouped provider (the
    // dispatcher is identical; only the tile-decode granularity differs).
    println!("\n== quantized eval (mix-tiny PMQ@2): per-token vs grouped provider ==");
    {
        let seqs = s.eval_seqs.clone();
        let row_only = RowOnly(&q);
        let st = time(budget, 50, || {
            let ppl = q.model.perplexity(
                &seqs,
                &mut ForwardOpts { provider: Some(&row_only), ..Default::default() },
            );
            std::hint::black_box(ppl);
        });
        report("eval ppl per-token provider", &st);
        let st = time(budget, 50, || {
            let ppl = q.model.perplexity(
                &seqs,
                &mut ForwardOpts { provider: Some(&q), ..Default::default() },
            );
            std::hint::black_box(ppl);
        });
        report("eval ppl grouped provider  ", &st);
    }

    println!("\n== engine step (batch 8, mix-tiny PMQ@2, native) ==");
    {
        let be = NativeBackend::quant(&q);
        let mut eng = DecodeEngine::new(EngineModel::Quant(&q), &be, None);
        let mut seqs: Vec<SeqState> = (0..8)
            .map(|i| SeqState::new(i, vec![1, 17, 30, 40], 1_000_000, s.base.cfg.n_layers))
            .collect();
        let st = time(budget, 2_000, || {
            let mut batch: Vec<&mut SeqState> = seqs.iter_mut().collect();
            eng.step(&mut batch).unwrap();
        });
        report("engine.step native-quant (8 seqs)", &st);
    }
    {
        let be = NativeBackend::fp(&s.base);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&s.base), &be, None);
        let mut seqs: Vec<SeqState> = (0..8)
            .map(|i| SeqState::new(i, vec![1, 17, 30, 40], 1_000_000, s.base.cfg.n_layers))
            .collect();
        let st = time(budget, 2_000, || {
            let mut batch: Vec<&mut SeqState> = seqs.iter_mut().collect();
            eng.step(&mut batch).unwrap();
        });
        report("engine.step native-fp (8 seqs)", &st);
    }

    // The paper's Table 5/8 speedup claim is a *memory-bound* effect: it
    // appears once weights exceed cache and decode streams them from
    // DRAM. mix-small (~28M params, ~110 MB f32) exceeds this core's LLC;
    // mix-tiny above (cache-resident) shows parity instead.
    println!("\n== engine step (batch 8, mix-small, native — memory-bound regime) ==");
    {
        let cfg = mcsharp::config::ModelConfig::load("mix-small").expect("config");
        let base = mcsharp::train::trainer::train_or_load("mix-small", common::steps_for("mix-small"), true)
            .expect("pretrain");
        // RTN here: quantizer choice does not affect throughput and GPTQ
        // on mix-small would dominate the bench's setup time
        let alloc = vec![vec![2u8; cfg.n_experts]; cfg.n_layers];
        let q = mcsharp::quant::qmodel::QuantModel::quantize(
            &base,
            &alloc,
            &mcsharp::config::PmqConfig::default(),
            &mcsharp::quant::qmodel::QuantMethod::Rtn,
        );
        let run = |em: EngineModel, be: &dyn ExpertBackend, label: &str| {
            let mut eng = DecodeEngine::new(em, be, None);
            let mut seqs: Vec<SeqState> = (0..8)
                .map(|i| SeqState::new(i, vec![1, 17, 30, 40], 1_000_000, cfg.n_layers))
                .collect();
            let st = time(budget, 200, || {
                let mut batch: Vec<&mut SeqState> = seqs.iter_mut().collect();
                eng.step(&mut batch).unwrap();
            });
            report(label, &st);
        };
        let be_q = NativeBackend::quant(&q);
        run(EngineModel::Quant(&q), &be_q, "engine.step native-quant mix-small");
        let be_f = NativeBackend::fp(&base);
        run(EngineModel::Fp(&base), &be_f, "engine.step native-fp    mix-small");
    }

    println!("\n== PJRT expert call (per bucket) ==");
    if let Ok(rt) = Runtime::open_default() {
        let be = PjrtBackend::new(&rt, &q, true).unwrap();
        for t_tok in [4usize, 16, 64] {
            let xb = Tensor2::randn(t_tok, s.base.cfg.d_model, &mut rng, 1.0);
            let st = time(budget, 2_000, || {
                std::hint::black_box(be.expert_batch(0, 0, &xb).unwrap());
            });
            report(&format!("pjrt expert_ffn_q* bucket t{t_tok}"), &st);
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT numbers)");
    }

    print_l1_estimates();
}

fn print_l1_estimates() {
    println!("\n== L1 structure estimates (TPU roofline inputs, DESIGN.md §8) ==");
    for bits in [1u8, 2, 3, 4] {
        let e = dequant_matmul_estimate(16, 128, 128, bits, 32);
        println!(
            "dequant tile bits={bits}: VMEM {} B, intensity {:.1} FLOP/B, {:.2}x f32 HBM traffic",
            e.vmem_bytes, e.intensity, e.traffic_ratio
        );
    }
}
