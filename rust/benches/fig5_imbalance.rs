//! Fig. 5 reproduction: expert quantization loss + activation imbalance,
//! MoE-LLM (mix-tiny / C4-analog) vs MoE-VLM (dsvl-s / M4-analog). The
//! paper's claim: the VLM's distributions are markedly more imbalanced,
//! which is why mixed precision helps it more.

#[path = "common.rs"]
mod common;

use mcsharp::moe::stats::gini;

fn summarize(name: &str) -> (f64, f64) {
    let s = common::setup(name);
    let cfg = &s.base.cfg;
    println!("--- {name} ---");
    println!("layer,expert,eps2bit,frequency");
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            println!("{l},{e},{:.5},{:.4}", s.eps[l][e][1], s.cal.stats.frequency(l, e));
        }
    }
    // imbalance of quant loss and of activation counts
    let mut eps_gini = 0.0;
    let mut act_gini = 0.0;
    for l in 0..cfg.n_layers {
        let eps_row: Vec<f64> = (0..cfg.n_experts).map(|e| s.eps[l][e][1]).collect();
        let act_row: Vec<f64> = (0..cfg.n_experts)
            .map(|e| s.cal.stats.counts[l * cfg.n_experts + e] as f64)
            .collect();
        eps_gini += gini(&eps_row);
        act_gini += gini(&act_row);
    }
    eps_gini /= cfg.n_layers as f64;
    act_gini /= cfg.n_layers as f64;
    println!("quant-loss gini {eps_gini:.3} | activation gini {act_gini:.3}\n");
    (eps_gini, act_gini)
}

fn main() {
    println!("== Fig. 5: LLM vs VLM expert imbalance ==\n");
    let (llm_eps, llm_act) = summarize("mix-tiny");
    let (vlm_eps, vlm_act) = summarize("dsvl-s");
    println!("summary (higher gini = more imbalanced):");
    println!("  mix-tiny (LLM): quant-loss {llm_eps:.3}, activation {llm_act:.3}");
    println!("  dsvl-s  (VLM): quant-loss {vlm_eps:.3}, activation {vlm_act:.3}");
    println!(
        "paper shape holds: {}",
        if vlm_act >= llm_act { "yes (VLM more imbalanced)" } else { "NO — investigate" }
    );
}
