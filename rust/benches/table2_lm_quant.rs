//! Table 2 reproduction: quantized Mixtral-analog on the 8-task zero-shot
//! suite — Uniform vs BSP vs Hessian vs PMQ across the paper's bit range —
//! plus the WikiText2-analog PPL column (the paper's primary LM metric,
//! Tables 2+6 combined).
//!
//! Testbed honesty: a 4-layer tiny model quantizes far more gracefully
//! than 32-layer Mixtral (quantization error compounds with depth), so
//! the paper's −28.6 % Uni@2 *collapse magnitude* does not reproduce
//! here and the easy zero-shot tasks saturate near fp16 at every bit
//! point. What transfers — and what the computed verdict below checks —
//! is the *ordering*: PPL(PMQ) ≤ PPL(Uniform) at matched 2-bit budgets,
//! with monotonic degradation as bits shrink.

#[path = "common.rs"]
mod common;

use mcsharp::eval::{lm_suite, mc::score_suite, EvalOpts};
use mcsharp::pmq::Strategy;
use mcsharp::util::bench::Table;

fn main() {
    println!("== Table 2: Mixtral-analog zero-shot suite ==\n");
    let s = common::setup("mix-tiny");
    let items = std::env::var("BENCH_ITEMS").ok().and_then(|v| v.parse().ok()).unwrap_or(16);
    let tasks = lm_suite::build(items, 0x7AB1E2);
    let mut header = vec!["Method".to_string(), "Bits".to_string()];
    header.extend(lm_suite::TASKS.iter().map(|t| t.to_string()));
    header.push("Avg.%".into());
    header.push("drop".into());
    header.push("PPL".into());
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    let (rows_fp, avg_fp) = score_suite(&s.base, &mut EvalOpts::default(), &tasks);
    push(&mut table, "fp16", 16.0, &rows_fp, avg_fp, avg_fp, s.ppl_fp());

    let mut ppls: Vec<(String, f64, f64)> = Vec::new(); // (method, expert bits, ppl)
    let mut run = |name: &str, strat: Strategy, bits: f64, ppls: &mut Vec<(String, f64, f64)>| {
        let q = s.quantize(strat, bits, 0x7AB1E);
        let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
        let (rows, avg) = score_suite(&q.model, &mut opts, &tasks);
        let ppl = s.ppl(&q);
        ppls.push((name.to_string(), bits, ppl));
        push(&mut table, name, q.avg_model_bits(), &rows, avg, avg_fp, ppl);
    };
    run("Uni", Strategy::Uniform, 3.0, &mut ppls);
    run("Uni", Strategy::Uniform, 2.0, &mut ppls);
    run("BSP", Strategy::BspLike, 2.5, &mut ppls);
    for &b in &[2.5, 2.0, 1.57] {
        run("Hessian", Strategy::Hessian, b, &mut ppls);
    }
    for &b in &common::PAPER_BIT_POINTS {
        run("PMQ", Strategy::Pmq, b, &mut ppls);
    }
    table.print();

    // computed verdict on the transferring claims (module doc)
    let find = |m: &str, b: f64| {
        ppls.iter()
            .find(|(n, bb, _)| n == m && (bb - b).abs() < 0.26)
            .map(|&(_, _, p)| p)
    };
    let uni2 = find("Uni", 2.0);
    let pmq2 = find("PMQ", 2.05);
    let pmq16 = find("PMQ", 1.57);
    println!();
    if let (Some(u), Some(p)) = (uni2, pmq2) {
        println!(
            "PPL @2-bit budget: PMQ {p:.2} vs Uniform {u:.2} — {}",
            if p <= u { "PMQ ahead (paper shape)" } else { "uniform ahead (noise floor)" }
        );
    }
    if let (Some(hi), Some(lo)) = (pmq2, pmq16) {
        println!(
            "PMQ degradation 2.05→1.57 bits: {hi:.2} → {lo:.2} ({})",
            if lo >= hi { "monotone, paper shape" } else { "non-monotone" }
        );
    }
    println!("(collapse *magnitude* needs 32-layer depth — see module doc)");
}

fn push(
    table: &mut Table,
    name: &str,
    bits: f64,
    rows: &[(String, f64)],
    avg: f64,
    avg_fp: f64,
    ppl: f64,
) {
    let mut cells = vec![name.to_string(), format!("{bits:.2}")];
    cells.extend(rows.iter().map(|(_, v)| format!("{v:.1}")));
    cells.push(format!("{avg:.2}"));
    cells.push(format!("{:+.1}%", avg - avg_fp));
    cells.push(format!("{ppl:.2}"));
    table.row(cells);
}
