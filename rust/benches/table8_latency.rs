//! Table 8 reproduction: loading memory + decode tokens/s across
//! platforms. The paper's point is (a) fp16 MoE OOMs consumer GPUs while
//! MC# fits, (b) the compressed model decodes *faster* because decode is
//! memory-bound. We scale our tiny models to the paper's footprints and
//! drive the roofline model with the real packed-byte ratios measured
//! from the quantized models, plus the measured single-core ratio.

#[path = "common.rs"]
mod common;

use mcsharp::config::PmqConfig;
use mcsharp::pmq::{strategies, Strategy};
use mcsharp::profile::{Deployment, A100_80G, RTX_3090};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::util::bench::Table;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

fn main() {
    println!("== Table 8: platform latency / memory (roofline-simulated) ==\n");
    let s = common::setup("mix-tiny");
    // The paper quantizes with GPTQ at group 128; our default group is 32
    // (pinned by the AOT artifacts), whose f32 scale/zero vectors add
    // ~2 bits/weight of overhead and would mask the paper's fits-vs-OOM
    // point. Table 8 is native-accounting only (no artifacts on this
    // path), so quantize at the paper's group here. mix-tiny's dims are
    // 128-divisible; dsvl-s (d_ff=160) below keeps group 32.
    let pmq128 = PmqConfig { group: 128, ..PmqConfig::default() };
    let q = {
        let mut rng = Rng::new(0x7AB8);
        let alloc = strategies::allocation(
            Strategy::Pmq, &s.base, &s.cal, &s.eps, &pmq128, 2.05, &mut rng,
        );
        QuantModel::quantize(&s.base, &alloc, &pmq128, &QuantMethod::Gptq(&s.cal.hessians))
    };

    // scale mix-tiny to Mixtral-8x7b's published footprint (96.8 GB fp16)
    let scale = 96.8e9 / s.base.nbytes_fp16() as f64;
    let fp = Deployment::fp16(&s.base.cfg, scale);
    let mc = Deployment::quantized(&q, 1.0, scale);
    let mc_otp = Deployment::quantized(&q, 0.77, scale); // OTP ~23% pruning

    let mut t = Table::new(&["model", "GPU", "loading memory", "tok/s (roofline)"]);
    let mut row = |name: &str, dep: &Deployment, dev: &mcsharp::profile::DeviceProfile, half: bool| {
        // `half`: model sharded over 2 GPUs (paper's 2×A100 row)
        let eff = if half {
            Deployment { weight_bytes: dep.weight_bytes / 2, act_bytes_per_token: dep.act_bytes_per_token }
        } else {
            dep.clone()
        };
        let fits = eff.fits(dev);
        t.row(vec![
            name.into(),
            format!("{}{}", if half { "2x " } else { "1x " }, dev.name),
            if fits { human_bytes(dep.weight_bytes) } else { format!("OOM ({})", human_bytes(dep.weight_bytes)) },
            match eff.tokens_per_sec(dev) {
                Some(tps) if fits => format!("{tps:.0}"),
                _ => "-".into(),
            },
        ]);
    };
    row("Mixtral-scale fp16", &fp, &A100_80G, true);
    row("Mixtral-scale fp16", &fp, &RTX_3090, false);
    row(&format!("MC# {:.2}-bit", q.avg_model_bits()), &mc, &RTX_3090, false);
    row(&format!("MC# {:.2}-bit +OTP", q.avg_model_bits()), &mc_otp, &RTX_3090, false);

    // DeepSeek-VL2-L-scale rows
    let s2 = common::setup("dsvl-s");
    let q2 = s2.quantize(Strategy::Pmq, 2.5, 0x7AB8);
    let scale2 = 55.0e9 / s2.base.nbytes_fp16() as f64;
    let fp2 = Deployment::fp16(&s2.base.cfg, scale2);
    let mc2 = Deployment::quantized(&q2, 1.0, scale2);
    row("DSVL-L-scale fp16", &fp2, &A100_80G, false);
    row("DSVL-L-scale fp16", &fp2, &RTX_3090, false);
    row(&format!("MC# {:.2}-bit (VLM)", q2.avg_model_bits()), &mc2, &RTX_3090, false);
    t.print();

    println!(
        "\nmeasured packed ratios driving the roofline: mix {:.1}x, dsvl {:.1}x",
        s.base.nbytes_fp16() as f64 / q.nbytes() as f64,
        s2.base.nbytes_fp16() as f64 / q2.nbytes() as f64
    );
    println!("paper shape: fp16 OOMs the 3090; MC# fits AND decodes faster than");
    println!("the fp16 model does on the bigger GPU (memory-bound decode).");
}
