//! Fig. 13 reproduction: mask ratio during OTP training for different
//! sparsity weights λ. Shape: the ratio rises over training and higher λ
//! settles at a higher ratio (paper: λ=1 ≈ 30%).

#[path = "common.rs"]
mod common;

use mcsharp::config::OtpConfig;
use mcsharp::otp::train_otp;
use mcsharp::pmq::Strategy;

fn main() {
    println!("== Fig. 13: OTP mask ratio during training, λ sweep (dsvl-s) ==\n");
    let s = common::setup("dsvl-s");
    let q = s.quantize(Strategy::Pmq, 2.0, 0xF13);
    println!("lambda,step,mask_ratio,distill_loss");
    let mut finals = Vec::new();
    for &lambda in &[1.0f32, 1.5, 2.0] {
        let oc = OtpConfig { lambda, steps: 200, ..Default::default() };
        let rep = train_otp(&q, &s.calib_seqs, &oc, 0xF13D);
        for (step, ratio, loss) in &rep.curve {
            println!("{lambda},{step},{ratio:.4},{loss:.6}");
        }
        finals.push((lambda, rep.curve.last().unwrap().1));
    }
    println!("\nfinal mask ratios:");
    for (l, r) in &finals {
        println!("  λ={l}: {:.1}%", 100.0 * r);
    }
    let monotone = finals.windows(2).all(|w| w[1].1 >= w[0].1 - 0.02);
    println!("paper shape (higher λ ⇒ higher ratio): {}", if monotone { "yes" } else { "NO" });
}
