//! Shared setup for the paper-table benches: pretrained models (cached
//! under checkpoints/), calibration, ε tables, and strategy quantization.
//! Every bench prints the corresponding paper table/figure structure;
//! absolute values are testbed-specific, orderings are the reproduction
//! target (DESIGN.md §5).

#![allow(dead_code)]

use mcsharp::config::{ModelConfig, PmqConfig};
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::moe::model::{ForwardOpts, MoeModel};
use mcsharp::pmq::{calibrate, strategies, Calibration, Strategy};
use mcsharp::quant::error::{eps_table, EpsTable};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::rng::Rng;

/// Pretrain steps per model (big models get fewer steps to keep `cargo
/// bench` tractable on the 1-core testbed; checkpoints are cached).
pub fn steps_for(name: &str) -> usize {
    match name {
        "mix-tiny" | "dsvl-s" => 300,
        "dsvl-t" => 200,
        _ => 150,
    }
}

pub struct Setup {
    pub base: MoeModel,
    pub cal: Calibration,
    pub eps: EpsTable,
    pub pmq: PmqConfig,
    pub corpus: Corpus,
    pub eval_seqs: Vec<Vec<u16>>,
    pub calib_seqs: Vec<Vec<u16>>,
}

/// Train-or-load + calibrate a model by zoo name.
pub fn setup(name: &str) -> Setup {
    let cfg = ModelConfig::load(name).expect("config");
    let base = train_or_load(name, steps_for(name), true).expect("pretrain");
    let kind = if cfg.modalities > 1 { CorpusKind::Multimodal } else { CorpusKind::General };
    let corpus = Corpus::new(kind, 0xDA7A);
    let mut rng = Rng::new(0xBE7C);
    let calib_seqs = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib_seqs, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let eval_seqs = corpus.batch(4, 48, &mut rng);
    Setup { base, cal, eps, pmq, corpus, eval_seqs, calib_seqs }
}

impl Setup {
    /// Quantize with a strategy at an average expert bit-width (GPTQ).
    pub fn quantize(&self, s: Strategy, avg_bits: f64, seed: u64) -> QuantModel {
        let mut rng = Rng::new(seed);
        let alloc =
            strategies::allocation(s, &self.base, &self.cal, &self.eps, &self.pmq, avg_bits, &mut rng);
        QuantModel::quantize(&self.base, &alloc, &self.pmq, &QuantMethod::Gptq(&self.cal.hessians))
    }

    /// Held-out perplexity of a quantized model.
    pub fn ppl(&self, q: &QuantModel) -> f64 {
        q.model.perplexity(
            &self.eval_seqs,
            &mut ForwardOpts { provider: Some(q), ..Default::default() },
        )
    }

    pub fn ppl_fp(&self) -> f64 {
        self.base.perplexity(&self.eval_seqs, &mut ForwardOpts::default())
    }
}

/// The paper's reported bit points (expert-average) used across tables.
pub const PAPER_BIT_POINTS: [f64; 5] = [2.54, 2.30, 2.05, 1.81, 1.57];
