//! Ablation: PMQ is orthogonal to the inner PTQ backend (paper §3.2.3:
//! "Current PTQ methods [14], [26], codebook-based works … can be
//! deployed for MC#"). Same PMQ bit allocation, three quantizers:
//!
//!   RTN   — group-wise round-to-nearest (Eq. 3)
//!   GPTQ  — Hessian error compensation (the paper's default)
//!   AWQ   — activation-aware per-channel scaling (ref. [26])
//!
//! Expected shape: GPTQ best at every bit point (error compensation is
//! exactly what ultra-low bits need); AWQ helps in its design regime
//! (≥2.5 avg bits) but its per-channel scaling saturates the group
//! min/max ranges below ~2 bits — AWQ targets 3/4-bit — so it falls
//! back behind RTN there. The *allocation* (PMQ) is held fixed,
//! demonstrating the orthogonality claim.

#[path = "common.rs"]
mod common;

use mcsharp::moe::model::ForwardOpts;
use mcsharp::pmq::{strategies, Strategy};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::util::bench::Table;
use mcsharp::util::rng::Rng;

fn main() {
    println!("== Ablation: PTQ backend under a fixed PMQ allocation ==\n");
    let s = common::setup("mix-tiny");
    let ppl_fp = s.ppl_fp();
    println!("fp16 PPL {ppl_fp:.3}\n");

    let mut t = Table::new(&["avg bits", "RTN", "AWQ", "GPTQ"]);
    for &avg in &[2.5f64, 2.0, 1.7] {
        let mut rng = Rng::new(0xAB1A);
        let alloc = strategies::allocation(
            Strategy::Pmq, &s.base, &s.cal, &s.eps, &s.pmq, avg, &mut rng,
        );
        let ppl = |m: &QuantMethod| -> f64 {
            let q = QuantModel::quantize(&s.base, &alloc, &s.pmq, m);
            q.model.perplexity(
                &s.eval_seqs,
                &mut ForwardOpts { provider: Some(&q), ..Default::default() },
            )
        };
        let rtn = ppl(&QuantMethod::Rtn);
        let awq = ppl(&QuantMethod::Awq(&s.cal.acts));
        let gptq = ppl(&QuantMethod::Gptq(&s.cal.hessians));
        t.row(vec![
            format!("{avg:.2}"),
            format!("{rtn:.3}"),
            format!("{awq:.3}"),
            format!("{gptq:.3}"),
        ]);
    }
    t.print();
    println!("\nshape: GPTQ dominates at every bit point under the same PMQ");
    println!("allocation (the allocation transfers across quantizers); AWQ wins");
    println!("only in its ≥2.5-bit design regime — per-channel scaling saturates");
    println!("the 2-bit group ranges, as the paper's choice of GPTQ anticipates.");
}
