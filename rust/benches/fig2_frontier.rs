//! Fig. 2 reproduction: quality vs *activated* parameter budget across
//! the model zoo, fp16 vs MC#-compressed. The paper's headline: a
//! compressed big MoE beats an uncompressed small model at the same
//! activated-parameter budget (16-bit = "one standard parameter", so a
//! 2-bit weight counts as 1/8th).

#[path = "common.rs"]
mod common;

use mcsharp::eval::vlm_suite::score_vlm;
use mcsharp::eval::{lm_suite, mc::score_suite, EvalOpts};
use mcsharp::pmq::Strategy;

fn main() {
    println!("== Fig. 2: score vs activated standard-params, fp16 vs MC# ==\n");
    println!("series,model,act_std_params,score");
    let items = 10;
    for model in ["mix-tiny", "mix-small"] {
        let s = common::setup(model);
        let tasks = lm_suite::build(items, 0xF2);
        let (_, acc_fp) = score_suite(&s.base, &mut EvalOpts::default(), &tasks);
        let act_fp = s.base.cfg.activated_params() as f64;
        println!("fp16,{model},{act_fp:.0},{acc_fp:.2}");
        let q = s.quantize(Strategy::Pmq, 2.0, 0xF2);
        let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
        let (_, acc_q) = score_suite(&q.model, &mut opts, &tasks);
        // activated standard params: activated bytes / 2 (fp16 byte-pair)
        let act_q = q.activated_bytes_per_token(1.0) as f64 / 2.0;
        println!("MC#,{model},{act_q:.0},{acc_q:.2}");
    }
    for model in ["dsvl-t", "dsvl-s"] {
        let s = common::setup(model);
        let fp = score_vlm(&s.base, &mut EvalOpts::default(), items, 0xF2);
        let act_fp = s.base.cfg.activated_params() as f64;
        println!("fp16,{model},{act_fp:.0},{:.2}", fp.avg);
        let q = s.quantize(Strategy::Pmq, 2.0, 0xF2);
        let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
        let r = score_vlm(&q.model, &mut opts, items, 0xF2);
        let act_q = q.activated_bytes_per_token(1.0) as f64 / 2.0;
        println!("MC#,{model},{act_q:.0},{:.2}", r.avg);
    }
    println!("\npaper shape: each MC# point sits far left of its fp16 twin at a");
    println!("small score cost — compressed-big beats fp16-small per act-param.");
}
