//! Ablation: the Eq. 7 weighting hyper-parameters (α, β on the
//! significance factors, γ on the quantization error). DESIGN.md §6
//! defaults to α=β=0.5, γ=2; this bench sweeps each around the default at
//! a fixed 2.0-bit budget and reports held-out PPL. Expected: the
//! default sits at/near the best; over-weighting significance (α=β=1)
//! or flattening the error term (γ=1) degrades; pure-ε (α=β=0) lands
//! within noise of the default at 2.0 bits — the same near-tie the
//! paper's Fig. 9 shows between F-norm and PMQ above 2 bits (PMQ's
//! edge is below 2 bits, covered by fig9_fig10_metric_ablation).

#[path = "common.rs"]
mod common;

use mcsharp::config::PmqConfig;
use mcsharp::moe::model::ForwardOpts;
use mcsharp::pmq::{strategies, Strategy};
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::util::bench::Table;
use mcsharp::util::rng::Rng;

fn main() {
    println!("== Ablation: PMQ objective hyper-parameters (Eq. 7) ==\n");
    let s = common::setup("mix-tiny");
    println!("fp16 PPL {:.3}\n", s.ppl_fp());

    let sweep: &[(f64, f64, f64)] = &[
        // (alpha, beta, gamma)
        (0.5, 0.5, 2.0), // paper default
        (1.0, 0.0, 2.0), // frequency-dominant
        (0.0, 1.0, 2.0), // weight-dominant
        (0.0, 0.0, 2.0), // significance off → pure ε (F-norm-like)
        (0.5, 0.5, 1.0), // linear error weighting
        (0.5, 0.5, 3.0), // sharper error weighting
        (1.0, 1.0, 2.0), // both factors full strength
    ];
    let mut t = Table::new(&["alpha", "beta", "gamma", "PPL@2.0b"]);
    for &(alpha, beta, gamma) in sweep {
        let pmq = PmqConfig { alpha, beta, gamma, ..PmqConfig::default() };
        let mut rng = Rng::new(0xAB2B);
        let alloc = strategies::allocation(
            Strategy::Pmq, &s.base, &s.cal, &s.eps, &pmq, 2.0, &mut rng,
        );
        let q = QuantModel::quantize(&s.base, &alloc, &pmq, &QuantMethod::Gptq(&s.cal.hessians));
        let ppl = q.model.perplexity(
            &s.eval_seqs,
            &mut ForwardOpts { provider: Some(&q), ..Default::default() },
        );
        t.row(vec![
            format!("{alpha:.1}"),
            format!("{beta:.1}"),
            format!("{gamma:.1}"),
            format!("{ppl:.3}"),
        ]);
    }
    t.print();
    println!("\nshape: the default (0.5, 0.5, 2) sits at/near the best PPL; pushing");
    println!("significance to full strength (1,1,·) or flattening γ to 1 degrades;");
    println!("pure-ε (0,0,·) ties the default at 2.0 bits, mirroring Fig. 9's");
    println!("F-norm ≈ PMQ above 2 bits (the PMQ edge below 2 bits is in");
    println!("fig9_fig10_metric_ablation).");
}
