//! Fig. 4 reproduction: per-(layer, expert) drop-F-norm, mean routing
//! weight and activation frequency heatmap data for the Mixtral-analog,
//! on the general ("C4") vs domain ("MATH") calibration sets — including
//! the paper's observation that domain data activates fewer experts.

#[path = "common.rs"]
mod common;

use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::moe::stats::gini;
use mcsharp::pmq::calibrate;
use mcsharp::quant::error::drop_fnorm;
use mcsharp::util::rng::Rng;

fn main() {
    println!("== Fig. 4: expert drop F-norm / activated weights / frequencies ==\n");
    let s = common::setup("mix-tiny");
    let mut rng = Rng::new(0xF16);
    for (label, kind) in [("C4-analog", CorpusKind::General), ("MATH-analog", CorpusKind::Math)] {
        let corpus = Corpus::new(kind, 0xDA7A);
        let seqs = corpus.batch(8, 64, &mut rng);
        let cal = calibrate(&s.base, &seqs, 256);
        let fnorm = drop_fnorm(&s.base, &cal.acts);
        println!("--- {label} ---");
        println!("layer,expert,drop_fnorm,mean_weight,frequency");
        for l in 0..s.base.cfg.n_layers {
            for e in 0..s.base.cfg.n_experts {
                println!(
                    "{l},{e},{:.4},{:.4},{:.4}",
                    fnorm[l][e],
                    cal.stats.mean_weight(l, e),
                    cal.stats.frequency(l, e)
                );
            }
        }
        // sparsity summary: how many experts carry 90% of activations
        let mut active = 0usize;
        for l in 0..s.base.cfg.n_layers {
            let mut f: Vec<f64> =
                (0..s.base.cfg.n_experts).map(|e| cal.stats.frequency(l, e)).collect();
            f.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let total: f64 = f.iter().sum();
            let mut cum = 0.0;
            for (i, v) in f.iter().enumerate() {
                cum += v;
                if cum >= 0.9 * total {
                    active += i + 1;
                    break;
                }
            }
        }
        println!(
            "experts covering 90% of routing: {:.1}/{} per layer | gini {:.3}\n",
            active as f64 / s.base.cfg.n_layers as f64,
            s.base.cfg.n_experts,
            (0..s.base.cfg.n_layers)
                .map(|l| {
                    let f: Vec<f64> = (0..s.base.cfg.n_experts)
                        .map(|e| cal.stats.counts[l * s.base.cfg.n_experts + e] as f64)
                        .collect();
                    gini(&f)
                })
                .sum::<f64>()
                / s.base.cfg.n_layers as f64
        );
    }
    println!("paper shape: domain (MATH) calibration is sparser than general (C4).");
}
