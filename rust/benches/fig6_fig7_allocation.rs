//! Fig. 6/7 reproduction: PMQ bit-allocation maps at an average of 2
//! bits — Mixtral-analog (Fig. 6) and DeepSeek-VL2-analog (Fig. 7).

#[path = "common.rs"]
mod common;

use mcsharp::pmq::Strategy;

fn show(name: &str) {
    let s = common::setup(name);
    let q = s.quantize(Strategy::Pmq, 2.0, 0x516);
    println!("--- {name}: per-expert bits (rows = MoE layers) ---");
    for (l, row) in q.allocation.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|b| b.to_string()).collect();
        println!("layer {l:>2}: {}", cells.join(" "));
    }
    let counts = [1u8, 2, 3].map(|b| {
        q.allocation.iter().flatten().filter(|&&x| x == b).count()
    });
    println!(
        "distribution: 1-bit {} | 2-bit {} | 3-bit {}  (avg {:.2})\n",
        counts[0],
        counts[1],
        counts[2],
        q.avg_expert_bits()
    );
}

fn main() {
    println!("== Fig. 6 / Fig. 7: bit-width allocation maps @ avg 2-bit ==\n");
    show("mix-tiny"); // Fig. 6 analog (8 experts / layer)
    show("dsvl-s"); // Fig. 7 analog (16 experts / layer, top-6 + shared)
}
