//! Synthetic corpus generators (C4 / MATH / M4 analogs).

use crate::util::rng::Rng;

use super::vocab::*;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusKind {
    /// Broad topic-mixture text ("C4").
    General,
    /// Narrow arithmetic domain ("MATH").
    Math,
    /// Interleaved patch+caption sequences ("M4").
    Multimodal,
}

/// A generator producing token sequences from a fixed, seeded
/// distribution. The distribution parameters (topic transition tables,
/// patch classes) are themselves derived from the seed, so two `Corpus`
/// instances with the same (kind, seed) are identical.
pub struct Corpus {
    pub kind: CorpusKind,
    n_topics: usize,
    /// Per-topic bigram tables: `trans[topic][prev_bucket]` = distribution
    /// over next-token buckets (dense, NEXT_BUCKETS wide).
    trans: Vec<Vec<Vec<f32>>>,
    /// Per-topic token offset — topics occupy overlapping slices of the
    /// text region so they share some tokens (like natural language).
    topic_base: Vec<u16>,
    topic_span: u16,
    /// Patch classes for the multimodal corpus: each class is a small set
    /// of preferred patch tokens + the caption topic it maps to.
    patch_class_center: Vec<u16>,
}

const NEXT_BUCKETS: usize = 16;

impl Corpus {
    pub fn new(kind: CorpusKind, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xC0_47B5);
        let n_topics = 8;
        let topic_span: u16 = 96;
        let mut trans = Vec::new();
        let mut topic_base = Vec::new();
        for t in 0..n_topics {
            // overlapping topic slices across the text region
            let base = TEXT_BASE + ((t as u16 * 37) % (TEXT_END - TEXT_BASE - topic_span));
            topic_base.push(base);
            let mut table = Vec::new();
            for _ in 0..NEXT_BUCKETS {
                // sparse-ish bigram rows: a few strong transitions + noise
                let mut row = vec![0.05f32; NEXT_BUCKETS];
                for _ in 0..3 {
                    row[rng.below(NEXT_BUCKETS)] += 1.0 + rng.f32() * 3.0;
                }
                table.push(row);
            }
            trans.push(table);
        }
        let patch_class_center: Vec<u16> = (0..n_topics)
            .map(|t| PATCH_BASE + (t as u16 * N_PATCH as u16 / n_topics as u16))
            .collect();
        Corpus { kind, n_topics, trans, topic_base, topic_span, patch_class_center }
    }

    /// Generate one sequence of exactly `len` tokens (BOS-prefixed).
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Vec<u16> {
        match self.kind {
            CorpusKind::General => self.sample_general(len, rng),
            CorpusKind::Math => self.sample_math(len, rng),
            CorpusKind::Multimodal => self.sample_multimodal(len, rng),
        }
    }

    /// Generate `n` sequences.
    pub fn batch(&self, n: usize, len: usize, rng: &mut Rng) -> Vec<Vec<u16>> {
        (0..n).map(|_| self.sample(len, rng)).collect()
    }

    fn topic_token(&self, topic: usize, bucket: usize) -> u16 {
        self.topic_base[topic] + (bucket as u16 * self.topic_span / NEXT_BUCKETS as u16)
    }

    fn sample_topic_text(&self, topic: usize, len: usize, rng: &mut Rng, out: &mut Vec<u16>) {
        let mut bucket = rng.below(NEXT_BUCKETS);
        for _ in 0..len {
            // token = bucket anchor + small intra-bucket jitter (Zipf-ish:
            // anchor token is most likely)
            let jitter = if rng.f32() < 0.6 { 0 } else { rng.below(6) as u16 };
            out.push((self.topic_token(topic, bucket) + jitter).min(TEXT_END - 1));
            bucket = rng.categorical(&self.trans[topic][bucket]);
        }
    }

    fn sample_general(&self, len: usize, rng: &mut Rng) -> Vec<u16> {
        let mut out = vec![BOS];
        while out.len() < len {
            // ~8% of spans are needle/retrieval patterns so models learn
            // the copy skill the NIAH-analog task (Table 7) probes:
            //   NEEDLE d d d  <filler...>  QUERY d d d
            if rng.f32() < 0.08 && len - out.len() > 16 {
                let digits: Vec<u16> =
                    (0..3).map(|_| DIGIT_BASE + rng.below(10) as u16).collect();
                out.push(NEEDLE);
                out.extend(&digits);
                let filler = (4 + rng.below(12)).min(len.saturating_sub(out.len() + 5));
                let topic = rng.below(self.n_topics);
                self.sample_topic_text(topic, filler, rng, &mut out);
                out.push(QUERY);
                out.extend(&digits);
            } else {
                let topic = rng.below(self.n_topics);
                let span = (8 + rng.below(24)).min(len - out.len());
                self.sample_topic_text(topic, span, rng, &mut out);
            }
            if out.len() < len {
                out.push(SEP);
            }
        }
        out.truncate(len);
        out
    }

    fn sample_math(&self, len: usize, rng: &mut Rng) -> Vec<u16> {
        let mut out = vec![BOS];
        let mut prev: Option<u32> = None;
        while out.len() < len {
            // ~30% of equations chain on the previous result (GSM-analog
            // multi-step skill: "a+b=c SEP c+d=e")
            let a = match prev {
                Some(p) if rng.f32() < 0.3 => p.min(99),
                _ => rng.below(100) as u32,
            };
            let b = rng.below(100) as u32;
            let (op, c) = match rng.below(3) {
                0 => (OP_PLUS, a + b),
                1 => (OP_MINUS, a.saturating_sub(b)),
                _ => (OP_TIMES, (a % 12) * (b % 12)),
            };
            let (a, b) = if op == OP_TIMES { (a % 12, b % 12) } else { (a, b) };
            encode_number(a, &mut out);
            out.push(op);
            encode_number(b, &mut out);
            out.push(EQUALS);
            encode_number(c, &mut out);
            out.push(SEP);
            prev = Some(c);
        }
        out.truncate(len);
        out
    }

    fn sample_multimodal(&self, len: usize, rng: &mut Rng) -> Vec<u16> {
        let mut out = vec![BOS];
        while out.len() < len {
            let class = rng.below(self.n_topics);
            // image span: patches clustered around the class center
            out.push(IMG_START);
            let n_patch = 8 + rng.below(8);
            let center = self.patch_class_center[class];
            for _ in 0..n_patch {
                let off = rng.below(N_PATCH / self.n_topics) as u16;
                out.push((center + off).min(PATCH_END - 1));
            }
            out.push(IMG_END);
            // caption: text from the correlated topic
            let cap = 6 + rng.below(12);
            self.sample_topic_text(class, cap, rng, &mut out);
            out.push(SEP);
        }
        out.truncate(len);
        out
    }

    /// The caption topic a patch-class index maps to (used by eval tasks).
    pub fn n_classes(&self) -> usize {
        self.n_topics
    }

    /// Patch tokens for class `c` (used by VLM eval task construction).
    pub fn class_patches(&self, class: usize, n: usize, rng: &mut Rng) -> Vec<u16> {
        let center = self.patch_class_center[class];
        (0..n)
            .map(|_| (center + rng.below(N_PATCH / self.n_topics) as u16).min(PATCH_END - 1))
            .collect()
    }

    /// A caption snippet for class `c`.
    pub fn class_caption(&self, class: usize, n: usize, rng: &mut Rng) -> Vec<u16> {
        let mut out = Vec::new();
        self.sample_topic_text(class, n, rng, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn deterministic_given_seed() {
        let c1 = Corpus::new(CorpusKind::General, 9);
        let c2 = Corpus::new(CorpusKind::General, 9);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(c1.sample(128, &mut r1), c2.sample(128, &mut r2));
    }

    #[test]
    fn lengths_exact() {
        prop::for_all(21, 20, |rng, case| {
            let kind = [CorpusKind::General, CorpusKind::Math, CorpusKind::Multimodal][case % 3];
            let c = Corpus::new(kind, 5);
            let len = 16 + rng.below(200);
            assert_eq!(c.sample(len, rng).len(), len);
        });
    }

    #[test]
    fn general_stays_in_text_region() {
        let c = Corpus::new(CorpusKind::General, 3);
        let mut rng = Rng::new(4);
        for &t in c.sample(512, &mut rng).iter() {
            // text + structure specials (needle spans included, §NIAH)
            assert!(
                t == BOS || t == SEP || t == NEEDLE || t == QUERY || is_text(t),
                "tok {t}"
            );
        }
    }

    #[test]
    fn general_contains_needle_patterns() {
        let c = Corpus::new(CorpusKind::General, 3);
        let mut rng = Rng::new(4);
        let seq = c.sample(2000, &mut rng);
        // needle spans: NEEDLE d d d ... QUERY d d d with matching digits
        let needles: Vec<usize> =
            seq.iter().enumerate().filter(|(_, &t)| t == NEEDLE).map(|(i, _)| i).collect();
        assert!(!needles.is_empty(), "no needle spans generated");
        let mut verified = 0;
        for &ni in &needles {
            if ni + 3 >= seq.len() {
                continue;
            }
            let digits = &seq[ni + 1..ni + 4];
            if let Some(qi) = seq[ni..].iter().position(|&t| t == QUERY) {
                let qi = ni + qi;
                if qi + 3 < seq.len() && &seq[qi + 1..qi + 4] == digits {
                    verified += 1;
                }
            }
        }
        assert!(verified > 0, "no verifiable needle/query pair");
    }

    #[test]
    fn math_equations_are_correct() {
        let c = Corpus::new(CorpusKind::Math, 3);
        let mut rng = Rng::new(4);
        let seq = c.sample(400, &mut rng);
        // parse complete "a op b = c SEP" groups and check arithmetic
        let mut checked = 0;
        let mut i = 1;
        while i < seq.len() {
            let start = i;
            let mut j = i;
            while j < seq.len() && seq[j] != SEP {
                j += 1;
            }
            if j >= seq.len() {
                break;
            }
            let eq = &seq[start..j];
            if let Some(pos_op) = eq.iter().position(|&t| matches!(t, OP_PLUS | OP_MINUS | OP_TIMES)) {
                if let Some(pos_eq) = eq.iter().position(|&t| t == EQUALS) {
                    let a = decode_number(&eq[..pos_op]);
                    let b = decode_number(&eq[pos_op + 1..pos_eq]);
                    let cc = decode_number(&eq[pos_eq + 1..]);
                    if let (Some(a), Some(b), Some(cc)) = (a, b, cc) {
                        let want = match eq[pos_op] {
                            OP_PLUS => a + b,
                            OP_MINUS => a.saturating_sub(b),
                            _ => a * b,
                        };
                        assert_eq!(cc, want, "equation mismatch");
                        checked += 1;
                    }
                }
            }
            i = j + 1;
        }
        assert!(checked >= 5, "only {checked} complete equations parsed");
    }

    #[test]
    fn multimodal_contains_both_modalities() {
        let c = Corpus::new(CorpusKind::Multimodal, 3);
        let mut rng = Rng::new(4);
        let seq = c.sample(256, &mut rng);
        assert!(seq.iter().any(|&t| is_patch(t)));
        assert!(seq.iter().any(|&t| is_text(t)));
        assert!(seq.iter().any(|&t| t == IMG_START));
    }

    #[test]
    fn math_distribution_is_narrower_than_general() {
        // unique-token count: math uses digits+ops only
        let mut rng = Rng::new(7);
        let gen = Corpus::new(CorpusKind::General, 1).sample(2000, &mut rng);
        let math = Corpus::new(CorpusKind::Math, 1).sample(2000, &mut rng);
        let uniq = |s: &[u16]| {
            let mut set = std::collections::BTreeSet::new();
            set.extend(s.iter().cloned());
            set.len()
        };
        assert!(uniq(&math) < uniq(&gen) / 2, "math {} vs general {}", uniq(&math), uniq(&gen));
    }
}
