//! Synthetic corpora standing in for C4 / MATH / M4 (see DESIGN.md §3).
//!
//! Three generators over a shared 512-token vocabulary:
//!
//! * `general` ("C4-analog"): a mixture of topic-specific bigram Markov
//!   chains with Zipf-ish marginals — broad distribution, activates many
//!   experts.
//! * `math` ("MATH-analog"): tokenized arithmetic equations
//!   `a OP b = c` — narrow domain distribution; the paper's Fig. 4 shows
//!   far sparser expert activation on such data.
//! * `multimodal` ("M4-analog"): interleaved `[IMG] patch… [/IMG]
//!   caption…` sequences where the patch "class" determines the caption
//!   topic. Modality-clustered token statistics drive the stronger expert
//!   imbalance the paper reports for MoE-VLMs (Fig. 5).
//!
//! Token-id layout (shared with the eval suites):
//! `0..16` specials, `16..384` text, `384..512` patch tokens.

pub mod corpus;
pub mod vocab;

pub use corpus::{Corpus, CorpusKind};
pub use vocab::*;
