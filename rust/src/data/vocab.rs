//! Fixed 512-token vocabulary layout shared by generators and eval suites.

pub const VOCAB_SIZE: usize = 512;

// -- special tokens --------------------------------------------------------
pub const PAD: u16 = 0;
pub const BOS: u16 = 1;
pub const EOS: u16 = 2;
pub const SEP: u16 = 3;
pub const IMG_START: u16 = 4;
pub const IMG_END: u16 = 5;
/// Needle marker for the NIAH-analog long-context task.
pub const NEEDLE: u16 = 6;
pub const QUERY: u16 = 7;
pub const ANSWER: u16 = 8;
pub const N_SPECIAL: u16 = 16;

// -- text region ------------------------------------------------------------
pub const TEXT_BASE: u16 = 16;
pub const TEXT_END: u16 = 384; // exclusive
pub const N_TEXT: usize = (TEXT_END - TEXT_BASE) as usize;

// digits/operators live at the start of the text region (math corpus)
pub const DIGIT_BASE: u16 = TEXT_BASE; // tokens 16..26 are digits 0..9
pub const OP_PLUS: u16 = 26;
pub const OP_MINUS: u16 = 27;
pub const OP_TIMES: u16 = 28;
pub const EQUALS: u16 = 29;

// -- patch (visual) region ---------------------------------------------------
pub const PATCH_BASE: u16 = 384;
pub const PATCH_END: u16 = 512; // exclusive
pub const N_PATCH: usize = (PATCH_END - PATCH_BASE) as usize;

/// Encode a non-negative number as digit tokens (most significant first).
pub fn encode_number(mut n: u32, out: &mut Vec<u16>) {
    let mut digits = [0u16; 10];
    let mut len = 0;
    loop {
        digits[len] = DIGIT_BASE + (n % 10) as u16;
        len += 1;
        n /= 10;
        if n == 0 {
            break;
        }
    }
    for i in (0..len).rev() {
        out.push(digits[i]);
    }
}

/// Decode digit tokens back to a number; `None` on any non-digit token.
pub fn decode_number(tokens: &[u16]) -> Option<u32> {
    if tokens.is_empty() {
        return None;
    }
    let mut n: u32 = 0;
    for &t in tokens {
        if !(DIGIT_BASE..DIGIT_BASE + 10).contains(&t) {
            return None;
        }
        n = n.checked_mul(10)?.checked_add((t - DIGIT_BASE) as u32)?;
    }
    Some(n)
}

pub fn is_text(t: u16) -> bool {
    (TEXT_BASE..TEXT_END).contains(&t)
}

pub fn is_patch(t: u16) -> bool {
    (PATCH_BASE..PATCH_END).contains(&t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn number_roundtrip() {
        for n in [0u32, 7, 10, 999, 123456] {
            let mut toks = Vec::new();
            encode_number(n, &mut toks);
            assert_eq!(decode_number(&toks), Some(n), "n={n}");
        }
    }

    #[test]
    fn decode_rejects_nondigits() {
        assert_eq!(decode_number(&[OP_PLUS]), None);
        assert_eq!(decode_number(&[]), None);
    }

    #[test]
    fn regions_disjoint() {
        assert!(N_SPECIAL <= TEXT_BASE);
        assert!(TEXT_END <= PATCH_BASE);
        assert_eq!(PATCH_END as usize, VOCAB_SIZE);
    }
}
