//! `mcsharp` — the MC# command line.
//!
//! ```text
//! mcsharp train     --model mix-tiny --steps 300          pretrain + checkpoint
//! mcsharp compress  --model mix-tiny --bits 2.05          calibrate → PMQ → pack
//!                   [--out q.bin]                         … and save the packed model
//! mcsharp eval      --model mix-tiny --bits 2.05 [--otp]  LM suite scores
//! mcsharp serve     --model mix-tiny --port 7077          TCP generation server
//!                   [--qckpt q.bin]                       serve a pre-compressed model
//!                   [--expert-cache-mb 64]                page experts under a byte budget
//!                                                         instead of preloading them all
//!                   [--max-batch 8] [--token-budget 4096] continuous-batching admission
//!                   [--workers N]                         cap concurrent connections (0 = ∞)
//!                   [--batch-window-us U]                 gather window before the first step
//!                   [--max-queue N]                       bound the admission queue (0 = ∞);
//!                                                         overflow answered BUSY immediately
//!                   [--kv-page P] [--prefill-chunk C]     paged-KV / prefix-sharing block size
//!                                                         and prompt positions per engine step
//!                   [--shards host:port,..]               page experts from shard servers over
//!                                                         the wire (needs --qckpt for the dense
//!                                                         base + seek index)
//!                   [--fetch-timeout-ms T]                per-RPC remote fetch deadline
//!                   [--trace-out t.json]                  dump the span ring as a Chrome
//!                                                         trace_event file at shutdown
//! mcsharp shard     --qckpt q.bin --layers a..b           serve expert records for layers
//!                   [--port 7177] [--max-requests N]      [a, b) off the checkpoint's mmap'd
//!                                                         seek index (FETCH/REC dialect)
//! mcsharp info      --model mix-tiny                      model zoo facts
//! ```
//!
//! Subcommands compose the library exactly the way the examples do; see
//! `examples/` for richer end-to-end drivers.

use anyhow::{anyhow, Result};

use mcsharp::backend::{NativeBackend, PjrtBackend};
use mcsharp::config::{ModelConfig, OtpConfig, PmqConfig, ServingConfig, MODEL_ZOO};
use mcsharp::coordinator::engine::{DecodeEngine, EngineModel};
use mcsharp::coordinator::server;
use mcsharp::data::{Corpus, CorpusKind};
use mcsharp::eval::{lm_suite, mc::score_suite, EvalOpts};
use mcsharp::otp::{train_otp, OtpPruner};
use mcsharp::pmq::{calibrate, strategies, Strategy};
use mcsharp::quant::error::eps_table;
use mcsharp::quant::qmodel::{QuantMethod, QuantModel};
use mcsharp::train::trainer::train_or_load;
use mcsharp::util::cli::Args;
use mcsharp::util::human_bytes;
use mcsharp::util::rng::Rng;

const FLAGS: &[&str] = &[
    "model", "steps", "bits", "otp", "port", "max-requests", "items", "seed", "pjrt",
    "calib-seqs", "lambda", "out", "qckpt", "expert-cache-mb", "max-batch",
    "token-budget", "workers", "batch-window-us", "max-queue", "kv-page", "prefill-chunk",
    "shards", "layers", "fetch-timeout-ms", "trace-out",
];

fn main() -> Result<()> {
    let args = Args::from_env(FLAGS)?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("compress") => cmd_compress(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("usage: mcsharp <train|compress|eval|serve|shard|info> [--model NAME] ...");
            eprintln!("models: {}", MODEL_ZOO.join(", "));
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mix-tiny");
    let steps = args.usize_or("steps", 300)?;
    let m = train_or_load(model, steps, false)?;
    println!("trained {model}: {} params ({} fp16)", m.n_params(), human_bytes(m.nbytes_fp16()));
    Ok(())
}

/// Shared pipeline: load → calibrate → allocate → quantize.
fn compress(
    model_name: &str,
    avg_bits: f64,
    steps: usize,
) -> Result<(mcsharp::moe::MoeModel, QuantModel)> {
    let cfg = ModelConfig::load(model_name)?;
    let base = train_or_load(model_name, steps, true)?;
    let kind = if cfg.modalities > 1 { CorpusKind::Multimodal } else { CorpusKind::General };
    let corpus = Corpus::new(kind, 0xDA7A);
    let mut rng = Rng::new(0xCA11B);
    let calib = corpus.batch(8, 64, &mut rng);
    let cal = calibrate(&base, &calib, 256);
    let pmq = PmqConfig::default();
    let eps = eps_table(&base, &cal.acts, &pmq);
    let alloc =
        strategies::allocation(Strategy::Pmq, &base, &cal, &eps, &pmq, avg_bits, &mut rng);
    let mut q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Gptq(&cal.hessians));
    // calibrated significance rides along: persisted by v2 checkpoints,
    // used as the paged store's eviction tie-break at serve time
    let importance: Vec<Vec<f64>> = (0..cfg.n_layers)
        .map(|l| {
            (0..cfg.n_experts).map(|e| cal.significance(l, e, pmq.alpha, pmq.beta)).collect()
        })
        .collect();
    q.set_importance(importance);
    Ok((base, q))
}

fn cmd_compress(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mix-tiny");
    let bits = args.f64_or("bits", 2.0)?;
    let steps = args.usize_or("steps", 300)?;
    let (base, q) = compress(model, bits, steps)?;
    if let Some(out) = args.get("out") {
        mcsharp::quant::qcheckpoint::save(&q, out)?;
        println!("wrote quantized checkpoint {out} ({})", human_bytes(std::fs::metadata(out)?.len()));
    }
    println!("PMQ allocation for {model} (avg expert bits target {bits}):");
    for (l, row) in q.allocation.iter().enumerate() {
        let row_s: Vec<String> = row.iter().map(|b| b.to_string()).collect();
        println!("  layer {l:>2}: [{}]", row_s.join(" "));
    }
    println!(
        "avg expert bits {:.2} | model bits {:.2} | packed {} (fp16 {}) | {:.1}x smaller",
        q.avg_expert_bits(),
        q.avg_model_bits(),
        human_bytes(q.nbytes()),
        human_bytes(base.nbytes_fp16()),
        base.nbytes_fp16() as f64 / q.nbytes() as f64
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mix-tiny");
    let bits = args.f64_or("bits", 2.0)?;
    let steps = args.usize_or("steps", 300)?;
    let items = args.usize_or("items", 30)?;
    let (base, q) = compress(model, bits, steps)?;
    let tasks = lm_suite::build(items, 0xBEEF);
    let (rows, avg) = score_suite(&base, &mut EvalOpts::default(), &tasks);
    println!("fp16   : avg {avg:.2}%  ({})", fmt_rows(&rows));
    let mut opts = EvalOpts { provider: Some(&q), ..Default::default() };
    let (rows, avg_q) = score_suite(&q.model, &mut opts, &tasks);
    println!("PMQ    : avg {avg_q:.2}%  ({})", fmt_rows(&rows));
    if args.has("otp") {
        let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
        let mut rng = Rng::new(9);
        let seqs = corpus.batch(8, 48, &mut rng);
        let oc = OtpConfig { lambda: args.f64_or("lambda", 1.0)? as f32, ..Default::default() };
        let rep = train_otp(&q, &seqs, &oc, 0xF00D);
        let mut pruner = OtpPruner { routers: rep.routers };
        let mut counter = (0u64, 0u64);
        let mut opts = EvalOpts {
            provider: Some(&q),
            pruner: Some(&mut pruner),
            pruning_counter: Some(&mut counter),
        };
        let (rows, avg_o) = score_suite(&q.model, &mut opts, &tasks);
        let ratio = 1.0 - counter.0 as f64 / counter.1.max(1) as f64;
        println!(
            "PMQ+OTP: avg {avg_o:.2}%  (pruned {:.1}%)  ({})",
            100.0 * ratio,
            fmt_rows(&rows)
        );
    }
    Ok(())
}

fn fmt_rows(rows: &[(String, f64)]) -> String {
    rows.iter().map(|(n, v)| format!("{n} {v:.1}")).collect::<Vec<_>>().join(", ")
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mix-tiny");
    let port = args.usize_or("port", 7077)?;
    let steps = args.usize_or("steps", 300)?;
    let bits = args.f64_or("bits", 2.0)?;
    let max_requests = args.usize_or("max-requests", 0)?;
    let defaults = ServingConfig::default();
    let sc = ServingConfig {
        max_batch: args.usize_or("max-batch", defaults.max_batch)?,
        token_budget: args.usize_or("token-budget", defaults.token_budget)?,
        expert_cache_mb: match args.usize_or("expert-cache-mb", 0)? {
            0 => None,
            mb => Some(mb),
        },
        workers: args.usize_or("workers", defaults.workers)?,
        batch_window_us: args.usize_or("batch-window-us", defaults.batch_window_us as usize)?
            as u64,
        max_queue: args.usize_or("max-queue", defaults.max_queue)?,
        kv_page: args.usize_or("kv-page", defaults.kv_page)?.max(1),
        prefill_chunk: args.usize_or("prefill-chunk", defaults.prefill_chunk)?.max(1),
        shards: args
            .get("shards")
            .map(|s| {
                s.split(',')
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default(),
        fetch_timeout_ms: args
            .usize_or("fetch-timeout-ms", defaults.fetch_timeout_ms as usize)?
            as u64,
        trace_out: args.get("trace-out").map(|s| s.to_string()),
    };
    // `--qckpt path` serves straight from a pre-compressed checkpoint —
    // the paper's pre-loading deployment story (no calibration at boot).
    // With `--expert-cache-mb N` the experts page in lazily under an
    // N-MiB residency budget instead of preloading into RAM. With
    // `--shards host:port,..` the experts live on shard servers and page
    // in over the wire (the coordinator keeps only the dense base plus
    // the cache budget resident).
    let q = if !sc.shards.is_empty() {
        let path = args
            .get("qckpt")
            .ok_or_else(|| anyhow!("--shards requires --qckpt (dense base + seek index)"))?;
        let budget = sc.expert_cache_bytes().unwrap_or(u64::MAX);
        println!(
            "opening {path} with remote experts from {} shard(s): {}",
            sc.shards.len(),
            sc.shards.join(", ")
        );
        mcsharp::quant::qcheckpoint::load_remote(path, &sc.shards, budget, sc.fetch_timeout_ms)?
    } else {
        match (args.get("qckpt"), sc.expert_cache_bytes()) {
            (Some(path), Some(budget)) => {
                println!("opening quantized checkpoint {path} (paged, {budget} B expert budget)");
                mcsharp::quant::qcheckpoint::load_paged(path, budget)?
            }
            (Some(path), None) => {
                println!("loading quantized checkpoint {path}");
                mcsharp::quant::qcheckpoint::load(path)?
            }
            (None, Some(budget)) => {
                // no checkpoint to page from: compress, spill the v2 file,
                // reopen it paged so the budget is enforced for real
                let q = compress(model, bits, steps)?.1;
                let spill = std::env::temp_dir()
                    .join(format!("mcsharp-serve-{model}-{}.q2", std::process::id()))
                    .to_string_lossy()
                    .into_owned();
                mcsharp::quant::qcheckpoint::save(&q, &spill)?;
                println!("spilled packed experts to {spill} ({budget} B expert budget)");
                let paged = mcsharp::quant::qcheckpoint::load_paged(&spill, budget)?;
                // unlink now: the paged store's mmap keeps the records
                // readable, and nothing leaks when the server exits
                std::fs::remove_file(&spill).ok();
                paged
            }
            (None, None) => compress(model, bits, steps)?.1,
        }
    };
    let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
    println!(
        "serving {model} (PMQ {:.2}-bit, {} expert store) on 127.0.0.1:{port}",
        q.avg_model_bits(),
        q.store.kind()
    );
    let max = if max_requests == 0 { None } else { Some(max_requests) };
    if args.has("pjrt") {
        if sc.expert_cache_mb.is_some() {
            println!(
                "note: --expert-cache-mb bounds the native store only; PJRT stages every \
                 expert as device literals at startup and skips the paging pre-phase"
            );
        }
        let rt = mcsharp::runtime::Runtime::open_default()?;
        let be = PjrtBackend::new(&rt, &q, true)?;
        let engine = std::sync::Mutex::new(
            DecodeEngine::new(EngineModel::Quant(&q), &be, None)
                .with_kv_page(sc.kv_page)
                .with_prefill_chunk(sc.prefill_chunk),
        );
        let n = server::serve_with(listener, &engine, &sc, max)?;
        let eng = engine.lock().unwrap();
        report_served(&eng, n, "pjrt");
        dump_trace(&eng, sc.trace_out.as_deref())?;
    } else {
        let be = NativeBackend::quant(&q);
        let engine = std::sync::Mutex::new(
            DecodeEngine::new(EngineModel::Quant(&q), &be, None)
                .with_kv_page(sc.kv_page)
                .with_prefill_chunk(sc.prefill_chunk),
        );
        let n = server::serve_with(listener, &engine, &sc, max)?;
        let eng = engine.lock().unwrap();
        report_served(&eng, n, "native");
        dump_trace(&eng, sc.trace_out.as_deref())?;
    }
    Ok(())
}

/// `--trace-out`: dump the engine's span ring as a Chrome trace_event
/// file (open in chrome://tracing or Perfetto).
fn dump_trace(eng: &DecodeEngine, path: Option<&str>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    let spans = eng.trace.snapshot(None);
    mcsharp::trace::write_chrome(path, &spans)?;
    println!("wrote {} trace span(s) to {path} (chrome://tracing)", spans.len());
    Ok(())
}

/// Shutdown line: request count + the expert-cache gauges when the
/// engine served from a store.
fn report_served(eng: &DecodeEngine, n: usize, backend: &str) {
    let kv = eng.metrics.kv;
    println!(
        "kv pool: {} pages ({}) | prefix-hit tokens {} | cow copies {} | tree blocks {}",
        kv.kv_pages,
        human_bytes(kv.kv_bytes),
        kv.prefix_hit_toks,
        kv.cow_copies,
        kv.tree_blocks
    );
    if let Some(c) = eng.metrics.cache {
        println!(
            "served {n} requests ({backend} backend) | expert cache: resident {} peak {} hits {} misses {} evictions {} prefetch-hits {} hit-rate {:.3}",
            human_bytes(c.resident_bytes),
            human_bytes(c.peak_resident_bytes),
            c.hits,
            c.misses,
            c.evictions,
            c.prefetch_hits,
            c.hit_rate()
        );
    } else {
        println!("served {n} requests ({backend} backend)");
    }
}

/// `mcsharp shard` — the storage node of multi-node expert sharding:
/// serve the expert records of layers `[a, b)` straight off a v2
/// quantized checkpoint's mmap'd seek index. The dense base never loads
/// here; the shard's footprint is the header + index, O(1) in experts.
fn cmd_shard(args: &Args) -> Result<()> {
    let path =
        args.get("qckpt").ok_or_else(|| anyhow!("shard requires --qckpt <file> (v2)"))?;
    let spec = args
        .get("layers")
        .ok_or_else(|| anyhow!("shard requires --layers a..b (half-open)"))?;
    let (a, b) = spec
        .split_once("..")
        .ok_or_else(|| anyhow!("--layers wants a..b, got {spec:?}"))?;
    let layers = a.trim().parse::<usize>()?..b.trim().parse::<usize>()?;
    let port = args.usize_or("port", 7177)?;
    let max_requests = args.usize_or("max-requests", 0)?;
    let source = mcsharp::quant::qcheckpoint::ShardSource::open(path, layers.clone())?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
    println!(
        "shard serving {path} layers {}..{} ({} experts/layer) on 127.0.0.1:{port}",
        layers.start,
        layers.end,
        source.n_experts()
    );
    let max = if max_requests == 0 { None } else { Some(max_requests) };
    let n = server::serve_shard(listener, &source, max)?;
    println!("shard answered {n} fetches");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let only = args.get("model");
    println!(
        "{:<10} {:>12} {:>14} {:>8} {:>6} {:>4} {:>3} {:>7}",
        "model", "params", "act-params", "layers", "H", "E", "k", "shared"
    );
    for name in MODEL_ZOO {
        if let Some(o) = only {
            if o != *name {
                continue;
            }
        }
        let c = ModelConfig::load(name)?;
        println!(
            "{:<10} {:>12} {:>14} {:>8} {:>6} {:>4} {:>3} {:>7}",
            name,
            c.total_params(),
            c.activated_params(),
            c.n_layers,
            c.d_model,
            c.n_experts,
            c.top_k,
            c.n_shared_experts
        );
    }
    Ok(())
}
