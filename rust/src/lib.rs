//! # MC# — Mixture Compressor for Mixture-of-Experts large models
//!
//! A from-scratch reproduction of *"MC#: Mixture Compressor for
//! Mixture-of-Experts Large Models"* as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request batching,
//!   top-k routing, OTP dynamic expert pruning, per-expert token grouping,
//!   KV-cache management, metrics.
//! * **L2/L1 (python/compile)** — JAX graphs + Pallas kernels
//!   (dequant-matmul, binary-matmul, fused expert FFN, gating, OTP
//!   router), AOT-lowered once to HLO text under `artifacts/` and executed
//!   here through PJRT (`runtime`).
//!
//! The paper's two contributions live in [`pmq`] (Pre-loading
//! Mixed-precision Quantization: expert-significance-weighted integer
//! programming over per-expert bit-widths) and [`otp`] (Online Top-any
//! Pruning: a learnable Gumbel-Softmax router that prunes activated
//! experts per token). Everything they depend on — the MoE model, a
//! training loop, GPTQ, bit-packed storage, synthetic corpora, evaluation
//! suites, a roofline model — is implemented here as well; see DESIGN.md
//! for the full inventory and the per-experiment index.

// Index-loop style is deliberate in the kernel code (mirrors the Pallas
// tile loops and keeps the autovectorization-friendly shapes obvious).
#![allow(clippy::needless_range_loop)]
// Every unsafe operation must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` justification, even inside `unsafe fn` bodies —
// `mcsharp-analyze` (pass 3) audits exactly those blocks.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod moe;
pub mod otp;
pub mod pmq;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;
