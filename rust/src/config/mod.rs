//! Configuration system: the model zoo (`configs/*.json`, shared with the
//! python AOT path), quantization settings, serving settings.

use anyhow::Result;

use crate::util::json::Value;

/// Quantization group size along the reduction axis. Must match
/// `python/compile/model.py::GROUP` — pinned by a manifest check in the
/// runtime and by cross-language packing tests.
pub const GROUP: usize = 32;

/// A model architecture (mirrors `configs/<name>.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub family: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_shared_experts: usize,
    pub max_seq_len: usize,
    pub rope_theta: f32,
    /// 1 = text-only (MoE-LLM analog), 2 = text+patch (MoE-VLM analog).
    pub modalities: usize,
    /// Token-count buckets the AOT artifacts were lowered for.
    pub buckets: Vec<usize>,
}

impl ModelConfig {
    pub fn from_json(v: &Value) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.get("name")?.as_str()?.to_string(),
            family: v.get("family")?.as_str()?.to_string(),
            vocab_size: v.get("vocab_size")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            n_experts: v.get("n_experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            n_shared_experts: v.get("n_shared_experts")?.as_usize()?,
            max_seq_len: v.get("max_seq_len")?.as_usize()?,
            rope_theta: v.get("rope_theta")?.as_f64()? as f32,
            modalities: v.get("modalities")?.as_usize()?,
            buckets: v
                .get("buckets")?
                .as_arr()?
                .iter()
                .map(|b| b.as_usize())
                .collect::<Result<_>>()?,
        })
    }

    /// Load `configs/<name>.json` relative to the repo root.
    pub fn load(name: &str) -> Result<ModelConfig> {
        let path = repo_path(&format!("configs/{name}.json"));
        ModelConfig::from_json(&Value::from_file(&path)?)
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameter count of one expert (SwiGLU: gate+up+down).
    pub fn expert_params(&self) -> usize {
        3 * self.d_model * self.d_ff
    }

    /// Total parameters (embedding, attention, gates, experts, head).
    pub fn total_params(&self) -> usize {
        let h = self.d_model;
        let per_layer_attn = 4 * h * h + h; // qkv+o + norm gains
        let per_layer_moe = h * self.n_experts // gate
            + (self.n_experts + self.n_shared_experts) * self.expert_params()
            + h; // norm
        self.vocab_size * h * 2 // embed + head
            + h // final norm
            + self.n_layers * (per_layer_attn + per_layer_moe)
    }

    /// Parameters activated for one token (top-k + shared experts only).
    pub fn activated_params(&self) -> usize {
        let h = self.d_model;
        let per_layer_attn = 4 * h * h + h;
        let per_layer_moe = h * self.n_experts
            + (self.top_k + self.n_shared_experts) * self.expert_params()
            + h;
        self.vocab_size * h * 2 + h + self.n_layers * (per_layer_attn + per_layer_moe)
    }
}

/// The named model zoo (see DESIGN.md §3 substitution table).
pub const MODEL_ZOO: &[&str] = &["mix-tiny", "mix-small", "dsvl-t", "dsvl-s", "dsvl-l"];

/// Resolve a path relative to the repository root (works from `cargo
/// test`, benches, and installed binaries run from the repo).
pub fn repo_path(rel: &str) -> String {
    // CARGO_MANIFEST_DIR is baked in at compile time and is `rust/`;
    // the repo root (configs/, artifacts/, checkpoints/) is its parent.
    let manifest = env!("CARGO_MANIFEST_DIR");
    format!("{manifest}/../{rel}")
}

/// PMQ hyper-parameters (paper Eq. 7: α, β weight the significance
/// factors; γ weights the quantization error).
#[derive(Clone, Debug)]
pub struct PmqConfig {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    /// Candidate bit-widths for experts.
    pub bit_options: Vec<u8>,
    /// Uniform bit-width for attention/gate/shared-expert weights.
    pub other_bits: u8,
    pub group: usize,
}

impl Default for PmqConfig {
    fn default() -> Self {
        PmqConfig {
            alpha: 0.5,
            beta: 0.5,
            gamma: 2.0,
            bit_options: vec![1, 2, 3],
            other_bits: 4,
            group: GROUP,
        }
    }
}

/// Serving-side knobs, threaded from the CLI (`mcsharp serve`) through
/// the server into the batcher and the expert store.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Max concurrently active sequences.
    pub max_batch: usize,
    /// Max summed (prompt + generated) tokens across the active set.
    pub token_budget: usize,
    /// Packed-expert residency budget in MiB (`--expert-cache-mb`).
    /// `None` keeps every expert resident (the pre-paging behaviour).
    pub expert_cache_mb: Option<usize>,
    /// Max concurrently served client connections (`--workers`);
    /// 0 = unbounded. Connections beyond the cap wait in the OS accept
    /// backlog — admission control happens per token via `token_budget`,
    /// this bounds reader threads.
    pub workers: usize,
    /// Micro-batch gather window in µs (`--batch-window-us`): when the
    /// engine goes idle, its loop waits this long (or until `max_batch`
    /// fills) after the first queued request so near-simultaneous
    /// requests share their first step. 0 = step immediately.
    pub batch_window_us: u64,
    /// Bound on requests queued but not yet admitted (`--max-queue`);
    /// 0 = unbounded (the historical behaviour). When the queue is at
    /// the cap, new `GEN` submissions are refused immediately with a
    /// `BUSY` response instead of growing the queue without limit — the
    /// overload guardrail for real traffic.
    pub max_queue: usize,
    /// Positions per paged-KV page (`--kv-page`): the prefix-sharing
    /// granularity and the free-list allocation unit.
    pub kv_page: usize,
    /// Pending prompt positions each sequence feeds through one engine
    /// step (`--prefill-chunk`); 1 = token-at-a-time prefill.
    pub prefill_chunk: usize,
    /// Shard endpoints (`--shards host:port,..`): when non-empty the
    /// coordinator serves experts from a `RemoteStore` paging records
    /// over the wire instead of a local file-backed store.
    pub shards: Vec<String>,
    /// Per-RPC remote fetch deadline in ms (`--fetch-timeout-ms`). A
    /// shard that misses it is marked down for the affected requests;
    /// later fetches lazily reconnect.
    pub fetch_timeout_ms: u64,
    /// Chrome trace_event output path (`--trace-out`): at shutdown the
    /// engine's span ring is dumped there for chrome://tracing /
    /// Perfetto. `None` = no file (the `TRACE` wire command still
    /// works; the ring always records).
    pub trace_out: Option<String>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            max_batch: 8,
            token_budget: 4096,
            expert_cache_mb: None,
            workers: 0,
            batch_window_us: 0,
            max_queue: 0,
            kv_page: 16,
            prefill_chunk: 16,
            shards: Vec::new(),
            fetch_timeout_ms: 2_000,
            trace_out: None,
        }
    }
}

impl ServingConfig {
    /// Residency budget in bytes, when one is configured.
    pub fn expert_cache_bytes(&self) -> Option<u64> {
        self.expert_cache_mb.map(|mb| mb as u64 * 1024 * 1024)
    }
}

/// OTP training hyper-parameters (paper §3.4.2, Fig. 13).
#[derive(Clone, Debug)]
pub struct OtpConfig {
    /// Sparsity-regularizer weight λ in Eq. 14.
    pub lambda: f32,
    /// Gumbel-Softmax temperature anneal (start → end).
    pub tau_start: f32,
    pub tau_end: f32,
    pub lr: f32,
    pub steps: usize,
    pub batch_tokens: usize,
}

impl Default for OtpConfig {
    fn default() -> Self {
        OtpConfig {
            lambda: 1.0,
            tau_start: 4.0,
            tau_end: 0.5,
            lr: 1e-2,
            steps: 300,
            batch_tokens: 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_zoo() {
        for name in MODEL_ZOO {
            let c = ModelConfig::load(name).unwrap();
            assert_eq!(&c.name, name);
            assert_eq!(c.d_model % c.n_heads, 0, "{name}: head split");
            assert_eq!(c.d_model % GROUP, 0, "{name}: group split");
            assert_eq!(c.d_ff % GROUP, 0, "{name}: group split (ff)");
            assert!(c.top_k <= c.n_experts);
        }
    }

    #[test]
    fn family_shapes_match_paper_structure() {
        let mix = ModelConfig::load("mix-tiny").unwrap();
        assert_eq!((mix.n_experts, mix.top_k, mix.n_shared_experts), (8, 2, 0));
        let dsvl = ModelConfig::load("dsvl-s").unwrap();
        assert_eq!(dsvl.top_k, 6);
        assert!(dsvl.n_experts >= 16 && dsvl.n_shared_experts >= 1);
    }

    #[test]
    fn activated_less_than_total() {
        let c = ModelConfig::load("mix-tiny").unwrap();
        assert!(c.activated_params() < c.total_params());
        // experts dominate total params (the paper's premise)
        let expert_total = c.n_layers * c.n_experts * c.expert_params();
        assert!(expert_total as f64 / c.total_params() as f64 > 0.5);
    }
}
