//! Device profiles + roofline model (Table 8) and the L1 VMEM/MXU
//! estimator (DESIGN.md §8 — interpret-mode Pallas gives no TPU timing,
//! so kernel efficiency is estimated from its memory/compute structure).

use crate::config::ModelConfig;
use crate::quant::qmodel::QuantModel;

/// A simulated deployment platform (paper Table 8 rows).
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub mem_bytes: u64,
    /// Memory bandwidth, bytes/s.
    pub bw: f64,
    /// Peak f16 compute, FLOP/s.
    pub flops: f64,
}

pub const A100_80G: DeviceProfile = DeviceProfile {
    name: "A100-80GB",
    mem_bytes: 80 * 1024 * 1024 * 1024,
    bw: 2.0e12,
    flops: 312e12,
};

pub const RTX_3090: DeviceProfile = DeviceProfile {
    name: "RTX-3090",
    mem_bytes: 24 * 1024 * 1024 * 1024,
    bw: 0.936e12,
    flops: 71e12,
};

/// What a model weighs on a device, scaled so our tiny zoo maps onto the
/// paper's model sizes: `scale` multiplies parameter bytes (the paper's
/// Mixtral 8×7b ≈ 13000× our mix-tiny; Table 8's point — fits vs OOM and
/// the decode-speed ratio — is scale-invariant).
#[derive(Clone, Debug)]
pub struct Deployment {
    pub weight_bytes: u64,
    pub act_bytes_per_token: u64,
}

impl Deployment {
    pub fn fp16(cfg: &ModelConfig, scale: f64) -> Deployment {
        Deployment {
            weight_bytes: ((cfg.total_params() * 2) as f64 * scale) as u64,
            act_bytes_per_token: ((cfg.activated_params() * 2) as f64 * scale) as u64,
        }
    }

    pub fn quantized(q: &QuantModel, keep_ratio: f64, scale: f64) -> Deployment {
        Deployment {
            weight_bytes: (q.nbytes() as f64 * scale) as u64,
            act_bytes_per_token: (q.activated_bytes_per_token(keep_ratio) as f64 * scale) as u64,
        }
    }

    pub fn fits(&self, dev: &DeviceProfile) -> bool {
        // leave 20% headroom for KV cache + activations
        (self.weight_bytes as f64) < dev.mem_bytes as f64 * 0.8
    }

    /// Roofline decode latency per token: max(bytes/bw, flops/peak).
    /// Decode is memory-bound on every platform the paper tests, so the
    /// bytes term dominates; FLOPs ≈ 2·activated-params.
    pub fn decode_latency_s(&self, dev: &DeviceProfile) -> f64 {
        let mem_t = self.act_bytes_per_token as f64 / dev.bw;
        // activated params ≈ act_bytes at fp16 / 2 → FLOPs = 2·params
        let flop_t = self.act_bytes_per_token as f64 / dev.flops;
        mem_t.max(flop_t)
    }

    pub fn tokens_per_sec(&self, dev: &DeviceProfile) -> Option<f64> {
        if !self.fits(dev) {
            return None; // OOM
        }
        Some(1.0 / self.decode_latency_s(dev))
    }
}

/// L1 kernel VMEM/MXU estimate for a dequant-matmul tile (DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct KernelEstimate {
    pub vmem_bytes: u64,
    /// Arithmetic intensity: FLOPs per HBM byte.
    pub intensity: f64,
    /// Fraction of f32 HBM traffic this kernel moves.
    pub traffic_ratio: f64,
}

/// Estimate the Pallas dequant-matmul at `(t, d_in, tile_o)` and `bits`.
pub fn dequant_matmul_estimate(
    t: usize,
    d_in: usize,
    tile_o: usize,
    bits: u8,
    group: usize,
) -> KernelEstimate {
    let planes = bits as u64 * (d_in as u64 / 8) * tile_o as u64;
    let params = 2 * (d_in as u64 / group as u64) * tile_o as u64 * 4;
    let x = (t * d_in * 4) as u64;
    let w_expanded = (d_in * tile_o * 4) as u64; // dequantized in VMEM
    let out = (t * tile_o * 4) as u64;
    let vmem = planes + params + x + w_expanded + out;
    let flops = 2.0 * t as f64 * d_in as f64 * tile_o as f64;
    let hbm = (planes + params + x + out) as f64;
    let f32_hbm = (d_in * tile_o * 4 + t * d_in * 4 + t * tile_o * 4) as f64;
    KernelEstimate {
        vmem_bytes: vmem,
        intensity: flops / hbm,
        traffic_ratio: hbm / f32_hbm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn fp16_mixtral_scale_ooms_3090() {
        // scale mix-tiny to Mixtral-8x7b's 96.8 GB weight footprint
        let cfg = ModelConfig::load("mix-tiny").unwrap();
        let base = (cfg.total_params() * 2) as f64;
        let scale = 96.8e9 / base;
        let dep = Deployment::fp16(&cfg, scale);
        assert!(!dep.fits(&RTX_3090), "96.8GB should OOM a 3090");
        assert!(!dep.fits(&A100_80G), "needs 2 GPUs, not one");
        // ~6.2x compression fits the 3090 (paper Table 8)
        let dep_q = Deployment {
            weight_bytes: (dep.weight_bytes as f64 / 6.2) as u64,
            act_bytes_per_token: (dep.act_bytes_per_token as f64 / 7.0) as u64,
        };
        assert!(dep_q.fits(&RTX_3090));
    }

    #[test]
    fn decode_is_memory_bound_and_quant_speeds_up() {
        let cfg = ModelConfig::load("mix-tiny").unwrap();
        let scale = 1e4;
        let fp = Deployment::fp16(&cfg, scale);
        let q = Deployment {
            weight_bytes: fp.weight_bytes / 6,
            act_bytes_per_token: fp.act_bytes_per_token / 6,
        };
        let t_fp = fp.decode_latency_s(&A100_80G);
        let t_q = q.decode_latency_s(&A100_80G);
        let speedup = t_fp / t_q;
        assert!(speedup > 3.0, "roofline speedup {speedup}");
    }

    #[test]
    fn kernel_estimate_sane() {
        let e2 = dequant_matmul_estimate(16, 128, 128, 2, 32);
        let e4 = dequant_matmul_estimate(16, 128, 128, 4, 32);
        assert!(e2.traffic_ratio < e4.traffic_ratio);
        assert!(e2.traffic_ratio < 0.5, "2-bit should move <50% of f32 traffic");
        assert!(e2.vmem_bytes < 16 * 1024 * 1024, "tile must fit VMEM");
        assert!(e2.intensity > e4.intensity);
    }
}
