//! Artifact registry: manifest-driven loading, compilation and cached
//! execution of the AOT HLO-text graphs.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use crate::config::repo_path;
use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct ArgMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub key: String,
    pub file: String,
    pub config: String,
    pub graph: String,
    pub bucket: usize,
    pub args: Vec<ArgMeta>,
    pub n_outputs: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub group: usize,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let v = Value::from_file(&format!("{dir}/manifest.json"))?;
        let group = v.get("group")?.as_usize()?;
        let mut artifacts = BTreeMap::new();
        for (key, meta) in v.get("artifacts")?.as_obj()? {
            let args = meta
                .get("args")?
                .as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArgMeta {
                        shape: a
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                key.clone(),
                ArtifactMeta {
                    key: key.clone(),
                    file: meta.get("file")?.as_str()?.to_string(),
                    config: meta.get("config")?.as_str()?.to_string(),
                    graph: meta.get("graph")?.as_str()?.to_string(),
                    bucket: meta.get("bucket")?.as_usize()?,
                    args,
                    n_outputs: meta.get("n_outputs")?.as_usize()?,
                },
            );
        }
        Ok(Manifest { group, artifacts })
    }

    /// Buckets available for (config, graph), ascending.
    pub fn buckets(&self, config: &str, graph: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.config == config && a.graph == graph)
            .map(|a| a.bucket)
            .collect();
        b.sort_unstable();
        b
    }

    /// Smallest bucket ≥ `tokens` (or the largest available).
    pub fn pick_bucket(&self, config: &str, graph: &str, tokens: usize) -> Result<usize> {
        let buckets = self.buckets(config, graph);
        if buckets.is_empty() {
            bail!("no artifacts for {config}/{graph}");
        }
        Ok(*buckets.iter().find(|&&b| b >= tokens).unwrap_or(buckets.last().unwrap()))
    }
}

/// A PJRT CPU client + compiled-executable cache over the artifact dir.
/// `Sync` (mutex-guarded caches) so a `PjrtBackend` can serve the
/// expert-grouped dispatcher's scoped-thread execution phase.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub dir: String,
    cache: Mutex<BTreeMap<String, Arc<PjRtLoadedExecutable>>>,
    /// (compiles, executions) counters for perf accounting.
    pub stats: Mutex<(u64, u64)>,
}

impl Runtime {
    /// Open the default `artifacts/` directory at the repo root.
    pub fn open_default() -> Result<Runtime> {
        Runtime::open(&repo_path("artifacts"))
    }

    pub fn open(dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(dir).map_err(|e| {
            anyhow!("loading {dir}/manifest.json — run `make artifacts` first: {e}")
        })?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_string(),
            cache: Mutex::new(BTreeMap::new()),
            stats: Mutex::new((0, 0)),
        })
    }

    pub fn meta(&self, key: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("unknown artifact {key}"))
    }

    /// Compile (cached) and return nothing — used to pre-warm at startup
    /// so compilation never happens on the request path.
    pub fn warmup(&self, key: &str) -> Result<()> {
        self.with_exe(key, |_| Ok(()))
    }

    fn with_exe<T>(&self, key: &str, f: impl FnOnce(&PjRtLoadedExecutable) -> Result<T>) -> Result<T> {
        // The cache lock covers only lookup/compile-insert; execution runs
        // on a cloned handle so concurrent expert groups (the dispatcher's
        // scoped threads) are not serialized behind one another.
        let exe = {
            let mut cache = self.cache.lock().unwrap();
            match cache.get(key) {
                Some(exe) => Arc::clone(exe),
                None => {
                    let meta = self.meta(key)?;
                    let path = format!("{}/{}", self.dir, meta.file);
                    let proto = xla::HloModuleProto::from_text_file(&path)
                        .map_err(|e| anyhow!("parsing {path}: {e}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = Arc::new(
                        self.client
                            .compile(&comp)
                            .map_err(|e| anyhow!("compiling {key}: {e}"))?,
                    );
                    self.stats.lock().unwrap().0 += 1;
                    cache.insert(key.to_string(), Arc::clone(&exe));
                    exe
                }
            }
        };
        f(&exe)
    }

    /// Execute artifact `key` with `args`; returns the flattened tuple of
    /// output literals (aot.py lowers with `return_tuple=True`).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        key: &str,
        args: &[L],
    ) -> Result<Vec<Literal>> {
        let meta = self.meta(key)?;
        if args.len() != meta.args.len() {
            bail!("{key}: expected {} args, got {}", meta.args.len(), args.len());
        }
        for (i, (l, am)) in args.iter().zip(&meta.args).enumerate() {
            let n: usize = am.shape.iter().product();
            if l.borrow().element_count() != n {
                bail!(
                    "{key}: arg {i} has {} elements, manifest says {n}",
                    l.borrow().element_count()
                );
            }
        }
        let n_out = meta.n_outputs;
        let result = self.with_exe(key, |exe| {
            exe.execute::<L>(args).map_err(|e| anyhow!("executing {key}: {e}"))
        })?;
        self.stats.lock().unwrap().1 += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync {key}: {e}"))?;
        let outs = tuple.to_tuple().map_err(|e| anyhow!("untuple {key}: {e}"))?;
        if outs.len() != n_out {
            bail!("{key}: {} outputs, manifest says {n_out}", outs.len());
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime-against-artifacts integration tests live in
    // rust/tests/pjrt_integration.rs (they need `make artifacts`).
    #[test]
    fn manifest_parse_smoke() {
        let dir = std::env::temp_dir().join("mcsharp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"group":32,"artifacts":{"m_g_t4":{"file":"m_g_t4.hlo.txt","config":"m","graph":"g","bucket":4,"args":[{"shape":[4,8],"dtype":"float32"}],"n_outputs":1}}}"#,
        )
        .unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.group, 32);
        let a = &m.artifacts["m_g_t4"];
        assert_eq!(a.bucket, 4);
        assert_eq!(a.args[0].shape, vec![4, 8]);
        assert_eq!(m.pick_bucket("m", "g", 3).unwrap(), 4);
        assert_eq!(m.pick_bucket("m", "g", 100).unwrap(), 4);
        assert!(m.pick_bucket("m", "nope", 1).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
