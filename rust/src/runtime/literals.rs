//! Literal staging helpers: Rust buffers → PJRT literals and back.

use anyhow::{anyhow, Result};
use xla::{ElementType, Literal};

use crate::tensor::Tensor2;

/// f32 slice → literal of the given dims.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    // SAFETY: an f32 slice is always valid to reinterpret as its raw
    // bytes — same allocation, same length in bytes (len * 4), no
    // alignment requirement on u8 — and the view dies with `data`.
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)?)
}

/// u8 slice → literal (packed planes).
pub fn u8_literal(data: &[u8], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::U8, dims, data)?)
}

pub fn tensor_literal(t: &Tensor2) -> Result<Literal> {
    f32_literal(&t.data, &[t.rows, t.cols])
}

/// Literal → f32 vec.
pub fn to_f32(l: &Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// Literal → i32 vec.
pub fn to_i32(l: &Literal) -> Result<Vec<i32>> {
    Ok(l.to_vec::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 7.0, 9.5];
        let l = f32_literal(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), data);
    }

    #[test]
    fn u8_roundtrip() {
        let data = vec![0xAAu8, 0xCC, 1, 2];
        let l = u8_literal(&data, &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<u8>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(u8_literal(&[1], &[2]).is_err());
    }
}
