//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the serving hot path.
//!
//! Artifacts are shape-static per (model config, graph, token bucket);
//! `Runtime` compiles lazily and caches executables. The manifest written
//! by `aot.py` describes the exact argument shapes/dtypes and output
//! arity so mismatches fail loudly at load time, not deep inside PJRT.
//!
//! Interchange is HLO *text* — see aot.py for the jax≥0.5 ↔ xla_extension
//! 0.5.1 proto-id incompatibility that rules out serialized protos.

pub mod artifacts;
pub mod literals;

pub use artifacts::{ArtifactMeta, Manifest, Runtime};
