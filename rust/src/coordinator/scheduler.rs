//! Cross-request continuous-batching scheduler: one admission queue and
//! one persistent engine loop shared by every client connection.
//!
//! The server's reader threads [`submit`](Scheduler::submit) parsed
//! requests into the shared [`Batcher`] queue (behind a `Mutex`/`Condvar`)
//! and block on a per-request response channel. A single engine thread
//! runs [`run_engine`](Scheduler::run_engine) — admit → step → retire,
//! never tearing down between requests — so sequences from different
//! connections share engine steps and expert groups the moment they
//! overlap. This is what makes `max_batch`, `token_budget` and the
//! SJF/Priority policies meaningful under real traffic: before this
//! scheduler the serve path built a throwaway batcher per protocol line
//! and could never batch across requests.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::ServingConfig;
use crate::coordinator::batcher::{ActiveSeq, Batcher};
use crate::coordinator::engine::DecodeEngine;
use crate::coordinator::request::{response_channel, GenRequest, ResponseRx, ResponseTx};

pub struct Scheduler {
    inner: Mutex<Inner>,
    work: Condvar,
    /// Micro-batch gather window (µs): on an idle→busy transition the
    /// engine loop lingers this long (or until the batch fills) for more
    /// arrivals, so near-simultaneous requests share their first step.
    /// 0 steps immediately.
    batch_window_us: u64,
}

struct Inner {
    batcher: Batcher,
    /// Per-request response routes, keyed by request id. An entry is
    /// removed (and its sender consumed) when the sequence retires;
    /// dropping a sender without sending wakes the waiter with an error.
    responders: HashMap<u64, ResponseTx>,
    /// Set by [`Scheduler::shutdown`]: no new admissions; the engine
    /// loop drains everything already submitted, then exits.
    draining: bool,
}

impl Scheduler {
    pub fn new(batcher: Batcher) -> Scheduler {
        Scheduler {
            inner: Mutex::new(Inner {
                batcher,
                responders: HashMap::new(),
                draining: false,
            }),
            work: Condvar::new(),
            batch_window_us: 0,
        }
    }

    pub fn from_config(sc: &ServingConfig) -> Scheduler {
        Scheduler::new(Batcher::from_config(sc)).with_window(sc.batch_window_us)
    }

    pub fn with_window(mut self, batch_window_us: u64) -> Scheduler {
        self.batch_window_us = batch_window_us;
        self
    }

    /// Queue a request under the admission policy. The result arrives on
    /// the returned channel when the engine loop retires the sequence;
    /// the channel errors if the engine dies, and submission itself
    /// fails once the scheduler is draining.
    pub fn submit(&self, req: GenRequest) -> Result<ResponseRx> {
        let (tx, rx) = response_channel();
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.draining {
                bail!("scheduler is draining, request {} rejected", req.id);
            }
            inner.responders.insert(req.id, tx);
            inner.batcher.submit(req);
        }
        self.work.notify_all();
        Ok(rx)
    }

    /// Requests queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().batcher.pending()
    }

    /// Stop admitting new requests; the engine loop finishes everything
    /// already submitted (queued and in flight), then returns — graceful
    /// drain, nothing is dropped.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().draining = true;
        self.work.notify_all();
    }

    /// The persistent engine loop: admit from the shared queue, take one
    /// engine step over the active set, retire finished sequences to
    /// their response channels — forever, until [`shutdown`](Self::shutdown)
    /// and the backlog drains. The engine lock is held only around the
    /// step itself, so `STATS`/`METRICS` scrapes interleave freely, and
    /// the scheduler lock is released during the step, so submissions
    /// never wait on compute. Returns the number of sequences served.
    pub fn run_engine(&self, engine: &Mutex<DecodeEngine>) -> Result<usize> {
        let n_layers = {
            let mut eng = engine.lock().unwrap();
            eng.metrics.start(); // first-call-wins: the server-lifetime window
            eng.em.model().cfg.n_layers
        };
        let mut active: Vec<ActiveSeq> = Vec::new();
        let mut served = 0usize;
        loop {
            // ---- admit (scheduler lock) ----
            {
                let mut inner = self.inner.lock().unwrap();
                loop {
                    let was_idle = active.is_empty();
                    inner.batcher.admit(&mut active, n_layers);
                    if !active.is_empty() {
                        if was_idle {
                            inner = self.linger(inner, &mut active, n_layers);
                        }
                        break;
                    }
                    if inner.draining {
                        engine.lock().unwrap().metrics.finish();
                        return Ok(served);
                    }
                    inner = self.work.wait(inner).unwrap();
                }
            }
            // ---- step + retire (engine lock) ----
            let finished = {
                let mut eng = engine.lock().unwrap();
                match Batcher::step_active(&mut eng, &mut active) {
                    Ok(()) => Batcher::retire(&mut active, &mut eng.metrics),
                    Err(e) => {
                        eng.metrics.finish(); // close the lifetime window
                        drop(eng);
                        // fail every waiter: dropping a sender wakes its
                        // connection thread with a recv error; queued
                        // requests are dropped too — nothing will run them
                        let mut inner = self.inner.lock().unwrap();
                        inner.draining = true;
                        inner.batcher.clear_queue();
                        inner.responders.clear();
                        drop(inner);
                        self.work.notify_all();
                        return Err(e);
                    }
                }
            };
            if !finished.is_empty() {
                let mut inner = self.inner.lock().unwrap();
                for r in finished {
                    served += 1;
                    if let Some(tx) = inner.responders.remove(&r.id) {
                        let _ = tx.send(r); // receiver gone ⇒ client vanished
                    }
                }
            }
        }
    }

    /// Hold admission open for up to the gather window after an
    /// idle→busy transition. Exits early once the batch is full or the
    /// scheduler starts draining.
    fn linger<'g>(
        &self,
        mut inner: MutexGuard<'g, Inner>,
        active: &mut Vec<ActiveSeq>,
        n_layers: usize,
    ) -> MutexGuard<'g, Inner> {
        if self.batch_window_us == 0 {
            return inner;
        }
        let deadline = Instant::now() + Duration::from_micros(self.batch_window_us);
        while active.len() < inner.batcher.max_batch && !inner.draining {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (guard, _) = self.work.wait_timeout(inner, left).unwrap();
            inner = guard;
            inner.batcher.admit(active, n_layers);
        }
        inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::coordinator::engine::EngineModel;
    use crate::moe::MoeModel;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "sched-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    /// Concurrent submissions through the long-lived loop produce the
    /// same greedy tokens as direct generation, and the shared active
    /// set means strictly fewer engine steps than running them serially.
    #[test]
    fn shared_loop_matches_reference_and_shares_steps() {
        let m = MoeModel::new(&cfg(), 80);
        let be = NativeBackend::fp(&m);
        let prompts: Vec<Vec<u16>> = vec![vec![1, 17, 30], vec![1, 9, 22]];
        let mut want = Vec::new();
        let mut seq_steps = 0u64;
        for p in &prompts {
            let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
            want.push(eng.generate(p, 6).unwrap());
            seq_steps += eng.metrics.steps;
        }
        let engine =
            Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
        // wide gather window + batch-of-2: the loop waits until both
        // requests are queued (the full batch short-circuits the wait),
        // making the step-sharing assertion deterministic
        let sched = Scheduler::new(Batcher::new(2, 256)).with_window(5_000_000);
        std::thread::scope(|s| {
            let loop_thread = s.spawn(|| sched.run_engine(&engine));
            let rx: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    sched.submit(GenRequest::greedy(i as u64, p.clone(), 6)).unwrap()
                })
                .collect();
            for (rx, w) in rx.into_iter().zip(&want) {
                assert_eq!(&rx.recv().unwrap().tokens, w);
            }
            sched.shutdown();
            assert_eq!(loop_thread.join().unwrap().unwrap(), 2);
        });
        let eng = engine.lock().unwrap();
        assert!(
            eng.metrics.steps < seq_steps,
            "requests did not share steps: {} !< {seq_steps}",
            eng.metrics.steps
        );
        assert_eq!(eng.metrics.tokens_out, 12);
        assert_eq!(eng.metrics.latencies_us.len(), 2);
    }

    #[test]
    fn submit_after_shutdown_is_rejected_and_drain_completes_inflight() {
        let m = MoeModel::new(&cfg(), 81);
        let be = NativeBackend::fp(&m);
        let engine =
            Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
        let sched = Scheduler::new(Batcher::new(2, 256));
        std::thread::scope(|s| {
            let loop_thread = s.spawn(|| sched.run_engine(&engine));
            let rx = sched.submit(GenRequest::greedy(0, vec![1, 2, 3], 4)).unwrap();
            sched.shutdown();
            // in-flight work still drains after shutdown …
            assert_eq!(rx.recv().unwrap().tokens.len(), 7);
            // … but new submissions are rejected
            assert!(sched.submit(GenRequest::greedy(1, vec![1], 1)).is_err());
            loop_thread.join().unwrap().unwrap();
        });
    }
}
