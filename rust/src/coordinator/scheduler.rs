//! Cross-request continuous-batching scheduler: one admission queue and
//! one persistent engine loop shared by every client connection.
//!
//! The server's reader threads [`submit`](Scheduler::submit) parsed
//! requests into the shared [`Batcher`] queue (behind a `Mutex`/`Condvar`)
//! and route responses through per-request [`EventSink`]s. A single
//! engine thread runs [`run_engine`](Scheduler::run_engine) — admit →
//! step → retire, never tearing down between requests — so sequences
//! from different connections (and pipelined requests from the *same*
//! connection) share engine steps and expert groups the moment they
//! overlap. Streaming requests get a [`SeqEvent::Tok`] per generated
//! token as a side effect of the same loop; everyone gets a terminal
//! [`SeqEvent::Done`] (or [`SeqEvent::Failed`] if the engine dies).
//!
//! Admission is bounded: [`ServingConfig::max_queue`] caps requests
//! queued-but-not-admitted, and a submit against a full queue returns
//! [`SubmitError::Busy`] immediately — the overload signal the wire
//! protocol surfaces as `BUSY id=..` — instead of growing the queue
//! without limit.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::ServingConfig;
use crate::coordinator::batcher::{ActiveSeq, Batcher};
use crate::coordinator::engine::DecodeEngine;
use crate::coordinator::request::{
    response_channel, EventSink, GenRequest, ResponseRx, SeqEvent,
};

/// Why a submission was refused. `Busy` is the backpressure signal — the
/// request was never queued and the client should retry later; `Draining`
/// is terminal for the scheduler.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is at `max_queue`. Carries the depth observed.
    Busy { queued: usize },
    /// [`Scheduler::shutdown`] was called; no new work is accepted.
    Draining,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy { queued } => {
                write!(f, "admission queue full ({queued} queued)")
            }
            SubmitError::Draining => write!(f, "scheduler is draining"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One in-flight request's response route.
struct Route {
    sink: EventSink,
    /// Forward per-token `Tok` events (the request had `stream` set).
    stream: bool,
}

pub struct Scheduler {
    inner: Mutex<Inner>,
    work: Condvar,
    /// Micro-batch gather window (µs): on an idle→busy transition the
    /// engine loop lingers this long (or until the batch fills) for more
    /// arrivals, so near-simultaneous requests share their first step.
    /// 0 steps immediately.
    batch_window_us: u64,
    /// Bound on requests queued but not yet admitted; 0 = unbounded.
    /// Submissions against a full queue fail fast with
    /// [`SubmitError::Busy`].
    max_queue: usize,
}

struct Inner {
    batcher: Batcher,
    /// Per-request response routes, keyed by request id. An entry is
    /// removed (after its terminal event) when the sequence retires;
    /// a route dropped without a terminal event means the waiter's
    /// channel errors — the legacy "engine died" signal.
    responders: HashMap<u64, Route>,
    /// Set by [`Scheduler::shutdown`]: no new admissions; the engine
    /// loop drains everything already submitted, then exits.
    draining: bool,
}

impl Scheduler {
    pub fn new(batcher: Batcher) -> Scheduler {
        Scheduler {
            inner: Mutex::new(Inner {
                batcher,
                responders: HashMap::new(),
                draining: false,
            }),
            work: Condvar::new(),
            batch_window_us: 0,
            max_queue: 0,
        }
    }

    pub fn from_config(sc: &ServingConfig) -> Scheduler {
        Scheduler::new(Batcher::from_config(sc))
            .with_window(sc.batch_window_us)
            .with_max_queue(sc.max_queue)
    }

    pub fn with_window(mut self, batch_window_us: u64) -> Scheduler {
        self.batch_window_us = batch_window_us;
        self
    }

    /// Cap the admission queue; 0 = unbounded (the pre-backpressure
    /// behaviour).
    pub fn with_max_queue(mut self, max_queue: usize) -> Scheduler {
        self.max_queue = max_queue;
        self
    }

    /// Queue a request under the admission policy. The result arrives on
    /// the returned channel when the engine loop retires the sequence;
    /// the channel errors if the engine dies. Fails fast with
    /// [`SubmitError::Busy`] when the queue is at `max_queue` and
    /// [`SubmitError::Draining`] after [`shutdown`](Self::shutdown).
    pub fn submit(&self, req: GenRequest) -> Result<ResponseRx, SubmitError> {
        let (tx, rx) = response_channel();
        self.submit_sink(
            req,
            Box::new(move |ev| {
                if let SeqEvent::Done(r) = ev {
                    let _ = tx.send(r); // receiver gone ⇒ client vanished
                }
            }),
        )?;
        Ok(rx)
    }

    /// [`submit`](Self::submit) with an explicit event route: the sink
    /// sees `Tok` events (when the request has `stream` set), then one
    /// terminal `Done`/`Failed`. Sinks run on the engine thread with the
    /// scheduler lock held — they must not block.
    pub fn submit_sink(&self, req: GenRequest, sink: EventSink) -> Result<(), SubmitError> {
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.draining {
                return Err(SubmitError::Draining);
            }
            let queued = inner.batcher.pending();
            if self.max_queue > 0 && queued >= self.max_queue {
                return Err(SubmitError::Busy { queued });
            }
            inner.responders.insert(req.id, Route { sink, stream: req.stream });
            inner.batcher.submit(req);
        }
        self.work.notify_all();
        Ok(())
    }

    /// Requests queued but not yet admitted.
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().batcher.pending()
    }

    /// Stop admitting new requests; the engine loop finishes everything
    /// already submitted (queued and in flight), then returns — graceful
    /// drain, nothing is dropped.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().draining = true;
        self.work.notify_all();
    }

    /// The persistent engine loop: admit from the shared queue, take one
    /// engine step over the active set, stream newly generated tokens to
    /// `stream` routes, retire finished sequences to their sinks —
    /// forever, until [`shutdown`](Self::shutdown) and the backlog
    /// drains. The engine lock is held only around the step itself, so
    /// `STATS`/`METRICS` scrapes interleave freely, and the scheduler
    /// lock is released during the step, so submissions never wait on
    /// compute. Returns the number of sequences served.
    pub fn run_engine(&self, engine: &Mutex<DecodeEngine>) -> Result<usize> {
        let (n_layers, pool) = {
            let mut eng = engine.lock().unwrap();
            eng.metrics.start(); // first-call-wins: the server-lifetime window
            (eng.em.model().cfg.n_layers, eng.kv_pool())
        };
        let mut active: Vec<ActiveSeq> = Vec::new();
        let mut served = 0usize;
        loop {
            // ---- admit (scheduler lock) ----
            {
                let mut inner = self.inner.lock().unwrap();
                loop {
                    let was_idle = active.is_empty();
                    inner.batcher.admit(&mut active, n_layers, &pool);
                    if !active.is_empty() {
                        if was_idle {
                            inner = self.linger(inner, &mut active, n_layers, &pool);
                        }
                        break;
                    }
                    if inner.draining {
                        engine.lock().unwrap().metrics.finish();
                        return Ok(served);
                    }
                    inner = self.work.wait(inner).unwrap();
                }
            }
            // ---- step + retire (engine lock) ----
            let (streamed, finished) = {
                let mut eng = engine.lock().unwrap();
                match Batcher::step_active(&mut eng, &mut active) {
                    Ok(()) => {
                        // collect per-step partials BEFORE retiring so a
                        // sequence's final token streams ahead of Done
                        let mut streamed: Vec<(u64, Vec<u16>)> = Vec::new();
                        for a in active.iter_mut().filter(|a| a.stream) {
                            let id = a.seq.id;
                            let new = a.take_unstreamed();
                            if !new.is_empty() {
                                streamed.push((id, new.to_vec()));
                            }
                        }
                        // split-borrow metrics + trace through the guard
                        let eng = &mut *eng;
                        (
                            streamed,
                            Batcher::retire(&mut active, &mut eng.metrics, &eng.trace, &pool),
                        )
                    }
                    // a shard became unreachable mid-step: degrade, don't
                    // die. The sequences in this step get a terminal ERR
                    // (their routed experts are unfetchable right now) and
                    // their KV is released; the loop keeps serving — later
                    // requests route normally once the shard heals, since
                    // remote fetches lazily reconnect.
                    Err(e) if crate::quant::remote::is_fetch_unavailable(&e) => {
                        // refresh the remote gauges now (the failed step
                        // never reached its end-of-step refresh), so
                        // STATS/METRICS report the outage immediately
                        eng.metrics.remote = eng.em.remote_stats();
                        drop(eng);
                        let failed: Vec<ActiveSeq> = active.drain(..).collect();
                        let msg = format!("expert fetch failed: {e:#}");
                        let mut inner = self.inner.lock().unwrap();
                        for mut a in failed {
                            pool.lock().unwrap().free_seq(&mut a.seq.kv);
                            let id = a.seq.id;
                            if let Some(mut route) = inner.responders.remove(&id) {
                                (route.sink)(SeqEvent::Failed { id, msg: msg.clone() });
                            }
                        }
                        (Vec::new(), Vec::new())
                    }
                    Err(e) => {
                        eng.metrics.finish(); // close the lifetime window
                        drop(eng);
                        // fail every waiter with a terminal event, then
                        // drop its route (dropping a oneshot route wakes
                        // its connection with a recv error); queued
                        // requests get the same — nothing will run them
                        let mut inner = self.inner.lock().unwrap();
                        inner.draining = true;
                        inner.batcher.clear_queue();
                        let msg = format!("engine unavailable: {e}");
                        for (id, mut route) in inner.responders.drain() {
                            (route.sink)(SeqEvent::Failed { id, msg: msg.clone() });
                        }
                        drop(inner);
                        self.work.notify_all();
                        return Err(e);
                    }
                }
            };
            if !streamed.is_empty() || !finished.is_empty() {
                let mut inner = self.inner.lock().unwrap();
                for (id, toks) in streamed {
                    if let Some(route) = inner.responders.get_mut(&id) {
                        for token in toks {
                            (route.sink)(SeqEvent::Tok { id, token });
                        }
                    }
                }
                for r in finished {
                    served += 1;
                    if let Some(mut route) = inner.responders.remove(&r.id) {
                        (route.sink)(SeqEvent::Done(r));
                    }
                }
            }
        }
    }

    /// Hold admission open for up to the gather window after an
    /// idle→busy transition. Exits early once the batch is full or the
    /// scheduler starts draining.
    fn linger<'g>(
        &self,
        mut inner: MutexGuard<'g, Inner>,
        active: &mut Vec<ActiveSeq>,
        n_layers: usize,
        pool: &Mutex<crate::moe::kv::KvPool>,
    ) -> MutexGuard<'g, Inner> {
        if self.batch_window_us == 0 {
            return inner;
        }
        let deadline = Instant::now() + Duration::from_micros(self.batch_window_us);
        while active.len() < inner.batcher.max_batch && !inner.draining {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (guard, _) = self.work.wait_timeout(inner, left).unwrap();
            inner = guard;
            inner.batcher.admit(active, n_layers, pool);
        }
        inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::coordinator::engine::{DecodeEngine, EngineModel};
    use crate::moe::MoeModel;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "sched-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    /// Concurrent submissions through the long-lived loop produce the
    /// same greedy tokens as direct generation, and the shared active
    /// set means strictly fewer engine steps than running them serially.
    #[test]
    fn shared_loop_matches_reference_and_shares_steps() {
        let m = MoeModel::new(&cfg(), 80);
        let be = NativeBackend::fp(&m);
        let prompts: Vec<Vec<u16>> = vec![vec![1, 17, 30], vec![1, 9, 22]];
        let mut want = Vec::new();
        let mut seq_steps = 0u64;
        for p in &prompts {
            let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
            want.push(eng.generate(p, 6).unwrap());
            seq_steps += eng.metrics.steps;
        }
        let engine =
            Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
        // wide gather window + batch-of-2: the loop waits until both
        // requests are queued (the full batch short-circuits the wait),
        // making the step-sharing assertion deterministic
        let sched = Scheduler::new(Batcher::new(2, 256)).with_window(5_000_000);
        std::thread::scope(|s| {
            let loop_thread = s.spawn(|| sched.run_engine(&engine));
            let rx: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    sched.submit(GenRequest::greedy(i as u64, p.clone(), 6)).unwrap()
                })
                .collect();
            for (rx, w) in rx.into_iter().zip(&want) {
                assert_eq!(&rx.recv().unwrap().tokens, w);
            }
            sched.shutdown();
            assert_eq!(loop_thread.join().unwrap().unwrap(), 2);
        });
        let eng = engine.lock().unwrap();
        assert!(
            eng.metrics.steps < seq_steps,
            "requests did not share steps: {} !< {seq_steps}",
            eng.metrics.steps
        );
        assert_eq!(eng.metrics.tokens_out, 12);
        assert_eq!(eng.metrics.latencies_us.count(), 2);
        assert_eq!(eng.metrics.queue_waits_us.count(), 2);
        // the shared loop's lifecycle spans landed in the engine tracer
        let spans = eng.trace.snapshot(None);
        let requests = spans
            .iter()
            .filter(|sp| sp.kind == crate::trace::SpanKind::Request)
            .count();
        assert_eq!(requests, 2, "one request span per retired sequence");
        assert!(
            spans.iter().any(|sp| sp.kind == crate::trace::SpanKind::DecodeStep),
            "engine steps must record step spans"
        );
    }

    #[test]
    fn submit_after_shutdown_is_rejected_and_drain_completes_inflight() {
        let m = MoeModel::new(&cfg(), 81);
        let be = NativeBackend::fp(&m);
        let engine =
            Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
        let sched = Scheduler::new(Batcher::new(2, 256));
        std::thread::scope(|s| {
            let loop_thread = s.spawn(|| sched.run_engine(&engine));
            let rx = sched.submit(GenRequest::greedy(0, vec![1, 2, 3], 4)).unwrap();
            sched.shutdown();
            // in-flight work still drains after shutdown …
            assert_eq!(rx.recv().unwrap().tokens.len(), 7);
            // … but new submissions are rejected
            assert_eq!(
                sched.submit(GenRequest::greedy(1, vec![1], 1)).unwrap_err(),
                SubmitError::Draining
            );
            loop_thread.join().unwrap().unwrap();
        });
    }

    /// Backpressure is a pure queue-depth predicate, so it is testable
    /// without an engine: with `max_queue = 2` and nothing admitting,
    /// the third submission is refused with `Busy` and is NOT queued —
    /// the queue cannot grow past the cap.
    #[test]
    fn bounded_queue_refuses_with_busy() {
        let sched = Scheduler::new(Batcher::new(1, 256)).with_max_queue(2);
        sched.submit(GenRequest::greedy(0, vec![1], 1)).unwrap();
        sched.submit(GenRequest::greedy(1, vec![1], 1)).unwrap();
        assert_eq!(
            sched.submit(GenRequest::greedy(2, vec![1], 1)).unwrap_err(),
            SubmitError::Busy { queued: 2 }
        );
        assert_eq!(sched.pending(), 2, "refused request must not enter the queue");
        // unbounded (0) keeps the legacy behaviour
        let open = Scheduler::new(Batcher::new(1, 256));
        for i in 0..16 {
            open.submit(GenRequest::greedy(i, vec![1], 1)).unwrap();
        }
        assert_eq!(open.pending(), 16);
    }

    /// Streaming routes see one `Tok` per generated token, in decode
    /// order, each before the terminal `Done` — and a non-streaming
    /// request through the same loop sees only `Done`.
    #[test]
    fn streaming_sink_gets_per_token_events_then_done() {
        let m = MoeModel::new(&cfg(), 82);
        let be = NativeBackend::fp(&m);
        let engine = Mutex::new(DecodeEngine::new(EngineModel::Fp(&m), &be, None));
        let sched = Scheduler::new(Batcher::new(2, 256));
        let (tx, rx) = std::sync::mpsc::channel::<SeqEvent>();
        let (qtx, qrx) = std::sync::mpsc::channel::<SeqEvent>();
        std::thread::scope(|s| {
            let loop_thread = s.spawn(|| sched.run_engine(&engine));
            sched
                .submit_sink(
                    GenRequest::greedy(7, vec![1, 17, 30], 5).with_stream(true),
                    Box::new(move |ev| drop(tx.send(ev))),
                )
                .unwrap();
            sched
                .submit_sink(
                    GenRequest::greedy(8, vec![1, 9], 3),
                    Box::new(move |ev| drop(qtx.send(ev))),
                )
                .unwrap();
            let events: Vec<SeqEvent> = rx.iter().collect(); // until tx drops
            let quiet: Vec<SeqEvent> = qrx.iter().collect();
            sched.shutdown();
            loop_thread.join().unwrap().unwrap();

            assert_eq!(events.len(), 6, "5 Toks + Done: {events:?}");
            let mut streamed = Vec::new();
            for ev in &events[..5] {
                match ev {
                    SeqEvent::Tok { id: 7, token } => streamed.push(*token),
                    other => panic!("expected Tok, got {other:?}"),
                }
            }
            let SeqEvent::Done(r) = &events[5] else {
                panic!("expected terminal Done, got {:?}", events[5])
            };
            assert_eq!(r.id, 7);
            assert_eq!(&r.tokens[3..], &streamed[..], "partials must equal the OK tail");
            // non-streaming: exactly one terminal event, no partials
            assert_eq!(quiet.len(), 1);
            assert!(matches!(&quiet[0], SeqEvent::Done(r) if r.id == 8));
        });
    }
}
