//! Serving metrics: latency percentiles, throughput, expert-activation,
//! activated-parameter accounting (feeds Tables 5/6/8) and — when the
//! engine serves from a paged [`ExpertStore`](crate::quant::store) — the
//! expert-cache gauges (resident bytes, hit/miss/evict/prefetch counts).
//!
//! Latency samples live in bounded log2 [`Histo`]s (O(1) memory, no
//! per-scrape sort under the engine lock); the old per-request
//! `Vec<u64>` vectors grew forever and were clone+sorted on every
//! `STATS`/`METRICS` read. Percentile reads report the bucket upper
//! bound — within one log2 bucket of the exact value (pinned in
//! `trace::tests`).

use std::time::Instant;

use crate::moe::kv::KvGauges;
use crate::quant::store::{CacheCounters, RemoteFetchStats};
use crate::trace::Histo;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Per-request end-to-end latency (µs), bounded log2 histogram.
    pub latencies_us: Histo,
    /// Per-request queue wait before admission (µs) — recorded together
    /// with `latencies_us` at retirement.
    pub queue_waits_us: Histo,
    /// Per-step routing + pruning time (µs, summed over layers).
    pub step_route_us: Histo,
    /// Per-step expert execute time (µs, summed over layers; includes
    /// the gather that builds each expert's row block).
    pub step_execute_us: Histo,
    /// Per-step attention + KV-cache time (µs, summed over layers).
    pub step_kv_us: Histo,
    /// Decoded tokens total.
    pub tokens_out: u64,
    /// Prompt tokens processed.
    pub tokens_in: u64,
    /// (kept, offered) expert slots across all token-layer decisions.
    pub experts_kept: u64,
    pub experts_offered: u64,
    /// Packed bytes of routed experts actually executed.
    pub routed_bytes: u64,
    /// Engine steps taken.
    pub steps: u64,
    /// Wall-clock of the serving run.
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
    /// Expert-cache gauges, refreshed from the store each engine step
    /// (`None` when the model does not serve from a store, i.e. fp).
    // analyze: gauge
    pub cache: Option<CacheCounters>,
    /// Remote-fetch gauges, refreshed each engine step when experts
    /// page in over the wire (`None` for local stores and fp models).
    // analyze: gauge
    pub remote: Option<RemoteFetchStats>,
    /// Demand-fetch wait histogram (µs), copied from the expert store
    /// each engine step (empty for fp / non-remote models) — the
    /// per-RPC distribution behind `remote.fetch_p95_us`.
    // analyze: gauge
    pub fetch_wait_us: Histo,
    /// Paged-KV gauges (pages/bytes in use, prefix hits, CoW copies),
    /// refreshed from the pool each engine step — O(1) reads.
    // analyze: gauge
    pub kv: KvGauges,
}

impl Metrics {
    /// Open the wall-clock window. First call wins: counters accumulate
    /// across every subsequent `run()`/step, so `tokens_per_sec` covers
    /// the whole serving lifetime rather than only the latest drain
    /// (which inflated `STATS` tps). Call [`reset`](Self::reset) for a
    /// fresh window.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Close (or extend) the window; the last call wins so the window
    /// spans first `start()` → last `finish()`.
    pub fn finish(&mut self) {
        self.finished = Some(Instant::now());
    }

    /// Drop every counter and the wall-clock window — the explicit
    /// opt-in for callers that want per-run numbers.
    pub fn reset(&mut self) {
        *self = Metrics::default();
    }

    /// Wall-clock covered by the window. While the window is still open
    /// (started, not finished) this reads up to now, so a live server's
    /// `STATS`/`METRICS` report a sane lifetime tps mid-flight.
    pub fn wall_secs(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            (Some(a), None) => a.elapsed().as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.wall_secs();
        if w > 0.0 {
            self.tokens_out as f64 / w
        } else {
            0.0
        }
    }

    pub fn pruning_ratio(&self) -> f64 {
        if self.experts_offered == 0 {
            return 0.0;
        }
        1.0 - self.experts_kept as f64 / self.experts_offered as f64
    }

    /// Requests retired so far (latency samples recorded).
    pub fn requests(&self) -> u64 {
        self.latencies_us.count()
    }

    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latencies_us.percentile(p)
    }

    /// Queue-wait percentile (µs) — how long requests sat in the
    /// admission queue before the engine picked them up.
    pub fn queue_percentile_us(&self, p: f64) -> u64 {
        self.queue_waits_us.percentile(p)
    }

    /// Several latency percentiles in one O(buckets·|ps|) pass over the
    /// bounded histogram — the `STATS`/`METRICS` scrape path runs under
    /// the engine lock, so there must be no clone+sort of lifetime
    /// sample vectors here (there is no longer such a vector to sort).
    pub fn latency_percentiles_us(&self, ps: &[f64]) -> Vec<u64> {
        self.latencies_us.percentiles(ps)
    }

    /// Several queue-wait percentiles (see
    /// [`latency_percentiles_us`](Self::latency_percentiles_us)).
    pub fn queue_percentiles_us(&self, ps: &[f64]) -> Vec<u64> {
        self.queue_waits_us.percentiles(ps)
    }

    /// Mean activated routed-expert bytes per decoded token.
    pub fn routed_bytes_per_token(&self) -> f64 {
        if self.tokens_out == 0 {
            return 0.0;
        }
        self.routed_bytes as f64 / self.tokens_out as f64
    }

    /// JSON snapshot for the server's `METRICS` command (monitoring
    /// scrape format — every quantity the operator dashboards need).
    /// Cache gauges report zero until an engine step over a store-backed
    /// model refreshes them.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{num, obj};
        let c = self.cache.unwrap_or_default();
        let r = self.remote.unwrap_or_default();
        let lat = self.latency_percentiles_us(&[0.5, 0.95, 0.99]);
        let queue = self.queue_percentiles_us(&[0.5, 0.95]);
        obj(vec![
            ("tokens_out", num(self.tokens_out as f64)),
            ("tokens_in", num(self.tokens_in as f64)),
            ("steps", num(self.steps as f64)),
            ("requests", num(self.requests() as f64)),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            ("latency_p50_us", num(lat[0] as f64)),
            ("latency_p95_us", num(lat[1] as f64)),
            ("latency_p99_us", num(lat[2] as f64)),
            ("queue_p50_us", num(queue[0] as f64)),
            ("queue_p95_us", num(queue[1] as f64)),
            ("step_route_p50_us", num(self.step_route_us.percentile(0.5) as f64)),
            ("step_route_p95_us", num(self.step_route_us.percentile(0.95) as f64)),
            ("step_execute_p50_us", num(self.step_execute_us.percentile(0.5) as f64)),
            ("step_execute_p95_us", num(self.step_execute_us.percentile(0.95) as f64)),
            ("step_kv_p50_us", num(self.step_kv_us.percentile(0.5) as f64)),
            ("step_kv_p95_us", num(self.step_kv_us.percentile(0.95) as f64)),
            ("fetch_wait_p50_us", num(self.fetch_wait_us.percentile(0.5) as f64)),
            ("fetch_wait_p95_us", num(self.fetch_wait_us.percentile(0.95) as f64)),
            ("pruning_ratio", num(self.pruning_ratio())),
            ("routed_bytes_per_token", num(self.routed_bytes_per_token())),
            ("experts_kept", num(self.experts_kept as f64)),
            ("experts_offered", num(self.experts_offered as f64)),
            ("cache_resident_bytes", num(c.resident_bytes as f64)),
            ("cache_peak_resident_bytes", num(c.peak_resident_bytes as f64)),
            ("cache_hits", num(c.hits as f64)),
            ("cache_misses", num(c.misses as f64)),
            ("cache_evictions", num(c.evictions as f64)),
            ("cache_prefetch_hits", num(c.prefetch_hits as f64)),
            ("cache_hit_rate", num(c.hit_rate())),
            ("remote_fetch_rpcs", num(r.fetch_rpcs as f64)),
            ("remote_prefetch_rpcs", num(r.prefetch_rpcs as f64)),
            ("remote_fetched_bytes", num(r.fetched_bytes as f64)),
            ("remote_fetch_p95_us", num(r.fetch_p95_us as f64)),
            ("shards_up", num(r.shards_up as f64)),
            ("shards_total", num(r.shards_total as f64)),
            ("kv_pages", num(self.kv.kv_pages as f64)),
            ("kv_bytes", num(self.kv.kv_bytes as f64)),
            ("prefix_hit_toks", num(self.kv.prefix_hit_toks as f64)),
            ("kv_cow_copies", num(self.kv.cow_copies as f64)),
            ("kv_tree_blocks", num(self.kv.tree_blocks as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `start()` must be first-call-wins: repeated `run()`s on one
    /// engine accumulate `tokens_out`, so the tps window has to span all
    /// of them — the old overwrite covered only the last run and
    /// inflated tps.
    #[test]
    fn start_is_first_call_wins_and_reset_reopens() {
        let mut m = Metrics::default();
        m.start();
        let t0 = m.started;
        assert!(t0.is_some());
        m.tokens_out = 100;
        m.finish();
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.start(); // second run on the same engine
        assert_eq!(m.started, t0, "start() must not move the window");
        m.tokens_out += 100;
        m.finish();
        assert!(m.wall_secs() >= 0.002, "window must span both runs");
        assert_eq!(m.tokens_out, 200);
        m.reset();
        assert!(m.started.is_none() && m.finished.is_none());
        assert_eq!(m.tokens_out, 0);
        m.start();
        assert_ne!(m.started, t0, "reset() reopens the window");
    }

    /// An open window (server still running) reports live wall-clock so
    /// STATS tps is sane before shutdown.
    #[test]
    fn open_window_reads_to_now() {
        let mut m = Metrics::default();
        assert_eq!(m.wall_secs(), 0.0);
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.wall_secs() > 0.0);
        m.tokens_out = 10;
        assert!(m.tokens_per_sec() > 0.0);
    }

    #[test]
    fn percentiles_and_ratio() {
        let mut m = Metrics::default();
        for v in [10, 20, 30, 40, 100] {
            m.latencies_us.record(v);
        }
        // histogram percentiles report the bucket upper bound of the
        // exact rank: p50 exact 30 → bucket [16,31]; p100 exact 100 →
        // bucket [64,127]
        assert_eq!(m.latency_percentile_us(0.5), 31);
        assert_eq!(m.latency_percentile_us(1.0), 127);
        assert_eq!(m.requests(), 5);
        for v in [1, 2, 3, 4, 50] {
            m.queue_waits_us.record(v);
        }
        assert_eq!(m.queue_percentile_us(0.5), 3); // exact 3 → bucket [2,3]
        assert_eq!(m.queue_percentile_us(1.0), 63); // exact 50 → bucket [32,63]
        assert_eq!(Metrics::default().queue_percentile_us(0.95), 0);
        // batched scrape path: same answers, no sort anywhere
        assert_eq!(m.latency_percentiles_us(&[0.5, 1.0]), vec![31, 127]);
        assert_eq!(m.queue_percentiles_us(&[0.5, 1.0]), vec![3, 63]);
        assert_eq!(Metrics::default().latency_percentiles_us(&[0.5, 0.95]), vec![0, 0]);
        m.experts_kept = 80;
        m.experts_offered = 100;
        assert!((m.pruning_ratio() - 0.2).abs() < 1e-12);
    }

    /// Old-vs-new pin: for the retired-latency sample sets the serving
    /// tests exercise, the histogram percentile lands in the same log2
    /// bucket as the exact value the old clone+sort implementation
    /// (`sorted[round((n-1)·p)]`) returned, and is never below it.
    #[test]
    fn histogram_percentiles_match_old_sort_within_one_bucket() {
        use crate::trace::bucket_of;
        let samples: Vec<u64> = (0..500u64).map(|i| (i * i * 7 + 13) % 90_000).collect();
        let mut m = Metrics::default();
        for &v in &samples {
            m.latencies_us.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for &p in &[0.5, 0.95, 0.99, 1.0] {
            let old = sorted[((sorted.len() - 1) as f64 * p).round() as usize];
            let new = m.latency_percentile_us(p);
            assert!(new >= old, "p{p}: histogram {new} below exact {old}");
            assert_eq!(
                bucket_of(new),
                bucket_of(old),
                "p{p}: histogram {new} not within one bucket of exact {old}"
            );
        }
    }
}
