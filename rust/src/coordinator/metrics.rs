//! Serving metrics: latency percentiles, throughput, expert-activation,
//! activated-parameter accounting (feeds Tables 5/6/8) and — when the
//! engine serves from a paged [`ExpertStore`](crate::quant::store) — the
//! expert-cache gauges (resident bytes, hit/miss/evict/prefetch counts).

use std::time::Instant;

use crate::quant::store::CacheCounters;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Per-request end-to-end latency (µs).
    pub latencies_us: Vec<u64>,
    /// Decoded tokens total.
    pub tokens_out: u64,
    /// Prompt tokens processed.
    pub tokens_in: u64,
    /// (kept, offered) expert slots across all token-layer decisions.
    pub experts_kept: u64,
    pub experts_offered: u64,
    /// Packed bytes of routed experts actually executed.
    pub routed_bytes: u64,
    /// Engine steps taken.
    pub steps: u64,
    /// Wall-clock of the serving run.
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
    /// Expert-cache gauges, refreshed from the store each engine step
    /// (`None` when the model does not serve from a store, i.e. fp).
    pub cache: Option<CacheCounters>,
}

impl Metrics {
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn finish(&mut self) {
        self.finished = Some(Instant::now());
    }

    pub fn wall_secs(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let w = self.wall_secs();
        if w > 0.0 {
            self.tokens_out as f64 / w
        } else {
            0.0
        }
    }

    pub fn pruning_ratio(&self) -> f64 {
        if self.experts_offered == 0 {
            return 0.0;
        }
        1.0 - self.experts_kept as f64 / self.experts_offered as f64
    }

    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        v[((v.len() - 1) as f64 * p).round() as usize]
    }

    /// Mean activated routed-expert bytes per decoded token.
    pub fn routed_bytes_per_token(&self) -> f64 {
        if self.tokens_out == 0 {
            return 0.0;
        }
        self.routed_bytes as f64 / self.tokens_out as f64
    }

    /// JSON snapshot for the server's `METRICS` command (monitoring
    /// scrape format — every quantity the operator dashboards need).
    /// Cache gauges report zero until an engine step over a store-backed
    /// model refreshes them.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{num, obj};
        let c = self.cache.unwrap_or_default();
        obj(vec![
            ("tokens_out", num(self.tokens_out as f64)),
            ("tokens_in", num(self.tokens_in as f64)),
            ("steps", num(self.steps as f64)),
            ("requests", num(self.latencies_us.len() as f64)),
            ("tokens_per_sec", num(self.tokens_per_sec())),
            ("latency_p50_us", num(self.latency_percentile_us(0.5) as f64)),
            ("latency_p95_us", num(self.latency_percentile_us(0.95) as f64)),
            ("latency_p99_us", num(self.latency_percentile_us(0.99) as f64)),
            ("pruning_ratio", num(self.pruning_ratio())),
            ("routed_bytes_per_token", num(self.routed_bytes_per_token())),
            ("experts_kept", num(self.experts_kept as f64)),
            ("experts_offered", num(self.experts_offered as f64)),
            ("cache_resident_bytes", num(c.resident_bytes as f64)),
            ("cache_peak_resident_bytes", num(c.peak_resident_bytes as f64)),
            ("cache_hits", num(c.hits as f64)),
            ("cache_misses", num(c.misses as f64)),
            ("cache_evictions", num(c.evictions as f64)),
            ("cache_prefetch_hits", num(c.prefetch_hits as f64)),
            ("cache_hit_rate", num(c.hit_rate())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_ratio() {
        let mut m = Metrics::default();
        m.latencies_us = vec![10, 20, 30, 40, 100];
        assert_eq!(m.latency_percentile_us(0.5), 30);
        assert_eq!(m.latency_percentile_us(1.0), 100);
        m.experts_kept = 80;
        m.experts_offered = 100;
        assert!((m.pruning_ratio() - 0.2).abs() < 1e-12);
    }
}
