//! Request/response types for the generation service, plus the response
//! channel a [`Scheduler`](crate::coordinator::scheduler::Scheduler)
//! uses to route each finished [`GenResult`] back to the connection
//! thread that submitted it.

/// A generation request (tokens in, tokens out — tokenization is the
/// synthetic vocabulary, so clients speak token ids directly).
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    /// Greedy if None, else temperature sampling with this seed.
    pub sample: Option<(f32, u64)>,
    /// Scheduling class for [`Policy::Priority`](crate::coordinator::batcher::Policy):
    /// higher admits first. 0 = default/batch traffic.
    pub priority: u8,
    /// Emit a [`SeqEvent::Tok`] for every generated token (wire
    /// `stream=1`) instead of only the terminal [`SeqEvent::Done`].
    pub stream: bool,
}

#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<u16>,
    /// Wall-clock from admission to completion (µs).
    pub latency_us: u64,
    /// Time the request waited in queue before admission (µs).
    pub queue_us: u64,
    pub prompt_len: usize,
}

/// Sending half of a request's response route: held by the scheduler
/// (keyed by request id) until the sequence retires. Dropping it without
/// sending wakes the waiting connection with a recv error — the "engine
/// died" signal.
pub type ResponseTx = std::sync::mpsc::Sender<GenResult>;

/// Receiving half: the submitting connection blocks here for its result.
pub type ResponseRx = std::sync::mpsc::Receiver<GenResult>;

/// One response route for one in-flight request.
pub fn response_channel() -> (ResponseTx, ResponseRx) {
    std::sync::mpsc::channel()
}

/// Lifecycle events the scheduler pushes through a request's
/// [`EventSink`]. A request sees zero or more `Tok`s (streaming requests
/// only), then exactly one terminal `Done` or `Failed`.
#[derive(Debug)]
pub enum SeqEvent {
    /// One newly generated token (requests submitted with
    /// [`GenRequest::stream`] set; emitted per engine step, in order).
    Tok { id: u64, token: u16 },
    /// The sequence retired — terminal.
    Done(GenResult),
    /// The engine died before the sequence finished — terminal.
    Failed { id: u64, msg: String },
}

/// Per-request event route. Called from the engine thread with the
/// scheduler lock held, so sinks must not block: send on an unbounded
/// channel, flip a flag — nothing that waits on another request.
pub type EventSink = Box<dyn FnMut(SeqEvent) + Send>;

impl GenRequest {
    pub fn greedy(id: u64, prompt: Vec<u16>, max_new_tokens: usize) -> GenRequest {
        GenRequest { id, prompt, max_new_tokens, sample: None, priority: 0, stream: false }
    }

    pub fn with_priority(mut self, priority: u8) -> GenRequest {
        self.priority = priority;
        self
    }

    /// Temperature sampling with a seed instead of greedy decoding.
    pub fn with_sample(mut self, temp: f32, seed: u64) -> GenRequest {
        self.sample = Some((temp, seed));
        self
    }

    /// Stream per-token [`SeqEvent::Tok`] events as the sequence decodes.
    pub fn with_stream(mut self, stream: bool) -> GenRequest {
        self.stream = stream;
        self
    }

    /// Total token footprint (admission-control unit).
    pub fn footprint(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_constructor() {
        let r = GenRequest::greedy(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert!(r.sample.is_none());
        assert_eq!(r.max_new_tokens, 16);
    }
}
