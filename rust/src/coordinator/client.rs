//! Blocking client for the wire protocol — the one client
//! implementation the round-trip tests, the protocol tests and the
//! `perf_hotpath` serving bench all drive, so client-side grammar lives
//! in exactly one place (`protocol::parse_response`).
//!
//! The client speaks v1 (tagged) exclusively: [`Client::submit_opts`]
//! writes a `GEN id=..` line and returns its tag without waiting, which
//! is what makes [`Client::gen_pipelined`] keep N requests in flight on
//! one connection while the server's continuous batch decodes them
//! together. [`Client::gen`] is the one-shot convenience (submit, then
//! wait for that tag), [`Client::gen_stream`] surfaces `TOK` partials
//! through a callback, and `BUSY` rejections are reported as
//! [`ClientError::Busy`] so callers can implement backoff —
//! [`Client::gen_with_retry`] is the built-in policy (jittered
//! exponential backoff under a deadline budget).

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::protocol::{self, parse_response, Response};

/// One completed generation as the wire reports it.
#[derive(Clone, Debug, PartialEq)]
pub struct GenOutput {
    /// Prompt + generated tokens, exactly what the engine produced.
    pub tokens: Vec<u16>,
    /// Submission-to-completion wall clock (µs), measured server-side.
    pub latency_us: u64,
    /// Time spent in the admission queue before the engine picked the
    /// request up (µs).
    pub queue_us: u64,
}

/// Options for [`Client::submit_opts`] beyond prompt and length.
#[derive(Clone, Copy, Debug, Default)]
pub struct GenOpts {
    /// Scheduling class (`prio=`); higher admits first.
    pub priority: u8,
    /// Temperature sampling (`temp=`/`seed=`); greedy when `None`.
    pub sample: Option<(f32, u64)>,
    /// Ask for per-token `TOK` partials (`stream=1`).
    pub stream: bool,
}

/// A server-side rejection the caller may want to branch on (`BUSY` is
/// retryable overload; `Err` lines are terminal for that request).
#[derive(Clone, Debug, PartialEq)]
pub enum ClientError {
    /// The server's admission queue was full; resubmit later.
    Busy { tag: u64 },
    /// The server answered `ERR` for this tag.
    Rejected { tag: Option<u64>, msg: String },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Busy { tag } => write!(f, "server busy (request {tag})"),
            ClientError::Rejected { tag, msg } => match tag {
                Some(t) => write!(f, "request {t} rejected: {msg}"),
                None => write!(f, "rejected: {msg}"),
            },
        }
    }
}

impl std::error::Error for ClientError {}

/// Blocking protocol-v1 client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    out: TcpStream,
    next_tag: u64,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let out = TcpStream::connect(addr)?;
        let reader = BufReader::new(out.try_clone()?);
        Ok(Client { reader, out, next_tag: 1 })
    }

    /// `PING` → `PONG` (connection liveness probe).
    pub fn ping(&mut self) -> Result<()> {
        self.out.write_all(b"PING\n")?;
        match self.recv_response()? {
            Response::Pong => Ok(()),
            other => bail!("expected PONG, got {other:?}"),
        }
    }

    /// Write one tagged `GEN` line and return its tag **without waiting
    /// for the response** — the pipelining primitive. Responses for
    /// outstanding tags arrive via [`recv_response`](Self::recv_response)
    /// in retirement order, not submission order.
    pub fn submit(&mut self, prompt: &[u16], max_new: usize) -> Result<u64> {
        self.submit_opts(prompt, max_new, GenOpts::default())
    }

    /// [`submit`](Self::submit) with priority/sampling/streaming
    /// options. The line is formatted by
    /// [`protocol::format_gen`](crate::coordinator::protocol::format_gen)
    /// — the same module that parses it server-side.
    pub fn submit_opts(&mut self, prompt: &[u16], max_new: usize, opts: GenOpts) -> Result<u64> {
        let tag = self.next_tag;
        self.next_tag += 1;
        let line =
            protocol::format_gen(tag, prompt, max_new, opts.priority, opts.sample, opts.stream);
        self.out.write_all(line.as_bytes())?;
        Ok(tag)
    }

    /// Read and parse the next response line (blocking). Response lines
    /// are deliberately *not* length-capped: `MAX_LINE_BYTES` is the
    /// server's defense against untrusted clients, while a legal `OK`
    /// for a long generation can be arbitrarily large — the client
    /// trusts the server it connected to.
    pub fn recv_response(&mut self) -> Result<Response> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        parse_response(&line)
    }

    /// Submit one request and block for its result (lockstep
    /// convenience; ignores nothing — any interleaved response for a
    /// different tag is an error, so use it only when this client has no
    /// other requests in flight).
    pub fn gen(&mut self, prompt: &[u16], max_new: usize) -> Result<GenOutput> {
        self.gen_opts(prompt, max_new, GenOpts::default())
    }

    /// [`gen`](Self::gen) with explicit submission options (same
    /// lockstep contract).
    pub fn gen_opts(&mut self, prompt: &[u16], max_new: usize, opts: GenOpts) -> Result<GenOutput> {
        let tag = self.submit_opts(prompt, max_new, opts)?;
        let mut got = self.collect_tags(&[tag])?;
        Ok(got.remove(&tag).expect("collect_tags returned the tag"))
    }

    /// [`gen`](Self::gen) with jittered exponential backoff on `BUSY`
    /// overload rejections, bounded by a total `deadline` budget.
    ///
    /// `BUSY` means the admission queue was full and nothing was queued,
    /// so resubmitting is always safe. The wait before attempt *n* is a
    /// uniform draw from `(backoff/2, backoff]` with `backoff` doubling
    /// from 2 ms up to a 256 ms cap — the jitter decorrelates a thundering
    /// herd of clients all seeing the same full queue. When the next wait
    /// would overrun the deadline the last `Busy` error is returned;
    /// every non-`Busy` outcome (success, `ERR`, transport failure)
    /// passes straight through.
    ///
    /// After the **second consecutive** `BUSY` the resubmission escalates
    /// `prio=` by one tier (once per call): a request that already waited
    /// through two full admission rounds is no longer background traffic,
    /// and the bump lets the priority scheduler admit it ahead of fresh
    /// batch arrivals instead of starving it behind them.
    pub fn gen_with_retry(
        &mut self,
        prompt: &[u16],
        max_new: usize,
        deadline: Duration,
    ) -> Result<GenOutput> {
        let started = Instant::now();
        let mut backoff = Duration::from_millis(2);
        let mut opts = GenOpts::default();
        let mut busies = 0u32;
        // deterministic per-call jitter stream; distinct clients diverge
        // via their tag counters
        let mut rng = crate::util::rng::Rng::new(0xB0FF_u64 ^ (self.next_tag << 17));
        loop {
            match self.gen_opts(prompt, max_new, opts) {
                Err(e)
                    if matches!(
                        e.downcast_ref::<ClientError>(),
                        Some(ClientError::Busy { .. })
                    ) =>
                {
                    busies += 1;
                    if busies == 2 {
                        opts.priority = opts.priority.saturating_add(1);
                    }
                    let frac = 0.5 + 0.5 * rng.f64(); // (0.5, 1.0]
                    let wait = backoff.mul_f64(frac);
                    if started.elapsed() + wait > deadline {
                        return Err(e);
                    }
                    std::thread::sleep(wait);
                    backoff = (backoff * 2).min(Duration::from_millis(256));
                }
                other => return other,
            }
        }
    }

    /// Pipeline every request on this one connection — all submitted
    /// before any response is read — then gather the out-of-order tagged
    /// responses. Returns outputs in **submission order**. A `BUSY` or
    /// `ERR` for any tag fails the whole call, but only after every
    /// outstanding tag's terminal response has been drained, so the
    /// connection stays usable afterwards (callers wanting per-tag
    /// handling drive [`submit_opts`](Self::submit_opts) /
    /// [`recv_response`](Self::recv_response) directly).
    pub fn gen_pipelined(&mut self, reqs: &[(Vec<u16>, usize)]) -> Result<Vec<GenOutput>> {
        let mut tags = Vec::with_capacity(reqs.len());
        for (prompt, max_new) in reqs {
            tags.push(self.submit(prompt, *max_new)?);
        }
        let mut by_tag = self.collect_tags(&tags)?;
        Ok(tags
            .iter()
            .map(|t| by_tag.remove(t).expect("collect_tags returned every tag"))
            .collect())
    }

    /// Submit with `stream=1` and invoke `on_tok` for every `TOK`
    /// partial as it arrives, returning the terminal result (whose tail
    /// repeats the streamed tokens).
    pub fn gen_stream(
        &mut self,
        prompt: &[u16],
        max_new: usize,
        mut on_tok: impl FnMut(u16),
    ) -> Result<GenOutput> {
        let tag =
            self.submit_opts(prompt, max_new, GenOpts { stream: true, ..Default::default() })?;
        loop {
            match self.recv_response()? {
                Response::Tok { tag: t, token } if t == tag => on_tok(token),
                Response::Ok { tag: Some(t), latency_us, queue_us, tokens } if t == tag => {
                    return Ok(GenOutput { tokens, latency_us, queue_us });
                }
                Response::Busy { tag: t } if t == tag => {
                    return Err(ClientError::Busy { tag }.into());
                }
                Response::Err { tag: t, msg } if t == Some(tag) || t.is_none() => {
                    return Err(ClientError::Rejected { tag: t, msg }.into());
                }
                other => bail!("unexpected response while streaming {tag}: {other:?}"),
            }
        }
    }

    /// Gather a terminal response (`OK`/`BUSY`/tagged `ERR`) for every
    /// tag in `tags` (in any arrival order), tolerating stray `TOK`
    /// partials. Always drains *all* the tags before reporting the first
    /// failure — leaving terminal responses unread would desynchronize
    /// every later call on this connection.
    fn collect_tags(&mut self, tags: &[u64]) -> Result<HashMap<u64, GenOutput>> {
        let mut out = HashMap::with_capacity(tags.len());
        let mut terminal: HashSet<u64> = HashSet::with_capacity(tags.len());
        let mut failed: Option<ClientError> = None;
        while terminal.len() < tags.len() {
            match self.recv_response()? {
                Response::Ok { tag: Some(t), latency_us, queue_us, tokens }
                    if tags.contains(&t) =>
                {
                    terminal.insert(t);
                    out.insert(t, GenOutput { tokens, latency_us, queue_us });
                }
                Response::Tok { tag: t, .. } if tags.contains(&t) => {}
                Response::Busy { tag: t } if tags.contains(&t) => {
                    terminal.insert(t);
                    failed.get_or_insert(ClientError::Busy { tag: t });
                }
                Response::Err { tag: Some(t), msg } if tags.contains(&t) => {
                    terminal.insert(t);
                    failed.get_or_insert(ClientError::Rejected { tag: Some(t), msg });
                }
                // an untagged ERR cannot be attributed to a tag, so the
                // connection state is unknowable — surface immediately
                Response::Err { tag, msg } => {
                    return Err(ClientError::Rejected { tag, msg }.into());
                }
                other => bail!("unexpected response: {other:?}"),
            }
        }
        match failed {
            None => Ok(out),
            Some(e) => Err(e.into()),
        }
    }

    /// `STATS` → the raw `k=v` payload.
    pub fn stats(&mut self) -> Result<String> {
        self.out.write_all(b"STATS\n")?;
        match self.recv_response()? {
            Response::Stats(s) => Ok(s),
            other => bail!("expected STATS, got {other:?}"),
        }
    }

    /// One field of the `STATS` payload, parsed.
    pub fn stats_field(&mut self, key: &str) -> Result<f64> {
        let stats = self.stats()?;
        stats
            .split_whitespace()
            .find_map(|f| f.strip_prefix(key).and_then(|f| f.strip_prefix('=')))
            .ok_or_else(|| anyhow!("STATS has no field {key:?}: {stats}"))?
            .parse()
            .map_err(|e| anyhow!("STATS {key}: {e}"))
    }

    /// `TRACE [last=<n>]` → the span dump, one JSON object string per
    /// span, oldest first. The `TRACE n=<k>` header tells this reader
    /// exactly how many span lines to consume, keeping the connection
    /// line-synchronized for whatever is pipelined behind it.
    pub fn trace(&mut self, last: Option<usize>) -> Result<Vec<String>> {
        self.out.write_all(protocol::format_trace_cmd(last).as_bytes())?;
        let n = match self.recv_response()? {
            Response::Trace { n } => n,
            other => bail!("expected TRACE, got {other:?}"),
        };
        let mut spans = Vec::with_capacity(n);
        for _ in 0..n {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                bail!("server closed mid span dump");
            }
            spans.push(line.trim_end().to_string());
        }
        Ok(spans)
    }

    /// `METRICS` → the raw JSON payload.
    pub fn metrics_json(&mut self) -> Result<String> {
        self.out.write_all(b"METRICS\n")?;
        match self.recv_response()? {
            Response::Metrics(s) => Ok(s),
            other => bail!("expected METRICS, got {other:?}"),
        }
    }

    /// `METRICS`, parsed into the crate's JSON value.
    pub fn metrics_value(&mut self) -> Result<crate::util::json::Value> {
        crate::util::json::Value::parse(&self.metrics_json()?)
    }

    /// Ask the server to close this connection (`QUIT`), consuming the
    /// client. In-flight requests still drain server-side; their
    /// responses are discarded with the socket.
    pub fn quit(mut self) -> Result<()> {
        self.out.write_all(b"QUIT\n")?;
        Ok(())
    }

    /// Send a raw protocol line — escape hatch for tests that exercise
    /// malformed input or the legacy v0 dialect through the same
    /// connection.
    pub fn send_raw(&mut self, line: &str) -> Result<()> {
        self.out.write_all(line.as_bytes())?;
        if !line.ends_with('\n') {
            self.out.write_all(b"\n")?;
        }
        Ok(())
    }
}

// The request-line grammar round-trip (format_gen → parse_command) is
// tested next to the formatter in protocol::tests; Client behaviour
// over real sockets is covered by rust/tests/protocol_v1.rs and
// rust/tests/server_roundtrip.rs. The retry-escalation policy below is
// unit-tested here against a scripted in-process acceptor.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{self, Command};
    use crate::coordinator::request::GenResult;
    use std::net::TcpListener;

    /// Accept one connection and answer each `GEN` per `script` (`true` =
    /// `BUSY`, `false` = `OK`), returning the `prio=` of every request
    /// line in arrival order.
    fn scripted_server(script: Vec<bool>) -> (std::net::SocketAddr, std::thread::JoinHandle<Vec<u8>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (sock, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(sock.try_clone().unwrap());
            let mut out = sock;
            let mut prios = Vec::new();
            for busy in script {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let g = match protocol::parse_command(&line).unwrap() {
                    Command::Gen(g) => g,
                    other => panic!("expected GEN, got {other:?}"),
                };
                prios.push(g.priority);
                let tag = g.tag.unwrap();
                let reply = if busy {
                    protocol::format_busy(tag)
                } else {
                    protocol::format_ok(
                        tag,
                        &GenResult {
                            id: tag,
                            tokens: g.toks.clone(),
                            latency_us: 7,
                            queue_us: 3,
                            prompt_len: g.toks.len(),
                        },
                    )
                };
                out.write_all(reply.as_bytes()).unwrap();
            }
            prios
        });
        (addr, handle)
    }

    /// ROADMAP §Churn-proof serving: after the second consecutive BUSY
    /// the resubmission must carry `prio=` one tier above the default —
    /// and only one tier, exactly once per call.
    #[test]
    fn retry_escalates_priority_after_second_busy() {
        let (addr, server) = scripted_server(vec![true, true, false]);
        let mut c = Client::connect(addr).unwrap();
        let out = c.gen_with_retry(&[5, 6], 4, Duration::from_secs(10)).unwrap();
        assert_eq!(out.tokens, vec![5, 6]);
        assert_eq!(
            server.join().unwrap(),
            vec![0, 0, 1],
            "third attempt (after two consecutive BUSYs) must escalate prio by one tier"
        );
    }

    /// One BUSY is ordinary overload: the immediate retry must stay at
    /// the default tier.
    #[test]
    fn single_busy_does_not_escalate() {
        let (addr, server) = scripted_server(vec![true, false]);
        let mut c = Client::connect(addr).unwrap();
        c.gen_with_retry(&[9], 2, Duration::from_secs(10)).unwrap();
        assert_eq!(server.join().unwrap(), vec![0, 0]);
    }

    /// The deadline budget still wins: with an exhausted budget the
    /// first BUSY surfaces as the terminal error (no endless resubmits).
    #[test]
    fn deadline_still_bounds_retries() {
        let (addr, server) = scripted_server(vec![true]);
        let mut c = Client::connect(addr).unwrap();
        let err = c.gen_with_retry(&[1], 2, Duration::ZERO).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ClientError>(),
            Some(ClientError::Busy { .. })
        ));
        assert_eq!(server.join().unwrap(), vec![0]);
    }
}
