//! L3 coordinator — the serving system around the compressed model.
//!
//! vLLM-router-shaped: every client connection feeds one shared
//! [`Scheduler`] admission queue; a single long-lived engine thread runs
//! the continuous-batching loop (admit → step → retire, never torn down
//! between requests), so sequences from different connections — and
//! pipelined requests from the *same* connection, via the tagged
//! [`protocol`] v1 and each connection's reader/writer demux — share
//! engine steps. The admission queue is bounded ([`SubmitError::Busy`]
//! → wire `BUSY`), and [`client::Client`] is the blocking counterpart
//! every test and bench drives. Each engine step decodes one token for every active
//! sequence. Per layer the engine routes tokens (softmax top-k), applies
//! the OTP pruner, groups the surviving (token, expert) pairs **by
//! expert** across the whole batch, executes each expert once over its
//! token block through the [`backend`](crate::backend) (PJRT or native),
//! and scatters the weighted results back. KV caches are per-sequence;
//! metrics track latency percentiles, lifetime throughput and
//! activated-parameter bytes — the quantities of Tables 5 and 8.

pub mod batcher;
pub mod client;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{ActiveSeq, Batcher, Policy};
pub use client::{Client, ClientError, GenOpts, GenOutput};
pub use engine::{DecodeEngine, EngineModel};
pub use metrics::Metrics;
pub use protocol::{parse_command, Command, Response};
pub use request::{GenRequest, GenResult, SeqEvent};
pub use scheduler::{Scheduler, SubmitError};
