//! L3 coordinator — the serving system around the compressed model.
//!
//! vLLM-router-shaped: every client connection feeds one shared
//! [`Scheduler`] admission queue; a single long-lived engine thread runs
//! the continuous-batching loop (admit → step → retire, never torn down
//! between requests), so sequences from different connections share
//! engine steps. Each engine step decodes one token for every active
//! sequence. Per layer the engine routes tokens (softmax top-k), applies
//! the OTP pruner, groups the surviving (token, expert) pairs **by
//! expert** across the whole batch, executes each expert once over its
//! token block through the [`backend`](crate::backend) (PJRT or native),
//! and scatters the weighted results back. KV caches are per-sequence;
//! metrics track latency percentiles, lifetime throughput and
//! activated-parameter bytes — the quantities of Tables 5 and 8.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{ActiveSeq, Batcher, Policy};
pub use engine::{DecodeEngine, EngineModel};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResult};
pub use scheduler::Scheduler;
