//! Wire protocol for the generation service — the single place where
//! protocol lines are parsed and formatted, shared by the server, the
//! [`Client`](crate::coordinator::client::Client), the protocol tests
//! and the serving benches, so the grammar cannot drift between them.
//!
//! Two request dialects share one parser ([`parse_command`]):
//!
//! **v1 (tagged, pipelined)** — requests carry a client-chosen `id` tag
//! and responses echo it, so one connection can keep many requests in
//! flight and receive responses out of order as they retire:
//!
//! ```text
//! GEN id=<u64> max_new=<n> [prio=<p>] [temp=<t> seed=<s>] [stream=1] toks=<t0,t1,...>
//!   → OK id=<id> latency_us=<µs> queue_us=<µs> toks=<t0,t1,...>      (terminal)
//!   → TOK id=<id> t=<tok>            (streaming partial, one per engine step; stream=1 only)
//!   → ERR id=<id> msg=<text>         (terminal)
//!   → BUSY id=<id>                   (terminal: admission queue full, resubmit later)
//! ```
//!
//! **v0 (legacy, lockstep)** — the original untagged lines, still
//! accepted verbatim so old clients keep working:
//!
//! ```text
//! GEN <max_new> <t0,t1,...>   → OK <t0,t1,...>   |   ERR <msg>
//! ```
//!
//! **Shard traffic** rides the same tagged grammar (and the same
//! parser): a coordinator's `RemoteStore` pages expert records from
//! `mcsharp shard` servers with batched fetches —
//!
//! ```text
//! FETCH id=<u64> layer=<l> experts=<e0,e1,...>
//!   → REC id=<id> layer=<l> expert=<e> len=<n>   then <n> raw payload bytes,
//!     one frame per requested expert, in request order   (terminal after the last)
//!   → ERR id=<id> msg=<text>                     (terminal, sent before any REC)
//! ```
//!
//! A shard validates the whole request before streaming, so a `FETCH`
//! yields either exactly `experts.len()` `REC` frames or one `ERR`; the
//! payload bytes ride *outside* the line discipline (the client reads
//! `len` raw bytes after each `REC` line before returning to lines).
//!
//! Control lines are shared by all dialects: `PING` → `PONG`,
//! `STATS` → one `STATS k=v ...` line, `METRICS` → `METRICS {json}`,
//! `QUIT` → server closes the connection. `TRACE [last=<n>]` dumps the
//! engine's span ring (newest `n` spans, or everything buffered):
//!
//! ```text
//! TRACE [last=<n>]
//!   → TRACE n=<k>   then k lines, one JSON span object per line
//! ```
//!
//! Responses to a v1 request are
//! always tagged; responses to v0 requests and control lines never are.
//! `id` tags are namespaced per connection — two connections may both
//! use `id=1` — and within a connection the client is responsible for
//! not reusing a tag while it is still in flight.

use std::io::BufRead;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::request::{GenRequest, GenResult};

/// Highest request-dialect revision this parser understands.
pub const PROTOCOL_VERSION: u32 = 1;

/// Hard cap on one protocol line (bytes, newline included). A line that
/// reaches the cap without a newline is answered with `ERR` and the
/// remainder of the oversized line is discarded — bounded memory per
/// connection no matter what a client sends.
pub const MAX_LINE_BYTES: usize = 256 * 1024;

/// One parsed generation request as it appeared on the wire. The server
/// assigns the internal scheduler id; `tag` is the client's namespace.
#[derive(Clone, Debug, PartialEq)]
pub struct WireGen {
    /// Client-supplied `id=` tag (v1). `None` = legacy v0 line, whose
    /// response is untagged.
    pub tag: Option<u64>,
    pub max_new: usize,
    /// Scheduling class (`prio=`, v1 only; 0 = default batch traffic).
    pub priority: u8,
    /// `temp=`/`seed=` sampling (v1 only); greedy when absent.
    pub sample: Option<(f32, u64)>,
    /// `stream=1` (v1 only): emit `TOK` partials as tokens decode.
    pub stream: bool,
    pub toks: Vec<u16>,
}

impl WireGen {
    /// Materialize the scheduler-side request under a server-assigned
    /// internal id (client tags are per-connection, internal ids are
    /// per-server — the mapping back to the tag lives in the response
    /// route, not here).
    pub fn into_request(self, internal_id: u64) -> GenRequest {
        let mut req = GenRequest::greedy(internal_id, self.toks, self.max_new)
            .with_priority(self.priority)
            .with_stream(self.stream);
        req.sample = self.sample;
        req
    }
}

/// One parsed batched expert-record fetch (shard traffic). Always
/// tagged — there is no v0 shard dialect.
#[derive(Clone, Debug, PartialEq)]
pub struct WireFetch {
    pub tag: u64,
    pub layer: usize,
    /// Requested expert indices; `REC` frames come back in this order.
    pub experts: Vec<usize>,
}

/// One parsed protocol line.
#[derive(Debug)]
pub enum Command {
    Gen(WireGen),
    Fetch(WireFetch),
    Ping,
    Stats,
    Metrics,
    /// Span-ring dump; `last` limits the reply to the newest `n` spans.
    Trace { last: Option<usize> },
    Quit,
    /// Blank line — ignored, no response.
    Empty,
}

/// Parse one protocol line — the single dispatch point for control
/// commands and generation requests, v0 and v1 alike.
pub fn parse_command(line: &str) -> Result<Command> {
    let line = line.trim();
    match line {
        "" => return Ok(Command::Empty),
        "PING" => return Ok(Command::Ping),
        "STATS" => return Ok(Command::Stats),
        "METRICS" => return Ok(Command::Metrics),
        "TRACE" => return Ok(Command::Trace { last: None }),
        "QUIT" => return Ok(Command::Quit),
        _ => {}
    }
    let mut parts = line.splitn(2, ' ');
    match parts.next() {
        Some("GEN") => {
            let rest = parts.next().ok_or_else(|| anyhow!("GEN missing arguments"))?;
            // v1 iff the first argument is a key=value pair; a bare
            // number is the v0 positional max_new. First *non-empty*
            // word: the v1 parser tolerates repeated spaces, so the
            // dialect detection must too.
            if rest.split(' ').find(|w| !w.is_empty()).is_some_and(|w| w.contains('=')) {
                parse_gen_v1(rest).map(Command::Gen)
            } else {
                parse_gen_v0(rest).map(Command::Gen)
            }
        }
        Some("FETCH") => {
            let rest = parts.next().ok_or_else(|| anyhow!("FETCH missing arguments"))?;
            parse_fetch(rest).map(Command::Fetch)
        }
        Some("TRACE") => {
            let rest = parts.next().ok_or_else(|| anyhow!("TRACE missing arguments"))?;
            parse_trace(rest).map(|last| Command::Trace { last })
        }
        Some(cmd) => bail!("unknown command {cmd:?}"),
        // splitn on a non-empty string always yields a first part, and
        // blank lines returned Command::Empty above
        None => unreachable!("blank line handled before the verb match"),
    }
}

/// Legacy positional form: `<max_new> <t0,t1,...>`.
fn parse_gen_v0(rest: &str) -> Result<WireGen> {
    let mut parts = rest.splitn(2, ' ');
    let max_new: usize = parts
        .next()
        .ok_or_else(|| anyhow!("GEN missing max_new"))?
        .parse()?;
    let toks = parse_toks(parts.next().ok_or_else(|| anyhow!("GEN missing tokens"))?)?;
    Ok(WireGen { tag: None, max_new, priority: 0, sample: None, stream: false, toks })
}

/// Tagged form: `id=<u64> max_new=<n> [prio=<p>] [temp=<t> seed=<s>]
/// [stream=0|1] toks=<t0,...>`, keys in any order, each at most once.
fn parse_gen_v1(rest: &str) -> Result<WireGen> {
    let (mut tag, mut max_new, mut prio) = (None, None, None);
    let (mut temp, mut seed, mut stream, mut toks) = (None, None, None, None);
    for word in rest.split(' ').filter(|w| !w.is_empty()) {
        let (key, val) = word
            .split_once('=')
            .ok_or_else(|| anyhow!("GEN expected key=value, got {word:?}"))?;
        let duplicate = match key {
            "id" => tag
                .replace(val.parse::<u64>().map_err(|e| anyhow!("id={val:?}: {e}"))?)
                .is_some(),
            "max_new" => max_new
                .replace(val.parse::<usize>().map_err(|e| anyhow!("max_new={val:?}: {e}"))?)
                .is_some(),
            "prio" => prio
                .replace(val.parse::<u8>().map_err(|e| anyhow!("prio={val:?}: {e}"))?)
                .is_some(),
            "temp" => temp
                .replace(val.parse::<f32>().map_err(|e| anyhow!("temp={val:?}: {e}"))?)
                .is_some(),
            "seed" => seed
                .replace(val.parse::<u64>().map_err(|e| anyhow!("seed={val:?}: {e}"))?)
                .is_some(),
            "stream" => stream
                .replace(match val {
                    "0" => false,
                    "1" => true,
                    _ => bail!("stream={val:?} (expected 0 or 1)"),
                })
                .is_some(),
            "toks" => toks.replace(parse_toks(val)?).is_some(),
            _ => bail!("unknown GEN key {key:?}"),
        };
        if duplicate {
            bail!("duplicate GEN key {key:?}");
        }
    }
    let tag = tag.ok_or_else(|| anyhow!("v1 GEN missing id="))?;
    let max_new = max_new.ok_or_else(|| anyhow!("v1 GEN missing max_new="))?;
    let toks = toks.ok_or_else(|| anyhow!("v1 GEN missing toks="))?;
    let sample = match (temp, seed) {
        (Some(t), s) => {
            if !(t.is_finite() && t > 0.0) {
                bail!("temp must be finite and > 0, got {t}");
            }
            Some((t, s.unwrap_or(0)))
        }
        (None, Some(_)) => bail!("seed= without temp="),
        (None, None) => None,
    };
    Ok(WireGen {
        tag: Some(tag),
        max_new,
        priority: prio.unwrap_or(0),
        sample,
        stream: stream.unwrap_or(false),
        toks,
    })
}

/// Tagged form: `id=<u64> layer=<l> experts=<e0,e1,...>`, keys in any
/// order, each at most once.
fn parse_fetch(rest: &str) -> Result<WireFetch> {
    let (mut tag, mut layer, mut experts) = (None, None, None);
    for word in rest.split(' ').filter(|w| !w.is_empty()) {
        let (key, val) = word
            .split_once('=')
            .ok_or_else(|| anyhow!("FETCH expected key=value, got {word:?}"))?;
        let duplicate = match key {
            "id" => tag
                .replace(val.parse::<u64>().map_err(|e| anyhow!("id={val:?}: {e}"))?)
                .is_some(),
            "layer" => layer
                .replace(val.parse::<usize>().map_err(|e| anyhow!("layer={val:?}: {e}"))?)
                .is_some(),
            "experts" => experts.replace(parse_index_csv(val)?).is_some(),
            _ => bail!("unknown FETCH key {key:?}"),
        };
        if duplicate {
            bail!("duplicate FETCH key {key:?}");
        }
    }
    Ok(WireFetch {
        tag: tag.ok_or_else(|| anyhow!("FETCH missing id="))?,
        layer: layer.ok_or_else(|| anyhow!("FETCH missing layer="))?,
        experts: experts.ok_or_else(|| anyhow!("FETCH missing experts="))?,
    })
}

/// Optional-key form: `[last=<n>]`, the key at most once.
fn parse_trace(rest: &str) -> Result<Option<usize>> {
    let mut last = None;
    for word in rest.split(' ').filter(|w| !w.is_empty()) {
        let (key, val) = word
            .split_once('=')
            .ok_or_else(|| anyhow!("TRACE expected key=value, got {word:?}"))?;
        let duplicate = match key {
            "last" => last
                .replace(val.parse::<usize>().map_err(|e| anyhow!("last={val:?}: {e}"))?)
                .is_some(),
            _ => bail!("unknown TRACE key {key:?}"),
        };
        if duplicate {
            bail!("duplicate TRACE key {key:?}");
        }
    }
    Ok(last)
}

/// Best-effort tag recovery for a line that failed [`parse_command`]:
/// if it is a `GEN` or `FETCH` line carrying a parseable `id=<u64>`,
/// return that tag so the `ERR` response can stay attributable — a
/// pipelined client must be able to mark the tag terminal instead of
/// waiting forever. Control lines and v0 `GEN`s never carry tags, so
/// `None` is correct for them.
pub fn salvage_tag(line: &str) -> Option<u64> {
    let line = line.trim();
    let rest = line.strip_prefix("GEN ").or_else(|| line.strip_prefix("FETCH "))?;
    rest.split(' ')
        .find_map(|w| w.strip_prefix("id="))
        .and_then(|v| v.parse().ok())
}

fn parse_toks(csv: &str) -> Result<Vec<u16>> {
    if csv.trim().is_empty() {
        bail!("empty prompt");
    }
    csv.split(',')
        .map(|t| t.trim().parse::<u16>().map_err(|e| anyhow!("token {t:?}: {e}")))
        .collect()
}

/// Comma-separated expert indices (`experts=` values).
fn parse_index_csv(csv: &str) -> Result<Vec<usize>> {
    if csv.trim().is_empty() {
        bail!("empty expert list");
    }
    csv.split(',')
        .map(|t| t.trim().parse::<usize>().map_err(|e| anyhow!("expert {t:?}: {e}")))
        .collect()
}

/// Outcome of [`read_command_line`].
pub enum LineRead {
    /// A complete line (newline stripped by the caller's parse).
    Line,
    /// Clean end of stream.
    Eof,
    /// The line hit `max` bytes without a newline; the rest of the
    /// oversized line has been consumed and discarded. Answer `ERR`.
    Oversized,
}

/// Read one protocol line into `buf` (cleared first), refusing to buffer
/// more than `max` bytes of it. On overflow the remainder of the line is
/// drained from the reader so the connection stays line-synchronized.
pub fn read_command_line(
    reader: &mut impl BufRead,
    buf: &mut String,
    max: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut raw = Vec::with_capacity(128);
    let n = (&mut *reader).take(max as u64).read_until(b'\n', &mut raw)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    if n == max && raw.last() != Some(&b'\n') {
        // drain to the newline (or EOF) without buffering
        loop {
            let mut byte = [0u8; 1];
            match std::io::Read::read(reader, &mut byte)? {
                0 => break,
                _ if byte[0] == b'\n' => break,
                _ => {}
            }
        }
        return Ok(LineRead::Oversized);
    }
    // invalid UTF-8 is a parse error, not a connection error: replace and
    // let parse_command reject the garbled verb with a normal ERR
    *buf = String::from_utf8_lossy(&raw).into_owned();
    Ok(LineRead::Line)
}

// ---- response formatting (server side) ----

fn fmt_toks(tokens: &[u16]) -> String {
    let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    toks.join(",")
}

/// Format a v1 request line — the client side of [`parse_gen_v1`],
/// kept here with the parser so the grammar cannot drift (the `Client`
/// writes exactly this). Optional keys are omitted at their defaults.
pub fn format_gen(
    tag: u64,
    prompt: &[u16],
    max_new: usize,
    priority: u8,
    sample: Option<(f32, u64)>,
    stream: bool,
) -> String {
    let mut line = format!("GEN id={tag} max_new={max_new}");
    if priority > 0 {
        line.push_str(&format!(" prio={priority}"));
    }
    if let Some((temp, seed)) = sample {
        line.push_str(&format!(" temp={temp} seed={seed}"));
    }
    if stream {
        line.push_str(" stream=1");
    }
    line.push_str(&format!(" toks={}\n", fmt_toks(prompt)));
    line
}

/// Untagged v0 success line.
pub fn format_ok_v0(tokens: &[u16]) -> String {
    format!("OK {}\n", fmt_toks(tokens))
}

/// Tagged v1 success line — surfaces the per-request latency and queue
/// wait the engine already measured.
pub fn format_ok(tag: u64, r: &GenResult) -> String {
    format!(
        "OK id={tag} latency_us={} queue_us={} toks={}\n",
        r.latency_us,
        r.queue_us,
        fmt_toks(&r.tokens)
    )
}

/// One streamed token (v1 `stream=1` requests only).
pub fn format_tok(tag: u64, token: u16) -> String {
    format!("TOK id={tag} t={token}\n")
}

/// Error line: tagged for v1 requests, bare `ERR <msg>` for v0 and for
/// lines that never parsed far enough to carry a tag. Newlines in `msg`
/// are flattened so the response stays one line.
pub fn format_err(tag: Option<u64>, msg: &str) -> String {
    let msg = msg.replace(['\n', '\r'], " ");
    match tag {
        Some(tag) => format!("ERR id={tag} msg={msg}\n"),
        None => format!("ERR {msg}\n"),
    }
}

/// Admission-queue-full overload signal (v1 only; terminal for the tag).
pub fn format_busy(tag: u64) -> String {
    format!("BUSY id={tag}\n")
}

/// Format a batched fetch request line — the coordinator side of
/// [`parse_fetch`], kept with the parser so the shard grammar cannot
/// drift (the `RemoteStore` writes exactly this).
pub fn format_fetch(tag: u64, layer: usize, experts: &[usize]) -> String {
    let list: Vec<String> = experts.iter().map(|e| e.to_string()).collect();
    format!("FETCH id={tag} layer={layer} experts={}\n", list.join(","))
}

/// One expert-record frame header; `len` raw payload bytes follow the
/// newline.
pub fn format_rec(tag: u64, layer: usize, expert: usize, len: usize) -> String {
    format!("REC id={tag} layer={layer} expert={expert} len={len}\n")
}

/// Format a span-ring dump request — the client side of [`parse_trace`].
pub fn format_trace_cmd(last: Option<usize>) -> String {
    match last {
        Some(n) => format!("TRACE last={n}\n"),
        None => "TRACE\n".to_string(),
    }
}

/// Span-dump reply header; `n` one-JSON-object-per-line span lines
/// follow the newline.
pub fn format_trace_header(n: usize) -> String {
    format!("TRACE n={n}\n")
}

// ---- response parsing (client side) ----

/// One parsed response line.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Terminal success. `tag`/`latency_us`/`queue_us` are `None`/0 for
    /// untagged v0 responses.
    Ok { tag: Option<u64>, latency_us: u64, queue_us: u64, tokens: Vec<u16> },
    /// Streaming partial.
    Tok { tag: u64, token: u16 },
    /// Terminal overload rejection.
    Busy { tag: u64 },
    /// Terminal error (tagged when the request parsed far enough).
    Err { tag: Option<u64>, msg: String },
    /// One expert-record frame header (shard traffic); the reader must
    /// consume `len` raw payload bytes before the next line.
    Rec { tag: u64, layer: usize, expert: usize, len: usize },
    Pong,
    /// Raw `STATS` payload (`k=v` fields).
    Stats(String),
    /// Raw `METRICS` payload (JSON).
    Metrics(String),
    /// Span-dump header; the reader must consume `n` JSON span lines
    /// before the next response line.
    Trace { n: usize },
}

fn parse_kv<'a>(word: &'a str, key: &str) -> Result<&'a str> {
    word.strip_prefix(key)
        .and_then(|w| w.strip_prefix('='))
        .ok_or_else(|| anyhow!("expected {key}=, got {word:?}"))
}

/// Parse one server response line (the inverse of the formatters above).
pub fn parse_response(line: &str) -> Result<Response> {
    let line = line.trim_end_matches(['\n', '\r']);
    if line == "PONG" {
        return Ok(Response::Pong);
    }
    if let Some(rest) = line.strip_prefix("STATS ") {
        return Ok(Response::Stats(rest.to_string()));
    }
    if let Some(rest) = line.strip_prefix("METRICS ") {
        return Ok(Response::Metrics(rest.to_string()));
    }
    if let Some(rest) = line.strip_prefix("TRACE ") {
        return Ok(Response::Trace { n: parse_kv(rest, "n")?.parse()? });
    }
    if let Some(rest) = line.strip_prefix("BUSY ") {
        return Ok(Response::Busy { tag: parse_kv(rest, "id")?.parse()? });
    }
    if let Some(rest) = line.strip_prefix("REC ") {
        let mut w = rest.split(' ').filter(|w| !w.is_empty());
        let tag = parse_kv(w.next().unwrap_or(""), "id")?.parse()?;
        let layer =
            parse_kv(w.next().ok_or_else(|| anyhow!("REC missing layer="))?, "layer")?.parse()?;
        let expert = parse_kv(w.next().ok_or_else(|| anyhow!("REC missing expert="))?, "expert")?
            .parse()?;
        let len =
            parse_kv(w.next().ok_or_else(|| anyhow!("REC missing len="))?, "len")?.parse()?;
        return Ok(Response::Rec { tag, layer, expert, len });
    }
    if let Some(rest) = line.strip_prefix("TOK ") {
        let mut w = rest.splitn(2, ' ');
        let tag = parse_kv(w.next().unwrap_or(""), "id")?.parse()?;
        let token = parse_kv(w.next().ok_or_else(|| anyhow!("TOK missing t="))?, "t")?
            .parse()?;
        return Ok(Response::Tok { tag, token });
    }
    if let Some(rest) = line.strip_prefix("OK ") {
        if !rest.starts_with("id=") {
            return Ok(Response::Ok {
                tag: None,
                latency_us: 0,
                queue_us: 0,
                tokens: parse_toks(rest)?,
            });
        }
        let mut w = rest.splitn(4, ' ');
        let tag = parse_kv(w.next().unwrap_or(""), "id")?.parse()?;
        let latency_us = parse_kv(w.next().ok_or_else(|| anyhow!("OK missing latency_us="))?, "latency_us")?
            .parse()?;
        let queue_us = parse_kv(w.next().ok_or_else(|| anyhow!("OK missing queue_us="))?, "queue_us")?
            .parse()?;
        let tokens = parse_toks(parse_kv(w.next().ok_or_else(|| anyhow!("OK missing toks="))?, "toks")?)?;
        return Ok(Response::Ok { tag: Some(tag), latency_us, queue_us, tokens });
    }
    if let Some(rest) = line.strip_prefix("ERR ") {
        // try the tagged form first, but fall back to untagged rather
        // than failing: an untagged error *message* may itself begin
        // with "id=" (e.g. the rejection of an unparseable id= key)
        if let Some(tagged) = parse_tagged_err(rest) {
            return Ok(tagged);
        }
        return Ok(Response::Err { tag: None, msg: rest.to_string() });
    }
    bail!("unparseable response line {line:?}")
}

/// `id=<u64> msg=<text>` if `rest` is exactly the tagged-ERR shape.
fn parse_tagged_err(rest: &str) -> Option<Response> {
    let after_id = rest.strip_prefix("id=")?;
    let (tag, msg_part) = after_id.split_once(' ')?;
    let tag = tag.parse().ok()?;
    let msg = msg_part.strip_prefix("msg=")?;
    Some(Response::Err { tag: Some(tag), msg: msg.to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v0_gen_still_parses_unchanged() {
        let Command::Gen(g) = parse_command("GEN 8 1,2,3").unwrap() else {
            panic!("not a GEN")
        };
        assert_eq!(g.tag, None);
        assert_eq!(g.max_new, 8);
        assert_eq!(g.toks, vec![1, 2, 3]);
        assert!(!g.stream && g.sample.is_none() && g.priority == 0);
    }

    #[test]
    fn v1_gen_full_grammar() {
        let line = "GEN id=42 max_new=6 prio=3 temp=0.8 seed=7 stream=1 toks=1,17,30";
        let Command::Gen(g) = parse_command(line).unwrap() else { panic!("not a GEN") };
        assert_eq!(g.tag, Some(42));
        assert_eq!(g.max_new, 6);
        assert_eq!(g.priority, 3);
        assert_eq!(g.sample, Some((0.8, 7)));
        assert!(g.stream);
        assert_eq!(g.toks, vec![1, 17, 30]);
        // minimal form + key order freedom
        let Command::Gen(g) = parse_command("GEN toks=5 max_new=1 id=0").unwrap() else {
            panic!("not a GEN")
        };
        assert_eq!((g.tag, g.max_new, &g.toks[..]), (Some(0), 1, &[5][..]));
    }

    #[test]
    fn control_verbs_parse() {
        assert!(matches!(parse_command("PING").unwrap(), Command::Ping));
        assert!(matches!(parse_command("STATS").unwrap(), Command::Stats));
        assert!(matches!(parse_command("METRICS").unwrap(), Command::Metrics));
        assert!(matches!(parse_command("QUIT").unwrap(), Command::Quit));
        assert!(matches!(parse_command("  \n").unwrap(), Command::Empty));
    }

    /// Satellite: table-driven malformed inputs — every row must be a
    /// clean parse error (no panic), v0 and v1 alike.
    #[test]
    fn malformed_lines_are_errors_not_panics() {
        let bad = [
            "NOPE 1",
            "GEN",
            "GEN 8",
            "GEN x 1,2",
            "GEN 8 ",
            "GEN 8 1,,2",
            "GEN 8 1,99999",
            "GEN 8 1,-2",
            "GEN id=1",                              // v1 missing max_new/toks
            "GEN id=1 max_new=4",                    // missing toks
            "GEN max_new=4 toks=1,2",                // missing id
            "GEN id=x max_new=4 toks=1,2",           // bad tag
            "GEN id=1 max_new=4 toks=",              // empty token list
            "GEN id=1 max_new=4 toks=1,2 toks=3",    // duplicate key
            "GEN id=1 id=2 max_new=4 toks=1",        // duplicate id
            "GEN id=1 max_new=4 bogus=1 toks=1",     // unknown key
            "GEN id=1 max_new=4 stream=2 toks=1",    // bad stream flag
            "GEN id=1 max_new=4 seed=3 toks=1",      // seed without temp
            "GEN id=1 max_new=4 temp=0 toks=1",      // non-positive temp
            "GEN id=1 max_new=4 temp=nan toks=1",    // non-finite temp
            "GEN id=1 max_new=nope toks=1",
        ];
        for line in bad {
            assert!(parse_command(line).is_err(), "{line:?} must not parse");
        }
    }

    /// A malformed v1 GEN whose `id=` did parse must still yield its tag
    /// for the ERR response (terminal-per-tag guarantee); lines without
    /// a recoverable tag yield `None`.
    #[test]
    fn salvage_tag_recovers_parseable_ids_only() {
        assert_eq!(salvage_tag("GEN id=4 max_new=2 toks=1,,2"), Some(4));
        assert_eq!(salvage_tag("GEN max_new=2 id=9"), Some(9));
        assert_eq!(salvage_tag("GEN id=x max_new=2 toks=1"), None);
        assert_eq!(salvage_tag("GEN 8 1,2"), None); // v0: never tagged
        assert_eq!(salvage_tag("BOGUS id=3"), None); // not a GEN line
        assert_eq!(salvage_tag("STATS"), None);
        assert_eq!(salvage_tag("FETCH id=6 layer=99 experts=1,,2"), Some(6));
        assert_eq!(salvage_tag("FETCH layer=0 experts=1"), None);
    }

    /// Shard grammar: FETCH round-trips through the same parse_command
    /// entry point GEN uses, and REC headers round-trip through
    /// parse_response.
    #[test]
    fn fetch_and_rec_round_trip() {
        let line = format_fetch(7, 3, &[0, 4, 11]);
        let Command::Fetch(f) = parse_command(&line).unwrap() else { panic!("not a FETCH") };
        assert_eq!(f, WireFetch { tag: 7, layer: 3, experts: vec![0, 4, 11] });
        // key order freedom, repeated spaces
        let Command::Fetch(f) = parse_command("FETCH  experts=2  id=1  layer=0").unwrap()
        else {
            panic!("not a FETCH")
        };
        assert_eq!(f, WireFetch { tag: 1, layer: 0, experts: vec![2] });
        assert_eq!(
            parse_response(&format_rec(7, 3, 11, 4096)).unwrap(),
            Response::Rec { tag: 7, layer: 3, expert: 11, len: 4096 }
        );
    }

    /// Malformed FETCH rows — clean parse errors, never panics.
    #[test]
    fn malformed_fetch_lines_are_errors() {
        let bad = [
            "FETCH",
            "FETCH 1 2",                       // no v0 shard dialect
            "FETCH id=1",                      // missing layer/experts
            "FETCH id=1 layer=0",              // missing experts
            "FETCH layer=0 experts=1",         // missing id
            "FETCH id=x layer=0 experts=1",    // bad tag
            "FETCH id=1 layer=0 experts=",     // empty expert list
            "FETCH id=1 layer=0 experts=1,,2", // gap in the list
            "FETCH id=1 layer=0 experts=-1",   // negative index
            "FETCH id=1 layer=0 experts=1 experts=2", // duplicate key
            "FETCH id=1 layer=0 experts=1 bogus=1",   // unknown key
        ];
        for line in bad {
            assert!(parse_command(line).is_err(), "{line:?} must not parse");
        }
        assert!(parse_response("REC id=1 layer=0 expert=2").is_err(), "REC missing len=");
    }

    /// The client's formatter and the server's parser live in this one
    /// module — this round-trip is what "the grammar cannot drift"
    /// means, exercising the exact function `Client::submit_opts` calls.
    #[test]
    fn format_gen_round_trips_through_parse_command() {
        let line = format_gen(8, &[3, 4], 5, 2, Some((0.7, 11)), true);
        let Command::Gen(g) = parse_command(&line).unwrap() else { panic!("not GEN") };
        assert_eq!(
            g,
            WireGen {
                tag: Some(8),
                max_new: 5,
                priority: 2,
                sample: Some((0.7, 11)),
                stream: true,
                toks: vec![3, 4],
            }
        );
        // defaults are omitted, not serialized
        assert_eq!(format_gen(1, &[9], 2, 0, None, false), "GEN id=1 max_new=2 toks=9\n");
    }

    /// Dialect detection tolerates the same repeated spaces the v1
    /// parser does.
    #[test]
    fn v1_detection_survives_repeated_spaces() {
        let Command::Gen(g) = parse_command("GEN  id=1  max_new=2  toks=5").unwrap() else {
            panic!("not GEN")
        };
        assert_eq!((g.tag, g.max_new, &g.toks[..]), (Some(1), 2, &[5][..]));
    }

    /// An *untagged* ERR whose message happens to begin with "id=" must
    /// not be misparsed as a tagged ERR (the tagged shape requires a
    /// parseable tag and a msg= key).
    #[test]
    fn untagged_err_starting_with_id_stays_untagged() {
        let got = parse_response("ERR id=\"x\": invalid digit found in string\n").unwrap();
        assert_eq!(
            got,
            Response::Err { tag: None, msg: "id=\"x\": invalid digit found in string".into() }
        );
    }

    #[test]
    fn response_lines_round_trip() {
        let r = GenResult {
            id: 9,
            tokens: vec![1, 2, 3],
            latency_us: 120,
            queue_us: 30,
            prompt_len: 1,
        };
        assert_eq!(
            parse_response(&format_ok(42, &r)).unwrap(),
            Response::Ok { tag: Some(42), latency_us: 120, queue_us: 30, tokens: vec![1, 2, 3] }
        );
        assert_eq!(
            parse_response(&format_ok_v0(&[5, 6])).unwrap(),
            Response::Ok { tag: None, latency_us: 0, queue_us: 0, tokens: vec![5, 6] }
        );
        assert_eq!(
            parse_response(&format_tok(7, 31)).unwrap(),
            Response::Tok { tag: 7, token: 31 }
        );
        assert_eq!(parse_response(&format_busy(3)).unwrap(), Response::Busy { tag: 3 });
        assert_eq!(
            parse_response(&format_err(Some(5), "bad\nthing")).unwrap(),
            Response::Err { tag: Some(5), msg: "bad thing".into() }
        );
        assert_eq!(
            parse_response(&format_err(None, "unknown command")).unwrap(),
            Response::Err { tag: None, msg: "unknown command".into() }
        );
        assert_eq!(parse_response("PONG\n").unwrap(), Response::Pong);
        assert!(matches!(parse_response("STATS tps=1.0").unwrap(), Response::Stats(_)));
        assert!(parse_response("GARBAGE").is_err());
    }

    /// TRACE grammar: bare and `last=` forms parse, the formatter
    /// round-trips through parse_command, and the reply header
    /// round-trips through parse_response.
    #[test]
    fn trace_round_trips_and_rejects_malformed() {
        assert!(matches!(parse_command("TRACE").unwrap(), Command::Trace { last: None }));
        assert!(matches!(
            parse_command("TRACE last=16").unwrap(),
            Command::Trace { last: Some(16) }
        ));
        assert!(matches!(
            parse_command(&format_trace_cmd(Some(3))).unwrap(),
            Command::Trace { last: Some(3) }
        ));
        assert!(matches!(
            parse_command(&format_trace_cmd(None)).unwrap(),
            Command::Trace { last: None }
        ));
        assert_eq!(
            parse_response(&format_trace_header(12)).unwrap(),
            Response::Trace { n: 12 }
        );
        let bad = [
            "TRACE last=x",        // bad count
            "TRACE last=-1",       // negative count
            "TRACE 5",             // no positional form
            "TRACE bogus=1",       // unknown key
            "TRACE last=1 last=2", // duplicate key
        ];
        for line in bad {
            assert!(parse_command(line).is_err(), "{line:?} must not parse");
        }
    }

    #[test]
    fn wiregen_into_request_threads_every_field() {
        let line = "GEN id=8 max_new=5 prio=2 temp=0.7 seed=11 stream=1 toks=3,4";
        let Command::Gen(g) = parse_command(line).unwrap() else { panic!() };
        let req = g.into_request(900);
        assert_eq!(req.id, 900); // internal id, not the wire tag
        assert_eq!(req.prompt, vec![3, 4]);
        assert_eq!(req.max_new_tokens, 5);
        assert_eq!(req.priority, 2);
        assert_eq!(req.sample, Some((0.7, 11)));
        assert!(req.stream);
    }

    #[test]
    fn oversized_lines_are_bounded_and_resynchronized() {
        use std::io::BufReader;
        let mut input = Vec::new();
        input.extend_from_slice(b"GEN 2 1,2\n");
        input.extend_from_slice(&vec![b'9'; 4096]); // oversized, no newline yet
        input.extend_from_slice(b"\nPING\n");
        let mut r = BufReader::new(std::io::Cursor::new(input));
        let mut line = String::new();
        assert!(matches!(read_command_line(&mut r, &mut line, 64).unwrap(), LineRead::Line));
        assert!(line.starts_with("GEN 2"));
        assert!(matches!(
            read_command_line(&mut r, &mut line, 64).unwrap(),
            LineRead::Oversized
        ));
        // the stream is line-synchronized again: PING parses next
        assert!(matches!(read_command_line(&mut r, &mut line, 64).unwrap(), LineRead::Line));
        assert!(matches!(parse_command(&line).unwrap(), Command::Ping));
        assert!(matches!(read_command_line(&mut r, &mut line, 64).unwrap(), LineRead::Eof));
    }

    /// A partial line at EOF (no trailing newline) parses normally — the
    /// table's "partial-line/EOF" rows exercise the truncated forms.
    #[test]
    fn partial_line_at_eof_is_parsed_not_hung() {
        use std::io::BufReader;
        let mut r = BufReader::new(std::io::Cursor::new(b"GEN id=1 max_new=".to_vec()));
        let mut line = String::new();
        assert!(matches!(
            read_command_line(&mut r, &mut line, 1024).unwrap(),
            LineRead::Line
        ));
        assert!(parse_command(&line).is_err(), "truncated v1 GEN must be an ERR");
        assert!(matches!(read_command_line(&mut r, &mut line, 1024).unwrap(), LineRead::Eof));
    }
}
