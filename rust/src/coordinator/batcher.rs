//! Continuous batcher: admission control + step loop over the engine.
//!
//! Requests queue FIFO; up to `max_batch` sequences are active at once
//! and new sequences are admitted the moment one finishes (continuous
//! batching, not static). A token budget caps the summed context length
//! of the active set — the KV-memory guardrail a real server needs.
//! The budget charges *unique* KV: prompt tokens covered by shared
//! prefix-tree blocks (see `moe::kv`) are already resident and cost
//! nothing, so N requests sharing a system prompt pay its pages once
//! and the same `token_budget` admits a wider batch.
//!
//! The drain loop is split into three reusable pieces — [`Batcher::admit`],
//! [`Batcher::step_active`], [`Batcher::retire`] — so the same admission
//! policies drive both the one-shot [`Batcher::run`] (evals, benches) and
//! the server's persistent engine loop
//! ([`Scheduler`](crate::coordinator::scheduler::Scheduler)), which never
//! tears down between requests.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::{DecodeEngine, SeqState};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenRequest, GenResult};
use crate::moe::kv::KvPool;
use crate::trace::{SpanKind, Tracer};

/// Admission-ordering policy. FIFO is the default; SJF (shortest job
/// first, by token footprint) minimizes mean latency on mixed workloads;
/// Priority serves higher [`GenRequest::priority`] classes first (FIFO
/// within a class). SJF/Priority are starvation-bounded: a request that
/// has waited longer than `aging_us` is treated as front-of-line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Policy {
    #[default]
    Fifo,
    Sjf,
    Priority,
}

/// One admitted sequence plus the bookkeeping its [`GenResult`] needs.
pub struct ActiveSeq {
    pub seq: SeqState,
    /// When the request entered the queue.
    pub submitted: Instant,
    /// When it was admitted to the active set.
    pub admitted: Instant,
    pub prompt_len: usize,
    /// Request asked for per-token streaming (`stream=1` on the wire).
    pub stream: bool,
    /// Generated tokens already handed to the streaming sink — the
    /// cursor [`take_unstreamed`](Self::take_unstreamed) advances.
    streamed: usize,
}

impl ActiveSeq {
    fn new(req: GenRequest, submitted: Instant, n_layers: usize, pool: &mut KvPool) -> ActiveSeq {
        let prompt_len = req.prompt.len();
        let stream = req.stream;
        let mut seq = SeqState::new(req.id, req.prompt, req.max_new_tokens, n_layers);
        seq.sample = req.sample;
        // adopt any cached prompt prefix: those positions skip prefill
        // and their (shared) pages stay off this sequence's budget
        seq.attach_prefix(pool);
        ActiveSeq { seq, submitted, admitted: Instant::now(), prompt_len, stream, streamed: 0 }
    }

    /// Tokens generated since the last call (empty during prefill),
    /// advancing the streaming cursor. The engine loop calls this after
    /// every step for `stream` sequences — including the step that
    /// finishes the sequence, so the final token is streamed before the
    /// terminal `Done`.
    pub fn take_unstreamed(&mut self) -> &[u16] {
        let start = self.prompt_len + self.streamed;
        let end = self.prompt_len + self.seq.generated;
        self.streamed = self.seq.generated;
        &self.seq.tokens[start..end]
    }

    /// Token footprint this sequence holds against the budget: context
    /// held now plus tokens still to be generated, *minus* the prompt
    /// tokens whose pages are shared full prefix-tree blocks (unique-page
    /// accounting: shared KV is charged once, to the tree, not per
    /// sequence). `tokens.len()` already counts generated tokens, so the
    /// remainder is `max_new - generated` — the sum stays
    /// `prompt + max_new - shared` for the sequence's lifetime.
    fn footprint(&self) -> usize {
        (self.seq.tokens.len() + self.seq.max_new.saturating_sub(self.seq.generated))
            .saturating_sub(self.seq.shared_toks())
    }
}

pub struct Batcher {
    pub max_batch: usize,
    /// Max summed (prompt + generated) tokens across active sequences.
    pub token_budget: usize,
    pub policy: Policy,
    /// Starvation bound for SJF/Priority (µs of queue wait).
    pub aging_us: u64,
    queue: VecDeque<(GenRequest, Instant)>,
}

impl Batcher {
    pub fn new(max_batch: usize, token_budget: usize) -> Batcher {
        Batcher {
            max_batch,
            token_budget,
            policy: Policy::Fifo,
            aging_us: 10_000_000,
            queue: VecDeque::new(),
        }
    }

    /// Batcher for one serving configuration (the expert-cache budget in
    /// the same config is consumed upstream, at model-load time).
    pub fn from_config(sc: &crate::config::ServingConfig) -> Batcher {
        Batcher::new(sc.max_batch, sc.token_budget)
    }

    pub fn with_policy(mut self, policy: Policy) -> Batcher {
        self.policy = policy;
        self
    }

    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back((req, Instant::now()));
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drop every queued (not yet admitted) request — used when the
    /// engine dies and nothing more will run.
    pub fn clear_queue(&mut self) {
        self.queue.clear();
    }

    /// Index of the next request to admit under the current policy (the
    /// caller checks budget fit). Aged requests jump the line.
    fn next_index(&self) -> Option<usize> {
        if self.queue.is_empty() {
            return None;
        }
        match self.policy {
            Policy::Fifo => Some(0),
            Policy::Sjf => {
                if let Some(aged) = self.aged_index() {
                    return Some(aged);
                }
                (0..self.queue.len()).min_by_key(|&i| self.queue[i].0.footprint())
            }
            Policy::Priority => {
                if let Some(aged) = self.aged_index() {
                    return Some(aged);
                }
                // max priority; FIFO within class (stable min over -prio)
                (0..self.queue.len())
                    .max_by_key(|&i| (self.queue[i].0.priority, usize::MAX - i))
            }
        }
    }

    fn aged_index(&self) -> Option<usize> {
        self.queue
            .iter()
            .position(|(_, t)| t.elapsed().as_micros() as u64 > self.aging_us)
    }

    /// Admit queued requests into `active` while there is room in both
    /// the batch and the token budget. A candidate's charge is probed
    /// against the prefix tree first: prompt tokens covered by resident
    /// shared blocks are free, so warm-prefix requests fit where cold
    /// ones would not. When `active` is empty and nothing fits, the
    /// policy head is force-admitted so oversized requests still
    /// progress. Lock order: callers may hold the scheduler inner or
    /// engine lock; the pool lock here is innermost.
    pub fn admit(&mut self, active: &mut Vec<ActiveSeq>, n_layers: usize, pool: &Mutex<KvPool>) {
        let mut pool = pool.lock().unwrap();
        let used: usize = active.iter().map(|a| a.footprint()).sum();
        let mut budget = self.token_budget.saturating_sub(used);
        while active.len() < self.max_batch {
            let fits = self
                .next_index()
                .map(|i| {
                    let req = &self.queue[i].0;
                    (i, req.footprint().saturating_sub(pool.probe_prefix(&req.prompt)))
                })
                .filter(|&(_, fp)| fp <= budget);
            let Some((idx, fp)) = fits else { break };
            let (req, submitted) = self.queue.remove(idx).unwrap();
            budget -= fp;
            active.push(ActiveSeq::new(req, submitted, n_layers, &mut pool));
        }
        if active.is_empty() {
            if let Some(idx) = self.next_index() {
                let (req, submitted) = self.queue.remove(idx).unwrap();
                active.push(ActiveSeq::new(req, submitted, n_layers, &mut pool));
            }
        }
    }

    /// One engine step over the active set (prefill and decode share
    /// steps — continuous batching at token granularity).
    pub fn step_active(engine: &mut DecodeEngine, active: &mut [ActiveSeq]) -> Result<()> {
        if active.is_empty() {
            return Ok(());
        }
        let mut batch: Vec<&mut SeqState> =
            active.iter_mut().map(|a| &mut a.seq).collect();
        engine.step(&mut batch)
    }

    /// Remove finished sequences from `active`, recording their latency
    /// in `metrics` (bounded histograms) and their lifecycle spans in
    /// `trace` — the retroactive path: the submit/admit instants were
    /// captured when the request queued, so the whole `queued` →
    /// `request` timeline is written here, under the engine lock, at
    /// retirement. KV pages go back to the pool (pages shared via the
    /// prefix tree stay resident for the next warm request). Returns
    /// results in completion order.
    pub fn retire(
        active: &mut Vec<ActiveSeq>,
        metrics: &mut Metrics,
        trace: &Tracer,
        pool: &Mutex<KvPool>,
    ) -> Vec<GenResult> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < active.len() {
            if active[i].seq.done() {
                let mut a = active.remove(i);
                pool.lock().unwrap().free_seq(&mut a.seq.kv);
                let lat = a.submitted.elapsed().as_micros() as u64;
                let queue = a.admitted.duration_since(a.submitted).as_micros() as u64;
                metrics.latencies_us.record(lat);
                metrics.queue_waits_us.record(queue);
                trace.record_range(
                    SpanKind::Queued,
                    a.seq.id,
                    a.submitted,
                    a.admitted,
                    a.prompt_len as u64,
                    0,
                );
                trace.record_since(
                    SpanKind::Request,
                    a.seq.id,
                    a.submitted,
                    a.prompt_len as u64,
                    a.seq.generated as u64,
                );
                out.push(GenResult {
                    id: a.seq.id,
                    tokens: a.seq.tokens,
                    latency_us: lat,
                    queue_us: queue,
                    prompt_len: a.prompt_len,
                });
            } else {
                i += 1;
            }
        }
        out
    }

    /// Drive the engine until the queue drains. Returns results in
    /// completion order.
    pub fn run(&mut self, engine: &mut DecodeEngine) -> Result<Vec<GenResult>> {
        let n_layers = engine.em.model().cfg.n_layers;
        let pool = engine.kv_pool();
        let mut active: Vec<ActiveSeq> = Vec::new();
        let mut results = Vec::new();
        engine.metrics.start();
        loop {
            self.admit(&mut active, n_layers, &pool);
            if active.is_empty() {
                break; // queue drained (admit force-admits when non-empty)
            }
            Self::step_active(engine, &mut active)?;
            results.append(&mut Self::retire(
                &mut active,
                &mut engine.metrics,
                &engine.trace,
                &pool,
            ));
        }
        engine.metrics.finish();
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::coordinator::engine::EngineModel;
    use crate::moe::MoeModel;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "batch-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    #[test]
    fn drains_queue_and_conserves_tokens() {
        let m = MoeModel::new(&cfg(), 70);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let mut b = Batcher::new(3, 256);
        for i in 0..7 {
            b.submit(GenRequest::greedy(i, vec![1, 10 + i as u16, 20], 4));
        }
        let results = b.run(&mut eng).unwrap();
        assert_eq!(results.len(), 7);
        assert_eq!(b.pending(), 0);
        for r in &results {
            assert_eq!(r.tokens.len(), 3 + 4, "req {}", r.id);
            assert_eq!(r.prompt_len, 3);
            assert!(r.latency_us >= r.queue_us);
        }
        // all ids accounted exactly once
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        assert_eq!(eng.metrics.tokens_out, 7 * 4);
    }

    #[test]
    fn batched_results_match_sequential() {
        let m = MoeModel::new(&cfg(), 71);
        let be = NativeBackend::fp(&m);
        // sequential reference
        let mut ref_eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let prompts: Vec<Vec<u16>> = vec![vec![1, 11, 21], vec![1, 12, 22, 32], vec![1, 13]];
        let want: Vec<Vec<u16>> =
            prompts.iter().map(|p| ref_eng.generate(p, 5).unwrap()).collect();
        // batched
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let mut b = Batcher::new(2, 128);
        for (i, p) in prompts.iter().enumerate() {
            b.submit(GenRequest::greedy(i as u64, p.clone(), 5));
        }
        let mut results = b.run(&mut eng).unwrap();
        results.sort_by_key(|r| r.id);
        for (r, w) in results.iter().zip(&want) {
            assert_eq!(&r.tokens, w);
        }
    }

    /// Regression for the admission over-reserve bug: `used_tokens`
    /// summed `tokens.len() + max_new`, charging already-generated tokens
    /// twice (`tokens.len()` includes them; `max_new` is the total, not
    /// the remainder). A sequence's charge must stay `prompt + max_new`
    /// for its whole lifetime, so mid-generation the batcher can still
    /// admit everything that fit at submission time.
    #[test]
    fn admission_does_not_double_count_generated_tokens() {
        let mut b = Batcher::new(4, 16);
        let pool = Mutex::new(KvPool::new(16, 32, 2));
        // long request: prompt 4 + max_new 8 = footprint 12 of budget 16
        b.submit(GenRequest::greedy(0, vec![1, 2, 3, 4], 8));
        let mut active: Vec<ActiveSeq> = Vec::new();
        b.admit(&mut active, 2, &pool);
        assert_eq!(active.len(), 1);
        // simulate mid-flight progress: 4 of 8 tokens generated
        active[0].seq.tokens.extend([9u16; 4]);
        active[0].seq.generated = 4;
        assert_eq!(active[0].footprint(), 12, "charge invariant over progress");
        // a footprint-4 request fits the remaining 16-12 budget; the old
        // accounting charged 8+8=16 and starved it until the long one
        // finished
        b.submit(GenRequest::greedy(1, vec![5, 6], 2));
        b.admit(&mut active, 2, &pool);
        assert_eq!(active.len(), 2, "budget double-count starved admission");
        // once the long sequence retires, its whole footprint comes back
        active[0].seq.generated = 8;
        let mut metrics = Metrics::default();
        let trace = Tracer::new(8);
        let done = Batcher::retire(&mut active, &mut metrics, &trace, &pool);
        assert_eq!(done.len(), 1);
        b.submit(GenRequest::greedy(2, vec![1, 2, 3, 4], 8));
        b.admit(&mut active, 2, &pool);
        assert_eq!(active.len(), 2, "retired footprint must be reclaimed");
    }

    /// Unique-page accounting: a request whose prompt prefix is already
    /// resident in the tree is charged only its unshared tail, so it
    /// fits a budget its cold footprint would blow.
    #[test]
    fn shared_prefix_discounts_admission_charge() {
        let m = MoeModel::new(&cfg(), 76);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None).with_kv_page(4);
        let pool = eng.kv_pool();
        // warm the tree: a 9-token prompt registers two full 4-blocks
        // (the last prompt position is always recomputed, so only
        // blocks under prompt_len - 1 are adoptable)
        let sys: Vec<u16> = (1..=9).collect();
        eng.generate(&sys, 2).unwrap();
        assert_eq!(pool.lock().unwrap().probe_prefix(&sys), 8);
        // cold charge would be 9 + 2 = 11, blowing budget 8 and starving
        // the second request; the warm charge is 11 - 8 = 3
        let mut b = Batcher::new(2, 8);
        b.submit(GenRequest::greedy(0, sys.clone(), 2));
        b.submit(GenRequest::greedy(1, vec![60, 61], 2));
        let mut active: Vec<ActiveSeq> = Vec::new();
        b.admit(&mut active, 2, &pool);
        assert_eq!(active.len(), 2, "warm prefix must discount the charge");
        assert_eq!(active[0].seq.shared_toks(), 8);
        assert_eq!(active[0].footprint(), 3);
        assert_eq!(active[0].seq.prefilled, 8, "admitted mid-prompt");
    }

    #[test]
    fn sjf_completes_short_jobs_first_and_cuts_mean_latency() {
        let m = MoeModel::new(&cfg(), 73);
        let be = NativeBackend::fp(&m);
        // workload: one long job in front, many short behind (the case
        // FIFO handles worst)
        let make_reqs = || {
            let mut v = vec![GenRequest::greedy(0, vec![1, 2, 3, 4], 20)];
            for i in 1..5 {
                v.push(GenRequest::greedy(i, vec![1, 2], 2));
            }
            v
        };
        let run = |policy: Policy| {
            let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
            let mut b = Batcher::new(1, 64).with_policy(policy); // serial ⇒ ordering visible
            for r in make_reqs() {
                b.submit(r);
            }
            let results = b.run(&mut eng).unwrap();
            let order: Vec<u64> = results.iter().map(|r| r.id).collect();
            let mean_steps: f64 = results
                .iter()
                .map(|r| r.latency_us as f64)
                .sum::<f64>()
                / results.len() as f64;
            (order, mean_steps)
        };
        let (fifo_order, fifo_mean) = run(Policy::Fifo);
        let (sjf_order, sjf_mean) = run(Policy::Sjf);
        assert_eq!(fifo_order[0], 0, "FIFO runs the long job first");
        assert_ne!(sjf_order[0], 0, "SJF must defer the long job");
        assert_eq!(*sjf_order.last().unwrap(), 0);
        assert!(sjf_mean < fifo_mean, "SJF mean {sjf_mean} !< FIFO {fifo_mean}");
    }

    #[test]
    fn priority_class_preempts_queue_order() {
        let m = MoeModel::new(&cfg(), 74);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let mut b = Batcher::new(1, 64).with_policy(Policy::Priority);
        b.submit(GenRequest::greedy(0, vec![1, 2], 3));
        b.submit(GenRequest::greedy(1, vec![1, 2], 3));
        b.submit(GenRequest::greedy(2, vec![1, 2], 3).with_priority(9));
        let results = b.run(&mut eng).unwrap();
        let order: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(order[0], 2, "high-priority request must run first: {order:?}");
        // FIFO within the same class
        assert_eq!(&order[1..], &[0, 1]);
    }

    #[test]
    fn all_policies_conserve_results() {
        let m = MoeModel::new(&cfg(), 75);
        let be = NativeBackend::fp(&m);
        for policy in [Policy::Fifo, Policy::Sjf, Policy::Priority] {
            let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
            let mut b = Batcher::new(3, 256).with_policy(policy);
            for i in 0..6 {
                b.submit(
                    GenRequest::greedy(i, vec![1, 5 + i as u16], 2 + (i as usize % 3))
                        .with_priority((i % 2) as u8),
                );
            }
            let results = b.run(&mut eng).unwrap();
            let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..6).collect::<Vec<_>>(), "{policy:?} lost requests");
        }
    }

    #[test]
    fn oversized_request_still_progresses() {
        let m = MoeModel::new(&cfg(), 72);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let mut b = Batcher::new(2, 4); // budget smaller than any request
        b.submit(GenRequest::greedy(0, vec![1, 2, 3, 4, 5, 6], 3));
        let results = b.run(&mut eng).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].tokens.len(), 9);
    }
}
