//! TCP serving front-end (std::net + threads — tokio is unavailable in
//! this offline environment; see DESIGN.md §3).
//!
//! Line protocol, one request per line:
//!
//! ```text
//! GEN <max_new_tokens> <tok>,<tok>,...\n   →  OK <tok>,<tok>,...\n
//! PING\n                                  →  PONG\n
//! STATS\n                                 →  STATS tokens_out=.. tps=.. ..\n
//! METRICS\n                               →  METRICS {json snapshot}\n
//! QUIT\n                                  →  (server closes this connection)
//! ```
//!
//! Every line — control commands included — goes through one parser,
//! [`parse_command`], so the protocol doc and the dispatch cannot drift.
//!
//! Concurrency model: the accept loop spawns one reader thread per
//! connection; all readers feed a single shared
//! [`Scheduler`](crate::coordinator::scheduler::Scheduler), and one
//! dedicated engine thread runs the continuous-batching loop for the
//! server's whole lifetime. Sequences from different connections share
//! engine steps (and expert groups) whenever they overlap, and an idle
//! connection never stalls anyone — it just parks its reader thread.
//! Results return to the submitting connection over per-request
//! channels. Engine access is serialized behind a mutex — on this
//! single-core testbed parallel engine steps would not help; the
//! batching provides the throughput.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::config::ServingConfig;
use crate::coordinator::engine::DecodeEngine;
use crate::coordinator::request::GenRequest;
use crate::coordinator::scheduler::Scheduler;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Accept-loop poll period (the listener is non-blocking so the quota
/// and worker-cap checks run without a wake-up connection). Backs off
/// exponentially to [`POLL_MAX`] while idle so a long-lived server
/// doesn't wake 1000x/s with no traffic; any accepted connection resets
/// it to [`POLL`].
const POLL: Duration = Duration::from_millis(1);
const POLL_MAX: Duration = Duration::from_millis(50);

/// One parsed protocol line.
#[derive(Debug)]
pub enum Command {
    Gen(GenRequest),
    Ping,
    Stats,
    Metrics,
    Quit,
    /// Blank line — ignored, no response.
    Empty,
}

/// Parse one protocol line — the single dispatch point for control
/// commands and generation requests alike.
pub fn parse_command(line: &str) -> Result<Command> {
    let line = line.trim();
    match line {
        "" => return Ok(Command::Empty),
        "PING" => return Ok(Command::Ping),
        "STATS" => return Ok(Command::Stats),
        "METRICS" => return Ok(Command::Metrics),
        "QUIT" => return Ok(Command::Quit),
        _ => {}
    }
    let mut parts = line.splitn(3, ' ');
    match parts.next() {
        Some("GEN") => {
            let max_new: usize = parts
                .next()
                .ok_or_else(|| anyhow!("GEN missing max_new"))?
                .parse()?;
            let toks: Vec<u16> = parts
                .next()
                .ok_or_else(|| anyhow!("GEN missing tokens"))?
                .split(',')
                .map(|t| t.trim().parse::<u16>())
                .collect::<Result<_, _>>()?;
            if toks.is_empty() {
                bail!("empty prompt");
            }
            Ok(Command::Gen(GenRequest::greedy(
                NEXT_ID.fetch_add(1, Ordering::Relaxed),
                toks,
                max_new,
            )))
        }
        Some(cmd) => bail!("unknown command {cmd:?}"),
        // splitn on a non-empty string always yields a first part, and
        // blank lines returned Command::Empty above
        None => unreachable!("blank line handled before the verb match"),
    }
}

/// Back-compat shim over [`parse_command`]: `GEN` lines parse to a
/// request, control lines (PING/STATS/METRICS/QUIT, blanks) to `None`.
pub fn parse_line(line: &str) -> Result<Option<GenRequest>> {
    Ok(match parse_command(line)? {
        Command::Gen(req) => Some(req),
        _ => None,
    })
}

pub fn format_result(tokens: &[u16]) -> String {
    let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("OK {}\n", toks.join(","))
}

/// Serve until `max_requests` have been answered (None = forever).
pub fn serve(
    listener: TcpListener,
    engine: &Mutex<DecodeEngine>,
    max_batch: usize,
    max_requests: Option<usize>,
) -> Result<usize> {
    let sc = ServingConfig { max_batch, ..Default::default() };
    serve_with(listener, engine, &sc, max_requests)
}

/// [`serve`] with the full serving configuration (`mcsharp serve` wires
/// the CLI flags through here; the expert-cache budget in `sc` was
/// already consumed when the engine's model was loaded).
///
/// The request quota is soft, matching the historical behaviour: once
/// `max_requests` generations have been answered the listener stops
/// accepting new connections, but connections already open are served
/// (all commands) until their clients close; the engine loop then drains
/// every in-flight sequence before the call returns.
pub fn serve_with(
    listener: TcpListener,
    engine: &Mutex<DecodeEngine>,
    sc: &ServingConfig,
    max_requests: Option<usize>,
) -> Result<usize> {
    let sched = Scheduler::from_config(sc);
    let answered = AtomicUsize::new(0);
    let live_conns = AtomicUsize::new(0);
    listener.set_nonblocking(true)?;
    let engine_result: Mutex<Option<Result<usize>>> = Mutex::new(None);
    let serve_result: Result<()> = std::thread::scope(|s| {
        s.spawn(|| {
            let r = sched.run_engine(engine);
            *engine_result.lock().unwrap() = Some(r);
        });
        let mut poll = POLL;
        let accept_result = loop {
            if let Some(m) = max_requests {
                if answered.load(Ordering::Acquire) >= m {
                    break Ok(());
                }
            }
            if engine_result.lock().unwrap().is_some() {
                break Ok(()); // engine loop died — stop accepting
            }
            if sc.workers > 0 && live_conns.load(Ordering::Acquire) >= sc.workers {
                // same backoff while pinned at the worker cap; reset on
                // the next accept below
                std::thread::sleep(poll);
                poll = (poll * 2).min(POLL_MAX);
                continue;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    poll = POLL;
                    live_conns.fetch_add(1, Ordering::AcqRel);
                    let (sched, answered, live) = (&sched, &answered, &live_conns);
                    s.spawn(move || {
                        // connection-level IO errors end that connection
                        // only; the server keeps running
                        let _ = handle_conn(stream, engine, sched, answered);
                        live.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(poll);
                    poll = (poll * 2).min(POLL_MAX);
                }
                Err(e) => break Err(anyhow::Error::from(e)),
            }
        };
        // graceful shutdown: stop accepting, let open connections finish
        // (their in-flight requests drain through the engine loop), then
        // release the engine thread
        while live_conns.load(Ordering::Acquire) > 0 {
            std::thread::sleep(POLL);
        }
        sched.shutdown();
        accept_result
    });
    serve_result?;
    if let Some(Err(e)) = engine_result.into_inner().unwrap() {
        return Err(e);
    }
    Ok(answered.into_inner())
}

/// One connection's reader loop: parse lines, answer control commands
/// in place, hand `GEN` requests to the shared scheduler and block on
/// the per-request response channel.
fn handle_conn(
    stream: TcpStream,
    engine: &Mutex<DecodeEngine>,
    sched: &Scheduler,
    answered: &AtomicUsize,
) -> Result<()> {
    // accepted sockets may inherit the listener's non-blocking mode on
    // some platforms; reader threads want blocking reads
    stream.set_nonblocking(false)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        match parse_command(&line) {
            Ok(Command::Empty) => {}
            Ok(Command::Ping) => out.write_all(b"PONG\n")?,
            Ok(Command::Stats) => {
                let eng = engine.lock().unwrap();
                let cache = eng.metrics.cache.unwrap_or_default();
                let msg = format!(
                    "STATS tokens_out={} steps={} tps={:.3} pruning={:.3} cache_resident={} cache_hits={} cache_misses={} cache_evictions={} cache_prefetch_hits={}\n",
                    eng.metrics.tokens_out,
                    eng.metrics.steps,
                    eng.metrics.tokens_per_sec(),
                    eng.metrics.pruning_ratio(),
                    cache.resident_bytes,
                    cache.hits,
                    cache.misses,
                    cache.evictions,
                    cache.prefetch_hits,
                );
                drop(eng);
                out.write_all(msg.as_bytes())?;
            }
            Ok(Command::Metrics) => {
                let eng = engine.lock().unwrap();
                let msg = format!("METRICS {}\n", eng.metrics.to_json().to_json());
                drop(eng);
                out.write_all(msg.as_bytes())?;
            }
            Ok(Command::Quit) => return Ok(()),
            Ok(Command::Gen(req)) => match sched.submit(req) {
                Ok(rx) => match rx.recv() {
                    Ok(r) => {
                        out.write_all(format_result(&r.tokens).as_bytes())?;
                        answered.fetch_add(1, Ordering::AcqRel);
                    }
                    // sender dropped without a result: engine loop died
                    Err(_) => out.write_all(b"ERR engine unavailable\n")?,
                },
                Err(e) => out.write_all(format!("ERR {e}\n").as_bytes())?,
            },
            Err(e) => {
                out.write_all(format!("ERR {e}\n").as_bytes())?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_format() {
        let r = parse_line("GEN 8 1,2,3").unwrap().unwrap();
        assert_eq!(r.max_new_tokens, 8);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert!(parse_line("PING").unwrap().is_none());
        assert!(parse_line("NOPE 1").is_err());
        assert!(parse_line("GEN 8").is_err());
        assert!(parse_line("GEN x 1,2").is_err());
        assert_eq!(format_result(&[5, 6]), "OK 5,6\n");
    }

    /// Control-command dispatch lives in exactly one place: every
    /// protocol verb the handler answers must round-trip through
    /// `parse_command` (this is the no-drift guarantee the old split
    /// PING/STATS/METRICS special-casing lacked — QUIT was accepted by
    /// the handler but unknown to the parser).
    #[test]
    fn every_control_verb_parses() {
        assert!(matches!(parse_command("PING").unwrap(), Command::Ping));
        assert!(matches!(parse_command("STATS").unwrap(), Command::Stats));
        assert!(matches!(parse_command("METRICS").unwrap(), Command::Metrics));
        assert!(matches!(parse_command("QUIT").unwrap(), Command::Quit));
        assert!(matches!(parse_command("  \n").unwrap(), Command::Empty));
        assert!(matches!(parse_command("GEN 2 7,8").unwrap(), Command::Gen(_)));
        assert!(parse_line("QUIT").unwrap().is_none());
    }

    // full TCP round-trips (including concurrent clients sharing engine
    // steps) live in rust/tests/server_roundtrip.rs
}
