//! TCP serving front-end (std::net + threads — tokio is unavailable in
//! this offline environment; see DESIGN.md §3).
//!
//! Wire protocol v1 (tagged, pipelined — grammar and parser in
//! [`protocol`](crate::coordinator::protocol), the single dispatch point
//! for v0 and v1 lines alike, so doc and dispatch cannot drift):
//!
//! ```text
//! GEN id=<u64> max_new=<n> [prio=<p>] [temp=<t> seed=<s>] [stream=1] toks=<t0,t1,...>\n
//!     → OK id=.. latency_us=.. queue_us=.. toks=..\n      (terminal)
//!     → TOK id=.. t=..\n                                  (per-token partial, stream=1)
//!     → ERR id=.. msg=..\n | BUSY id=..\n                 (terminal)
//! GEN <max_new_tokens> <tok>,<tok>,...\n  →  OK <tok>,...\n     (legacy v0, lockstep)
//! PING\n                                  →  PONG\n
//! STATS\n                                 →  STATS tokens_out=.. tps=.. lat_p50_us=.. ..\n
//! METRICS\n                               →  METRICS {json snapshot}\n
//! QUIT\n                                  →  (server closes this connection)
//! ```
//!
//! A second front-end, [`serve_shard`], speaks the shard dialect of the
//! same grammar: it answers `FETCH id=.. layer=.. experts=..` with the
//! requested expert records (`REC` line + raw payload each, request
//! order) straight off a quantized checkpoint's mmap'd seek index — the
//! storage half of multi-node expert sharding. The coordinator's
//! `RemoteStore` is the client side.
//!
//! Concurrency model: the accept loop spawns a **reader/writer pair**
//! per connection. The reader parses lines and submits `GEN` requests to
//! the single shared [`Scheduler`](crate::coordinator::scheduler::Scheduler)
//! without waiting for their results; the writer is the connection's one
//! socket-writing thread, draining a channel fed by control responses
//! and by per-request scheduler sinks. That demux is what makes **one
//! connection pipelined**: many requests in flight, responses returning
//! out of order (tagged) as they retire, all of them sharing engine
//! steps in the continuous batch. v0 `GEN` lines still work — their
//! untagged responses arrive in retirement order, so v0 clients should
//! keep at most one request in flight (the historical lockstep usage).
//!
//! Backpressure: [`ServingConfig::max_queue`] bounds the admission
//! queue; a submit against a full queue is answered `BUSY id=..`
//! immediately (v1) while in-flight work is untouched. One dedicated
//! engine thread runs the continuous-batching loop for the server's
//! whole lifetime; engine access is serialized behind a mutex — on this
//! single-core testbed parallel engine steps would not help; the
//! batching provides the throughput.

use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::ServingConfig;
use crate::coordinator::engine::DecodeEngine;
use crate::coordinator::protocol::{self, Command, LineRead, WireGen};
use crate::coordinator::request::{EventSink, SeqEvent};
use crate::coordinator::scheduler::{Scheduler, SubmitError};

/// Accept-loop poll period (the listener is non-blocking so the quota
/// and worker-cap checks run without a wake-up connection). Backs off
/// exponentially to [`POLL_MAX`] while idle so a long-lived server
/// doesn't wake 1000x/s with no traffic; any accepted connection resets
/// it to [`POLL`].
const POLL: Duration = Duration::from_millis(1);
const POLL_MAX: Duration = Duration::from_millis(50);

/// One message to a connection's writer thread — the demux point where
/// control responses, streamed `TOK` partials and out-of-order `OK`
/// lines serialize onto the socket.
enum ConnOut {
    /// A response line to write verbatim.
    Line(String),
    /// A terminal generation success — counts against the request quota.
    Done(String),
}

/// Serve until `max_requests` have been answered (None = forever).
pub fn serve(
    listener: TcpListener,
    engine: &Mutex<DecodeEngine>,
    max_batch: usize,
    max_requests: Option<usize>,
) -> Result<usize> {
    let sc = ServingConfig { max_batch, ..Default::default() };
    serve_with(listener, engine, &sc, max_requests)
}

/// [`serve`] with the full serving configuration (`mcsharp serve` wires
/// the CLI flags through here; the expert-cache budget in `sc` was
/// already consumed when the engine's model was loaded).
///
/// The request quota is soft, matching the historical behaviour: once
/// `max_requests` generations have been answered the listener stops
/// accepting new connections, but connections already open are served
/// (all commands) until their clients close; the engine loop then drains
/// every in-flight sequence before the call returns.
pub fn serve_with(
    listener: TcpListener,
    engine: &Mutex<DecodeEngine>,
    sc: &ServingConfig,
    max_requests: Option<usize>,
) -> Result<usize> {
    let sched = Scheduler::from_config(sc);
    let answered = AtomicUsize::new(0);
    let live_conns = AtomicUsize::new(0);
    // per-server internal request ids: client-supplied `id=` tags are a
    // per-connection namespace and never index the scheduler directly,
    // so ids cannot interleave across server instances in one process
    // (the old global counter could)
    let next_id = AtomicU64::new(1);
    listener.set_nonblocking(true)?;
    let engine_result: Mutex<Option<Result<usize>>> = Mutex::new(None);
    let serve_result: Result<()> = std::thread::scope(|s| {
        s.spawn(|| {
            let r = sched.run_engine(engine);
            *engine_result.lock().unwrap() = Some(r);
        });
        let mut poll = POLL;
        let accept_result = loop {
            if let Some(m) = max_requests {
                if answered.load(Ordering::Acquire) >= m {
                    break Ok(());
                }
            }
            if engine_result.lock().unwrap().is_some() {
                break Ok(()); // engine loop died — stop accepting
            }
            if sc.workers > 0 && live_conns.load(Ordering::Acquire) >= sc.workers {
                // same backoff while pinned at the worker cap; reset on
                // the next accept below
                std::thread::sleep(poll);
                poll = (poll * 2).min(POLL_MAX);
                continue;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    poll = POLL;
                    live_conns.fetch_add(1, Ordering::AcqRel);
                    let (sched, answered, live) = (&sched, &answered, &live_conns);
                    let next_id = &next_id;
                    s.spawn(move || {
                        // connection-level IO errors end that connection
                        // only; the server keeps running
                        let _ = handle_conn(stream, engine, sched, answered, next_id);
                        live.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(poll);
                    poll = (poll * 2).min(POLL_MAX);
                }
                Err(e) => break Err(anyhow::Error::from(e)),
            }
        };
        // graceful shutdown: stop accepting, let open connections finish
        // (their in-flight requests drain through the engine loop), then
        // release the engine thread
        while live_conns.load(Ordering::Acquire) > 0 {
            std::thread::sleep(POLL);
        }
        sched.shutdown();
        accept_result
    });
    serve_result?;
    if let Some(Err(e)) = engine_result.into_inner().unwrap() {
        return Err(e);
    }
    Ok(answered.into_inner())
}

/// One connection: a reader thread (this function) that parses lines and
/// submits generations without blocking on their results, plus a writer
/// thread that owns the socket's write half and drains [`ConnOut`]
/// messages — control responses in submission order, generation
/// responses in retirement order. Returning (client EOF, `QUIT`, IO
/// error) stops reading; the writer then drains whatever the connection
/// still has in flight before the socket closes.
fn handle_conn(
    stream: TcpStream,
    engine: &Mutex<DecodeEngine>,
    sched: &Scheduler,
    answered: &AtomicUsize,
    next_id: &AtomicU64,
) -> Result<()> {
    // accepted sockets may inherit the listener's non-blocking mode on
    // some platforms; reader threads want blocking reads
    stream.set_nonblocking(false)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let (otx, orx) = mpsc::channel::<ConnOut>();
    std::thread::scope(|s| {
        let writer = s.spawn(move || -> Result<()> {
            for msg in orx {
                let line = match msg {
                    ConnOut::Line(line) => line,
                    ConnOut::Done(line) => {
                        answered.fetch_add(1, Ordering::AcqRel);
                        line
                    }
                };
                out.write_all(line.as_bytes())?;
            }
            Ok(())
        });
        let read_result = read_loop(&mut reader, engine, sched, next_id, &otx);
        // the reader's sender drops here; the writer exits once every
        // in-flight request's sink has delivered its terminal line
        drop(otx);
        let write_result = writer.join().expect("connection writer panicked");
        read_result.and(write_result)
    })
}

/// Send one message to the connection's writer; an error means the
/// writer is gone (socket dead), which ends the reader loop too.
fn send(otx: &mpsc::Sender<ConnOut>, msg: ConnOut) -> Result<()> {
    otx.send(msg).map_err(|_| anyhow!("connection writer closed"))
}

fn read_loop(
    reader: &mut BufReader<TcpStream>,
    engine: &Mutex<DecodeEngine>,
    sched: &Scheduler,
    next_id: &AtomicU64,
    otx: &mpsc::Sender<ConnOut>,
) -> Result<()> {
    let mut line = String::new();
    loop {
        match protocol::read_command_line(reader, &mut line, protocol::MAX_LINE_BYTES)? {
            LineRead::Eof => return Ok(()), // client closed
            LineRead::Oversized => {
                let msg = format!("line exceeds {} bytes", protocol::MAX_LINE_BYTES);
                send(otx, ConnOut::Line(protocol::format_err(None, &msg)))?;
                continue;
            }
            LineRead::Line => {}
        }
        match protocol::parse_command(&line) {
            Ok(Command::Empty) => {}
            Ok(Command::Ping) => send(otx, ConnOut::Line("PONG\n".into()))?,
            Ok(Command::Stats) => {
                let msg = stats_line(&engine.lock().unwrap());
                send(otx, ConnOut::Line(msg))?;
            }
            Ok(Command::Metrics) => {
                let msg = {
                    let eng = engine.lock().unwrap();
                    format!("METRICS {}\n", eng.metrics.to_json().to_json())
                };
                send(otx, ConnOut::Line(msg))?;
            }
            Ok(Command::Trace { last }) => {
                // snapshot under the engine lock, format outside it
                let spans = engine.lock().unwrap().trace.snapshot(last);
                let mut msg = protocol::format_trace_header(spans.len());
                for sp in &spans {
                    msg.push_str(&sp.to_value().to_json());
                    msg.push('\n');
                }
                send(otx, ConnOut::Line(msg))?;
            }
            Ok(Command::Quit) => return Ok(()),
            Ok(Command::Gen(wire)) => submit_gen(wire, sched, next_id, otx)?,
            // FETCH is the shard dialect; a coordinator answers it with a
            // tagged ERR (and no REC frames) so a misdirected RemoteStore
            // fails fast instead of deadlocking on a missing record
            Ok(Command::Fetch(wf)) => {
                let msg = protocol::format_err(Some(wf.tag), "coordinator does not serve FETCH");
                send(otx, ConnOut::Line(msg))?;
            }
            // keep the ERR attributable when the bad line carried a
            // parseable id= (a pipelined client needs the tag to mark it
            // terminal); otherwise the untagged ERR both dialects get
            Err(e) => {
                let tag = protocol::salvage_tag(&line);
                send(otx, ConnOut::Line(protocol::format_err(tag, &e.to_string())))?;
            }
        }
    }
}

/// Submit one parsed `GEN` to the shared scheduler, wiring its response
/// route straight into the connection's writer: `TOK` partials and the
/// terminal `OK`/`ERR` are formatted in the sink (tagged for v1,
/// untagged v0 otherwise), so the reader never blocks on a result and
/// the connection pipelines.
fn submit_gen(
    wire: WireGen,
    sched: &Scheduler,
    next_id: &AtomicU64,
    otx: &mpsc::Sender<ConnOut>,
) -> Result<()> {
    let tag = wire.tag;
    let req = wire.into_request(next_id.fetch_add(1, Ordering::Relaxed));
    let sink_tx = otx.clone();
    let sink: EventSink = Box::new(move |ev| {
        let msg = match ev {
            SeqEvent::Tok { token, .. } => match tag {
                Some(t) => ConnOut::Line(protocol::format_tok(t, token)),
                None => return, // v0 requests cannot ask for streaming
            },
            SeqEvent::Done(r) => ConnOut::Done(match tag {
                Some(t) => protocol::format_ok(t, &r),
                None => protocol::format_ok_v0(&r.tokens),
            }),
            SeqEvent::Failed { msg, .. } => ConnOut::Line(protocol::format_err(tag, &msg)),
        };
        let _ = sink_tx.send(msg); // writer gone ⇒ client vanished
    });
    match sched.submit_sink(req, sink) {
        Ok(()) => Ok(()),
        // overload: answer immediately, nothing was queued
        Err(SubmitError::Busy { .. }) => match tag {
            Some(t) => send(otx, ConnOut::Line(protocol::format_busy(t))),
            None => send(
                otx,
                ConnOut::Line(protocol::format_err(None, "busy: admission queue full")),
            ),
        },
        Err(e @ SubmitError::Draining) => {
            send(otx, ConnOut::Line(protocol::format_err(tag, &e.to_string())))
        }
    }
}

/// The one-line `STATS` scrape: lifetime counters plus the latency and
/// queue-wait percentile summaries (µs) the tagged `OK` responses report
/// per request.
fn stats_line(eng: &DecodeEngine) -> String {
    let m = &eng.metrics;
    let cache = m.cache.unwrap_or_default();
    let remote = m.remote.unwrap_or_default();
    let lat = m.latency_percentiles_us(&[0.5, 0.95]);
    let queue = m.queue_percentiles_us(&[0.5, 0.95]);
    format!(
        "STATS tokens_out={} tokens_in={} steps={} tps={:.3} pruning={:.3} lat_p50_us={} lat_p95_us={} queue_p50_us={} queue_p95_us={} cache_resident={} cache_hits={} cache_misses={} cache_evictions={} cache_prefetch_hits={} kv_pages={} kv_bytes={} prefix_hit_toks={} kv_cow_copies={} remote_fetch_rpcs={} remote_prefetch_rpcs={} remote_fetched_bytes={} remote_fetch_p95_us={} shards_up={} shards_total={}\n",
        m.tokens_out,
        m.tokens_in,
        m.steps,
        m.tokens_per_sec(),
        m.pruning_ratio(),
        lat[0],
        lat[1],
        queue[0],
        queue[1],
        cache.resident_bytes,
        cache.hits,
        cache.misses,
        cache.evictions,
        cache.prefetch_hits,
        m.kv.kv_pages,
        m.kv.kv_bytes,
        m.kv.prefix_hit_toks,
        m.kv.cow_copies,
        remote.fetch_rpcs,
        remote.prefetch_rpcs,
        remote.fetched_bytes,
        remote.fetch_p95_us,
        remote.shards_up,
        remote.shards_total,
    )
}

/// Serve expert records off a sharded quantized checkpoint until
/// `max_requests` `FETCH`es have been answered (None = forever).
///
/// This is the storage node of multi-node expert sharding (`mcsharp
/// shard`). Each connection is one blocking read→respond loop — the
/// shard dialect is strictly request/response per FETCH, and the
/// coordinator's pipelining (a second FETCH written before the first's
/// records are read) rides the kernel socket buffer, so no writer
/// demux thread is needed. `STATS` answers with `kind=shard
/// layers=a..b ..`; the `layers=` token is how a coordinator discovers
/// the shard's residency at connect time.
pub fn serve_shard(
    listener: TcpListener,
    source: &crate::quant::qcheckpoint::ShardSource,
    max_requests: Option<usize>,
) -> Result<usize> {
    let answered = AtomicUsize::new(0);
    let live_conns = AtomicUsize::new(0);
    listener.set_nonblocking(true)?;
    let result: Result<()> = std::thread::scope(|s| {
        let mut poll = POLL;
        let accept_result = loop {
            if let Some(m) = max_requests {
                if answered.load(Ordering::Acquire) >= m {
                    break Ok(());
                }
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    poll = POLL;
                    live_conns.fetch_add(1, Ordering::AcqRel);
                    let (answered, live) = (&answered, &live_conns);
                    s.spawn(move || {
                        // connection-level IO errors end that connection
                        // only; the shard keeps serving
                        let _ = handle_shard_conn(stream, source, answered);
                        live.fetch_sub(1, Ordering::AcqRel);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(poll);
                    poll = (poll * 2).min(POLL_MAX);
                }
                Err(e) => break Err(anyhow::Error::from(e)),
            }
        };
        while live_conns.load(Ordering::Acquire) > 0 {
            std::thread::sleep(POLL);
        }
        accept_result
    });
    result?;
    Ok(answered.into_inner())
}

fn handle_shard_conn(
    stream: TcpStream,
    source: &crate::quant::qcheckpoint::ShardSource,
    answered: &AtomicUsize,
) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = std::io::BufWriter::new(stream);
    let mut line = String::new();
    loop {
        match protocol::read_command_line(&mut reader, &mut line, protocol::MAX_LINE_BYTES)? {
            LineRead::Eof => return Ok(()),
            LineRead::Oversized => {
                let msg = format!("line exceeds {} bytes", protocol::MAX_LINE_BYTES);
                out.write_all(protocol::format_err(None, &msg).as_bytes())?;
                out.flush()?;
                continue;
            }
            LineRead::Line => {}
        }
        match protocol::parse_command(&line) {
            Ok(Command::Empty) => {}
            Ok(Command::Ping) => {
                out.write_all(b"PONG\n")?;
                out.flush()?;
            }
            Ok(Command::Stats) => {
                let l = source.layers();
                let msg = format!(
                    "STATS kind=shard layers={}..{} n_experts={} fetches={}\n",
                    l.start,
                    l.end,
                    source.n_experts(),
                    answered.load(Ordering::Acquire),
                );
                out.write_all(msg.as_bytes())?;
                out.flush()?;
            }
            Ok(Command::Metrics) => {
                let l = source.layers();
                let msg = format!(
                    "METRICS {{\"kind\":\"shard\",\"layer_start\":{},\"layer_end\":{},\"n_experts\":{},\"fetches\":{}}}\n",
                    l.start,
                    l.end,
                    source.n_experts(),
                    answered.load(Ordering::Acquire),
                );
                out.write_all(msg.as_bytes())?;
                out.flush()?;
            }
            Ok(Command::Quit) => return Ok(()),
            Ok(Command::Gen(wire)) => {
                let msg = protocol::format_err(wire.tag, "shard serves FETCH only");
                out.write_all(msg.as_bytes())?;
                out.flush()?;
            }
            // a shard has no decode engine, hence no span ring
            Ok(Command::Trace { .. }) => {
                let msg = protocol::format_err(None, "shard does not serve TRACE");
                out.write_all(msg.as_bytes())?;
                out.flush()?;
            }
            Ok(Command::Fetch(wf)) => {
                serve_fetch(&wf, source, &mut out)?;
                answered.fetch_add(1, Ordering::AcqRel);
            }
            Err(e) => {
                let tag = protocol::salvage_tag(&line);
                out.write_all(protocol::format_err(tag, &e.to_string()).as_bytes())?;
                out.flush()?;
            }
        }
    }
}

/// Answer one `FETCH`: the response is either exactly
/// `experts.len()` `REC` frames in request order, or one `ERR` before
/// any `REC` — never a prefix. The whole request validates against the
/// seek index up front so a bad expert id cannot leave the client
/// mid-stream.
fn serve_fetch(
    wf: &protocol::WireFetch,
    source: &crate::quant::qcheckpoint::ShardSource,
    out: &mut impl Write,
) -> Result<()> {
    let mut spans = Vec::with_capacity(wf.experts.len());
    let mut bad = None;
    for &e in &wf.experts {
        match source.record_span(wf.layer, e) {
            Ok(s) => spans.push(s),
            Err(er) => {
                bad = Some(er);
                break;
            }
        }
    }
    if let Some(e) = bad {
        out.write_all(protocol::format_err(Some(wf.tag), &format!("{e:#}")).as_bytes())?;
        out.flush()?;
        return Ok(());
    }
    for (&e, span) in wf.experts.iter().zip(&spans) {
        out.write_all(protocol::format_rec(wf.tag, wf.layer, e, span.len()).as_bytes())?;
        out.write_all(span)?;
    }
    out.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Control-command dispatch lives in exactly one place
    /// ([`protocol::parse_command`]): every protocol verb the reader
    /// loop answers must round-trip through it — the no-drift guarantee.
    /// (The old `parse_line` shim is gone; grammar-level tests live in
    /// `protocol::tests`.)
    #[test]
    fn every_served_verb_parses() {
        assert!(matches!(protocol::parse_command("PING").unwrap(), Command::Ping));
        assert!(matches!(protocol::parse_command("STATS").unwrap(), Command::Stats));
        assert!(matches!(protocol::parse_command("METRICS").unwrap(), Command::Metrics));
        assert!(matches!(
            protocol::parse_command("TRACE").unwrap(),
            Command::Trace { last: None }
        ));
        assert!(matches!(protocol::parse_command("QUIT").unwrap(), Command::Quit));
        assert!(matches!(protocol::parse_command("  \n").unwrap(), Command::Empty));
        assert!(matches!(protocol::parse_command("GEN 2 7,8").unwrap(), Command::Gen(_)));
        assert!(matches!(
            protocol::parse_command("GEN id=1 max_new=2 toks=7,8").unwrap(),
            Command::Gen(_)
        ));
    }

    /// The stats line carries every field the docs promise, including
    /// the new percentile summaries (satellite: latency/queue surfaced
    /// in STATS).
    #[test]
    fn stats_line_reports_percentiles() {
        use crate::coordinator::metrics::Metrics;
        let mut m = Metrics { tokens_out: 9, ..Default::default() };
        for v in [100, 200, 300] {
            m.latencies_us.record(v);
        }
        for v in [10, 20, 30] {
            m.queue_waits_us.record(v);
        }
        let line = format!(
            "lat_p50_us={} lat_p95_us={} queue_p50_us={} queue_p95_us={}",
            m.latency_percentile_us(0.5),
            m.latency_percentile_us(0.95),
            m.queue_percentile_us(0.5),
            m.queue_percentile_us(0.95),
        );
        // histogram percentiles report log2-bucket upper bounds
        assert_eq!(line, "lat_p50_us=255 lat_p95_us=511 queue_p50_us=31 queue_p95_us=31");
    }

    // full TCP round-trips (pipelining, streaming, BUSY backpressure,
    // v0↔v1 mixed traffic) live in rust/tests/server_roundtrip.rs and
    // rust/tests/protocol_v1.rs, driven through coordinator::client
}
