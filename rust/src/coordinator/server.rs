//! TCP serving front-end (std::net + threads — tokio is unavailable in
//! this offline environment; see DESIGN.md §3).
//!
//! Line protocol, one request per line:
//!
//! ```text
//! GEN <max_new_tokens> <tok>,<tok>,...\n   →  OK <tok>,<tok>,...\n
//! PING\n                                  →  PONG\n
//! STATS\n                                 →  STATS tokens_out=.. tps=..\n
//! METRICS\n                               →  METRICS {json snapshot}\n
//! ```
//!
//! The listener thread accumulates a micro-batch window, then runs the
//! batcher over the engine. Engine access is serialized behind a mutex —
//! on this single-core testbed parallel engine steps would not help; the
//! batching provides the throughput.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::config::ServingConfig;
use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::DecodeEngine;
use crate::coordinator::request::GenRequest;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Parse one protocol line into a request.
pub fn parse_line(line: &str) -> Result<Option<GenRequest>> {
    let line = line.trim();
    if line == "PING" || line == "STATS" || line == "METRICS" || line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.splitn(3, ' ');
    match parts.next() {
        Some("GEN") => {
            let max_new: usize = parts
                .next()
                .ok_or_else(|| anyhow!("GEN missing max_new"))?
                .parse()?;
            let toks: Vec<u16> = parts
                .next()
                .ok_or_else(|| anyhow!("GEN missing tokens"))?
                .split(',')
                .map(|t| t.trim().parse::<u16>())
                .collect::<Result<_, _>>()?;
            if toks.is_empty() {
                bail!("empty prompt");
            }
            Ok(Some(GenRequest::greedy(
                NEXT_ID.fetch_add(1, Ordering::Relaxed),
                toks,
                max_new,
            )))
        }
        Some(cmd) => bail!("unknown command {cmd:?}"),
        None => Ok(None),
    }
}

pub fn format_result(tokens: &[u16]) -> String {
    let toks: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
    format!("OK {}\n", toks.join(","))
}

/// Serve until `max_requests` have been answered (None = forever).
/// Single-connection-at-a-time handling per line keeps the protocol
/// trivial; batching happens across lines pending in one connection.
pub fn serve(
    listener: TcpListener,
    engine: &Mutex<DecodeEngine>,
    max_batch: usize,
    max_requests: Option<usize>,
) -> Result<usize> {
    let sc = ServingConfig { max_batch, ..Default::default() };
    serve_with(listener, engine, &sc, max_requests)
}

/// [`serve`] with the full serving configuration (`mcsharp serve` wires
/// the CLI flags through here; the expert-cache budget in `sc` was
/// already consumed when the engine's model was loaded).
pub fn serve_with(
    listener: TcpListener,
    engine: &Mutex<DecodeEngine>,
    sc: &ServingConfig,
    max_requests: Option<usize>,
) -> Result<usize> {
    let mut answered = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        answered += handle_conn(stream, engine, sc)?;
        if let Some(m) = max_requests {
            if answered >= m {
                break;
            }
        }
    }
    Ok(answered)
}

fn handle_conn(
    stream: TcpStream,
    engine: &Mutex<DecodeEngine>,
    sc: &ServingConfig,
) -> Result<usize> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut answered = 0usize;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(answered); // client closed
        }
        let trimmed = line.trim();
        if trimmed == "PING" {
            out.write_all(b"PONG\n")?;
            continue;
        }
        if trimmed == "STATS" {
            let eng = engine.lock().unwrap();
            let cache = eng.metrics.cache.unwrap_or_default();
            let msg = format!(
                "STATS tokens_out={} steps={} pruning={:.3} cache_resident={} cache_hits={} cache_misses={} cache_evictions={} cache_prefetch_hits={}\n",
                eng.metrics.tokens_out,
                eng.metrics.steps,
                eng.metrics.pruning_ratio(),
                cache.resident_bytes,
                cache.hits,
                cache.misses,
                cache.evictions,
                cache.prefetch_hits,
            );
            drop(eng);
            out.write_all(msg.as_bytes())?;
            continue;
        }
        if trimmed == "METRICS" {
            let eng = engine.lock().unwrap();
            let msg = format!("METRICS {}\n", eng.metrics.to_json().to_json());
            drop(eng);
            out.write_all(msg.as_bytes())?;
            continue;
        }
        if trimmed == "QUIT" {
            return Ok(answered);
        }
        match parse_line(trimmed) {
            Ok(Some(req)) => {
                let mut eng = engine.lock().unwrap();
                let mut b = Batcher::from_config(sc);
                let id = req.id;
                b.submit(req);
                let results = b.run(&mut eng)?;
                drop(eng);
                let r = results
                    .into_iter()
                    .find(|r| r.id == id)
                    .ok_or_else(|| anyhow!("result lost"))?;
                out.write_all(format_result(&r.tokens).as_bytes())?;
                answered += 1;
            }
            Ok(None) => {}
            Err(e) => {
                out.write_all(format!("ERR {e}\n").as_bytes())?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_format() {
        let r = parse_line("GEN 8 1,2,3").unwrap().unwrap();
        assert_eq!(r.max_new_tokens, 8);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert!(parse_line("PING").unwrap().is_none());
        assert!(parse_line("NOPE 1").is_err());
        assert!(parse_line("GEN 8").is_err());
        assert!(parse_line("GEN x 1,2").is_err());
        assert_eq!(format_result(&[5, 6]), "OK 5,6\n");
    }

    // full TCP round-trip lives in rust/tests/server_roundtrip.rs
}
