//! The decode engine: batched, KV-cached, expert-grouped generation.
//!
//! One engine instance now serves for the whole server lifetime (the
//! [`Scheduler`](crate::coordinator::scheduler::Scheduler) steps it from
//! a persistent loop), so [`Metrics`] accumulate across requests: the
//! wall-clock window opens at the first `start()` and `tokens_per_sec`
//! reads the lifetime rate, not the latest drain's.

use anyhow::Result;

use crate::backend::ExpertBackend;
use crate::moe::attention::KvCache;
use crate::moe::dispatch::{dispatch_moe_layer, DispatchExecutor, DispatchHooks};
use crate::moe::model::{ExpertId, MoeModel, Pruner};
use crate::quant::qmodel::QuantModel;
use crate::tensor::{rmsnorm, softmax, Tensor2};
use crate::util::rng::Rng;

use super::metrics::Metrics;

/// The dense-side weights the engine reads (embedding, norms, attention,
/// gate, lm head): either the fp model or the quantized model's base.
pub enum EngineModel<'a> {
    Fp(&'a MoeModel),
    Quant(&'a QuantModel),
}

impl EngineModel<'_> {
    pub fn model(&self) -> &MoeModel {
        match self {
            EngineModel::Fp(m) => m,
            EngineModel::Quant(q) => &q.model,
        }
    }

    fn routed_expert_bytes(&self, layer: usize, expert: usize) -> u64 {
        match self {
            EngineModel::Fp(m) => {
                (m.blocks[layer].experts[expert].n_params() * 2) as u64
            }
            // store metadata — never faults a paged expert in
            EngineModel::Quant(q) => q.store.expert_nbytes(layer, expert),
        }
    }

    /// Expert-cache gauges when the model serves from a store (always
    /// for quantized models; fp weights live in the model itself).
    pub fn cache_counters(&self) -> Option<crate::quant::store::CacheCounters> {
        match self {
            EngineModel::Fp(_) => None,
            EngineModel::Quant(q) => Some(q.store.counters()),
        }
    }
}

/// [`DispatchExecutor`] over the engine's [`ExpertBackend`] — the
/// serving-path adapter (native fused-dequant or PJRT execution), with
/// routed-bytes accounting from the engine's weight store.
struct BackendExec<'s, 'a> {
    em: &'s EngineModel<'a>,
    be: &'s dyn ExpertBackend,
}

impl DispatchExecutor for BackendExec<'_, '_> {
    fn expert_batch_acc(
        &self,
        layer: usize,
        id: ExpertId,
        x: &Tensor2,
        weights: &[f32],
        out: &mut Tensor2,
    ) -> Result<()> {
        let y = match id {
            ExpertId::Routed(e) => self.be.expert_batch(layer, e, x)?,
            ExpertId::Shared(s) => self.be.shared_batch(layer, s, x)?,
        };
        for i in 0..x.rows {
            let w = weights[i];
            for (o, v) in out.row_mut(i).iter_mut().zip(y.row(i)) {
                *o += w * v;
            }
        }
        Ok(())
    }

    fn expert_bytes(&self, layer: usize, id: ExpertId) -> u64 {
        match id {
            ExpertId::Routed(e) => self.em.routed_expert_bytes(layer, e),
            ExpertId::Shared(_) => 0,
        }
    }

    /// Serving-side residency: page the routed set in before the execute
    /// fan-out — but only when the backend actually reads the store at
    /// call time (PJRT executes from pre-staged literals; paging for it
    /// would be I/O nothing consumes).
    fn prepare(&self, layer: usize, routed: &[usize]) -> Result<()> {
        match self.em {
            EngineModel::Quant(q) if self.be.uses_expert_store() => {
                q.store.ensure_resident(layer, routed)
            }
            _ => Ok(()),
        }
    }
}

/// One live sequence: token history + per-layer KV caches.
pub struct SeqState {
    pub id: u64,
    pub tokens: Vec<u16>,
    pub caches: Vec<KvCache>,
    /// Number of prompt tokens already prefilled.
    pub prefilled: usize,
    pub generated: usize,
    pub max_new: usize,
    pub sample: Option<(f32, u64)>,
}

impl SeqState {
    pub fn new(id: u64, prompt: Vec<u16>, max_new: usize, n_layers: usize) -> SeqState {
        SeqState {
            id,
            tokens: prompt,
            caches: (0..n_layers).map(|_| KvCache::default()).collect(),
            prefilled: 0,
            generated: 0,
            max_new,
            sample: None,
        }
    }

    pub fn done(&self) -> bool {
        self.generated >= self.max_new
    }
}

pub struct DecodeEngine<'a> {
    pub em: EngineModel<'a>,
    pub backend: &'a dyn ExpertBackend,
    pub pruner: Option<Box<dyn Pruner + 'a>>,
    pub metrics: Metrics,
    rng: Rng,
}

impl<'a> DecodeEngine<'a> {
    pub fn new(
        em: EngineModel<'a>,
        backend: &'a dyn ExpertBackend,
        pruner: Option<Box<dyn Pruner + 'a>>,
    ) -> DecodeEngine<'a> {
        DecodeEngine { em, backend, pruner, metrics: Metrics::default(), rng: Rng::new(0x5EED) }
    }

    /// Process one position for every sequence in `batch`: the token at
    /// `seq.prefilled` if still prefilling, else decode the next token
    /// (appending it to `seq.tokens`). This is continuous batching at
    /// token-step granularity — prefill and decode share engine steps.
    pub fn step(&mut self, batch: &mut [&mut SeqState]) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let model = self.em.model();
        let cfg = model.cfg.clone();
        let h = cfg.d_model;
        let n = batch.len();
        // gather input rows (embedding of the current position's token)
        let mut x = Tensor2::zeros(n, h);
        for (i, seq) in batch.iter().enumerate() {
            let pos = seq.prefilled.min(seq.tokens.len() - 1);
            let tok = seq.tokens[pos] as usize;
            x.row_mut(i).copy_from_slice(model.embed.row(tok));
        }
        let mut normed = Tensor2::zeros(n, h);
        for (l, block) in model.blocks.iter().enumerate() {
            // attention (per sequence, KV cached)
            for (i, seq) in batch.iter_mut().enumerate() {
                rmsnorm(x.row(i), &block.attn_norm, normed.row_mut(i));
                let out = block.attn.forward_step(normed.row(i), &mut seq.caches[l]);
                let xr = x.row_mut(i);
                for (a, o) in xr.iter_mut().zip(&out) {
                    *a += o;
                }
            }
            // MoE: the shared expert-grouped dispatcher (route + prune +
            // group + execute-once-per-expert + scatter)
            for i in 0..n {
                rmsnorm(x.row(i), &block.moe_norm, normed.row_mut(i));
            }
            let exec = BackendExec { em: &self.em, be: self.backend };
            let mut hooks = DispatchHooks {
                pruner: self.pruner.as_deref_mut(),
                ..Default::default()
            };
            let outcome = dispatch_moe_layer(
                l,
                &block.gate,
                cfg.top_k,
                cfg.n_shared_experts,
                &normed,
                &exec,
                &mut hooks,
                &mut x,
            )?;
            self.metrics.experts_kept += outcome.kept;
            self.metrics.experts_offered += outcome.offered;
            self.metrics.routed_bytes += outcome.routed_bytes;
        }
        // head + token transition per sequence
        for (i, seq) in batch.iter_mut().enumerate() {
            if seq.prefilled + 1 < seq.tokens.len() {
                // still prefilling: just advance (logits unused)
                seq.prefilled += 1;
                self.metrics.tokens_in += 1;
                continue;
            }
            rmsnorm(x.row(i), &model.final_norm, normed.row_mut(i));
            let mut logits = crate::moe::attention::mat_vec(&model.lm_head, normed.row(i));
            let next = match seq.sample {
                None => {
                    logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(t, _)| t as u16)
                        .unwrap_or(0)
                }
                Some((temp, _)) => {
                    for v in logits.iter_mut() {
                        *v /= temp.max(1e-3);
                    }
                    softmax(&mut logits);
                    self.rng.categorical(&logits) as u16
                }
            };
            seq.tokens.push(next);
            seq.prefilled += 1;
            seq.generated += 1;
            self.metrics.tokens_out += 1;
        }
        self.metrics.steps += 1;
        // refresh the expert-cache gauges (monotonic counters read off
        // the store; cheap — one small struct copy under the store lock)
        self.metrics.cache = self.em.cache_counters();
        Ok(())
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Run one sequence to completion (used by tests & simple paths).
    pub fn generate(&mut self, prompt: &[u16], max_new: usize) -> Result<Vec<u16>> {
        let model = self.em.model();
        let n_layers = model.cfg.n_layers;
        let mut seq = SeqState::new(0, prompt.to_vec(), max_new, n_layers);
        while !seq.done() {
            let mut batch = [&mut seq];
            self.step(&mut batch)?;
        }
        Ok(seq.tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::moe::model::ForwardOpts;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "eng-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    /// The decode engine (KV-cached, expert-grouped, batched) must agree
    /// with the reference full-sequence forward on greedy generation.
    #[test]
    fn engine_matches_full_forward_greedy() {
        let m = MoeModel::new(&cfg(), 60);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let prompt = vec![1u16, 17, 30, 45];
        let got = eng.generate(&prompt, 6).unwrap();
        // reference: repeated full-sequence forward + argmax
        let mut want = prompt.clone();
        for _ in 0..6 {
            let logits = m.forward_opts(&want, &mut ForwardOpts::default());
            let last = logits.row(logits.rows - 1);
            let next = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u16;
            want.push(next);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn batched_equals_single() {
        let m = MoeModel::new(&cfg(), 61);
        let be = NativeBackend::fp(&m);
        let p1 = vec![1u16, 20, 21];
        let p2 = vec![1u16, 40, 41, 42];
        // single
        let mut e1 = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let a1 = e1.generate(&p1, 4).unwrap();
        let a2 = e1.generate(&p2, 4).unwrap();
        // batched together
        let mut eb = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let mut s1 = SeqState::new(1, p1.clone(), 4, 2);
        let mut s2 = SeqState::new(2, p2.clone(), 4, 2);
        while !s1.done() || !s2.done() {
            let mut batch: Vec<&mut SeqState> = Vec::new();
            if !s1.done() {
                batch.push(&mut s1);
            }
            if !s2.done() {
                batch.push(&mut s2);
            }
            eb.step(&mut batch).unwrap();
        }
        assert_eq!(s1.tokens, a1);
        assert_eq!(s2.tokens, a2);
    }

    #[test]
    fn metrics_track_activation() {
        let m = MoeModel::new(&cfg(), 62);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        eng.generate(&[1, 2, 3], 5).unwrap();
        assert_eq!(eng.metrics.tokens_out, 5);
        assert_eq!(eng.metrics.tokens_in, 2); // prompt len 3 => 2 prefill steps
        assert!(eng.metrics.experts_offered > 0);
        assert_eq!(eng.metrics.experts_kept, eng.metrics.experts_offered);
        assert!(eng.metrics.routed_bytes > 0);
        assert!(eng.metrics.cache.is_none(), "fp model has no expert cache");
    }

    #[test]
    fn quant_engine_reports_cache_gauges() {
        use crate::config::PmqConfig;
        use crate::quant::qmodel::QuantMethod;
        let m = MoeModel::new(&cfg(), 63);
        let alloc = vec![vec![2u8; 4]; 2];
        let q = QuantModel::quantize(&m, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
        let be = NativeBackend::quant(&q);
        let mut eng = DecodeEngine::new(EngineModel::Quant(&q), &be, None);
        eng.generate(&[1, 2, 3], 4).unwrap();
        let c = eng.metrics.cache.expect("quant engine exposes cache gauges");
        // resident store: everything in RAM, nothing paged
        assert_eq!(c.resident_bytes, q.store.total_nbytes());
        assert_eq!(c.misses, 0);
        assert_eq!(c.evictions, 0);
    }
}
