//! The decode engine: batched, paged-KV, expert-grouped generation.
//!
//! One engine instance now serves for the whole server lifetime (the
//! [`Scheduler`](crate::coordinator::scheduler::Scheduler) steps it from
//! a persistent loop), so [`Metrics`] accumulate across requests: the
//! wall-clock window opens at the first `start()` and `tokens_per_sec`
//! reads the lifetime rate, not the latest drain's.
//!
//! KV lives in one shared [`KvPool`] (paged, refcounted, prefix-shared
//! — see `moe::kv`), and prefill is *chunked*: each engine step feeds
//! up to `prefill_chunk` pending prompt positions per sequence through
//! [`Attention::forward_chunk`](crate::moe::attention::Attention::forward_chunk)
//! and one expert-grouped dispatch over all rows, so prompt ingestion
//! rides the same blocked/fused matmul path as expert execution
//! instead of one row per full engine step.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::backend::ExpertBackend;
use crate::moe::dispatch::{dispatch_moe_layer, DispatchExecutor, DispatchHooks};
use crate::moe::kv::{KvPool, SeqKv, DEFAULT_KV_PAGE};
use crate::moe::model::{ExpertId, MoeModel, Pruner};
use crate::quant::qmodel::QuantModel;
use crate::tensor::{rmsnorm, softmax, Tensor2};
use crate::trace::{SpanKind, Tracer};
use crate::util::rng::Rng;

use super::metrics::Metrics;

/// Default pending prompt positions consumed per sequence per engine
/// step (`--prefill-chunk`). Decoding sequences always contribute one.
pub const DEFAULT_PREFILL_CHUNK: usize = 16;

/// The dense-side weights the engine reads (embedding, norms, attention,
/// gate, lm head): either the fp model or the quantized model's base.
pub enum EngineModel<'a> {
    Fp(&'a MoeModel),
    Quant(&'a QuantModel),
}

impl EngineModel<'_> {
    pub fn model(&self) -> &MoeModel {
        match self {
            EngineModel::Fp(m) => m,
            EngineModel::Quant(q) => &q.model,
        }
    }

    fn routed_expert_bytes(&self, layer: usize, expert: usize) -> u64 {
        match self {
            EngineModel::Fp(m) => {
                (m.blocks[layer].experts[expert].n_params() * 2) as u64
            }
            // store metadata — never faults a paged expert in
            EngineModel::Quant(q) => q.store.expert_nbytes(layer, expert),
        }
    }

    /// Expert-cache gauges when the model serves from a store (always
    /// for quantized models; fp weights live in the model itself).
    pub fn cache_counters(&self) -> Option<crate::quant::store::CacheCounters> {
        match self {
            EngineModel::Fp(_) => None,
            EngineModel::Quant(q) => Some(q.store.counters()),
        }
    }

    /// Remote-fetch gauges when the experts page in over the wire
    /// (`RemoteStore`); `None` for resident/paged local stores.
    pub fn remote_stats(&self) -> Option<crate::quant::RemoteFetchStats> {
        match self {
            EngineModel::Fp(_) => None,
            EngineModel::Quant(q) => q.store.remote_stats(),
        }
    }

    /// Per-RPC demand-fetch wait histogram (µs) when the experts page
    /// in over the wire; empty for fp models and local stores.
    pub fn fetch_histo(&self) -> crate::trace::Histo {
        match self {
            EngineModel::Fp(_) => crate::trace::Histo::default(),
            EngineModel::Quant(q) => q.store.fetch_histo().unwrap_or_default(),
        }
    }
}

/// [`DispatchExecutor`] over the engine's [`ExpertBackend`] — the
/// serving-path adapter (native fused-dequant or PJRT execution), with
/// routed-bytes accounting from the engine's weight store.
struct BackendExec<'s, 'a> {
    em: &'s EngineModel<'a>,
    be: &'s dyn ExpertBackend,
}

impl DispatchExecutor for BackendExec<'_, '_> {
    fn expert_batch_acc(
        &self,
        layer: usize,
        id: ExpertId,
        x: &Tensor2,
        weights: &[f32],
        out: &mut Tensor2,
    ) -> Result<()> {
        let y = match id {
            ExpertId::Routed(e) => self.be.expert_batch(layer, e, x)?,
            ExpertId::Shared(s) => self.be.shared_batch(layer, s, x)?,
        };
        for i in 0..x.rows {
            let w = weights[i];
            for (o, v) in out.row_mut(i).iter_mut().zip(y.row(i)) {
                *o += w * v;
            }
        }
        Ok(())
    }

    fn expert_bytes(&self, layer: usize, id: ExpertId) -> u64 {
        match id {
            ExpertId::Routed(e) => self.em.routed_expert_bytes(layer, e),
            ExpertId::Shared(_) => 0,
        }
    }

    /// Serving-side residency: page the routed set in before the execute
    /// fan-out — but only when the backend actually reads the store at
    /// call time (PJRT executes from pre-staged literals; paging for it
    /// would be I/O nothing consumes).
    fn prepare(&self, layer: usize, routed: &[usize]) -> Result<()> {
        match self.em {
            EngineModel::Quant(q) if self.be.uses_expert_store() => {
                q.store.ensure_resident(layer, routed)
            }
            _ => Ok(()),
        }
    }
}

/// One live sequence: token history + paged per-layer KV page tables.
pub struct SeqState {
    pub id: u64,
    pub tokens: Vec<u16>,
    pub kv: SeqKv,
    /// Number of prompt tokens already prefilled (or adopted from the
    /// prefix tree).
    pub prefilled: usize,
    pub generated: usize,
    pub max_new: usize,
    pub sample: Option<(f32, u64)>,
}

impl SeqState {
    pub fn new(id: u64, prompt: Vec<u16>, max_new: usize, n_layers: usize) -> SeqState {
        SeqState {
            id,
            tokens: prompt,
            kv: SeqKv::new(n_layers),
            prefilled: 0,
            generated: 0,
            max_new,
            sample: None,
        }
    }

    /// Adopt any cached prefix of the prompt from the pool's prefix
    /// tree: the adopted positions are skipped by prefill entirely.
    /// Call once, before the first step.
    pub fn attach_prefix(&mut self, pool: &mut KvPool) {
        debug_assert!(self.kv.is_empty() && self.prefilled == 0);
        self.kv = pool.lookup_prefix(&self.tokens);
        self.prefilled = self.kv.len();
    }

    /// Prompt tokens covered by shared full blocks — already resident,
    /// so the admission token-budget does not charge them.
    pub fn shared_toks(&self) -> usize {
        self.kv.shared_toks()
    }

    pub fn done(&self) -> bool {
        self.generated >= self.max_new
    }
}

/// NaN-safe greedy argmax over logits. Ties keep the last maximum
/// (matching `Iterator::max_by`); NaN logits sort below every finite
/// value instead of panicking the old `partial_cmp().unwrap()` way.
pub fn greedy_argmax(logits: &[f32]) -> u16 {
    fn key(v: f32) -> f32 {
        if v.is_nan() {
            f32::NEG_INFINITY
        } else {
            v
        }
    }
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| key(*a.1).total_cmp(&key(*b.1)))
        .map(|(t, _)| t as u16)
        .unwrap_or(0)
}

pub struct DecodeEngine<'a> {
    pub em: EngineModel<'a>,
    pub backend: &'a dyn ExpertBackend,
    pub pruner: Option<Box<dyn Pruner + 'a>>,
    pub metrics: Metrics,
    /// Span recorder for the engine's timeline (step/phase spans written
    /// here in [`step`](Self::step), request-lifecycle spans written by
    /// the batcher's retire path). Every writer holds the engine lock.
    pub trace: Tracer,
    rng: Rng,
    /// Shared paged KV pool. `Arc` so admission (batcher/scheduler) can
    /// probe/adopt/free without holding the engine lock. Lock order:
    /// (scheduler-inner | engine) → pool; the pool lock is innermost
    /// and never held across another lock acquisition.
    pool: Arc<Mutex<KvPool>>,
    prefill_chunk: usize,
}

impl<'a> DecodeEngine<'a> {
    pub fn new(
        em: EngineModel<'a>,
        backend: &'a dyn ExpertBackend,
        pruner: Option<Box<dyn Pruner + 'a>>,
    ) -> DecodeEngine<'a> {
        let cfg = &em.model().cfg;
        let pool = KvPool::new(DEFAULT_KV_PAGE, cfg.d_model, cfg.n_layers);
        DecodeEngine {
            em,
            backend,
            pruner,
            metrics: Metrics::default(),
            trace: Tracer::new(crate::trace::DEFAULT_RING_CAP),
            rng: Rng::new(0x5EED),
            pool: Arc::new(Mutex::new(pool)),
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
        }
    }

    /// Rebuild the span ring with `cap` entries (`MCSHARP_TRACE_OFF`
    /// is re-read). Call before serving; the old ring is discarded.
    pub fn with_trace_capacity(mut self, cap: usize) -> Self {
        self.trace = Tracer::new(cap);
        self
    }

    /// Rebuild the pool with `page` positions per KV page
    /// (`--kv-page`). Call before any sequence is admitted.
    pub fn with_kv_page(mut self, page: usize) -> Self {
        let cfg = &self.em.model().cfg;
        self.pool = Arc::new(Mutex::new(KvPool::new(page, cfg.d_model, cfg.n_layers)));
        self
    }

    /// Pending prompt positions consumed per sequence per step
    /// (`--prefill-chunk`); 1 reproduces token-at-a-time prefill.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.prefill_chunk = chunk.max(1);
        self
    }

    /// The shared KV pool (admission and retirement paths use this).
    pub fn kv_pool(&self) -> Arc<Mutex<KvPool>> {
        self.pool.clone()
    }

    /// Process up to `prefill_chunk` pending prompt positions for every
    /// sequence in `batch` (decoding sequences contribute exactly one
    /// row), all rows sharing each layer's expert-grouped dispatch.
    /// A sequence whose last prompt position was computed this step
    /// decodes its next token. This is continuous batching at
    /// chunk-step granularity — prefill and decode share engine steps.
    // analyze: hot-path
    pub fn step(&mut self, batch: &mut [&mut SeqState]) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let step_id = self.metrics.steps;
        // RAII step span: two Instant reads + one ring write on drop
        // (end of this function); phase spans nest inside its window
        let mut step_span = self.trace.span(SpanKind::DecodeStep, step_id);
        let step_start = Instant::now();
        // analyze: allow(alloc): Arc refcount bump, not a heap allocation
        let pool_arc = self.pool.clone();
        let mut pool = pool_arc.lock().unwrap();
        let model = self.em.model();
        // analyze: allow(alloc): small config copy taken once per step to
        // end the borrow of `model`; O(1) in batch and model size
        let cfg = model.cfg.clone();
        let h = cfg.d_model;
        let chunk = self.prefill_chunk;
        // row layout: seq i owns rows off[i] .. off[i] + counts[i]
        let counts: Vec<usize> = batch
            .iter()
            .map(|s| {
                debug_assert!(s.prefilled < s.tokens.len());
                (s.tokens.len() - s.prefilled).min(chunk)
            })
            // analyze: allow(alloc): one usize per sequence per step
            .collect();
        // analyze: allow(alloc): one usize per sequence per step
        let mut off = Vec::with_capacity(counts.len());
        let mut total = 0;
        for &c in &counts {
            off.push(total);
            total += c;
        }
        // gather input rows (embeddings of the pending positions)
        let mut x = Tensor2::zeros(total, h);
        for (i, seq) in batch.iter().enumerate() {
            for j in 0..counts[i] {
                let tok = seq.tokens[seq.prefilled + j] as usize;
                x.row_mut(off[i] + j).copy_from_slice(model.embed.row(tok));
            }
        }
        step_span.a = batch.len() as u64;
        step_span.b = total as u64;
        // per-step phase accumulators (µs, summed over layers)
        let (mut route_acc, mut gather_acc, mut exec_acc, mut kv_acc) = (0u64, 0u64, 0u64, 0u64);
        let mut normed = Tensor2::zeros(total, h);
        for (l, block) in model.blocks.iter().enumerate() {
            // attention (per sequence, chunked against the paged pool)
            let t_attn = Instant::now();
            for (i, seq) in batch.iter_mut().enumerate() {
                let (o, c) = (off[i], counts[i]);
                for j in 0..c {
                    rmsnorm(x.row(o + j), &block.attn_norm, normed.row_mut(o + j));
                }
                // analyze: allow(alloc): contiguous per-seq chunk copy
                // for attention, bounded by prefill_chunk x d_model
                let xc = Tensor2::from_vec(c, h, normed.data[o * h..(o + c) * h].to_vec());
                let out = block.attn.forward_chunk(&xc, &mut pool, &mut seq.kv.layers[l]);
                for j in 0..c {
                    let xr = x.row_mut(o + j);
                    for (a, ov) in xr.iter_mut().zip(out.row(j)) {
                        *a += ov;
                    }
                }
            }
            kv_acc += t_attn.elapsed().as_micros() as u64;
            self.trace.record_since(SpanKind::Kv, step_id, t_attn, l as u64, 0);
            // MoE: the shared expert-grouped dispatcher (route + prune +
            // group + execute-once-per-expert + scatter) over all rows —
            // prefill rows ride the same fused token-group kernels
            for r in 0..total {
                rmsnorm(x.row(r), &block.moe_norm, normed.row_mut(r));
            }
            let exec = BackendExec { em: &self.em, be: self.backend };
            let mut hooks = DispatchHooks {
                pruner: self.pruner.as_deref_mut(),
                ..Default::default()
            };
            let t_disp = Instant::now();
            let outcome = dispatch_moe_layer(
                l,
                &block.gate,
                cfg.top_k,
                cfg.n_shared_experts,
                &normed,
                &exec,
                &mut hooks,
                &mut x,
            )?;
            self.metrics.experts_kept += outcome.kept;
            self.metrics.experts_offered += outcome.offered;
            self.metrics.routed_bytes += outcome.routed_bytes;
            route_acc += outcome.route_us;
            gather_acc += outcome.gather_us;
            exec_acc += outcome.execute_us;
            // lay the phases dispatch measured internally out end-to-end
            // inside its window (dispatch runs route → gather → prepare
            // → execute sequentially), so they nest under the step span
            let layer = l as u64;
            let mut sub = 0u64;
            let tr = &self.trace;
            tr.record_offset(SpanKind::Route, step_id, t_disp, sub, outcome.route_us, layer, 0);
            sub += outcome.route_us;
            tr.record_offset(SpanKind::Gather, step_id, t_disp, sub, outcome.gather_us, layer, 0);
            sub += outcome.gather_us;
            if outcome.prepare_us > 0 {
                // expert paging / remote FETCH wait (store `prepare`)
                self.trace.record_offset(
                    SpanKind::Fetch,
                    step_id,
                    t_disp,
                    sub,
                    outcome.prepare_us,
                    layer,
                    0,
                );
            }
            sub += outcome.prepare_us;
            self.trace.record_offset(
                SpanKind::Execute,
                step_id,
                t_disp,
                sub,
                outcome.execute_us,
                layer,
                outcome.kept,
            );
        }
        // head + token transition per sequence
        for (i, seq) in batch.iter_mut().enumerate() {
            let c = counts[i];
            // `tokens.len() - generated` is the prompt length (both grow
            // together on decode), so this spots steps that consumed
            // prompt positions — those get a prefill-chunk span
            if seq.prefilled < seq.tokens.len() - seq.generated {
                self.trace.record_since(
                    SpanKind::PrefillChunk,
                    seq.id,
                    step_start,
                    c as u64,
                    step_id,
                );
            }
            seq.prefilled += c;
            if seq.prefilled < seq.tokens.len() {
                // still prefilling: logits unused
                self.metrics.tokens_in += c as u64;
            } else {
                // the chunk's last row sits at the final prompt (or
                // latest generated) position: decode from it
                self.metrics.tokens_in += (c - 1) as u64;
                let last = off[i] + c - 1;
                rmsnorm(x.row(last), &model.final_norm, normed.row_mut(last));
                let mut logits =
                    crate::moe::attention::mat_vec(&model.lm_head, normed.row(last));
                let next = match seq.sample {
                    None => greedy_argmax(&logits),
                    Some((temp, _)) => {
                        for v in logits.iter_mut() {
                            *v /= temp.max(1e-3);
                        }
                        softmax(&mut logits);
                        self.rng.categorical(&logits) as u16
                    }
                };
                seq.tokens.push(next);
                seq.generated += 1;
                self.metrics.tokens_out += 1;
            }
            // publish completed blocks into the prefix tree (dedups
            // identical chains onto one set of pages)
            pool.register_progress(&mut seq.kv, &seq.tokens);
        }
        self.metrics.steps += 1;
        // per-step phase histograms: O(1) records, bounded memory
        self.metrics.step_route_us.record(route_acc);
        self.metrics.step_execute_us.record(gather_acc + exec_acc);
        self.metrics.step_kv_us.record(kv_acc);
        // refresh the expert-cache + KV gauges (all O(1) reads; the
        // fetch-wait histogram is a fixed-size struct copy)
        self.metrics.cache = self.em.cache_counters();
        self.metrics.remote = self.em.remote_stats();
        self.metrics.fetch_wait_us = self.em.fetch_histo();
        self.metrics.kv = pool.gauges();
        Ok(())
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Run one sequence to completion (used by tests & simple paths).
    /// Adopts any cached prompt prefix and frees the sequence's pages
    /// on the way out.
    pub fn generate(&mut self, prompt: &[u16], max_new: usize) -> Result<Vec<u16>> {
        let n_layers = self.em.model().cfg.n_layers;
        let mut seq = SeqState::new(0, prompt.to_vec(), max_new, n_layers);
        let pool = self.pool.clone();
        seq.attach_prefix(&mut pool.lock().unwrap());
        while !seq.done() {
            let mut batch = [&mut seq];
            self.step(&mut batch)?;
        }
        pool.lock().unwrap().free_seq(&mut seq.kv);
        Ok(seq.tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::moe::model::ForwardOpts;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "eng-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    /// The decode engine (paged-KV, expert-grouped, chunk-prefilled)
    /// must agree with the reference full-sequence forward on greedy
    /// generation.
    #[test]
    fn engine_matches_full_forward_greedy() {
        let m = MoeModel::new(&cfg(), 60);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let prompt = vec![1u16, 17, 30, 45];
        let got = eng.generate(&prompt, 6).unwrap();
        // reference: repeated full-sequence forward + argmax
        let mut want = prompt.clone();
        for _ in 0..6 {
            let logits = m.forward_opts(&want, &mut ForwardOpts::default());
            let next = greedy_argmax(logits.row(logits.rows - 1));
            want.push(next);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn batched_equals_single() {
        let m = MoeModel::new(&cfg(), 61);
        let be = NativeBackend::fp(&m);
        let p1 = vec![1u16, 20, 21];
        let p2 = vec![1u16, 40, 41, 42];
        // single
        let mut e1 = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let a1 = e1.generate(&p1, 4).unwrap();
        let a2 = e1.generate(&p2, 4).unwrap();
        // batched together
        let mut eb = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        let mut s1 = SeqState::new(1, p1.clone(), 4, 2);
        let mut s2 = SeqState::new(2, p2.clone(), 4, 2);
        while !s1.done() || !s2.done() {
            let mut batch: Vec<&mut SeqState> = Vec::new();
            if !s1.done() {
                batch.push(&mut s1);
            }
            if !s2.done() {
                batch.push(&mut s2);
            }
            eb.step(&mut batch).unwrap();
        }
        assert_eq!(s1.tokens, a1);
        assert_eq!(s2.tokens, a2);
    }

    #[test]
    fn metrics_track_activation() {
        let m = MoeModel::new(&cfg(), 62);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        eng.generate(&[1, 2, 3], 5).unwrap();
        assert_eq!(eng.metrics.tokens_out, 5);
        assert_eq!(eng.metrics.tokens_in, 2); // prompt len 3 => 2 prefill tokens
        assert_eq!(eng.metrics.steps, 5, "chunked prefill folds the prompt into step 1");
        assert!(eng.metrics.experts_offered > 0);
        assert_eq!(eng.metrics.experts_kept, eng.metrics.experts_offered);
        assert!(eng.metrics.routed_bytes > 0);
        assert!(eng.metrics.cache.is_none(), "fp model has no expert cache");
        assert!(eng.metrics.kv.kv_pages > 0, "kv gauges published");
        assert!(eng.metrics.kv.kv_bytes > 0);
    }

    #[test]
    fn quant_engine_reports_cache_gauges() {
        use crate::config::PmqConfig;
        use crate::quant::qmodel::QuantMethod;
        let m = MoeModel::new(&cfg(), 63);
        let alloc = vec![vec![2u8; 4]; 2];
        let q = QuantModel::quantize(&m, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
        let be = NativeBackend::quant(&q);
        let mut eng = DecodeEngine::new(EngineModel::Quant(&q), &be, None);
        eng.generate(&[1, 2, 3], 4).unwrap();
        let c = eng.metrics.cache.expect("quant engine exposes cache gauges");
        // resident store: everything in RAM, nothing paged
        assert_eq!(c.resident_bytes, q.store.total_nbytes());
        assert_eq!(c.misses, 0);
        assert_eq!(c.evictions, 0);
    }

    /// Every step records a step span plus per-layer phase spans that
    /// nest inside its window, and the phase histograms fill — the
    /// signal the METRICS scrape and the TRACE dump are built from.
    #[test]
    fn step_records_spans_and_phase_histograms() {
        let m = MoeModel::new(&cfg(), 65);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None);
        eng.generate(&[1, 2, 3], 4).unwrap();
        assert_eq!(eng.metrics.step_route_us.count(), eng.metrics.steps);
        assert_eq!(eng.metrics.step_execute_us.count(), eng.metrics.steps);
        assert_eq!(eng.metrics.step_kv_us.count(), eng.metrics.steps);
        assert_eq!(eng.metrics.fetch_wait_us.count(), 0, "fp model never fetches");
        let spans = eng.trace.snapshot(None);
        let steps =
            spans.iter().filter(|sp| sp.kind == SpanKind::DecodeStep).count() as u64;
        assert_eq!(steps, eng.metrics.steps, "one step span per engine step");
        for kind in [SpanKind::Route, SpanKind::Gather, SpanKind::Execute, SpanKind::Kv] {
            let n = spans.iter().filter(|sp| sp.kind == kind).count() as u64;
            assert_eq!(n, eng.metrics.steps * 2, "{kind:?}: one span per layer per step");
        }
        assert_eq!(
            spans.iter().filter(|sp| sp.kind == SpanKind::PrefillChunk).count(),
            1,
            "the 3-token prompt prefills in one chunk"
        );
        // phase spans lie inside their step span's window (µs rounding)
        let step0 = spans
            .iter()
            .find(|sp| sp.kind == SpanKind::DecodeStep && sp.id == 0)
            .unwrap();
        for sp in spans.iter().filter(|sp| sp.id == 0 && sp.kind == SpanKind::Route) {
            assert!(sp.t_start_us >= step0.t_start_us, "phase starts inside the step");
            assert!(
                sp.t_start_us + sp.dur_us <= step0.t_start_us + step0.dur_us + 2,
                "phase ends inside the step"
            );
        }
    }

    /// Regression: the greedy sampler must not panic on (or select)
    /// NaN logits — the old `partial_cmp().unwrap()` aborted the
    /// engine thread on the first NaN.
    #[test]
    fn greedy_argmax_is_nan_safe() {
        assert_eq!(greedy_argmax(&[0.5, f32::NAN, 2.0, 1.0]), 2);
        assert_eq!(greedy_argmax(&[1.0, 2.0, 2.0]), 2, "ties keep the last, like max_by");
        assert_eq!(greedy_argmax(&[f32::NAN, f32::NAN]), 1, "all-NaN: no panic");
        assert_eq!(greedy_argmax(&[]), 0);
        assert_eq!(greedy_argmax(&[f32::NEG_INFINITY, 3.0, f32::NAN]), 1);
    }

    /// Token-budget view: generate frees its pages, and repeated
    /// identical prompts converge on the tree's shared pages instead
    /// of growing the pool.
    #[test]
    fn generate_releases_kv_pages() {
        let m = MoeModel::new(&cfg(), 64);
        let be = NativeBackend::fp(&m);
        let mut eng = DecodeEngine::new(EngineModel::Fp(&m), &be, None).with_kv_page(4);
        let pool = eng.kv_pool();
        let first = eng.generate(&[1, 2, 3, 4, 5, 6], 4).unwrap();
        let after_first = pool.lock().unwrap().pages_in_use();
        for _ in 0..3 {
            let again = eng.generate(&[1, 2, 3, 4, 5, 6], 4).unwrap();
            assert_eq!(again, first);
            // only tree-held pages survive; repeats re-adopt them
            assert_eq!(pool.lock().unwrap().pages_in_use(), after_first);
        }
        assert!(pool.lock().unwrap().gauges().prefix_hit_toks > 0);
    }
}
