//! Always-on, zero-steady-state-alloc tracing and histogram metrics.
//!
//! Two primitives, both fixed-size after construction:
//!
//! - [`Histo`] — a 64-bucket log2 histogram of `u64` samples (we use it
//!   for microsecond durations). Recording is a shift + two increments;
//!   a percentile read is one cumulative walk over the buckets. It
//!   replaces the unbounded per-request `Vec<u64>` latency vectors the
//!   metrics used to keep (which every `STATS`/`METRICS` scrape had to
//!   clone + sort *under the engine lock*). Percentiles are reported as
//!   the upper bound of the bucket holding the requested rank, so they
//!   agree with the exact (sorted-vector) percentile to within one
//!   log2 bucket — pinned by a unit test below.
//!
//! - [`Tracer`] — a preallocated ring of [`Span`] records covering the
//!   request lifecycle (enqueue → admit → prefill/decode steps →
//!   retire) and the engine-step phase breakdown (route, gather, expert
//!   execute, attention/KV, expert paging/FETCH). Spans are recorded
//!   either through the [`SpanGuard`] RAII timer — whose hot-path cost
//!   is two `Instant` reads and one ring write — or retroactively via
//!   [`Tracer::record_range`] when the start instant was captured
//!   earlier (e.g. a request's submit time lives in the batcher).
//!   The ring has fixed capacity; old spans are overwritten, never
//!   reallocated, so tracing cannot grow the engine's footprint.
//!
//! All writers run under the engine lock (the engine's step body and
//! the batcher's retire path), so the ring needs no lock of its own —
//! a `RefCell` gives the interior mutability that lets several
//! `SpanGuard`s coexist while the engine mutates its other fields.
//!
//! Export paths: the `TRACE` wire command dumps recent spans as JSON
//! lines (one [`Span::to_value`] object per line), and
//! [`write_chrome`] writes the whole snapshot as a Chrome
//! `trace_event`-format file (`mcsharp serve --trace-out t.json`) that
//! opens directly in Perfetto / `chrome://tracing`.
//!
//! Tracing is on by default; setting the `MCSHARP_TRACE_OFF`
//! environment variable (read once, at [`Tracer`] construction — same
//! pattern as `MCSHARP_FORCE_SCALAR`) turns span recording into a
//! no-op so the bench suite can price the overhead.

use std::cell::RefCell;
use std::time::Instant;

use crate::util::json::{num, obj, s, Value};

/// Number of log2 buckets in a [`Histo`]. Bucket 0 holds the value 0;
/// bucket `i` (i ≥ 1) holds values whose bit length is `i`, i.e. the
/// range `[2^(i-1), 2^i - 1]`; the top bucket saturates.
pub const HISTO_BUCKETS: usize = 64;

/// Log2 bucket index for a sample (0 → 0, else its bit length, capped).
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        return 0;
    }
    let bits = (64 - v.leading_zeros()) as usize;
    bits.min(HISTO_BUCKETS - 1)
}

/// Upper bound of a bucket — the conservative value percentile reads
/// report (never below the exact percentile, same bucket).
fn bucket_upper(idx: usize) -> u64 {
    if idx == 0 {
        0
    } else if idx >= HISTO_BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << idx) - 1
    }
}

/// Fixed-bucket log2 histogram: O(1) record, O(buckets) percentile,
/// constant memory. `Copy` so gauge-style snapshots (e.g. the remote
/// store's fetch-wait histogram copied into `Metrics` each step) are a
/// plain struct copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Histo {
    counts: [u64; HISTO_BUCKETS],
    total: u64,
}

impl Default for Histo {
    // [u64; 64] is past the derive limit for Default
    fn default() -> Histo {
        Histo { counts: [0; HISTO_BUCKETS], total: 0 }
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo::default()
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.total += 1;
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// The `p`-quantile (`p` in `[0, 1]`), reported as the upper bound
    /// of the bucket holding that rank. Empty histogram → 0. Matches
    /// the old sorted-vector percentile (`sorted[round((n-1)·p)]`) to
    /// within one log2 bucket.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let p = p.clamp(0.0, 1.0);
        // same rank the sorted-vector read used: round((n-1)·p), 0-based
        let rank = ((self.total - 1) as f64 * p).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_upper(idx);
            }
        }
        bucket_upper(HISTO_BUCKETS - 1)
    }

    pub fn percentiles(&self, ps: &[f64]) -> Vec<u64> {
        ps.iter().map(|&p| self.percentile(p)).collect()
    }
}

/// What a span measures. `name()` is the stable string used in both
/// the JSON-lines dump and the Chrome trace `name` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Whole request lifecycle: submit → retire. `id` = request id,
    /// `a` = prompt tokens, `b` = generated tokens.
    Request,
    /// Submit → admission. `id` = request id, `a` = prompt tokens.
    Queued,
    /// One prefill chunk inside a step. `id` = request id, `a` = chunk
    /// tokens, `b` = step ordinal.
    PrefillChunk,
    /// One engine step over the active batch. `id` = step ordinal,
    /// `a` = batch size, `b` = rows (tokens) processed.
    DecodeStep,
    /// Routing + pruning phase of one MoE layer. `id` = step ordinal,
    /// `a` = layer.
    Route,
    /// Expert-group gather phase. `id` = step ordinal, `a` = layer.
    Gather,
    /// Expert execute phase. `id` = step ordinal, `a` = layer,
    /// `b` = experts kept.
    Execute,
    /// Attention + KV-cache phase. `id` = step ordinal, `a` = layer.
    Kv,
    /// Expert paging / remote FETCH wait (the store `prepare` call).
    /// `id` = step ordinal, `a` = layer.
    Fetch,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Queued => "queued",
            SpanKind::PrefillChunk => "prefill-chunk",
            SpanKind::DecodeStep => "decode-step",
            SpanKind::Route => "route",
            SpanKind::Gather => "gather",
            SpanKind::Execute => "execute",
            SpanKind::Kv => "attn-kv",
            SpanKind::Fetch => "fetch",
        }
    }

    /// Chrome trace category: request-lifecycle spans get their own
    /// per-request track; engine-step spans share the engine track.
    fn is_request_scope(self) -> bool {
        matches!(self, SpanKind::Request | SpanKind::Queued)
    }
}

/// One timed interval. Timestamps are microseconds since the tracer's
/// epoch (engine construction), so every span in a dump shares one
/// clock and nesting is a plain interval-containment check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub kind: SpanKind,
    /// Request id for lifecycle spans, step ordinal for phase spans.
    pub id: u64,
    pub t_start_us: u64,
    pub dur_us: u64,
    /// Kind-specific payload (see [`SpanKind`] docs).
    pub a: u64,
    pub b: u64,
}

impl Span {
    /// The JSON object a `TRACE` response emits per line.
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("kind", s(self.kind.name())),
            ("id", num(self.id as f64)),
            ("t_start_us", num(self.t_start_us as f64)),
            ("dur_us", num(self.dur_us as f64)),
            ("a", num(self.a as f64)),
            ("b", num(self.b as f64)),
        ])
    }
}

/// Fixed-capacity overwrite-oldest span storage. Preallocated at
/// construction; steady-state recording never allocates.
struct SpanRing {
    buf: Vec<Span>,
    cap: usize,
    /// Next write position; wraps.
    head: usize,
    /// Spans currently held (≤ cap).
    len: usize,
}

impl SpanRing {
    fn new(cap: usize) -> SpanRing {
        let cap = cap.max(1);
        SpanRing { buf: Vec::with_capacity(cap), cap, head: 0, len: 0 }
    }

    fn push(&mut self, sp: Span) {
        if self.buf.len() < self.cap {
            self.buf.push(sp);
        } else {
            self.buf[self.head] = sp;
        }
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    /// Retained spans, oldest first, optionally only the last `n`.
    fn snapshot(&self, last: Option<usize>) -> Vec<Span> {
        let take = last.unwrap_or(self.len).min(self.len);
        let newest_end = if self.buf.len() < self.cap { self.buf.len() } else { self.head };
        // oldest retained span sits at `newest_end` once the ring wraps
        let start = (newest_end + self.cap - take) % self.cap.max(1);
        let mut out = Vec::with_capacity(take);
        for i in 0..take {
            out.push(self.buf[(start + i) % self.cap]);
        }
        out
    }
}

/// Default span-ring capacity for an engine: enough for several
/// hundred steps of per-layer phase spans on the test models.
pub const DEFAULT_RING_CAP: usize = 4096;

/// Per-engine span recorder. Owned by the `DecodeEngine`, so every
/// writer already holds the engine lock; the `RefCell` only provides
/// interior mutability (multiple live [`SpanGuard`]s borrow the tracer
/// shared while the engine mutates its own fields).
pub struct Tracer {
    t0: Instant,
    ring: RefCell<SpanRing>,
    enabled: bool,
}

impl Tracer {
    /// Ring of `cap` spans; recording is disabled for the tracer's
    /// lifetime when `MCSHARP_TRACE_OFF` is set in the environment at
    /// construction time.
    pub fn new(cap: usize) -> Tracer {
        Tracer {
            t0: Instant::now(),
            ring: RefCell::new(SpanRing::new(cap)),
            enabled: std::env::var_os("MCSHARP_TRACE_OFF").is_none(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Spans currently retained.
    pub fn len(&self) -> usize {
        self.ring.borrow().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.ring.borrow().cap
    }

    fn rel_us(&self, t: Instant) -> u64 {
        // saturate to the epoch for instants captured before t0
        t.checked_duration_since(self.t0).map_or(0, |d| d.as_micros() as u64)
    }

    /// Start a RAII-timed span: records on drop. Bind it to a *named*
    /// `let` — `let _ = tracer.span(..)` drops immediately and records
    /// a zero-length span (the `trace-guard` analyzer pass flags this).
    #[must_use]
    pub fn span(&self, kind: SpanKind, id: u64) -> SpanGuard<'_> {
        SpanGuard { tracer: self, kind, id, start: Instant::now(), a: 0, b: 0 }
    }

    /// Record a span whose endpoints were captured by the caller —
    /// the retroactive path for instants that live outside the engine
    /// (a request's submit/admit times in the batcher).
    pub fn record_range(
        &self,
        kind: SpanKind,
        id: u64,
        start: Instant,
        end: Instant,
        a: u64,
        b: u64,
    ) {
        if !self.enabled {
            return;
        }
        let t_start_us = self.rel_us(start);
        let dur_us = end.checked_duration_since(start).map_or(0, |d| d.as_micros() as u64);
        self.ring.borrow_mut().push(Span { kind, id, t_start_us, dur_us, a, b });
    }

    /// [`record_range`](Self::record_range) ending now.
    pub fn record_since(&self, kind: SpanKind, id: u64, start: Instant, a: u64, b: u64) {
        self.record_range(kind, id, start, Instant::now(), a, b);
    }

    /// Record a span from an offset + duration pair (µs) inside an
    /// enclosing window that started at `start` — how the engine lays
    /// out the route/gather/fetch/execute sub-phases a dispatch call
    /// measured internally.
    pub fn record_offset(
        &self,
        kind: SpanKind,
        id: u64,
        start: Instant,
        offset_us: u64,
        dur_us: u64,
        a: u64,
        b: u64,
    ) {
        if !self.enabled {
            return;
        }
        let t_start_us = self.rel_us(start) + offset_us;
        self.ring.borrow_mut().push(Span { kind, id, t_start_us, dur_us, a, b });
    }

    /// Retained spans oldest-first, optionally capped to the last `n`.
    pub fn snapshot(&self, last: Option<usize>) -> Vec<Span> {
        self.ring.borrow().snapshot(last)
    }
}

/// RAII span timer from [`Tracer::span`]: one `Instant` read at
/// construction, one at drop, one ring write. Set `a`/`b` on the guard
/// before it drops to attach payload.
pub struct SpanGuard<'t> {
    tracer: &'t Tracer,
    kind: SpanKind,
    id: u64,
    start: Instant,
    pub a: u64,
    pub b: u64,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.tracer.record_range(self.kind, self.id, self.start, Instant::now(), self.a, self.b);
    }
}

/// Render a span snapshot as a Chrome `trace_event`-format JSON value
/// (`{"traceEvents": [...]}`, all complete `"ph":"X"` events) — the
/// format Perfetto and `chrome://tracing` open directly. Lifecycle
/// spans get one track (`tid`) per request; engine-step and phase
/// spans share the engine track, where their intervals nest by
/// containment.
pub fn chrome_value(spans: &[Span]) -> Value {
    let mut events = Vec::with_capacity(spans.len());
    for sp in spans {
        let tid = if sp.kind.is_request_scope() { 2 + sp.id } else { 1 };
        let cat = if sp.kind.is_request_scope() { "request" } else { "engine" };
        events.push(obj(vec![
            ("name", s(sp.kind.name())),
            ("cat", s(cat)),
            ("ph", s("X")),
            ("ts", num(sp.t_start_us as f64)),
            ("dur", num(sp.dur_us as f64)),
            ("pid", num(1.0)),
            ("tid", num(tid as f64)),
            (
                "args",
                obj(vec![
                    ("id", num(sp.id as f64)),
                    ("a", num(sp.a as f64)),
                    ("b", num(sp.b as f64)),
                ]),
            ),
        ]));
    }
    obj(vec![("traceEvents", Value::Arr(events))])
}

/// Write a span snapshot as a Chrome trace_event file (the
/// `mcsharp serve --trace-out` shutdown artifact).
pub fn write_chrome(path: &str, spans: &[Span]) -> std::io::Result<()> {
    std::fs::write(path, chrome_value(spans).to_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- Histo ----

    #[test]
    fn histo_buckets_cover_the_u64_range() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), HISTO_BUCKETS - 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(10), 1023);
    }

    #[test]
    fn histo_percentile_is_bucket_upper_bound_of_the_rank() {
        let mut h = Histo::new();
        for v in [10u64, 20, 30, 40, 100] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        // rank(round((5-1)*0.5)) = 2 → exact 30 → bucket [16,31] → 31
        assert_eq!(h.percentile(0.5), 31);
        // p100 → exact 100 → bucket [64,127] → 127
        assert_eq!(h.percentile(1.0), 127);
        assert_eq!(Histo::new().percentile(0.95), 0, "empty histogram reads 0");
    }

    /// The pinned old-vs-new contract: for any sample set the histogram
    /// percentile and the exact sorted-vector percentile land in the
    /// same log2 bucket (the histogram reports the bucket's upper
    /// bound, so it is never below the exact value).
    #[test]
    fn histo_percentile_agrees_with_exact_within_one_bucket() {
        let samples: Vec<u64> =
            (1..200u64).map(|i| i.wrapping_mul(2_654_435_761) % 50_000).collect();
        let mut h = Histo::new();
        for &v in &samples {
            h.record(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for &p in &[0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let exact = sorted[((sorted.len() - 1) as f64 * p).round() as usize];
            let approx = h.percentile(p);
            assert!(approx >= exact, "p{p}: histo {approx} below exact {exact}");
            assert_eq!(
                bucket_of(approx),
                bucket_of(exact),
                "p{p}: histo {approx} and exact {exact} in different buckets"
            );
        }
    }

    // ---- ring + tracer ----

    #[test]
    fn ring_caps_and_overwrites_oldest() {
        let tr = Tracer::new(4);
        let t = Instant::now();
        for i in 0..7u64 {
            tr.record_range(SpanKind::DecodeStep, i, t, t, 0, 0);
        }
        assert_eq!(tr.len(), 4);
        assert_eq!(tr.capacity(), 4);
        let snap = tr.snapshot(None);
        let ids: Vec<u64> = snap.iter().map(|sp| sp.id).collect();
        assert_eq!(ids, vec![3, 4, 5, 6], "oldest spans overwritten, order kept");
        let last2: Vec<u64> = tr.snapshot(Some(2)).iter().map(|sp| sp.id).collect();
        assert_eq!(last2, vec![5, 6]);
        assert_eq!(tr.snapshot(Some(99)).len(), 4, "last > len clamps");
    }

    #[test]
    fn span_guard_records_on_drop_with_payload() {
        let tr = Tracer::new(8);
        {
            let mut g = tr.span(SpanKind::Execute, 3);
            g.a = 7;
            g.b = 2;
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let snap = tr.snapshot(None);
        assert_eq!(snap.len(), 1);
        let sp = snap[0];
        assert_eq!((sp.kind, sp.id, sp.a, sp.b), (SpanKind::Execute, 3, 7, 2));
        assert!(sp.dur_us >= 1_000, "a ~2ms guard must not read as zero: {}", sp.dur_us);
    }

    #[test]
    fn two_guards_can_coexist_and_nest() {
        let tr = Tracer::new(8);
        {
            let _outer = tr.span(SpanKind::DecodeStep, 0);
            {
                let _inner = tr.span(SpanKind::Route, 0);
            }
            // inner dropped first: one span already in the ring
            assert_eq!(tr.len(), 1);
        }
        let snap = tr.snapshot(None);
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].kind, SpanKind::Route);
        assert_eq!(snap[1].kind, SpanKind::DecodeStep);
        assert!(snap[1].t_start_us <= snap[0].t_start_us, "outer starts first");
    }

    #[test]
    fn span_json_line_and_chrome_export_parse_back() {
        let tr = Tracer::new(8);
        let t = Instant::now();
        tr.record_range(SpanKind::Request, 42, t, t, 3, 5);
        let sp = tr.snapshot(None)[0];
        let line = sp.to_value().to_json();
        let v = Value::parse(&line).expect("span JSON line parses");
        assert_eq!(v.get("kind").unwrap().as_str().unwrap(), "request");
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 42);

        let chrome = chrome_value(&tr.snapshot(None));
        let parsed = Value::parse(&chrome.to_json()).expect("chrome JSON parses");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(events[0].get("name").unwrap().as_str().unwrap(), "request");
        assert_eq!(events[0].get("cat").unwrap().as_str().unwrap(), "request");
    }

    #[test]
    fn record_offset_lays_sub_phases_inside_the_window() {
        let tr = Tracer::new(8);
        let t = Instant::now();
        tr.record_offset(SpanKind::Route, 0, t, 0, 10, 0, 0);
        tr.record_offset(SpanKind::Gather, 0, t, 10, 5, 0, 0);
        let snap = tr.snapshot(None);
        assert_eq!(snap[1].t_start_us, snap[0].t_start_us + 10);
        assert_eq!(snap[1].dur_us, 5);
    }
}
