//! The Eq. 7 integer program, solved exactly.
//!
//! Per MoE block: minimize `Σ_i Σ_j φ_i^α w_i^β (ε_{ij})^γ x_{ij}`
//! subject to `Σ_ij j·x_ij = round(n·b̄)` (exact bit budget),
//! `Σ_j x_ij = 1`, `Σ_i x_{i,3} ≥ 1`, `Σ_i x_{i,2} ≥ 1`, `x ∈ {0,1}`.
//!
//! With bit options {1,2,3} the state space is tiny, so we solve by
//! dynamic programming over (expert prefix, bits used, has-3-bit,
//! has-2-bit) — provably optimal; a brute-force cross-check lives in the
//! tests (`prop` sweep, E ≤ 8).

/// One block's allocation problem: `cost[i][j]` for expert `i` at
/// `bit_options[j]` bits.
pub struct AllocProblem {
    pub cost: Vec<Vec<f64>>,
    pub bit_options: Vec<u8>,
    /// Exact total bit budget for the block (`round(n * avg_bits)`).
    pub budget: usize,
    /// Enforce the paper's ≥1-expert-at-3-bit / ≥1-at-2-bit constraints.
    pub anchor_constraints: bool,
}

const INF: f64 = f64::INFINITY;

/// Solve one block. Returns per-expert bit-widths, or `None` if the
/// budget is infeasible.
pub fn solve_block(p: &AllocProblem) -> Option<Vec<u8>> {
    let n = p.cost.len();
    let m = p.bit_options.len();
    let maxb = p.budget;
    let flags = if p.anchor_constraints { 4 } else { 1 };
    // dp[b][flag] after processing experts 0..e; flag bit0 = has max-bit
    // anchor, bit1 = has second-bit anchor. Indices into bit_options that
    // anchor: highest option and second-highest option.
    let hi_idx = m - 1;
    let lo_idx = m.saturating_sub(2);
    let idx = |b: usize, f: usize| b * flags + f;
    let flag_of = |j: usize, f: usize| -> usize {
        if !p.anchor_constraints {
            return 0;
        }
        let mut nf = f;
        if j == hi_idx {
            nf |= 1;
        }
        if j == lo_idx {
            nf |= 2;
        }
        nf
    };
    // dp[e] = cost table after assigning experts 0..e
    let mut dp: Vec<Vec<f64>> = Vec::with_capacity(n + 1);
    let mut first = vec![INF; (maxb + 1) * flags];
    first[idx(0, 0)] = 0.0;
    dp.push(first);
    for e in 0..n {
        let mut next = vec![INF; (maxb + 1) * flags];
        for b in 0..=maxb {
            for f in 0..flags {
                let cur = dp[e][idx(b, f)];
                if cur == INF {
                    continue;
                }
                for (j, &bits) in p.bit_options.iter().enumerate() {
                    let nb = b + bits as usize;
                    if nb > maxb {
                        continue;
                    }
                    let nf = flag_of(j, f);
                    let c = cur + p.cost[e][j];
                    if c < next[idx(nb, nf)] {
                        next[idx(nb, nf)] = c;
                    }
                }
            }
        }
        dp.push(next);
    }
    let goal_flag = if p.anchor_constraints { 3 } else { 0 };
    let mut best: Option<(f64, usize)> = None;
    for f in 0..flags {
        if f & goal_flag == goal_flag && dp[n][idx(maxb, f)] < INF {
            let v = dp[n][idx(maxb, f)];
            if best.map_or(true, |(bv, _)| v < bv) {
                best = Some((v, f));
            }
        }
    }
    // Constraints can be infeasible for tiny n or extreme budgets — the
    // paper's fallback is to drop the anchors.
    let (_, mut f) = match best {
        Some(b) => b,
        None if p.anchor_constraints => {
            return solve_block(&AllocProblem {
                cost: p.cost.clone(),
                bit_options: p.bit_options.clone(),
                budget: p.budget,
                anchor_constraints: false,
            })
        }
        None => return None,
    };
    // exact backtrack: find (j, predecessor flag) reproducing dp[e+1]
    let mut b = maxb;
    let mut out = vec![0u8; n];
    for e in (0..n).rev() {
        let target = dp[e + 1][idx(b, f)];
        let mut found = false;
        'search: for (j, &bits) in p.bit_options.iter().enumerate() {
            if (bits as usize) > b {
                continue;
            }
            let pb = b - bits as usize;
            for pf in 0..flags {
                if flag_of(j, pf) != f {
                    continue;
                }
                let prev = dp[e][idx(pb, pf)];
                if prev < INF && (prev + p.cost[e][j] - target).abs() <= 1e-12 * (1.0 + target.abs()) {
                    out[e] = bits;
                    b = pb;
                    f = pf;
                    found = true;
                    break 'search;
                }
            }
        }
        debug_assert!(found, "backtrack failed at expert {e}");
        if !found {
            return None;
        }
    }
    Some(out)
}

/// Solve every MoE block of a model for a target average expert
/// bit-width. `costs[layer][expert][bit_idx]`.
pub fn allocate_bits(
    costs: &[Vec<Vec<f64>>],
    bit_options: &[u8],
    avg_bits: f64,
    anchors: bool,
) -> Vec<Vec<u8>> {
    costs
        .iter()
        .map(|block| {
            let n = block.len();
            let budget = (avg_bits * n as f64).round() as usize;
            let lo = bit_options[0] as usize * n;
            let hi = *bit_options.last().unwrap() as usize * n;
            let budget = budget.clamp(lo, hi);
            solve_block(&AllocProblem {
                cost: block.clone(),
                bit_options: bit_options.to_vec(),
                budget,
                anchor_constraints: anchors,
            })
            .expect("clamped budget must be feasible")
        })
        .collect()
}

/// Brute-force optimum (tests only, m^n enumeration).
pub fn brute_force(p: &AllocProblem) -> Option<(f64, Vec<u8>)> {
    let n = p.cost.len();
    let m = p.bit_options.len();
    let mut best: Option<(f64, Vec<u8>)> = None;
    let mut assign = vec![0usize; n];
    loop {
        let bits: usize = assign.iter().map(|&j| p.bit_options[j] as usize).sum();
        if bits == p.budget {
            let ok = !p.anchor_constraints
                || (assign.iter().any(|&j| j == m - 1)
                    && assign.iter().any(|&j| j == m.saturating_sub(2)));
            if ok {
                let c: f64 = assign.iter().enumerate().map(|(e, &j)| p.cost[e][j]).sum();
                if best.as_ref().map_or(true, |(bc, _)| c < *bc) {
                    best = Some((c, assign.iter().map(|&j| p.bit_options[j]).collect()));
                }
            }
        }
        // increment odometer
        let mut i = 0;
        loop {
            if i == n {
                return best;
            }
            assign[i] += 1;
            if assign[i] < m {
                break;
            }
            assign[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn random_problem(rng: &mut crate::util::rng::Rng, n: usize, anchors: bool) -> AllocProblem {
        let cost: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                // monotone-decreasing cost in bits, like real ε tables
                let base = rng.f64() + 0.05;
                vec![base, base * (0.2 + 0.5 * rng.f64()), base * 0.1 * rng.f64()]
            })
            .collect();
        let budget = n + rng.below(2 * n + 1); // within [n, 3n]
        AllocProblem { cost, bit_options: vec![1, 2, 3], budget, anchor_constraints: anchors }
    }

    #[test]
    fn dp_matches_brute_force() {
        prop::for_all(91, 40, |rng, case| {
            let n = 2 + rng.below(6);
            let p = random_problem(rng, n, case % 2 == 0);
            let dp = solve_block(&p);
            let bf = brute_force(&p);
            match (dp, bf) {
                (Some(d), Some((bc, _))) => {
                    let dc: f64 = d
                        .iter()
                        .enumerate()
                        .map(|(e, &b)| {
                            let j = p.bit_options.iter().position(|&x| x == b).unwrap();
                            p.cost[e][j]
                        })
                        .sum();
                    let bits: usize = d.iter().map(|&b| b as usize).sum();
                    assert_eq!(bits, p.budget, "budget violated");
                    // dp may legitimately fall back to anchor-free if bf
                    // found an anchored solution — then dp cost must be ≤
                    assert!(dc <= bc + 1e-9, "dp {dc} worse than brute force {bc}");
                }
                (None, Some(_)) => panic!("dp missed a feasible solution"),
                _ => {}
            }
        });
    }

    #[test]
    fn anchors_respected_when_feasible() {
        let mut rng = crate::util::rng::Rng::new(92);
        for _ in 0..20 {
            let n = 4 + rng.below(4);
            let mut p = random_problem(&mut rng, n, true);
            p.budget = 2 * n; // avg 2-bit: plenty of room for anchors
            let sol = solve_block(&p).unwrap();
            assert!(sol.contains(&3), "no 3-bit anchor: {sol:?}");
            assert!(sol.contains(&2), "no 2-bit anchor: {sol:?}");
        }
    }

    #[test]
    fn important_experts_get_more_bits() {
        // expert 0 hugely sensitive, expert 3 insensitive
        let cost = vec![
            vec![100.0, 10.0, 0.1],
            vec![1.0, 0.3, 0.1],
            vec![1.0, 0.3, 0.1],
            vec![0.01, 0.005, 0.001],
        ];
        let p = AllocProblem { cost, bit_options: vec![1, 2, 3], budget: 8, anchor_constraints: false };
        let sol = solve_block(&p).unwrap();
        assert_eq!(sol[0], 3, "{sol:?}");
        assert_eq!(sol[3], 1, "{sol:?}");
    }

    #[test]
    fn allocate_bits_hits_average() {
        let costs = vec![vec![vec![1.0, 0.5, 0.1]; 8]; 3];
        let alloc = allocate_bits(&costs, &[1, 2, 3], 2.0, true);
        for block in &alloc {
            let sum: usize = block.iter().map(|&b| b as usize).sum();
            assert_eq!(sum, 16);
        }
    }

    #[test]
    fn infeasible_budget_none() {
        let p = AllocProblem {
            cost: vec![vec![1.0, 0.5, 0.1]; 3],
            bit_options: vec![1, 2, 3],
            budget: 100,
            anchor_constraints: false,
        };
        assert!(solve_block(&p).is_none());
    }
}
