//! PMQ — Pre-Loading Mixed-Precision Quantization (paper §3.2).
//!
//! Pipeline: [`importance::calibrate`] runs the 16-bit model over a
//! calibration set collecting routing statistics, per-layer MoE inputs
//! and GPTQ Hessians → [`eps_table`](crate::quant::error::eps_table) builds the Eq. 6
//! sensitivity table → [`allocate::allocate_bits`] solves the Eq. 7
//! integer program per MoE block → `quant::QuantModel::quantize` packs
//! the experts. [`strategies`] implements every allocation baseline the
//! paper compares against (uniform / random / weights / frequency /
//! F-norm / Hessian / BSP-like).

pub mod allocate;
pub mod importance;
pub mod strategies;

pub use allocate::{allocate_bits, AllocProblem};
pub use importance::{calibrate, Calibration};
pub use strategies::Strategy;
