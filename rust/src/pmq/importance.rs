//! Calibration pass: everything PMQ needs from data, in one forward sweep
//! (paper §3.2.1–3.2.2).

use crate::moe::gating::route;
use crate::moe::model::{ForwardOpts, MoeModel};
use crate::moe::stats::RoutingStats;
use crate::quant::error::LayerActivations;
use crate::quant::gptq::GptqQuantizer;
use crate::tensor::silu;

/// Everything the allocators and quantizers consume.
pub struct Calibration {
    pub stats: RoutingStats,
    /// Per-layer MoE-input token rows.
    pub acts: Vec<LayerActivations>,
    /// Per-layer (d_model-input, d_ff-input) GPTQ Hessian accumulators —
    /// shared across the layer's experts (documented approximation: the
    /// d_ff Hessian pools the post-SwiGLU activations of all routed
    /// experts in the layer).
    pub hessians: Vec<(GptqQuantizer, GptqQuantizer)>,
}

impl Calibration {
    /// φ_i^α · w_i^β significance factor (paper §3.2.2).
    pub fn significance(&self, layer: usize, expert: usize, alpha: f64, beta: f64) -> f64 {
        let phi = self.stats.frequency(layer, expert);
        let w = self.stats.mean_weight(layer, expert);
        phi.powf(alpha) * w.powf(beta)
    }
}

/// Run `seqs` through the model, collecting stats + activations + Hessians.
///
/// `max_tokens_per_layer` caps the retained activation rows (reservoir of
/// the first N — calibration order is already randomized upstream).
pub fn calibrate(model: &MoeModel, seqs: &[Vec<u16>], max_tokens_per_layer: usize) -> Calibration {
    let cfg = &model.cfg;
    let mut stats = RoutingStats::new(cfg.n_layers, cfg.n_experts);
    let mut captured: Vec<Vec<Vec<f32>>> = vec![Vec::new(); cfg.n_layers];
    for seq in seqs {
        let mut opts = ForwardOpts {
            stats: Some(&mut stats),
            capture_moe_inputs: Some(&mut captured),
            ..Default::default()
        };
        model.forward_opts(seq, &mut opts);
    }
    for layer in captured.iter_mut() {
        layer.truncate(max_tokens_per_layer);
    }
    // Hessians from the captured activations
    let mut hessians: Vec<(GptqQuantizer, GptqQuantizer)> = (0..cfg.n_layers)
        .map(|_| (GptqQuantizer::new(cfg.d_model), GptqQuantizer::new(cfg.d_ff)))
        .collect();
    for (l, block) in model.blocks.iter().enumerate() {
        for x in &captured[l] {
            hessians[l].0.add_sample(x);
            let r = route(x, &block.gate, cfg.top_k);
            for &e in &r.experts {
                let expert = &block.experts[e];
                let f = cfg.d_ff;
                let mut g = vec![0.0f32; f];
                let mut u = vec![0.0f32; f];
                for (k, &xk) in x.iter().enumerate() {
                    if xk != 0.0 {
                        crate::tensor::axpy(xk, expert.wg.row(k), &mut g);
                        crate::tensor::axpy(xk, expert.wu.row(k), &mut u);
                    }
                }
                for j in 0..f {
                    g[j] = silu(g[j]) * u[j];
                }
                hessians[l].1.add_sample(&g);
            }
        }
    }
    Calibration {
        stats,
        acts: captured.into_iter().map(|xs| LayerActivations { xs }).collect(),
        hessians,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{Corpus, CorpusKind};
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "calib-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    #[test]
    fn calibration_collects_everything() {
        let model = MoeModel::new(&cfg(), 13);
        let corpus = Corpus::new(CorpusKind::General, 2);
        let mut rng = Rng::new(3);
        let seqs = corpus.batch(4, 24, &mut rng);
        let cal = calibrate(&model, &seqs, 64);
        assert_eq!(cal.stats.tokens, 4 * 24);
        assert_eq!(cal.acts.len(), 2);
        assert_eq!(cal.acts[0].xs.len(), 64);
        assert!(cal.hessians[0].0.n_samples > 0);
        assert!(cal.hessians[0].1.n_samples > 0);
        // frequencies sum to top_k per layer
        let fsum: f64 = (0..4).map(|e| cal.stats.frequency(0, e)).sum();
        assert!((fsum - 2.0).abs() < 1e-9);
    }

    #[test]
    fn significance_monotone_in_factors() {
        let model = MoeModel::new(&cfg(), 14);
        let corpus = Corpus::new(CorpusKind::General, 2);
        let mut rng = Rng::new(4);
        let seqs = corpus.batch(4, 24, &mut rng);
        let cal = calibrate(&model, &seqs, 64);
        // find two experts with different frequency; higher φ ⇒ higher
        // significance at β=0
        let mut freqs: Vec<(usize, f64)> =
            (0..4).map(|e| (e, cal.stats.frequency(0, e))).collect();
        freqs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let (lo, hi) = (freqs[0], freqs[3]);
        if hi.1 > lo.1 {
            assert!(cal.significance(0, hi.0, 1.0, 0.0) > cal.significance(0, lo.0, 1.0, 0.0));
        }
    }
}
