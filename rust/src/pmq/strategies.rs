//! Every bit-allocation strategy the paper compares (Figs. 9–12, Tables
//! 2/4/7): the full PMQ objective plus uniform, random, routing-weight-
//! only, frequency-only, F-norm-only, Hessian(HAWQ-trace)-style, and the
//! BSP-like layer-granularity baseline.

use crate::config::PmqConfig;
use crate::moe::model::MoeModel;
use crate::quant::error::EpsTable;
use crate::quant::{binary::BinaryMatrix, packed::PackedMatrix, rtn};
use crate::tensor::Tensor2;
use crate::util::rng::Rng;

use super::allocate::allocate_bits;
use super::importance::Calibration;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Full PMQ objective: φ^α w^β ε^γ through the integer program.
    Pmq,
    /// Uniform bit-width for every expert.
    Uniform,
    /// Random feasible allocation (Pareto "others", Figs. 11/12).
    Random,
    /// Routing-weight significance only.
    WeightsOnly,
    /// Activation-frequency significance only.
    FrequencyOnly,
    /// Quantization F-norm error only (no routing factors).
    FNorm,
    /// HAWQ-style: Tr(H) · ‖ΔW‖² sensitivity.
    Hessian,
    /// BSP-like layer-granularity mix: top-¼ layers 3-bit, rest filled to
    /// budget at layer granularity (the ref.-\[6\] baseline in Table 2).
    BspLike,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Pmq => "PMQ",
            Strategy::Uniform => "Uni",
            Strategy::Random => "Random",
            Strategy::WeightsOnly => "Weights",
            Strategy::FrequencyOnly => "Frequency",
            Strategy::FNorm => "F-norm",
            Strategy::Hessian => "Hessian",
            Strategy::BspLike => "BSP",
        }
    }

    pub const ALL: [Strategy; 8] = [
        Strategy::Pmq,
        Strategy::Uniform,
        Strategy::Random,
        Strategy::WeightsOnly,
        Strategy::FrequencyOnly,
        Strategy::FNorm,
        Strategy::Hessian,
        Strategy::BspLike,
    ];
}

/// HAWQ-style sensitivity: mean Hessian diagonal (input second moment)
/// times the squared weight perturbation at each bit-width.
fn hessian_costs(model: &MoeModel, cal: &Calibration, pmq: &PmqConfig) -> Vec<Vec<Vec<f64>>> {
    let cfg = &model.cfg;
    let mut costs = Vec::new();
    for (l, block) in model.blocks.iter().enumerate() {
        let trace_h = cal.hessians[l].0.mean_diag();
        let trace_f = cal.hessians[l].1.mean_diag();
        let mut row = Vec::new();
        for e in &block.experts {
            let mut per_bit = Vec::new();
            for &bits in &pmq.bit_options {
                let dw = |w: &Tensor2, tr: f64| -> f64 {
                    let w_hat = match bits {
                        1 => BinaryMatrix::binarize(w).dequantize(),
                        b => {
                            let (c, s, z) = rtn::quantize_rtn(w, b, pmq.group);
                            PackedMatrix::from_codes(&c, s, z, w.rows, w.cols, b, pmq.group)
                                .dequantize()
                        }
                    };
                    tr * w
                        .data
                        .iter()
                        .zip(&w_hat.data)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum::<f64>()
                };
                per_bit.push(dw(&e.wg, trace_h) + dw(&e.wu, trace_h) + dw(&e.wd, trace_f));
            }
            row.push(per_bit);
        }
        costs.push(row);
        let _ = cfg;
    }
    costs
}

/// Build `[layer][expert][bit]` costs for a strategy, then solve for the
/// target average expert bit-width. ε must come from
/// `quant::error::eps_table` on the same calibration set.
pub fn allocation(
    strategy: Strategy,
    model: &MoeModel,
    cal: &Calibration,
    eps: &EpsTable,
    pmq: &PmqConfig,
    avg_bits: f64,
    rng: &mut Rng,
) -> Vec<Vec<u8>> {
    let cfg = &model.cfg;
    let n = cfg.n_experts;
    match strategy {
        Strategy::Uniform => {
            let b = avg_bits.round().clamp(1.0, 3.0) as u8;
            vec![vec![b; n]; cfg.n_layers]
        }
        Strategy::Random => (0..cfg.n_layers)
            .map(|_| random_feasible(n, avg_bits, &pmq.bit_options, rng))
            .collect(),
        Strategy::BspLike => bsp_allocation(model, cal, eps, avg_bits),
        Strategy::Hessian => {
            let costs = hessian_costs(model, cal, pmq);
            allocate_bits(&costs, &pmq.bit_options, avg_bits, false)
        }
        _ => {
            // score-weighted ε costs through the same IP solver
            let mut costs = vec![vec![vec![0.0f64; pmq.bit_options.len()]; n]; cfg.n_layers];
            for l in 0..cfg.n_layers {
                for e in 0..n {
                    let sig = match strategy {
                        Strategy::Pmq => {
                            cal.significance(l, e, pmq.alpha, pmq.beta).max(1e-8)
                        }
                        Strategy::WeightsOnly => cal.stats.mean_weight(l, e).max(1e-8),
                        Strategy::FrequencyOnly => cal.stats.frequency(l, e).max(1e-8),
                        Strategy::FNorm => 1.0,
                        _ => unreachable!(),
                    };
                    for (bi, _) in pmq.bit_options.iter().enumerate() {
                        let e_term = eps[l][e][bi].powf(pmq.gamma);
                        costs[l][e][bi] = sig * e_term;
                    }
                }
            }
            allocate_bits(&costs, &pmq.bit_options, avg_bits, strategy == Strategy::Pmq)
        }
    }
}

/// Random allocation meeting the exact per-block budget.
pub fn random_feasible(n: usize, avg_bits: f64, options: &[u8], rng: &mut Rng) -> Vec<u8> {
    let lo = options[0] as usize;
    let hi = *options.last().unwrap() as usize;
    let budget = ((avg_bits * n as f64).round() as usize).clamp(lo * n, hi * n);
    let mut alloc = vec![options[0]; n];
    let mut total = lo * n;
    // greedily bump random experts until budget is met
    while total < budget {
        let i = rng.below(n);
        let cur = alloc[i];
        if let Some(&next) = options.iter().find(|&&o| o > cur) {
            let delta = (next - cur) as usize;
            if total + delta <= budget {
                alloc[i] = next;
                total += delta;
            } else if budget - total >= 1 && options.contains(&(cur + 1)) {
                alloc[i] = cur + 1;
                total += 1;
            }
        }
        // tiny chance of stalls when only +2 jumps remain; resolve by +1s
        if options.contains(&2) && total < budget && alloc.iter().all(|&b| b as usize >= hi - 1)
        {
            for a in alloc.iter_mut() {
                if total == budget {
                    break;
                }
                if (*a as usize) < hi {
                    *a += 1;
                    total += 1;
                }
            }
        }
    }
    alloc
}

/// BSP-like: layer-granularity allocation. Rank layers by mean ε at
/// 2-bit; the most sensitive quarter gets the max bit option, the rest
/// get a uniform width chosen to land on the global budget.
fn bsp_allocation(
    model: &MoeModel,
    _cal: &Calibration,
    eps: &EpsTable,
    avg_bits: f64,
) -> Vec<Vec<u8>> {
    let cfg = &model.cfg;
    let l = cfg.n_layers;
    let n = cfg.n_experts;
    let mut sens: Vec<(usize, f64)> = (0..l)
        .map(|li| (li, (0..n).map(|e| eps[li][e][1]).sum::<f64>()))
        .collect();
    sens.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let n_hi = (l as f64 * 0.25).ceil() as usize;
    let hi_layers: Vec<usize> = sens[..n_hi].iter().map(|&(i, _)| i).collect();
    // remaining layers uniform: solve for the width meeting the budget
    let total_budget = (avg_bits * (l * n) as f64).round() as usize;
    let hi_bits = 3usize * n_hi * n;
    let rest_layers = l - n_hi;
    let per_rest = if rest_layers == 0 {
        2.0
    } else {
        (total_budget.saturating_sub(hi_bits)) as f64 / (rest_layers * n) as f64
    };
    let rest_b = per_rest.round().clamp(1.0, 3.0) as u8;
    (0..l)
        .map(|li| {
            if hi_layers.contains(&li) {
                vec![3u8; n]
            } else {
                vec![rest_b; n]
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{Corpus, CorpusKind};
    use crate::pmq::importance::calibrate;
    use crate::quant::error::eps_table;

    fn setup() -> (MoeModel, Calibration, EpsTable, PmqConfig) {
        let cfg = ModelConfig {
            name: "strat-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let model = MoeModel::new(&cfg, 15);
        let corpus = Corpus::new(CorpusKind::General, 5);
        let mut rng = Rng::new(6);
        let seqs = corpus.batch(4, 24, &mut rng);
        let cal = calibrate(&model, &seqs, 48);
        let pmq = PmqConfig::default();
        let eps = eps_table(&model, &cal.acts, &pmq);
        (model, cal, eps, pmq)
    }

    #[test]
    fn all_strategies_meet_budget() {
        let (model, cal, eps, pmq) = setup();
        let mut rng = Rng::new(7);
        for s in Strategy::ALL {
            for &avg in &[1.5f64, 2.0, 2.5] {
                let alloc = allocation(s, &model, &cal, &eps, &pmq, avg, &mut rng);
                assert_eq!(alloc.len(), 2);
                let total: usize = alloc.iter().flatten().map(|&b| b as usize).sum();
                let target = (avg * 8.0).round() as usize;
                // uniform & BSP quantize at coarser granularity — allow slack
                let slack = match s {
                    Strategy::Uniform | Strategy::BspLike => 8,
                    _ => 0,
                };
                assert!(
                    (total as i64 - target as i64).unsigned_abs() as usize <= slack,
                    "{s:?} avg {avg}: total {total} target {target}"
                );
                for &b in alloc.iter().flatten() {
                    assert!((1..=3).contains(&b), "{s:?} produced bit {b}");
                }
            }
        }
    }

    #[test]
    fn random_feasible_exact() {
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            let n = 4 + rng.below(12);
            let avg = 1.5 + rng.f64();
            let a = random_feasible(n, avg, &[1, 2, 3], &mut rng);
            let total: usize = a.iter().map(|&b| b as usize).sum();
            assert_eq!(total, (avg * n as f64).round() as usize);
        }
    }

    #[test]
    fn pmq_assigns_more_bits_to_significant_experts_on_average() {
        let (model, cal, eps, pmq) = setup();
        let mut rng = Rng::new(9);
        let alloc = allocation(Strategy::Pmq, &model, &cal, &eps, &pmq, 2.0, &mut rng);
        // correlation between significance*eps and bits should be ≥ 0
        let mut pairs = Vec::new();
        for l in 0..2 {
            for e in 0..4 {
                let sig = cal.significance(l, e, pmq.alpha, pmq.beta) * eps[l][e][1];
                pairs.push((sig, alloc[l][e] as f64));
            }
        }
        let mean_s: f64 = pairs.iter().map(|p| p.0).sum::<f64>() / pairs.len() as f64;
        let mean_b: f64 = pairs.iter().map(|p| p.1).sum::<f64>() / pairs.len() as f64;
        let cov: f64 = pairs.iter().map(|p| (p.0 - mean_s) * (p.1 - mean_b)).sum();
        assert!(cov >= 0.0, "PMQ anti-correlated with significance: {cov}");
    }
}
