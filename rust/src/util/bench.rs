//! Hand-rolled bench harness (criterion is not available offline).
//!
//! Used by every `rust/benches/*.rs` target (declared with
//! `harness = false`): adaptive iteration count, warmup, and robust
//! statistics (mean / p50 / p95 / min), plus Markdown-style table
//! printers that the paper-table benches share so `cargo bench` output
//! lines up with the paper's rows.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Time `f` adaptively: warm up, then run until `budget` elapses or
/// `max_iters` samples are collected (at least 5).
pub fn time<F: FnMut()>(budget: Duration, max_iters: usize, mut f: F) -> Stats {
    // warmup
    for _ in 0..2 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 5 || (start.elapsed() < budget && samples.len() < max_iters) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= max_iters {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats {
        iters: samples.len(),
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_ns: percentile(&samples, 0.5),
        p95_ns: percentile(&samples, 0.95),
        min_ns: samples[0],
    }
}

/// One-line report in criterion-ish style.
pub fn report(name: &str, s: &Stats) {
    println!(
        "{name:<44} time: [{:>10.3} ms  p50 {:>10.3} ms  p95 {:>10.3} ms]  ({} iters)",
        s.mean_ms(),
        s.p50_ns / 1e6,
        s.p95_ns / 1e6,
        s.iters
    );
}

/// Markdown-style table printer used by the paper-table benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Format an f64 with fixed decimals (bench tables).
pub fn fmt(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_counts_and_orders() {
        let s = time(Duration::from_millis(20), 50, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p95_ns);
    }

    #[test]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    #[should_panic]
    fn table_wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
