//! Minimal JSON parser + serializer (crates.io is offline here, so no
//! serde). Covers the full JSON grammar; used for model configs, the AOT
//! artifact manifest, checkpoints metadata, and experiment result dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects preserve deterministic (sorted) key order.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &str) -> Result<Value> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        Value::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
    }

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result dumps.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // handle UTF-8 continuation bytes transparently
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_config_like() {
        let v = Value::parse(r#"{"name":"mix-tiny","d_model":128,"buckets":[4,16,64],"x":null,"ok":true}"#).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "mix-tiny");
        assert_eq!(v.get("d_model").unwrap().as_usize().unwrap(), 128);
        assert_eq!(v.get("buckets").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("x").unwrap(), &Value::Null);
        assert_eq!(v.get("ok").unwrap(), &Value::Bool(true));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3e-2],"b":{"c":"hi\nthere","d":[]},"e":false}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = Value::parse(r#""aA\t\"\\ end""#).unwrap();
        assert_eq!(v, Value::Str("aA\t\"\\ end".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v, Value::Str("héllo → 世界".into()));
        assert_eq!(Value::parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn nested_depth() {
        let src = "[".repeat(50) + &"]".repeat(50);
        assert!(Value::parse(&src).is_ok());
    }
}
