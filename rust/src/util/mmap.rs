//! Read-only file memory-mapping without a libc dependency: the offline
//! toolchain has no `libc`/`memmap` crate, so the `mmap`/`munmap`
//! syscalls are issued directly (x86_64 linux only, the deployment
//! target) and every other platform transparently falls back to reading
//! the file into an owned buffer.
//!
//! Why it exists: the v2 qcheckpoint's per-expert seek index turns the
//! checkpoint into a random-access record database. Mapping it means a
//! paged/shard record read is a slice copy out of the page cache instead
//! of a seek+read syscall pair, the dense base can be decoded straight
//! from the map, and — the part that matters for footprint — bytes
//! nothing touches (e.g. the dense base in `mcsharp shard` mode, expert
//! records outside the residency budget) are never resident at all.

use anyhow::{bail, Context, Result};

/// A read-only view of a whole file: an OS mapping when the platform
/// supports our raw-syscall path, an owned heap copy otherwise. Either
/// way [`as_slice`](Mmap::as_slice) is the entire file.
pub struct Mmap {
    ptr: *const u8,
    len: usize,
    /// Fallback storage when the file could not be mapped; `ptr` points
    /// into it (or is dangling for empty files).
    owned: Option<Vec<u8>>,
}

// SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) for its
// whole lifetime and owned buffers move with the struct, so sending the
// view to another thread cannot observe a mutation or a dangling ptr.
unsafe impl Send for Mmap {}
// SAFETY: same invariant as Send — the bytes behind `ptr` never change
// after construction, so concurrent shared reads are safe.
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only, falling back to a heap read when mapping is
    /// unavailable (non-linux/x86_64, empty file, or a refused syscall).
    pub fn open(path: &str) -> Result<Mmap> {
        let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
        let len = f.metadata()?.len();
        if len > usize::MAX as u64 {
            bail!("{path}: file too large to map");
        }
        let len = len as usize;
        if len > 0 {
            if let Some(ptr) = sys::map_readonly(&f, len) {
                return Ok(Mmap { ptr, len, owned: None });
            }
        }
        // fallback: plain read (also the empty-file path — zero-length
        // mmap is EINVAL)
        let buf = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        let ptr = buf.as_ptr();
        Ok(Mmap { ptr, len: buf.len(), owned: Some(buf) })
    }

    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len cover either a live PROT_READ mapping (unmapped
        // only in Drop) or the owned buffer held alive by `self`.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether this view is a real OS mapping (false = heap fallback).
    pub fn is_mapped(&self) -> bool {
        self.owned.is_none()
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        if self.owned.is_none() && self.len > 0 {
            sys::unmap(self.ptr, self.len);
        }
    }
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod sys {
    //! Raw x86_64 linux syscalls — no libc in the vendored toolchain.
    use std::os::unix::io::AsRawFd;

    const SYS_MMAP: usize = 9;
    const SYS_MUNMAP: usize = 11;
    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;

    pub fn map_readonly(f: &std::fs::File, len: usize) -> Option<*const u8> {
        let fd = f.as_raw_fd();
        let ret: isize;
        // SAFETY: well-formed mmap(NULL, len, PROT_READ, MAP_PRIVATE,
        // fd, 0); the kernel either returns a mapping or -errno.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") SYS_MMAP as isize => ret,
                in("rdi") 0usize,
                in("rsi") len,
                in("rdx") PROT_READ,
                in("r10") MAP_PRIVATE,
                in("r8") fd as isize,
                in("r9") 0usize,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        // errno range: [-4095, -1]
        if (-4095..0).contains(&ret) {
            None
        } else {
            Some(ret as usize as *const u8)
        }
    }

    pub fn unmap(ptr: *const u8, len: usize) {
        let ret: isize;
        // SAFETY: ptr/len came from a successful map_readonly.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") SYS_MUNMAP as isize => ret,
                in("rdi") ptr as usize,
                in("rsi") len,
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        let _ = ret; // nothing sensible to do on munmap failure
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod sys {
    pub fn map_readonly(_f: &std::fs::File, _len: usize) -> Option<*const u8> {
        None
    }

    pub fn unmap(_ptr: *const u8, _len: usize) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmppath(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("mcsharp-mmap-{name}-{}.bin", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn maps_whole_file_contents() {
        let path = tmppath("basic");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &payload).unwrap();
        let m = Mmap::open(&path).unwrap();
        assert_eq!(m.len(), payload.len());
        assert_eq!(m.as_slice(), &payload[..]);
        // on the deployment target this must be a real mapping
        #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
        assert!(m.is_mapped());
        drop(m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_is_an_empty_slice() {
        let path = tmppath("empty");
        std::fs::write(&path, b"").unwrap();
        let m = Mmap::open(&path).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), b"");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        assert!(Mmap::open("/definitely/not/a/real/path.bin").is_err());
    }

    #[test]
    fn view_is_shareable_across_threads() {
        let path = tmppath("threads");
        std::fs::write(&path, vec![7u8; 4096]).unwrap();
        let m = std::sync::Arc::new(Mmap::open(&path).unwrap());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = std::sync::Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                assert!(m.as_slice().iter().all(|&b| b == 7));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        std::fs::remove_file(&path).ok();
    }
}
