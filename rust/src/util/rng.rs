//! Deterministic xoshiro256** RNG with the distributions this crate needs
//! (uniform, normal, Gumbel, categorical, shuffles). Every stochastic
//! component (data generation, init, training, Gumbel sampling, benches)
//! takes an explicit seed so all experiments replay bit-identically.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Gumbel(0,1) noise: `-ln(-ln(u))` (paper Eq. 12).
    pub fn gumbel(&mut self) -> f32 {
        let u = self.f64().clamp(1e-12, 1.0 - 1e-12);
        (-(-u.ln()).ln()) as f32
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..20_000).map(|_| r.f32()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut r = Rng::new(3);
        let m: f64 = (0..50_000).map(|_| r.gumbel() as f64).sum::<f64>() / 50_000.0;
        assert!((m - 0.5772).abs() < 0.03, "gumbel mean {m}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let p2 = counts[2] as f64 / 30_000.0;
        assert!((p2 - 0.7).abs() < 0.02, "p2 {p2}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
