//! Stand-alone substrates the offline environment forces us to hand-roll:
//! deterministic RNG, a JSON parser/serializer, a CLI argument parser, a
//! criterion-style bench harness, and a mini property-testing helper.

pub mod bench;
pub mod cli;
pub mod json;
pub mod mmap;
pub mod prop;
pub mod rng;

/// Format a byte count as a human-readable string (GB/MB/KB).
pub fn human_bytes(b: u64) -> String {
    const KB: f64 = 1024.0;
    let b = b as f64;
    if b >= KB * KB * KB {
        format!("{:.2} GB", b / (KB * KB * KB))
    } else if b >= KB * KB {
        format!("{:.2} MB", b / (KB * KB))
    } else if b >= KB {
        format!("{:.1} KB", b / KB)
    } else {
        format!("{b:.0} B")
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KB");
        assert!(human_bytes(3 * 1024 * 1024).starts_with("3.00 MB"));
        assert!(human_bytes(5 * 1024 * 1024 * 1024).starts_with("5.00 GB"));
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
