//! Mini property-testing helper (proptest is not available offline).
//!
//! `for_all` runs `cases` seeded random trials; on failure it reports the
//! failing seed so the case replays deterministically:
//!
//! ```text
//! prop failed at case 17 (seed 0xdeadbeef...): <your message>
//! ```
//!
//! Invariant sweeps in this crate (DP-vs-brute-force allocator
//! optimality, pack/unpack round-trips, batcher token conservation,
//! KV-cache equivalence, ...) all run through here.

use super::rng::Rng;

/// Run `check(rng, case_idx)` for `cases` independent seeded trials.
/// `check` should panic (assert!) on violation.
pub fn for_all<F: FnMut(&mut Rng, usize)>(base_seed: u64, cases: usize, mut check: F) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng, case);
        }));
        if let Err(e) = result {
            eprintln!("prop failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random dimensions helper: multiple-of-`m` value in [lo, hi].
pub fn dim(rng: &mut Rng, lo: usize, hi: usize, m: usize) -> usize {
    let steps = (hi - lo) / m;
    lo + m * rng.below(steps + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        for_all(1, 25, |_, _| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn dim_respects_bounds_and_multiple() {
        for_all(2, 50, |rng, _| {
            let d = dim(rng, 32, 256, 32);
            assert!((32..=256).contains(&d));
            assert_eq!(d % 32, 0);
        });
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        for_all(3, 10, |rng, _| {
            assert!(rng.f32() < 0.9, "expected failure eventually");
        });
    }
}
