//! Tiny CLI argument parser (no clap offline): subcommand + `--key value`
//! flags + `--switch` booleans + positionals, with defaults and typed
//! getters. Unknown flags are an error, so typos fail fast.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`; the first non-flag token becomes the subcommand.
    pub fn parse(argv: &[String], known_flags: &[&str]) -> Result<Args> {
        let mut a = Args {
            known: known_flags.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if !a.known.iter().any(|k| k == name) {
                    bail!("unknown flag --{name} (known: {})", a.known.join(", "));
                }
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    a.switches.push(name.to_string());
                    i += 1;
                }
            } else {
                if a.subcommand.is_none() {
                    a.subcommand = Some(tok.clone());
                } else {
                    a.positional.push(tok.clone());
                }
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn from_env(known_flags: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, known_flags)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        // note: `--verbose extra` would bind "extra" as the flag's value
        // (flags are greedy); trailing switches are unambiguous.
        let a = Args::parse(
            &argv("serve --model mix-tiny --steps 200 extra --verbose"),
            &["model", "steps", "verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("mix-tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 200);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&argv("run --nope 1"), &["model"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("run"), &["x"]).unwrap();
        assert_eq!(a.usize_or("x", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_or("x", "d"), "d");
        assert!(!a.has("x"));
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv("run --x abc"), &["x"]).unwrap();
        assert!(a.usize_or("x", 0).is_err());
    }
}
