//! Forward-with-cache and manual backward for the MoE decoder.
//!
//! Gradient semantics match standard MoE training: the top-k selection is
//! treated as a constant; gradients flow into the gate through the
//! renormalized routing weights of the *selected* experts (plus an
//! optional Switch-style load-balancing auxiliary loss).

use crate::moe::attention::rope;
use crate::moe::gating::{route, Route};
use crate::moe::model::MoeModel;
use crate::tensor::{rmsnorm, silu, silu_grad, softmax, Tensor2};

/// Gradient buffers mirroring the model parameters.
pub struct Grads {
    pub embed: Tensor2,
    pub blocks: Vec<BlockGrads>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor2,
}

pub struct BlockGrads {
    pub attn_norm: Vec<f32>,
    pub wq: Tensor2,
    pub wk: Tensor2,
    pub wv: Tensor2,
    pub wo: Tensor2,
    pub moe_norm: Vec<f32>,
    pub gate: Tensor2,
    pub experts: Vec<ExpertGrads>,
    pub shared: Vec<ExpertGrads>,
}

pub struct ExpertGrads {
    pub wg: Tensor2,
    pub wu: Tensor2,
    pub wd: Tensor2,
}

impl Grads {
    pub fn zeros_like(m: &MoeModel) -> Grads {
        let h = m.cfg.d_model;
        let f = m.cfg.d_ff;
        Grads {
            embed: Tensor2::zeros(m.cfg.vocab_size, h),
            blocks: m
                .blocks
                .iter()
                .map(|b| BlockGrads {
                    attn_norm: vec![0.0; h],
                    wq: Tensor2::zeros(h, h),
                    wk: Tensor2::zeros(h, h),
                    wv: Tensor2::zeros(h, h),
                    wo: Tensor2::zeros(h, h),
                    moe_norm: vec![0.0; h],
                    gate: Tensor2::zeros(h, m.cfg.n_experts),
                    experts: (0..b.experts.len())
                        .map(|_| ExpertGrads {
                            wg: Tensor2::zeros(h, f),
                            wu: Tensor2::zeros(h, f),
                            wd: Tensor2::zeros(f, h),
                        })
                        .collect(),
                    shared: (0..b.shared.len())
                        .map(|_| ExpertGrads {
                            wg: Tensor2::zeros(h, f),
                            wu: Tensor2::zeros(h, f),
                            wd: Tensor2::zeros(f, h),
                        })
                        .collect(),
                })
                .collect(),
            final_norm: vec![0.0; h],
            lm_head: Tensor2::zeros(h, m.cfg.vocab_size),
        }
    }

    /// Flat views over every gradient buffer, canonical order (must match
    /// [`model_param_vecs`]).
    pub fn param_vecs_mut(&mut self) -> Vec<&mut Vec<f32>> {
        let mut out: Vec<&mut Vec<f32>> = vec![&mut self.embed.data];
        for b in &mut self.blocks {
            out.push(&mut b.attn_norm);
            out.push(&mut b.wq.data);
            out.push(&mut b.wk.data);
            out.push(&mut b.wv.data);
            out.push(&mut b.wo.data);
            out.push(&mut b.moe_norm);
            out.push(&mut b.gate.data);
            for e in b.experts.iter_mut().chain(b.shared.iter_mut()) {
                out.push(&mut e.wg.data);
                out.push(&mut e.wu.data);
                out.push(&mut e.wd.data);
            }
        }
        out.push(&mut self.final_norm);
        out.push(&mut self.lm_head.data);
        out
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.param_vecs_mut() {
            for x in v.iter_mut() {
                *x *= s;
            }
        }
    }

    pub fn accumulate(&mut self, other: &mut Grads) {
        let mut a = self.param_vecs_mut();
        let b = other.param_vecs_mut();
        for (av, bv) in a.iter_mut().zip(b) {
            for (x, y) in av.iter_mut().zip(bv.iter()) {
                *x += *y;
            }
        }
    }
}

/// Flat views over every model parameter, canonical order.
pub fn model_param_vecs(m: &mut MoeModel) -> Vec<&mut Vec<f32>> {
    let mut out: Vec<&mut Vec<f32>> = vec![&mut m.embed.data];
    for b in &mut m.blocks {
        out.push(&mut b.attn_norm);
        out.push(&mut b.attn.wq.data);
        out.push(&mut b.attn.wk.data);
        out.push(&mut b.attn.wv.data);
        out.push(&mut b.attn.wo.data);
        out.push(&mut b.moe_norm);
        out.push(&mut b.gate.data);
        for e in b.experts.iter_mut().chain(b.shared.iter_mut()) {
            out.push(&mut e.wg.data);
            out.push(&mut e.wu.data);
            out.push(&mut e.wd.data);
        }
    }
    out.push(&mut m.final_norm);
    out.push(&mut m.lm_head.data);
    out
}

// ---------------------------------------------------------------------------
// forward with cache
// ---------------------------------------------------------------------------

struct TokenMoe {
    route: Route,
    /// Per selected rank: (g, u, expert_out).
    sel: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    shared: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
}

struct LayerCache {
    x_in: Tensor2,
    attn_normed: Tensor2,
    q: Tensor2,
    k: Tensor2,
    v: Tensor2,
    /// Per head, `[T, T]` attention probabilities (lower triangular).
    probs: Vec<Tensor2>,
    ctx: Tensor2,
    x_mid: Tensor2,
    moe_normed: Tensor2,
    moe: Vec<TokenMoe>,
}

struct FwdCache {
    layers: Vec<LayerCache>,
    final_in: Tensor2,
    final_normed: Tensor2,
    logits: Tensor2,
}

fn expert_fwd_cached(
    e: &crate::moe::Expert,
    x: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let f = e.wg.cols;
    let h = e.wd.cols;
    let mut g = vec![0.0f32; f];
    let mut u = vec![0.0f32; f];
    for (kk, &xk) in x.iter().enumerate() {
        if xk == 0.0 {
            continue;
        }
        crate::tensor::axpy(xk, e.wg.row(kk), &mut g);
        crate::tensor::axpy(xk, e.wu.row(kk), &mut u);
    }
    let mut out = vec![0.0f32; h];
    for j in 0..f {
        let hj = silu(g[j]) * u[j];
        if hj != 0.0 {
            crate::tensor::axpy(hj, e.wd.row(j), &mut out);
        }
    }
    (g, u, out)
}

fn forward_cached(m: &MoeModel, tokens: &[u16]) -> FwdCache {
    let h = m.cfg.d_model;
    let t = tokens.len();
    let mut x = Tensor2::zeros(t, h);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(m.embed.row(tok as usize));
    }
    let mut layers = Vec::new();
    for block in &m.blocks {
        let x_in = x.clone();
        let mut attn_normed = Tensor2::zeros(t, h);
        for i in 0..t {
            rmsnorm(x_in.row(i), &block.attn_norm, attn_normed.row_mut(i));
        }
        // attention with cached internals
        let d_head = h / block.attn.n_heads;
        let scale = 1.0 / (d_head as f32).sqrt();
        let mut q = attn_normed.matmul(&block.attn.wq);
        let mut k = attn_normed.matmul(&block.attn.wk);
        let v = attn_normed.matmul(&block.attn.wv);
        for i in 0..t {
            rope(q.row_mut(i), i, block.attn.n_heads, block.attn.rope_theta);
            rope(k.row_mut(i), i, block.attn.n_heads, block.attn.rope_theta);
        }
        let mut probs = Vec::new();
        let mut ctx = Tensor2::zeros(t, h);
        for head in 0..block.attn.n_heads {
            let base = head * d_head;
            let mut p = Tensor2::zeros(t, t);
            for i in 0..t {
                let qi = &q.row(i)[base..base + d_head];
                let prow = p.row_mut(i);
                for j in 0..=i {
                    let kj = &k.row(j)[base..base + d_head];
                    prow[j] = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax(&mut prow[..i + 1]);
                for j in i + 1..t {
                    prow[j] = 0.0;
                }
            }
            for i in 0..t {
                let orow = ctx.row_mut(i);
                for j in 0..=i {
                    let w = p.at(i, j);
                    if w != 0.0 {
                        let vj = &v.row(j)[base..base + d_head];
                        for (d, &vv) in vj.iter().enumerate() {
                            orow[base + d] += w * vv;
                        }
                    }
                }
            }
            probs.push(p);
        }
        let attn_out = ctx.matmul(&block.attn.wo);
        let mut x_mid = x_in.clone();
        x_mid.add_assign(&attn_out);
        let mut moe_normed = Tensor2::zeros(t, h);
        for i in 0..t {
            rmsnorm(x_mid.row(i), &block.moe_norm, moe_normed.row_mut(i));
        }
        let mut moe = Vec::new();
        let mut x_next = x_mid.clone();
        for i in 0..t {
            let xn = moe_normed.row(i);
            let r = route(xn, &block.gate, m.cfg.top_k);
            let mut sel = Vec::new();
            let xr = x_next.row_mut(i);
            for (rank, &e) in r.experts.iter().enumerate() {
                let (g, u, out) = expert_fwd_cached(&block.experts[e], xn);
                let w = r.weights[rank];
                for (o, &v) in xr.iter_mut().zip(&out) {
                    *o += w * v;
                }
                sel.push((g, u, out));
            }
            let mut shared = Vec::new();
            for s in &block.shared {
                let (g, u, out) = expert_fwd_cached(s, xn);
                for (o, &v) in xr.iter_mut().zip(&out) {
                    *o += v;
                }
                shared.push((g, u, out));
            }
            moe.push(TokenMoe { route: r, sel, shared });
        }
        layers.push(LayerCache {
            x_in,
            attn_normed,
            q,
            k,
            v,
            probs,
            ctx,
            x_mid,
            moe_normed,
            moe,
        });
        x = x_next;
    }
    let final_in = x;
    let t_len = final_in.rows;
    let mut final_normed = Tensor2::zeros(t_len, h);
    for i in 0..t_len {
        rmsnorm(final_in.row(i), &m.final_norm, final_normed.row_mut(i));
    }
    let logits = final_normed.matmul(&m.lm_head);
    FwdCache { layers, final_in, final_normed, logits }
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

/// RMSNorm backward for one row.
/// y_i = x_i * inv * g_i, inv = (mean(x²)+eps)^(-1/2).
fn rmsnorm_backward(x: &[f32], gain: &[f32], dy: &[f32], dx: &mut [f32], dgain: &mut [f32]) {
    let n = x.len();
    let ms = x.iter().map(|v| v * v).sum::<f32>() / n as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    let dot: f32 = (0..n).map(|i| dy[i] * gain[i] * x[i]).sum();
    let c = inv * inv * inv / n as f32;
    for i in 0..n {
        dx[i] += dy[i] * gain[i] * inv - x[i] * c * dot;
        dgain[i] += dy[i] * x[i] * inv;
    }
}

/// Backward of a SwiGLU expert for one token.
/// Inputs: cached (g, u, out-unused), upstream `dout`, token input `x`.
/// Accumulates weight grads and `dx`.
fn expert_backward(
    e: &crate::moe::Expert,
    ge: &mut ExpertGrads,
    x: &[f32],
    g: &[f32],
    u: &[f32],
    dout: &[f32],
    dx: &mut [f32],
) {
    let f = e.wg.cols;
    // dh = dout @ wd^T ; dwd += h ⊗ dout
    let mut dh = vec![0.0f32; f];
    for j in 0..f {
        let hj = silu(g[j]) * u[j];
        let wdr = e.wd.row(j);
        let mut s = 0.0f32;
        for (o, &d) in dout.iter().enumerate() {
            s += d * wdr[o];
        }
        dh[j] = s;
        if hj != 0.0 {
            crate::tensor::axpy(hj, dout, ge.wd.row_mut(j));
        }
    }
    // dg = dh ⊙ u ⊙ silu'(g); du = dh ⊙ silu(g)
    let mut dg = vec![0.0f32; f];
    let mut du = vec![0.0f32; f];
    for j in 0..f {
        dg[j] = dh[j] * u[j] * silu_grad(g[j]);
        du[j] = dh[j] * silu(g[j]);
    }
    // dwg += x ⊗ dg ; dwu += x ⊗ du ; dx += dg @ wg^T + du @ wu^T
    for (kk, &xk) in x.iter().enumerate() {
        if xk != 0.0 {
            crate::tensor::axpy(xk, &dg, ge.wg.row_mut(kk));
            crate::tensor::axpy(xk, &du, ge.wu.row_mut(kk));
        }
        let wgr = e.wg.row(kk);
        let wur = e.wu.row(kk);
        let mut s = 0.0f32;
        for j in 0..f {
            s += dg[j] * wgr[j] + du[j] * wur[j];
        }
        dx[kk] += s;
    }
}

/// Full backward pass. Returns (CE loss, aux loss); fills `grads`.
///
/// `aux_coef` weights a Switch-style load-balancing loss
/// `E * Σ_e f_e P_e` per layer, which keeps routing from collapsing
/// during pretraining while still permitting specialization.
pub fn backward(m: &MoeModel, tokens: &[u16], aux_coef: f32, grads: &mut Grads) -> (f64, f64) {
    let cache = forward_cached(m, tokens);
    let t = tokens.len();
    let h = m.cfg.d_model;
    let n_pred = t - 1;

    // CE loss + dlogits
    let mut dlogits = Tensor2::zeros(t, m.cfg.vocab_size);
    let mut loss = 0.0f64;
    for i in 0..n_pred {
        let row = cache.logits.row(i);
        let target = tokens[i + 1] as usize;
        let mut probs = row.to_vec();
        softmax(&mut probs);
        loss += -(probs[target].max(1e-30).ln() as f64);
        let drow = dlogits.row_mut(i);
        let inv = 1.0 / n_pred as f32;
        for j in 0..probs.len() {
            drow[j] = (probs[j] - if j == target { 1.0 } else { 0.0 }) * inv;
        }
    }
    loss /= n_pred as f64;

    // head + final norm
    grads.lm_head.add_assign(&cache.final_normed.t_matmul(&dlogits));
    // d(final_normed) = dlogits @ lm_head^T
    let dfinal_normed = dlogits.matmul_t(&m.lm_head);
    let mut dx = Tensor2::zeros(t, h);
    for i in 0..t {
        rmsnorm_backward(
            cache.final_in.row(i),
            &m.final_norm,
            dfinal_normed.row(i),
            dx.row_mut(i),
            &mut grads.final_norm,
        );
    }

    let mut aux_total = 0.0f64;
    for (l, block) in m.blocks.iter().enumerate().rev() {
        let lc = &cache.layers[l];
        let bg = &mut grads.blocks[l];
        let e_count = m.cfg.n_experts;

        // ---- aux loss bookkeeping for this layer (computed on scores) ----
        let mut freq = vec![0.0f32; e_count];
        let mut pmean = vec![0.0f32; e_count];
        for tm in &lc.moe {
            for &e in &tm.route.experts {
                freq[e] += 1.0 / t as f32;
            }
            for (e, &sc) in tm.route.scores.iter().enumerate() {
                pmean[e] += sc / t as f32;
            }
        }
        let aux: f32 = e_count as f32 * freq.iter().zip(&pmean).map(|(f, p)| f * p).sum::<f32>();
        aux_total += aux as f64;

        // ---- MoE sub-layer backward ----
        let mut dmoe_normed = Tensor2::zeros(t, h);
        let mut dx_mid = dx.clone(); // residual path
        for i in 0..t {
            let tm = &lc.moe[i];
            let xn = lc.moe_normed.row(i);
            let dy = dx.row(i);
            let k = tm.route.experts.len();
            // gradient w.r.t. renormalized weights
            let mut dwr = vec![0.0f32; k];
            for (rank, &e) in tm.route.experts.iter().enumerate() {
                let (g, u, out) = &tm.sel[rank];
                dwr[rank] = dy.iter().zip(out).map(|(a, b)| a * b).sum();
                // expert weight grads with upstream scaled by w
                let w = tm.route.weights[rank];
                let mut dout = vec![0.0f32; h];
                for (d, &dyv) in dout.iter_mut().zip(dy) {
                    *d = w * dyv;
                }
                expert_backward(
                    &block.experts[e],
                    &mut bg.experts[e],
                    xn,
                    g,
                    u,
                    &dout,
                    dmoe_normed.row_mut(i),
                );
            }
            for (s, sh) in block.shared.iter().enumerate() {
                let (g, u, _) = &tm.shared[s];
                expert_backward(sh, &mut bg.shared[s], xn, g, u, dy, dmoe_normed.row_mut(i));
            }
            // renormalization backward: w_r = s_r / Σ_topk s
            let ssum: f32 = tm.route.experts.iter().map(|&e| tm.route.scores[e]).sum();
            let mut dscores = vec![0.0f32; e_count];
            // dL/ds_a = Σ_r dwr_r * dw_r/ds_a with w_r = s_r / Σ_topk s
            for (a_rank, &ea) in tm.route.experts.iter().enumerate() {
                let mut d = 0.0f32;
                for (r_rank, &er) in tm.route.experts.iter().enumerate() {
                    let sr = tm.route.scores[er];
                    let delta = if r_rank == a_rank { 1.0 } else { 0.0 };
                    d += dwr[r_rank] * (delta * ssum - sr) / (ssum * ssum);
                }
                dscores[ea] = d;
            }
            // aux loss gradient through scores: d aux/d s_{t,e} = coef*E*f_e/T
            if aux_coef > 0.0 {
                for e in 0..e_count {
                    dscores[e] += aux_coef * e_count as f32 * freq[e] / t as f32;
                }
            }
            // softmax backward over all experts
            let s = &tm.route.scores;
            let dot: f32 = dscores.iter().zip(s).map(|(d, p)| d * p).sum();
            let mut dz = vec![0.0f32; e_count];
            for e in 0..e_count {
                dz[e] = s[e] * (dscores[e] - dot);
            }
            // gate grads: gate is [H, E]; z = xn @ gate
            for (kk, &xk) in xn.iter().enumerate() {
                if xk != 0.0 {
                    crate::tensor::axpy(xk, &dz, bg.gate.row_mut(kk));
                }
                let gr = block.gate.row(kk);
                let mut sdx = 0.0f32;
                for e in 0..e_count {
                    sdx += dz[e] * gr[e];
                }
                dmoe_normed.row_mut(i)[kk] += sdx;
            }
        }
        // moe norm backward
        for i in 0..t {
            rmsnorm_backward(
                lc.x_mid.row(i),
                &block.moe_norm,
                dmoe_normed.row(i),
                dx_mid.row_mut(i),
                &mut bg.moe_norm,
            );
        }

        // ---- attention sub-layer backward ----
        // x_mid = x_in + ctx @ wo
        let dattn_out = dx_mid.clone();
        bg.wo.add_assign(&lc.ctx.t_matmul(&dattn_out));
        let dctx = dattn_out.matmul_t(&block.attn.wo);
        let d_head = h / block.attn.n_heads;
        let scale = 1.0 / (d_head as f32).sqrt();
        let mut dq = Tensor2::zeros(t, h);
        let mut dk = Tensor2::zeros(t, h);
        let mut dv = Tensor2::zeros(t, h);
        for head in 0..block.attn.n_heads {
            let base = head * d_head;
            let p = &lc.probs[head];
            for i in 0..t {
                let dctx_i = &dctx.row(i)[base..base + d_head];
                // dA_ij = dctx_i · v_j ; dv_j += A_ij * dctx_i
                let mut da = vec![0.0f32; i + 1];
                for j in 0..=i {
                    let vj = &lc.v.row(j)[base..base + d_head];
                    da[j] = dctx_i.iter().zip(vj).map(|(a, b)| a * b).sum();
                    let w = p.at(i, j);
                    if w != 0.0 {
                        let dvj = &mut dv.row_mut(j)[base..base + d_head];
                        for (d, &dc) in dvj.iter_mut().zip(dctx_i) {
                            *d += w * dc;
                        }
                    }
                }
                // softmax backward on row i
                let prow = &p.row(i)[..i + 1];
                let dot: f32 = da.iter().zip(prow).map(|(a, b)| a * b).sum();
                for j in 0..=i {
                    let ds = prow[j] * (da[j] - dot) * scale;
                    if ds != 0.0 {
                        let kj = &lc.k.row(j)[base..base + d_head];
                        let qi = &lc.q.row(i)[base..base + d_head];
                        let dqi = &mut dq.row_mut(i)[base..base + d_head];
                        for (d, &kv) in dqi.iter_mut().zip(kj) {
                            *d += ds * kv;
                        }
                        let dkj = &mut dk.row_mut(j)[base..base + d_head];
                        for (d, &qv) in dkj.iter_mut().zip(qi) {
                            *d += ds * qv;
                        }
                    }
                }
            }
        }
        // rope backward = inverse rotation
        for i in 0..t {
            rope_inverse(dq.row_mut(i), i, block.attn.n_heads, block.attn.rope_theta);
            rope_inverse(dk.row_mut(i), i, block.attn.n_heads, block.attn.rope_theta);
        }
        bg.wq.add_assign(&lc.attn_normed.t_matmul(&dq));
        bg.wk.add_assign(&lc.attn_normed.t_matmul(&dk));
        bg.wv.add_assign(&lc.attn_normed.t_matmul(&dv));
        let mut dattn_normed = dq.matmul_t(&block.attn.wq);
        dattn_normed.add_assign(&dk.matmul_t(&block.attn.wk));
        dattn_normed.add_assign(&dv.matmul_t(&block.attn.wv));
        // attn norm backward; residual: dx_in = dx_mid + norm-path grads
        let mut dx_in = dx_mid.clone();
        for i in 0..t {
            rmsnorm_backward(
                lc.x_in.row(i),
                &block.attn_norm,
                dattn_normed.row(i),
                dx_in.row_mut(i),
                &mut bg.attn_norm,
            );
        }
        dx = dx_in;
    }

    // embedding backward
    for (i, &tok) in tokens.iter().enumerate() {
        let g = dx.row(i).to_vec();
        let row = grads.embed.row_mut(tok as usize);
        for (r, v) in row.iter_mut().zip(&g) {
            *r += v;
        }
    }

    (loss, aux_total / m.cfg.n_layers as f64)
}

/// Inverse RoPE rotation (rotate by -angle) — the adjoint of `rope`.
fn rope_inverse(x: &mut [f32], pos: usize, n_heads: usize, theta: f32) {
    let d_head = x.len() / n_heads;
    for hh in 0..n_heads {
        let base = hh * d_head;
        let mut i = 0;
        while i + 1 < d_head {
            let freq = 1.0 / theta.powf(i as f32 / d_head as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let (a, b) = (x[base + i], x[base + i + 1]);
            x[base + i] = a * cos + b * sin;
            x[base + i + 1] = -a * sin + b * cos;
            i += 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "bw-test".into(),
            family: "mixtral".into(),
            vocab_size: 24,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 12,
            n_experts: 3,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 16,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    fn loss_of(m: &MoeModel, tokens: &[u16]) -> f64 {
        m.nll(tokens, &mut Default::default())
    }

    /// Finite-difference check over a random subset of every param group.
    #[test]
    fn gradcheck_all_param_groups() {
        let cfg = tiny_cfg();
        let mut m = MoeModel::new(&cfg, 7);
        let tokens: Vec<u16> = vec![1, 5, 9, 17, 3, 20];
        let mut grads = Grads::zeros_like(&m);
        let (loss0, _) = backward(&m, &tokens, 0.0, &mut grads);
        // forward_cached and forward_opts sum in different orders (blocked
        // matmul vs axpy) — agree to f32 accumulation precision
        assert!(
            (loss0 - loss_of(&m, &tokens)).abs() < 1e-4 * (1.0 + loss0.abs()),
            "cached fwd loss {loss0} vs plain {}",
            loss_of(&m, &tokens)
        );

        let mut rng = crate::util::rng::Rng::new(77);
        let n_groups = {
            let gv = grads.param_vecs_mut();
            gv.len()
        };
        for gi in 0..n_groups {
            // probe up to 3 random coordinates per group
            let glen = grads.param_vecs_mut()[gi].len();
            for _ in 0..3.min(glen) {
                let idx = rng.below(glen);
                let analytic = grads.param_vecs_mut()[gi][idx] as f64;
                let eps = 5e-3f32;
                {
                    let mut pv = model_param_vecs(&mut m);
                    pv[gi][idx] += eps;
                }
                let lp = loss_of(&m, &tokens);
                {
                    let mut pv = model_param_vecs(&mut m);
                    pv[gi][idx] -= 2.0 * eps;
                }
                let lm = loss_of(&m, &tokens);
                {
                    let mut pv = model_param_vecs(&mut m);
                    pv[gi][idx] += eps;
                }
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let denom = analytic.abs().max(numeric.abs()).max(1e-4);
                assert!(
                    (analytic - numeric).abs() / denom < 0.08,
                    "group {gi} idx {idx}: analytic {analytic:.6} vs numeric {numeric:.6}"
                );
            }
        }
    }

    #[test]
    fn aux_loss_positive_and_bounded() {
        let cfg = tiny_cfg();
        let m = MoeModel::new(&cfg, 9);
        let mut grads = Grads::zeros_like(&m);
        let (_, aux) = backward(&m, &[1, 5, 9, 17, 3], 0.01, &mut grads);
        // Switch aux is ≥ k (≈ k when perfectly balanced at top-k routing)
        assert!(aux >= 0.9 * 2.0 && aux < 3.0 * 2.0, "aux={aux}");
    }

    #[test]
    fn grads_accumulate_and_scale() {
        let cfg = tiny_cfg();
        let m = MoeModel::new(&cfg, 3);
        let mut g1 = Grads::zeros_like(&m);
        let mut g2 = Grads::zeros_like(&m);
        backward(&m, &[1, 2, 3, 4], 0.0, &mut g1);
        backward(&m, &[1, 2, 3, 4], 0.0, &mut g2);
        let before = g1.lm_head.data[0];
        g1.accumulate(&mut g2);
        assert!((g1.lm_head.data[0] - 2.0 * before).abs() < 1e-6);
        g1.scale(0.5);
        assert!((g1.lm_head.data[0] - before).abs() < 1e-6);
    }
}
