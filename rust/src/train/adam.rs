//! Adam optimizer over the canonical flat parameter-group ordering shared
//! by `MoeModel` and `Grads` (see `backward::model_param_vecs`).

pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    /// `shapes` are the lengths of each parameter group, in canonical order.
    pub fn new(lr: f32, shapes: &[usize]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            v: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
        }
    }

    /// One update: `params[g][i] -= lr * m̂ / (sqrt(v̂) + eps)`.
    pub fn step(&mut self, params: &mut [&mut Vec<f32>], grads: &[&mut Vec<f32>]) {
        assert_eq!(params.len(), self.m.len(), "param group count");
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for g in 0..params.len() {
            let p = &mut *params[g];
            let gr = &*grads[g];
            let m = &mut self.m[g];
            let v = &mut self.v[g];
            for i in 0..p.len() {
                let grad = gr[i] + self.weight_decay * p[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * grad;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * grad * grad;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                p[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = Σ (x_i - c_i)²
        let c = [3.0f32, -2.0, 0.5];
        let mut x = vec![0.0f32; 3];
        let mut adam = Adam::new(0.1, &[3]);
        for _ in 0..500 {
            let mut g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            adam.step(&mut [&mut x], &[&mut g]);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-2, "{xi} vs {ci}");
        }
    }
}
