//! Training substrate: manual backprop through the full MoE decoder plus
//! an Adam optimizer and an LM pretraining loop.
//!
//! The paper compresses *pretrained* MoE models whose experts have
//! genuinely uneven importance; we reproduce that precondition by
//! pretraining the model zoo from scratch on the synthetic corpora
//! (topic-/modality-clustered data ⇒ expert specialization ⇒ the Fig. 4/5
//! imbalance PMQ exploits). The trainer is also reused by OTP's
//! distillation loop (`otp::train`), which backprops only through the
//! tiny mask routers.
//!
//! Correctness is pinned by finite-difference gradient checks over every
//! parameter group (`backward::tests`).

pub mod adam;
pub mod backward;
pub mod trainer;

pub use adam::Adam;
pub use backward::{backward, Grads};
pub use trainer::{TrainConfig, Trainer};
