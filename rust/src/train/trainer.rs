//! LM pretraining loop: synthetic corpus → batched backprop → Adam.

use anyhow::Result;

use crate::config::ModelConfig;
use crate::data::{Corpus, CorpusKind};
use crate::moe::MoeModel;
use crate::util::rng::Rng;

use super::adam::Adam;
use super::backward::{backward, model_param_vecs, Grads};

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub lr: f32,
    /// Load-balancing auxiliary-loss coefficient. Small enough to permit
    /// the expert specialization PMQ exploits, large enough to avoid
    /// routing collapse.
    pub aux_coef: f32,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            batch: 4,
            seq_len: 48,
            lr: 3e-3,
            aux_coef: 5e-3,
            log_every: 25,
            seed: 1234,
        }
    }
}

pub struct Trainer {
    pub model: MoeModel,
    pub tc: TrainConfig,
    adam: Adam,
    rng: Rng,
    /// (step, train CE loss) pairs — the loss curve for EXPERIMENTS.md.
    pub loss_curve: Vec<(usize, f64)>,
}

impl Trainer {
    pub fn new(cfg: &ModelConfig, tc: TrainConfig) -> Trainer {
        let model = MoeModel::new(cfg, tc.seed);
        let shapes: Vec<usize> = {
            let mut m = MoeModel::new(cfg, tc.seed);
            model_param_vecs(&mut m).iter().map(|v| v.len()).collect()
        };
        let adam = Adam::new(tc.lr, &shapes);
        let rng = Rng::new(tc.seed ^ 0xABCD);
        Trainer { model, tc, adam, rng, loss_curve: Vec::new() }
    }

    /// The corpus a model family pretrains on (text for Mixtral-analogs,
    /// multimodal for DeepSeek-VL2-analogs).
    pub fn default_corpus(cfg: &ModelConfig) -> Corpus {
        let kind = if cfg.modalities > 1 { CorpusKind::Multimodal } else { CorpusKind::General };
        Corpus::new(kind, 0xDA7A)
    }

    /// Family-dependent load-balance coefficient. VLM-analogs train with
    /// a weaker balance term: modality-clustered data routes patch and
    /// text tokens to largely disjoint expert sets, and the paper's
    /// Fig. 5 observation (VLM experts markedly more imbalanced than LLM
    /// experts) only emerges if balancing does not fight that clustering
    /// — mirroring DeepSeek-VL2's fine-grained-expert training, which
    /// tolerates much more per-expert skew than Mixtral's.
    pub fn default_aux_coef(cfg: &ModelConfig) -> f32 {
        if cfg.modalities > 1 {
            2e-4
        } else {
            5e-3
        }
    }

    /// One optimizer step over a fresh batch; returns mean CE loss.
    pub fn step(&mut self, corpus: &Corpus) -> f64 {
        let mut grads = Grads::zeros_like(&self.model);
        let mut total = 0.0;
        for _ in 0..self.tc.batch {
            let seq = corpus.sample(self.tc.seq_len, &mut self.rng);
            let mut g = Grads::zeros_like(&self.model);
            let (loss, _aux) = backward(&self.model, &seq, self.tc.aux_coef, &mut g);
            total += loss;
            grads.accumulate(&mut g);
        }
        grads.scale(1.0 / self.tc.batch as f32);
        let mut params = model_param_vecs(&mut self.model);
        let gvecs = grads.param_vecs_mut();
        self.adam.step(&mut params, &gvecs);
        total / self.tc.batch as f64
    }

    /// Full training run with loss-curve logging.
    pub fn train(&mut self, corpus: &Corpus, quiet: bool) -> Result<()> {
        for step in 0..self.tc.steps {
            let loss = self.step(corpus);
            if step % self.tc.log_every == 0 || step + 1 == self.tc.steps {
                self.loss_curve.push((step, loss));
                if !quiet {
                    println!("step {step:>5}  ce-loss {loss:.4}");
                }
            }
        }
        Ok(())
    }
}

/// Train (or load a cached checkpoint of) a model for `name`, storing it
/// under `checkpoints/<name>-s<steps>.bin`. Examples & benches share this
/// so the expensive pretrain happens once per configuration.
pub fn train_or_load(name: &str, steps: usize, quiet: bool) -> Result<MoeModel> {
    let cfg = ModelConfig::load(name)?;
    let path = crate::config::repo_path(&format!("checkpoints/{name}-s{steps}.bin"));
    if let Ok(m) = MoeModel::load(&path) {
        if m.cfg == cfg {
            return Ok(m);
        }
        // config drifted: retrain below
    }
    let tc = TrainConfig {
        steps,
        aux_coef: Trainer::default_aux_coef(&cfg),
        ..Default::default()
    };
    let mut t = Trainer::new(&cfg, tc);
    let corpus = Trainer::default_corpus(&cfg);
    if !quiet {
        println!("pretraining {name} ({} params, {steps} steps)...", t.model.n_params());
    }
    t.train(&corpus, quiet)?;
    t.model.save(&path)?;
    Ok(t.model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_decreases() {
        let cfg = ModelConfig {
            name: "train-test".into(),
            family: "mixtral".into(),
            // full synthetic vocab: the corpus emits tokens up to 511
            vocab_size: 512,
            d_model: 24,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 32,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let tc = TrainConfig { steps: 30, batch: 2, seq_len: 24, lr: 5e-3, ..Default::default() };
        let mut t = Trainer::new(&cfg, tc);
        let corpus = Corpus::new(CorpusKind::General, 1);
        let first: f64 = (0..3).map(|_| t.step(&corpus)).sum::<f64>() / 3.0;
        for _ in 0..27 {
            t.step(&corpus);
        }
        let last: f64 = (0..3).map(|_| t.step(&corpus)).sum::<f64>() / 3.0;
        assert!(
            last < first - 0.2,
            "loss did not decrease: first {first:.3} last {last:.3}"
        );
    }
}
