//! Pure-Rust expert execution over a quantized (or fp) model.

use anyhow::Result;

use crate::moe::MoeModel;
use crate::quant::qmodel::QuantModel;
use crate::tensor::Tensor2;

use super::ExpertBackend;

/// Which weight store the native backend reads.
pub enum NativeWeights<'a> {
    Fp(&'a MoeModel),
    Quant(&'a QuantModel),
}

pub struct NativeBackend<'a> {
    pub weights: NativeWeights<'a>,
}

impl<'a> NativeBackend<'a> {
    pub fn fp(m: &'a MoeModel) -> NativeBackend<'a> {
        NativeBackend { weights: NativeWeights::Fp(m) }
    }

    pub fn quant(q: &'a QuantModel) -> NativeBackend<'a> {
        NativeBackend { weights: NativeWeights::Quant(q) }
    }
}

impl ExpertBackend for NativeBackend<'_> {
    fn expert_batch(&self, layer: usize, expert: usize, x: &Tensor2) -> Result<Tensor2> {
        match &self.weights {
            // row path: per-expert token groups are small (≈ k·B/E rows),
            // where the blocked matmul's buffer setup costs more than it
            // saves (measured: 2× slower at 2-row groups — §Perf log)
            NativeWeights::Fp(m) => {
                let mut out = Tensor2::zeros(x.rows, x.cols);
                for i in 0..x.rows {
                    m.blocks[layer].experts[expert].ffn_row_acc(x.row(i), 1.0, out.row_mut(i))
                }
                Ok(out)
            }
            // batched path: decode each packed weight tile once per call.
            // The store handle is a cache hit here whenever the dispatch
            // pre-execute phase ran (it pages the routed set in batch);
            // a direct call on a paged store faults the expert in.
            NativeWeights::Quant(q) => {
                let mut out = Tensor2::zeros(x.rows, x.cols);
                q.store.get(layer, expert)?.ffn_batch_acc(x, &mut out);
                Ok(out)
            }
        }
    }

    fn shared_batch(&self, layer: usize, idx: usize, x: &Tensor2) -> Result<Tensor2> {
        let model = match &self.weights {
            NativeWeights::Fp(m) => *m,
            NativeWeights::Quant(q) => &q.model,
        };
        Ok(model.blocks[layer].shared[idx].ffn(x))
    }

    /// Quantized native execution streams packed tiles from the store
    /// per call, so the dispatcher's residency pre-phase applies.
    fn uses_expert_store(&self) -> bool {
        matches!(self.weights, NativeWeights::Quant(_))
    }

    fn name(&self) -> &'static str {
        match self.weights {
            NativeWeights::Fp(_) => "native-fp",
            NativeWeights::Quant(_) => "native-quant",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn matches_direct_expert_call() {
        let cfg = ModelConfig {
            name: "nb-test".into(),
            family: "mixtral".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            n_experts: 2,
            top_k: 1,
            n_shared_experts: 1,
            max_seq_len: 16,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let m = MoeModel::new(&cfg, 50);
        let b = NativeBackend::fp(&m);
        let mut rng = crate::util::rng::Rng::new(51);
        let x = Tensor2::randn(3, 16, &mut rng, 1.0);
        let out = b.expert_batch(0, 1, &x).unwrap();
        for i in 0..3 {
            let mut want = vec![0.0f32; 16];
            m.blocks[0].experts[1].ffn_row_acc(x.row(i), 1.0, &mut want);
            for (a, w) in out.row(i).iter().zip(&want) {
                assert!((a - w).abs() < 1e-6);
            }
        }
        let sh = b.shared_batch(0, 0, &x).unwrap();
        assert_eq!(sh.rows, 3);
    }
}
