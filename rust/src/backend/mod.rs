//! Expert-execution backends behind one trait.
//!
//! * [`NativeBackend`] — pure-Rust fused dequant matvecs (`quant`),
//!   used for evaluation sweeps and as the CPU-reference semantics.
//! * [`PjrtBackend`] — executes the AOT Pallas/JAX artifacts through the
//!   `runtime` registry: packed expert weights are staged as PJRT
//!   literals once at startup; per step the coordinator sends padded
//!   token blocks. This is the "real" serving path (L1/L2 compute, L3
//!   control).
//!
//! `rust/tests/pjrt_integration.rs` pins the two within f32 tolerance.

pub mod native;
pub mod pjrt;

use anyhow::Result;

use crate::tensor::Tensor2;

/// `Sync` because the expert-grouped dispatcher executes independent
/// expert groups of one layer on scoped threads.
pub trait ExpertBackend: Sync {
    /// Run routed expert `expert` of `layer` over token rows `x [n, H]`.
    fn expert_batch(&self, layer: usize, expert: usize, x: &Tensor2) -> Result<Tensor2>;
    /// Run shared expert `idx` of `layer`.
    fn shared_batch(&self, layer: usize, idx: usize, x: &Tensor2) -> Result<Tensor2>;
    /// Whether `expert_batch` reads packed weights through the model's
    /// `ExpertStore` at call time. The engine only runs the dispatcher's
    /// residency pre-phase when this is true — PJRT executes from
    /// literals staged at construction, so paging for it would be I/O
    /// nothing consumes.
    fn uses_expert_store(&self) -> bool {
        false
    }
    fn name(&self) -> &'static str;
}

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;
