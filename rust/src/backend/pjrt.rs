//! PJRT expert execution: the serving path that runs the AOT-compiled
//! Pallas kernels (dequant-matmul / binary-matmul / fused SwiGLU).
//!
//! At construction every expert's packed weights are staged as PJRT
//! literals once (planes, scales, zeros / plane, α / fp matrices), and
//! the per-(config, graph, bucket) executables are pre-warmed so the
//! request path never compiles. Per call the token block is padded to
//! the nearest artifact bucket — the same trick vLLM-style servers use
//! for shape-static compiled kernels.

use anyhow::{bail, Result};
use xla::Literal;

use crate::quant::qlinear::QuantLinear;
use crate::quant::qmodel::QuantModel;
use crate::runtime::literals::{f32_literal, to_f32, u8_literal};
use crate::runtime::Runtime;
use crate::tensor::Tensor2;

use super::ExpertBackend;

/// Pre-staged per-expert arguments (everything except the token block).
struct StagedExpert {
    graph: &'static str,
    args: Vec<Literal>,
}

pub struct PjrtBackend<'a> {
    pub rt: &'a Runtime,
    pub config_name: String,
    staged: Vec<Vec<StagedExpert>>,
    staged_shared: Vec<Vec<StagedExpert>>,
    buckets: Vec<usize>,
}

fn stage_linear(lin: &QuantLinear, args: &mut Vec<Literal>) -> Result<()> {
    match lin {
        QuantLinear::Fp(w) => args.push(f32_literal(&w.data, &[w.rows, w.cols])?),
        QuantLinear::Packed(p) => {
            args.push(u8_literal(&p.planes, &[p.bits as usize, p.d_in / 8, p.d_out])?);
            let g = p.d_in / p.group;
            args.push(f32_literal(&p.scales, &[g, p.d_out])?);
            args.push(f32_literal(&p.zeros, &[g, p.d_out])?);
        }
        QuantLinear::Binary(b) => {
            args.push(u8_literal(&b.plane, &[b.d_in / 8, b.d_out])?);
            args.push(f32_literal(&b.alpha, &[b.d_out])?);
        }
        // AWQ-scaled: inv_s is per input *row*, which does not fold into
        // the per-(group, column) scales the dequant artifact expects —
        // stage the effective dequantized weights on the fp graph instead
        // (memory savings are a native-backend/storage property; this
        // path keeps PJRT correctness for AWQ-quantized models).
        QuantLinear::Scaled { .. } => {
            let w = lin.dequantize();
            args.push(f32_literal(&w.data, &[w.rows, w.cols])?);
        }
    }
    Ok(())
}

fn graph_for_bits(bits: u8) -> Result<&'static str> {
    Ok(match bits {
        1 => "expert_ffn_q1",
        2 => "expert_ffn_q2",
        3 => "expert_ffn_q3",
        16 => "expert_ffn_fp",
        b => bail!("no artifact graph for {b}-bit experts"),
    })
}

impl<'a> PjrtBackend<'a> {
    /// Stage a quantized model. `warm` pre-compiles every needed
    /// (graph, bucket) executable.
    pub fn new(rt: &'a Runtime, q: &'a QuantModel, warm: bool) -> Result<PjrtBackend<'a>> {
        let cfg = &q.model.cfg;
        let mut staged = Vec::new();
        // staging materializes every expert as PJRT literals anyway, so a
        // paged store is streamed through (each handle dropped after its
        // literals are built — residency stays bounded by the budget)
        for l in 0..cfg.n_layers {
            let mut row = Vec::new();
            for idx in 0..cfg.n_experts {
                let e = q.store.get(l, idx)?;
                // AWQ-scaled experts ride the fp graph (see stage_linear)
                let graph = if matches!(e.wg, QuantLinear::Scaled { .. }) {
                    "expert_ffn_fp"
                } else {
                    graph_for_bits(e.bits)?
                };
                let mut args = Vec::new();
                stage_linear(&e.wg, &mut args)?;
                stage_linear(&e.wu, &mut args)?;
                stage_linear(&e.wd, &mut args)?;
                row.push(StagedExpert { graph, args });
            }
            staged.push(row);
        }
        // staging was a one-shot bulk read: drop whatever the store
        // cached for it and zero the gauges, so a paged store neither
        // strands budget-bytes of records nothing will read again nor
        // reports staging I/O as serving-time cache behaviour
        q.store.clear_cache();
        // shared experts ride the fp graph (they are 4-bit round-tripped
        // f32 in q.model)
        let mut staged_shared = Vec::new();
        for block in &q.model.blocks {
            let mut row = Vec::new();
            for s in &block.shared {
                let mut args = Vec::new();
                stage_linear(&QuantLinear::Fp(s.wg.clone()), &mut args)?;
                stage_linear(&QuantLinear::Fp(s.wu.clone()), &mut args)?;
                stage_linear(&QuantLinear::Fp(s.wd.clone()), &mut args)?;
                row.push(StagedExpert { graph: "expert_ffn_fp", args });
            }
            staged_shared.push(row);
        }
        let buckets = rt.manifest.buckets(&cfg.name, "expert_ffn_fp");
        if buckets.is_empty() {
            bail!("no artifacts for config {} — run `make artifacts`", cfg.name);
        }
        let be = PjrtBackend {
            rt,
            config_name: cfg.name.clone(),
            staged,
            staged_shared,
            buckets,
        };
        if warm {
            let mut graphs: Vec<&'static str> = vec!["expert_ffn_fp"];
            for row in &be.staged {
                for s in row {
                    if !graphs.contains(&s.graph) {
                        graphs.push(s.graph);
                    }
                }
            }
            for g in graphs {
                for &b in &be.buckets {
                    be.rt.warmup(&format!("{}_{g}_t{b}", be.config_name))?;
                }
            }
        }
        Ok(be)
    }

    fn run(&self, s: &StagedExpert, x: &Tensor2) -> Result<Tensor2> {
        let n = x.rows;
        let h = x.cols;
        let bucket = *self
            .buckets
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or(self.buckets.last().unwrap());
        if n > bucket {
            // split oversize blocks across bucket-size chunks
            let mut out = Tensor2::zeros(n, h);
            let mut i = 0;
            while i < n {
                let m = bucket.min(n - i);
                let chunk = Tensor2::from_vec(m, h, x.data[i * h..(i + m) * h].to_vec());
                let r = self.run(s, &chunk)?;
                out.data[i * h..(i + m) * h].copy_from_slice(&r.data);
                i += m;
            }
            return Ok(out);
        }
        let key = format!("{}_{}_t{}", self.config_name, s.graph, bucket);
        // pad token block to the bucket
        let mut padded = vec![0.0f32; bucket * h];
        padded[..n * h].copy_from_slice(&x.data);
        let x_lit = f32_literal(&padded, &[bucket, h])?;
        let mut args: Vec<&Literal> = Vec::with_capacity(1 + s.args.len());
        args.push(&x_lit);
        args.extend(s.args.iter());
        let outs = self.rt.execute(&key, &args)?;
        let y = to_f32(&outs[0])?;
        Ok(Tensor2::from_vec(n, h, y[..n * h].to_vec()))
    }
}

impl ExpertBackend for PjrtBackend<'_> {
    fn expert_batch(&self, layer: usize, expert: usize, x: &Tensor2) -> Result<Tensor2> {
        self.run(&self.staged[layer][expert], x)
    }

    fn shared_batch(&self, layer: usize, idx: usize, x: &Tensor2) -> Result<Tensor2> {
        self.run(&self.staged_shared[layer][idx], x)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// Integration tests (need `make artifacts`): rust/tests/pjrt_integration.rs
