//! A linear layer in any of the precisions MC# mixes: f32, bit-plane
//! packed 2–4-bit, or 1-bit binary. One enum so the expert engine and the
//! memory accounting treat them uniformly.

use crate::tensor::Tensor2;

use super::binary::BinaryMatrix;
use super::packed::PackedMatrix;

#[derive(Clone, Debug)]
pub enum QuantLinear {
    Fp(Tensor2),
    Packed(PackedMatrix),
    Binary(BinaryMatrix),
    /// AWQ-scaled packed weights: stored codes quantize `diag(s)·W`, the
    /// per-input-channel `inv_s = 1/s` is applied to the activation at
    /// matvec time (`y = (x ⊘ s) · Ŵ`). See `quant::awq`.
    Scaled { inv_s: Vec<f32>, inner: PackedMatrix },
}

impl QuantLinear {
    /// `y += x @ W` in whatever format the layer is stored.
    pub fn matvec_acc(&self, x: &[f32], y: &mut [f32]) {
        match self {
            QuantLinear::Fp(w) => {
                for (r, &xr) in x.iter().enumerate() {
                    if xr != 0.0 {
                        crate::tensor::axpy(xr, w.row(r), y);
                    }
                }
            }
            QuantLinear::Packed(p) => p.matvec_fused(x, y),
            QuantLinear::Binary(b) => b.matvec_fused(x, y),
            QuantLinear::Scaled { inv_s, inner } => {
                let xs: Vec<f32> =
                    x.iter().zip(inv_s).map(|(&v, &s)| v * s).collect();
                inner.matvec_fused(&xs, y);
            }
        }
    }

    /// Batched `y += x @ W` over a token block — packed/binary formats
    /// decode each weight tile once and reuse it for every row (the
    /// serving hot path; see `PackedMatrix::matmul_fused`).
    pub fn matmul_acc(&self, x: &Tensor2, y: &mut Tensor2) {
        match self {
            QuantLinear::Fp(w) => {
                for ti in 0..x.rows {
                    let yrow = y.row_mut(ti);
                    for (r, &xr) in x.row(ti).iter().enumerate() {
                        if xr != 0.0 {
                            crate::tensor::axpy(xr, w.row(r), yrow);
                        }
                    }
                }
            }
            QuantLinear::Packed(p) => p.matmul_fused(x, y),
            QuantLinear::Binary(b) => b.matmul_fused(x, y),
            QuantLinear::Scaled { inv_s, inner } => {
                let mut xs = x.clone();
                for ti in 0..xs.rows {
                    for (v, &s) in xs.row_mut(ti).iter_mut().zip(inv_s) {
                        *v *= s;
                    }
                }
                inner.matmul_fused(&xs, y);
            }
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            QuantLinear::Fp(w) => w.rows,
            QuantLinear::Packed(p) => p.d_in,
            QuantLinear::Binary(b) => b.d_in,
            QuantLinear::Scaled { inner, .. } => inner.d_in,
        }
    }

    pub fn d_out(&self) -> usize {
        match self {
            QuantLinear::Fp(w) => w.cols,
            QuantLinear::Packed(p) => p.d_out,
            QuantLinear::Binary(b) => b.d_out,
            QuantLinear::Scaled { inner, .. } => inner.d_out,
        }
    }

    /// Nominal code bit-width (f32 counted as 16 — the paper treats
    /// 16-bit as "one standard parameter").
    pub fn bits(&self) -> u8 {
        match self {
            QuantLinear::Fp(_) => 16,
            QuantLinear::Packed(p) => p.bits,
            QuantLinear::Binary(_) => 1,
            QuantLinear::Scaled { inner, .. } => inner.bits,
        }
    }

    /// Stored bytes (f32 counted at fp16 to match the paper's baseline).
    pub fn nbytes(&self) -> u64 {
        match self {
            QuantLinear::Fp(w) => (w.data.len() * 2) as u64,
            QuantLinear::Packed(p) => p.nbytes(),
            QuantLinear::Binary(b) => b.nbytes(),
            // inv_s stored at fp16 alongside the group scales
            QuantLinear::Scaled { inv_s, inner } => {
                inner.nbytes() + (inv_s.len() * 2) as u64
            }
        }
    }

    /// Dense f32 reconstruction (ε probes, PJRT staging of fp variants).
    pub fn dequantize(&self) -> Tensor2 {
        match self {
            QuantLinear::Fp(w) => w.clone(),
            QuantLinear::Packed(p) => p.dequantize(),
            QuantLinear::Binary(b) => b.dequantize(),
            QuantLinear::Scaled { inv_s, inner } => {
                let mut w = inner.dequantize();
                for r in 0..w.rows {
                    let s = inv_s[r];
                    for v in w.row_mut(r) {
                        *v *= s;
                    }
                }
                w
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::quantize_rtn;
    use crate::util::rng::Rng;

    #[test]
    fn formats_agree_on_matvec_of_their_own_dequant() {
        let mut rng = Rng::new(30);
        let w = Tensor2::randn(64, 16, &mut rng, 1.0);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let variants: Vec<QuantLinear> = vec![
            QuantLinear::Fp(w.clone()),
            {
                let (c, s, z) = quantize_rtn(&w, 3, 32);
                QuantLinear::Packed(PackedMatrix::from_codes(&c, s, z, 64, 16, 3, 32))
            },
            QuantLinear::Binary(BinaryMatrix::binarize(&w)),
        ];
        for v in &variants {
            let wd = v.dequantize();
            let mut want = vec![0.0f32; 16];
            for (r, &xr) in x.iter().enumerate() {
                for o in 0..16 {
                    want[o] += xr * wd.at(r, o);
                }
            }
            let mut got = vec![0.0f32; 16];
            v.matvec_acc(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn nbytes_ordering() {
        let mut rng = Rng::new(31);
        let w = Tensor2::randn(128, 64, &mut rng, 1.0);
        let fp = QuantLinear::Fp(w.clone());
        let (c, s, z) = quantize_rtn(&w, 2, 32);
        let p2 = QuantLinear::Packed(PackedMatrix::from_codes(&c, s, z, 128, 64, 2, 32));
        let b1 = QuantLinear::Binary(BinaryMatrix::binarize(&w));
        assert!(b1.nbytes() < p2.nbytes());
        assert!(p2.nbytes() < fp.nbytes());
    }
}
