//! A linear layer in any of the precisions MC# mixes: f32, bit-plane
//! packed 2–4-bit, or 1-bit binary. One enum so the expert engine and the
//! memory accounting treat them uniformly.

use crate::tensor::Tensor2;

use super::binary::BinaryMatrix;
use super::kernels::{self, Scratch};
use super::packed::PackedMatrix;

#[derive(Clone, Debug)]
pub enum QuantLinear {
    Fp(Tensor2),
    Packed(PackedMatrix),
    Binary(BinaryMatrix),
    /// AWQ-scaled packed weights: stored codes quantize `diag(s)·W`, the
    /// per-input-channel `inv_s = 1/s` is applied to the activation at
    /// matvec time (`y = (x ⊘ s) · Ŵ`). See `quant::awq`.
    Scaled { inv_s: Vec<f32>, inner: PackedMatrix },
}

impl QuantLinear {
    /// `y += x @ W` in whatever format the layer is stored.
    pub fn matvec_acc(&self, x: &[f32], y: &mut [f32]) {
        kernels::with_scratch(|s| self.matvec_acc_sc(x, y, s));
    }

    /// Scratch-threaded variant of [`matvec_acc`](Self::matvec_acc) for
    /// callers that already hold the thread's kernel scratch — the
    /// steady-state decode path allocates nothing, including the AWQ
    /// `Scaled` activation rescale (folded into the kernel prologue via a
    /// scratch buffer instead of a per-call `Vec`).
    pub fn matvec_acc_sc(&self, x: &[f32], y: &mut [f32], s: &mut Scratch) {
        match self {
            QuantLinear::Fp(w) => {
                for (r, &xr) in x.iter().enumerate() {
                    if xr != 0.0 {
                        crate::tensor::axpy(xr, w.row(r), y);
                    }
                }
            }
            QuantLinear::Packed(p) => kernels::packed_matvec(p, x, y, s),
            QuantLinear::Binary(b) => kernels::binary_matvec(b, x, y, s),
            QuantLinear::Scaled { inv_s, inner } => {
                kernels::packed_matvec_scaled(inner, inv_s, x, y, s)
            }
        }
    }

    /// Batched `y += x @ W` over a token block — packed/binary formats
    /// decode each weight tile once and reuse it for every row (the
    /// serving hot path; see `PackedMatrix::matmul_fused`).
    pub fn matmul_acc(&self, x: &Tensor2, y: &mut Tensor2) {
        assert_eq!(x.cols, self.d_in());
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out()));
        kernels::with_scratch(|s| self.matmul_acc_sc(&x.data, x.rows, &mut y.data, s));
    }

    /// Scratch-threaded batched accumulate over `t` row-major tokens
    /// (`x: [t, d_in]`, `y: [t, d_out]`). Same zero-allocation contract
    /// as [`matvec_acc_sc`](Self::matvec_acc_sc).
    pub fn matmul_acc_sc(&self, x: &[f32], t: usize, y: &mut [f32], s: &mut Scratch) {
        match self {
            QuantLinear::Fp(w) => {
                for ti in 0..t {
                    let yrow = &mut y[ti * w.cols..][..w.cols];
                    let xrow = &x[ti * w.rows..][..w.rows];
                    for (r, &xr) in xrow.iter().enumerate() {
                        if xr != 0.0 {
                            crate::tensor::axpy(xr, w.row(r), yrow);
                        }
                    }
                }
            }
            QuantLinear::Packed(p) => kernels::packed_matmul(p, x, t, y, s),
            QuantLinear::Binary(b) => kernels::binary_matmul(b, x, t, y, s),
            QuantLinear::Scaled { inv_s, inner } => {
                kernels::packed_matmul_scaled(inner, inv_s, x, t, y, s)
            }
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            QuantLinear::Fp(w) => w.rows,
            QuantLinear::Packed(p) => p.d_in,
            QuantLinear::Binary(b) => b.d_in,
            QuantLinear::Scaled { inner, .. } => inner.d_in,
        }
    }

    pub fn d_out(&self) -> usize {
        match self {
            QuantLinear::Fp(w) => w.cols,
            QuantLinear::Packed(p) => p.d_out,
            QuantLinear::Binary(b) => b.d_out,
            QuantLinear::Scaled { inner, .. } => inner.d_out,
        }
    }

    /// Nominal code bit-width (f32 counted as 16 — the paper treats
    /// 16-bit as "one standard parameter").
    pub fn bits(&self) -> u8 {
        match self {
            QuantLinear::Fp(_) => 16,
            QuantLinear::Packed(p) => p.bits,
            QuantLinear::Binary(_) => 1,
            QuantLinear::Scaled { inner, .. } => inner.bits,
        }
    }

    /// Stored bytes (f32 counted at fp16 to match the paper's baseline).
    pub fn nbytes(&self) -> u64 {
        match self {
            QuantLinear::Fp(w) => (w.data.len() * 2) as u64,
            QuantLinear::Packed(p) => p.nbytes(),
            QuantLinear::Binary(b) => b.nbytes(),
            // inv_s stored at fp16 alongside the group scales
            QuantLinear::Scaled { inv_s, inner } => {
                inner.nbytes() + (inv_s.len() * 2) as u64
            }
        }
    }

    /// Dense f32 reconstruction (ε probes, PJRT staging of fp variants).
    pub fn dequantize(&self) -> Tensor2 {
        match self {
            QuantLinear::Fp(w) => w.clone(),
            QuantLinear::Packed(p) => p.dequantize(),
            QuantLinear::Binary(b) => b.dequantize(),
            QuantLinear::Scaled { inv_s, inner } => {
                let mut w = inner.dequantize();
                for r in 0..w.rows {
                    let s = inv_s[r];
                    for v in w.row_mut(r) {
                        *v *= s;
                    }
                }
                w
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::quantize_rtn;
    use crate::util::rng::Rng;

    #[test]
    fn formats_agree_on_matvec_of_their_own_dequant() {
        let mut rng = Rng::new(30);
        let w = Tensor2::randn(64, 16, &mut rng, 1.0);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let variants: Vec<QuantLinear> = vec![
            QuantLinear::Fp(w.clone()),
            {
                let (c, s, z) = quantize_rtn(&w, 3, 32);
                QuantLinear::Packed(PackedMatrix::from_codes(&c, s, z, 64, 16, 3, 32))
            },
            QuantLinear::Binary(BinaryMatrix::binarize(&w)),
        ];
        for v in &variants {
            let wd = v.dequantize();
            let mut want = vec![0.0f32; 16];
            for (r, &xr) in x.iter().enumerate() {
                for o in 0..16 {
                    want[o] += xr * wd.at(r, o);
                }
            }
            let mut got = vec![0.0f32; 16];
            v.matvec_acc(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn nbytes_ordering() {
        let mut rng = Rng::new(31);
        let w = Tensor2::randn(128, 64, &mut rng, 1.0);
        let fp = QuantLinear::Fp(w.clone());
        let (c, s, z) = quantize_rtn(&w, 2, 32);
        let p2 = QuantLinear::Packed(PackedMatrix::from_codes(&c, s, z, 128, 64, 2, 32));
        let b1 = QuantLinear::Binary(BinaryMatrix::binarize(&w));
        assert!(b1.nbytes() < p2.nbytes());
        assert!(p2.nbytes() < fp.nbytes());
    }
}
