//! Quantized-model checkpoints — PMQ's *pre-loading* artifact (paper
//! §3.2/§3.3): the packed expert planes, group scale/zero vectors, the
//! bit allocation and the 4-bit-round-tripped dense weights, all in one
//! streamable file. `compress` writes it once; `serve`/`eval` load it
//! without re-running calibration or GPTQ — exactly the deployment story
//! the paper's "pre-loading" phase describes.
//!
//! Layout: `MCSHARPQ1` magic, u64-length JSON header (model config + PMQ
//! hyper-params + allocation), the dense base payload (same field order
//! as `moe::checkpoint`, *without* the routed experts — those live only
//! in packed form), then one tagged [`QuantLinear`] record per expert
//! matrix.

use std::io::{BufReader, BufWriter, Read, Write};

use anyhow::{bail, Context, Result};

use crate::config::PmqConfig;
use crate::moe::model::MoeModel;
use crate::tensor::Tensor2;
use crate::util::json::{self, Value};

use super::binary::BinaryMatrix;
use super::packed::PackedMatrix;
use super::qlinear::QuantLinear;
use super::qmodel::{QuantExpert, QuantModel};

const MAGIC: &[u8; 9] = b"MCSHARPQ1";

// ------------------------------------------------------------ primitives

fn write_f32s(w: &mut impl Write, v: &[f32]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_bytes(r: &mut impl Read, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

// ----------------------------------------------------- QuantLinear codec

const TAG_FP: u8 = 0;
const TAG_PACKED: u8 = 1;
const TAG_BINARY: u8 = 2;
const TAG_SCALED: u8 = 3;

fn write_packed(w: &mut impl Write, p: &PackedMatrix) -> Result<()> {
    w.write_all(&[p.bits])?;
    write_u64(w, p.d_in as u64)?;
    write_u64(w, p.d_out as u64)?;
    write_u64(w, p.group as u64)?;
    w.write_all(&p.planes)?;
    write_f32s(w, &p.scales)?;
    write_f32s(w, &p.zeros)?;
    Ok(())
}

fn read_packed(r: &mut impl Read) -> Result<PackedMatrix> {
    let mut bits = [0u8; 1];
    r.read_exact(&mut bits)?;
    let bits = bits[0];
    let d_in = read_u64(r)? as usize;
    let d_out = read_u64(r)? as usize;
    let group = read_u64(r)? as usize;
    if bits == 0 || bits > 8 || d_in == 0 || d_out == 0 || group == 0 || d_in % 8 != 0 {
        bail!("corrupt packed-matrix header (bits {bits}, {d_in}x{d_out}, group {group})");
    }
    let planes = read_bytes(r, bits as usize * d_in / 8 * d_out)?;
    let n_groups = d_in / group;
    let scales = read_f32s(r, n_groups * d_out)?;
    let zeros = read_f32s(r, n_groups * d_out)?;
    Ok(PackedMatrix { d_in, d_out, bits, group, planes, scales, zeros })
}

fn write_qlinear(w: &mut impl Write, q: &QuantLinear) -> Result<()> {
    match q {
        QuantLinear::Fp(t) => {
            w.write_all(&[TAG_FP])?;
            write_u64(w, t.rows as u64)?;
            write_u64(w, t.cols as u64)?;
            write_f32s(w, &t.data)?;
        }
        QuantLinear::Packed(p) => {
            w.write_all(&[TAG_PACKED])?;
            write_packed(w, p)?;
        }
        QuantLinear::Binary(b) => {
            w.write_all(&[TAG_BINARY])?;
            write_u64(w, b.d_in as u64)?;
            write_u64(w, b.d_out as u64)?;
            w.write_all(&b.plane)?;
            write_f32s(w, &b.alpha)?;
        }
        QuantLinear::Scaled { inv_s, inner } => {
            w.write_all(&[TAG_SCALED])?;
            write_u64(w, inv_s.len() as u64)?;
            write_f32s(w, inv_s)?;
            write_packed(w, inner)?;
        }
    }
    Ok(())
}

fn read_qlinear(r: &mut impl Read) -> Result<QuantLinear> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    Ok(match tag[0] {
        TAG_FP => {
            let rows = read_u64(r)? as usize;
            let cols = read_u64(r)? as usize;
            if rows == 0 || cols == 0 || rows * cols > (1 << 30) {
                bail!("corrupt fp tensor header {rows}x{cols}");
            }
            QuantLinear::Fp(Tensor2::from_vec(rows, cols, read_f32s(r, rows * cols)?))
        }
        TAG_PACKED => QuantLinear::Packed(read_packed(r)?),
        TAG_BINARY => {
            let d_in = read_u64(r)? as usize;
            let d_out = read_u64(r)? as usize;
            if d_in == 0 || d_out == 0 || d_in % 8 != 0 {
                bail!("corrupt binary-matrix header {d_in}x{d_out}");
            }
            let plane = read_bytes(r, d_in / 8 * d_out)?;
            let alpha = read_f32s(r, d_out)?;
            QuantLinear::Binary(BinaryMatrix { d_in, d_out, plane, alpha })
        }
        TAG_SCALED => {
            let n = read_u64(r)? as usize;
            if n == 0 || n > (1 << 24) {
                bail!("corrupt scaled-matrix header (inv_s len {n})");
            }
            let inv_s = read_f32s(r, n)?;
            let inner = read_packed(r)?;
            if inner.d_in != n {
                bail!("inv_s length {n} != packed d_in {}", inner.d_in);
            }
            QuantLinear::Scaled { inv_s, inner }
        }
        t => bail!("unknown QuantLinear tag {t}"),
    })
}

// ------------------------------------------------------------- top level

fn pmq_json(p: &PmqConfig, allocation: &[Vec<u8>]) -> Value {
    json::obj(vec![
        ("alpha", json::num(p.alpha)),
        ("beta", json::num(p.beta)),
        ("gamma", json::num(p.gamma)),
        (
            "bit_options",
            Value::Arr(p.bit_options.iter().map(|&b| json::num(b as f64)).collect()),
        ),
        ("other_bits", json::num(p.other_bits as f64)),
        ("group", json::num(p.group as f64)),
        (
            "allocation",
            Value::Arr(
                allocation
                    .iter()
                    .map(|row| {
                        Value::Arr(row.iter().map(|&b| json::num(b as f64)).collect())
                    })
                    .collect(),
            ),
        ),
    ])
}

fn pmq_from_json(v: &Value) -> Result<(PmqConfig, Vec<Vec<u8>>)> {
    let pmq = PmqConfig {
        alpha: v.get("alpha")?.as_f64()?,
        beta: v.get("beta")?.as_f64()?,
        gamma: v.get("gamma")?.as_f64()?,
        bit_options: v
            .get("bit_options")?
            .as_arr()?
            .iter()
            .map(|b| Ok(b.as_usize()? as u8))
            .collect::<Result<_>>()?,
        other_bits: v.get("other_bits")?.as_usize()? as u8,
        group: v.get("group")?.as_usize()?,
    };
    let allocation = v
        .get("allocation")?
        .as_arr()?
        .iter()
        .map(|row| {
            row.as_arr()?
                .iter()
                .map(|b| Ok(b.as_usize()? as u8))
                .collect::<Result<Vec<u8>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((pmq, allocation))
}

/// Save a quantized model (packed experts + 4-bit-round-tripped dense
/// base) to `path`.
pub fn save(q: &QuantModel, path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    let header = json::obj(vec![
        ("config", config_json(&q.model)),
        ("pmq", pmq_json(&q.pmq, &q.allocation)),
    ])
    .to_json();
    write_u64(&mut w, header.len() as u64)?;
    w.write_all(header.as_bytes())?;
    // dense base (routed experts excluded — they only exist packed)
    write_f32s(&mut w, &q.model.embed.data)?;
    for b in &q.model.blocks {
        write_f32s(&mut w, &b.attn_norm)?;
        for t in [&b.attn.wq, &b.attn.wk, &b.attn.wv, &b.attn.wo] {
            write_f32s(&mut w, &t.data)?;
        }
        write_f32s(&mut w, &b.moe_norm)?;
        write_f32s(&mut w, &b.gate.data)?;
        for e in &b.shared {
            write_f32s(&mut w, &e.wg.data)?;
            write_f32s(&mut w, &e.wu.data)?;
            write_f32s(&mut w, &e.wd.data)?;
        }
    }
    write_f32s(&mut w, &q.model.final_norm)?;
    write_f32s(&mut w, &q.model.lm_head.data)?;
    // packed experts
    for row in &q.experts {
        for e in row {
            w.write_all(&[e.bits])?;
            write_qlinear(&mut w, &e.wg)?;
            write_qlinear(&mut w, &e.wu)?;
            write_qlinear(&mut w, &e.wd)?;
        }
    }
    w.flush()?;
    Ok(())
}

fn config_json(m: &MoeModel) -> Value {
    let c = &m.cfg;
    json::obj(vec![
        ("name", json::s(&c.name)),
        ("family", json::s(&c.family)),
        ("vocab_size", json::num(c.vocab_size as f64)),
        ("d_model", json::num(c.d_model as f64)),
        ("n_layers", json::num(c.n_layers as f64)),
        ("n_heads", json::num(c.n_heads as f64)),
        ("d_ff", json::num(c.d_ff as f64)),
        ("n_experts", json::num(c.n_experts as f64)),
        ("top_k", json::num(c.top_k as f64)),
        ("n_shared_experts", json::num(c.n_shared_experts as f64)),
        ("max_seq_len", json::num(c.max_seq_len as f64)),
        ("rope_theta", json::num(c.rope_theta as f64)),
        ("modalities", json::num(c.modalities as f64)),
        (
            "buckets",
            Value::Arr(c.buckets.iter().map(|&b| json::num(b as f64)).collect()),
        ),
    ])
}

/// Load a quantized model saved by [`save`].
pub fn load(path: &str) -> Result<QuantModel> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 9];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path}: not an MC# quantized checkpoint");
    }
    let hlen = read_u64(&mut r)? as usize;
    if hlen > (1 << 24) {
        bail!("{path}: implausible header length {hlen}");
    }
    let header = read_bytes(&mut r, hlen)?;
    let v = Value::parse(std::str::from_utf8(&header)?)?;
    let cfg = crate::config::ModelConfig::from_json(v.get("config")?)?;
    let (pmq, allocation) = pmq_from_json(v.get("pmq")?)?;
    if allocation.len() != cfg.n_layers
        || allocation.iter().any(|row| row.len() != cfg.n_experts)
    {
        bail!("{path}: allocation shape does not match config");
    }
    // dense base — routed experts are placeholders (provider intercepts)
    let h = cfg.d_model;
    let read_t = |r: &mut BufReader<std::fs::File>, rows: usize, cols: usize| -> Result<Tensor2> {
        Ok(Tensor2::from_vec(rows, cols, read_f32s(r, rows * cols)?))
    };
    let embed = read_t(&mut r, cfg.vocab_size, h)?;
    let mut blocks = Vec::new();
    for _ in 0..cfg.n_layers {
        let attn_norm = read_f32s(&mut r, h)?;
        let wq = read_t(&mut r, h, h)?;
        let wk = read_t(&mut r, h, h)?;
        let wv = read_t(&mut r, h, h)?;
        let wo = read_t(&mut r, h, h)?;
        let moe_norm = read_f32s(&mut r, h)?;
        let gate = read_t(&mut r, h, cfg.n_experts)?;
        let shared: Vec<crate::moe::Expert> = (0..cfg.n_shared_experts)
            .map(|_| {
                Ok(crate::moe::Expert {
                    wg: read_t(&mut r, h, cfg.d_ff)?,
                    wu: read_t(&mut r, h, cfg.d_ff)?,
                    wd: read_t(&mut r, cfg.d_ff, h)?,
                })
            })
            .collect::<Result<_>>()?;
        // routed experts: zero placeholders (never read at inference)
        let experts: Vec<crate::moe::Expert> = (0..cfg.n_experts)
            .map(|_| crate::moe::Expert {
                wg: Tensor2::zeros(h, cfg.d_ff),
                wu: Tensor2::zeros(h, cfg.d_ff),
                wd: Tensor2::zeros(cfg.d_ff, h),
            })
            .collect();
        blocks.push(crate::moe::model::Block {
            attn_norm,
            attn: crate::moe::attention::Attention {
                wq,
                wk,
                wv,
                wo,
                n_heads: cfg.n_heads,
                rope_theta: cfg.rope_theta,
            },
            moe_norm,
            gate,
            experts,
            shared,
        });
    }
    let final_norm = read_f32s(&mut r, h)?;
    let lm_head = read_t(&mut r, h, cfg.vocab_size)?;
    let model = MoeModel { cfg: cfg.clone(), embed, blocks, final_norm, lm_head };
    // packed experts
    let mut experts = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let mut row = Vec::with_capacity(cfg.n_experts);
        for e in 0..cfg.n_experts {
            let mut bits = [0u8; 1];
            r.read_exact(&mut bits)?;
            if bits[0] != allocation[l][e] && bits[0] != 16 {
                bail!("{path}: expert ({l},{e}) bits {} != allocation {}", bits[0], allocation[l][e]);
            }
            row.push(QuantExpert {
                wg: read_qlinear(&mut r)?,
                wu: read_qlinear(&mut r)?,
                wd: read_qlinear(&mut r)?,
                bits: bits[0],
            });
        }
        experts.push(row);
    }
    Ok(QuantModel { model, experts, allocation, pmq })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::ForwardOpts;
    use crate::quant::qmodel::QuantMethod;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "qckpt-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 32,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    fn tmppath(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("mcsharp-qckpt-{name}-{}.bin", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn roundtrip_preserves_forward_exactly() {
        let base = MoeModel::new(&cfg(), 50);
        let alloc = vec![vec![1u8, 2, 3, 2], vec![2, 3, 1, 2]];
        let pmq = PmqConfig::default();
        let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Rtn);
        let path = tmppath("rt");
        save(&q, &path).unwrap();
        let q2 = load(&path).unwrap();
        assert_eq!(q2.allocation, alloc);
        assert_eq!(q2.pmq.group, pmq.group);
        let toks: Vec<u16> = vec![1, 9, 30, 45, 8, 22];
        let a = q
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        let b = q2
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q2), ..Default::default() });
        assert_eq!(a.data, b.data, "quantized forward changed across save/load");
        assert_eq!(q.nbytes(), q2.nbytes());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_scaled_awq_variant() {
        let base = MoeModel::new(&cfg(), 51);
        let toks: Vec<u16> = (0..24).map(|i| (i * 5 % 60 + 1) as u16).collect();
        let mut captured: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 2];
        base.forward_opts(
            &toks,
            &mut ForwardOpts { capture_moe_inputs: Some(&mut captured), ..Default::default() },
        );
        let acts: Vec<crate::quant::error::LayerActivations> = captured
            .into_iter()
            .map(|xs| crate::quant::error::LayerActivations { xs })
            .collect();
        let alloc = vec![vec![2u8; 4]; 2];
        let q = QuantModel::quantize(
            &base,
            &alloc,
            &PmqConfig::default(),
            &QuantMethod::Awq(&acts),
        );
        let path = tmppath("awq");
        save(&q, &path).unwrap();
        let q2 = load(&path).unwrap();
        let a = q
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        let b = q2
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q2), ..Default::default() });
        assert_eq!(a.data, b.data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_qcheckpoint_is_an_error() {
        let base = MoeModel::new(&cfg(), 52);
        let q = QuantModel::quantize(
            &base,
            &vec![vec![2u8; 4]; 2],
            &PmqConfig::default(),
            &QuantMethod::Rtn,
        );
        let path = tmppath("trunc");
        save(&q, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() * 2 / 3]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_is_an_error() {
        let path = tmppath("magic");
        std::fs::write(&path, b"MCSHARP1\0garbage....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_smaller_than_fp16_checkpoint() {
        let base = MoeModel::new(&cfg(), 53);
        let q = QuantModel::quantize(
            &base,
            &vec![vec![2u8; 4]; 2],
            &PmqConfig::default(),
            &QuantMethod::Rtn,
        );
        let qpath = tmppath("size-q");
        let fpath = tmppath("size-f");
        save(&q, &qpath).unwrap();
        base.save(&fpath).unwrap();
        let qsize = std::fs::metadata(&qpath).unwrap().len();
        let fsize = std::fs::metadata(&fpath).unwrap().len();
        // dense base dominates at this toy size, but the packed expert
        // payload must still shrink the file
        assert!(qsize < fsize, "quantized {qsize} !< fp {fsize}");
        std::fs::remove_file(&qpath).ok();
        std::fs::remove_file(&fpath).ok();
    }
}
