//! Quantized-model checkpoints — PMQ's *pre-loading* artifact (paper
//! §3.2/§3.3): the packed expert planes, group scale/zero vectors, the
//! bit allocation and the 4-bit-round-tripped dense weights, all in one
//! streamable file. `compress` writes it once; `serve`/`eval` load it
//! without re-running calibration or GPTQ — exactly the deployment story
//! the paper's "pre-loading" phase describes.
//!
//! v1 layout (`MCSHARPQ1`): magic, u64-length JSON header (model config
//! + PMQ hyper-params + allocation), the dense base payload (same field
//! order as `moe::checkpoint`, *without* the routed experts — those live
//! only in packed form), then one [`QuantExpert`] record (bits byte +
//! three tagged [`QuantLinear`]s) per routed expert, streamed in layer
//! -major order.
//!
//! v2 layout (`MCSHARPQ2`, written by [`save`]): same magic/header shape
//! plus a per-expert **index table** — `n_layers * n_experts` entries of
//! `(layer, expert, offset, len)` little-endian u64s — directly after
//! the header and before the dense base, so each expert record is
//! independently seekable. That is what lets [`load_paged`] serve a
//! model whose packed experts never fully enter RAM (`quant::store`'s
//! `PagedStore`): the deployment half of the paper's "pre-loading" story.
//! The v2 header additionally carries `expert_nbytes` (per-expert packed
//! sizes, so budget accounting never faults a record in) and, when
//! calibrated, `importance` (PMQ significance, the eviction tie-break).
//! v1 files stay readable via [`load`]; [`save_v1`] keeps a writer for
//! them.

use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, PmqConfig};
use crate::moe::model::MoeModel;
use crate::tensor::Tensor2;
use crate::util::json::{self, Value};
use crate::util::mmap::Mmap;

use super::binary::BinaryMatrix;
use super::packed::PackedMatrix;
use super::qlinear::QuantLinear;
use super::qmodel::{QuantExpert, QuantModel};
use super::store::{PagedStore, RecordSource, ResidentStore};

const MAGIC_V1: &[u8; 9] = b"MCSHARPQ1";
const MAGIC_V2: &[u8; 9] = b"MCSHARPQ2";

// ------------------------------------------------------------ primitives

fn write_f32s(w: &mut impl Write, v: &[f32]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_bytes(r: &mut impl Read, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

// ----------------------------------------------------- QuantLinear codec

const TAG_FP: u8 = 0;
const TAG_PACKED: u8 = 1;
const TAG_BINARY: u8 = 2;
const TAG_SCALED: u8 = 3;

fn write_packed(w: &mut impl Write, p: &PackedMatrix) -> Result<()> {
    w.write_all(&[p.bits])?;
    write_u64(w, p.d_in as u64)?;
    write_u64(w, p.d_out as u64)?;
    write_u64(w, p.group as u64)?;
    w.write_all(&p.planes)?;
    write_f32s(w, &p.scales)?;
    write_f32s(w, &p.zeros)?;
    Ok(())
}

fn read_packed(r: &mut impl Read) -> Result<PackedMatrix> {
    let mut bits = [0u8; 1];
    r.read_exact(&mut bits)?;
    let bits = bits[0];
    let d_in = read_u64(r)? as usize;
    let d_out = read_u64(r)? as usize;
    let group = read_u64(r)? as usize;
    if bits == 0
        || bits > 8
        || d_in == 0
        || d_out == 0
        || group == 0
        || d_in % 8 != 0
        || d_in % group != 0
        || group % 8 != 0
    {
        bail!("corrupt packed-matrix header (bits {bits}, {d_in}x{d_out}, group {group})");
    }
    let planes = read_bytes(r, bits as usize * d_in / 8 * d_out)?;
    let n_groups = d_in / group;
    let scales = read_f32s(r, n_groups * d_out)?;
    let zeros = read_f32s(r, n_groups * d_out)?;
    // from_parts builds the kernel repack eagerly, so a freshly loaded
    // checkpoint pays the interleave cost here, not on the first decode.
    Ok(PackedMatrix::from_parts(planes, scales, zeros, d_in, d_out, bits, group))
}

fn write_qlinear(w: &mut impl Write, q: &QuantLinear) -> Result<()> {
    match q {
        QuantLinear::Fp(t) => {
            w.write_all(&[TAG_FP])?;
            write_u64(w, t.rows as u64)?;
            write_u64(w, t.cols as u64)?;
            write_f32s(w, &t.data)?;
        }
        QuantLinear::Packed(p) => {
            w.write_all(&[TAG_PACKED])?;
            write_packed(w, p)?;
        }
        QuantLinear::Binary(b) => {
            w.write_all(&[TAG_BINARY])?;
            write_u64(w, b.d_in as u64)?;
            write_u64(w, b.d_out as u64)?;
            w.write_all(&b.plane)?;
            write_f32s(w, &b.alpha)?;
        }
        QuantLinear::Scaled { inv_s, inner } => {
            w.write_all(&[TAG_SCALED])?;
            write_u64(w, inv_s.len() as u64)?;
            write_f32s(w, inv_s)?;
            write_packed(w, inner)?;
        }
    }
    Ok(())
}

fn read_qlinear(r: &mut impl Read) -> Result<QuantLinear> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    Ok(match tag[0] {
        TAG_FP => {
            let rows = read_u64(r)? as usize;
            let cols = read_u64(r)? as usize;
            if rows == 0 || cols == 0 || rows * cols > (1 << 30) {
                bail!("corrupt fp tensor header {rows}x{cols}");
            }
            QuantLinear::Fp(Tensor2::from_vec(rows, cols, read_f32s(r, rows * cols)?))
        }
        TAG_PACKED => QuantLinear::Packed(read_packed(r)?),
        TAG_BINARY => {
            let d_in = read_u64(r)? as usize;
            let d_out = read_u64(r)? as usize;
            if d_in == 0 || d_out == 0 || d_in % 8 != 0 {
                bail!("corrupt binary-matrix header {d_in}x{d_out}");
            }
            let plane = read_bytes(r, d_in / 8 * d_out)?;
            let alpha = read_f32s(r, d_out)?;
            QuantLinear::Binary(BinaryMatrix::from_parts(plane, alpha, d_in, d_out))
        }
        TAG_SCALED => {
            let n = read_u64(r)? as usize;
            if n == 0 || n > (1 << 24) {
                bail!("corrupt scaled-matrix header (inv_s len {n})");
            }
            let inv_s = read_f32s(r, n)?;
            let inner = read_packed(r)?;
            if inner.d_in != n {
                bail!("inv_s length {n} != packed d_in {}", inner.d_in);
            }
            QuantLinear::Scaled { inv_s, inner }
        }
        t => bail!("unknown QuantLinear tag {t}"),
    })
}

// ------------------------------------------------------------- top level

fn pmq_json(p: &PmqConfig, allocation: &[Vec<u8>]) -> Value {
    json::obj(vec![
        ("alpha", json::num(p.alpha)),
        ("beta", json::num(p.beta)),
        ("gamma", json::num(p.gamma)),
        (
            "bit_options",
            Value::Arr(p.bit_options.iter().map(|&b| json::num(b as f64)).collect()),
        ),
        ("other_bits", json::num(p.other_bits as f64)),
        ("group", json::num(p.group as f64)),
        (
            "allocation",
            Value::Arr(
                allocation
                    .iter()
                    .map(|row| {
                        Value::Arr(row.iter().map(|&b| json::num(b as f64)).collect())
                    })
                    .collect(),
            ),
        ),
    ])
}

fn pmq_from_json(v: &Value) -> Result<(PmqConfig, Vec<Vec<u8>>)> {
    let pmq = PmqConfig {
        alpha: v.get("alpha")?.as_f64()?,
        beta: v.get("beta")?.as_f64()?,
        gamma: v.get("gamma")?.as_f64()?,
        bit_options: v
            .get("bit_options")?
            .as_arr()?
            .iter()
            .map(|b| Ok(b.as_usize()? as u8))
            .collect::<Result<_>>()?,
        other_bits: v.get("other_bits")?.as_usize()? as u8,
        group: v.get("group")?.as_usize()?,
    };
    let allocation = v
        .get("allocation")?
        .as_arr()?
        .iter()
        .map(|row| {
            row.as_arr()?
                .iter()
                .map(|b| Ok(b.as_usize()? as u8))
                .collect::<Result<Vec<u8>>>()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((pmq, allocation))
}

/// One packed expert record: bits byte + wg/wu/wd [`QuantLinear`]s. The
/// unit of the v2 index — independently decodable from its `(offset,
/// len)` span.
fn write_expert_record(w: &mut impl Write, e: &QuantExpert) -> Result<()> {
    w.write_all(&[e.bits])?;
    write_qlinear(w, &e.wg)?;
    write_qlinear(w, &e.wu)?;
    write_qlinear(w, &e.wd)?;
    Ok(())
}

fn read_expert_record(r: &mut impl Read) -> Result<QuantExpert> {
    let mut bits = [0u8; 1];
    r.read_exact(&mut bits)?;
    Ok(QuantExpert {
        wg: read_qlinear(r)?,
        wu: read_qlinear(r)?,
        wd: read_qlinear(r)?,
        bits: bits[0],
    })
}

/// Decode one expert record from a raw indexed span — a v2 `(offset,
/// len)` slice or a shard `REC` payload. The buffer must be exactly one
/// record; trailing bytes mean a corrupt index or a framing bug.
pub fn decode_expert_record(buf: &[u8]) -> Result<QuantExpert> {
    let mut r = buf;
    let rec = read_expert_record(&mut r)?;
    if !r.is_empty() {
        bail!("{} trailing bytes after expert record", r.len());
    }
    Ok(rec)
}

/// Dense base payload (routed experts excluded — they only exist packed).
fn write_dense_base(w: &mut impl Write, m: &MoeModel) -> Result<()> {
    write_f32s(w, &m.embed.data)?;
    for b in &m.blocks {
        write_f32s(w, &b.attn_norm)?;
        for t in [&b.attn.wq, &b.attn.wk, &b.attn.wv, &b.attn.wo] {
            write_f32s(w, &t.data)?;
        }
        write_f32s(w, &b.moe_norm)?;
        write_f32s(w, &b.gate.data)?;
        for e in &b.shared {
            write_f32s(w, &e.wg.data)?;
            write_f32s(w, &e.wu.data)?;
            write_f32s(w, &e.wd.data)?;
        }
    }
    write_f32s(w, &m.final_norm)?;
    write_f32s(w, &m.lm_head.data)?;
    Ok(())
}

fn read_t(r: &mut impl Read, rows: usize, cols: usize) -> Result<Tensor2> {
    Ok(Tensor2::from_vec(rows, cols, read_f32s(r, rows * cols)?))
}

/// Dense base. Routed experts are not in the payload (they only exist
/// packed); `with_placeholders` controls what stands in for them:
/// full-size zero tensors (legacy [`load`] shape, and the only shape a
/// provider-less forward can survive) or nothing at all — store-backed
/// loads ([`load_paged`]/[`load_remote`]) always route expert math
/// through the store, so the placeholders were pure footprint: 3 zero
/// `d_model x d_ff` tensors per expert per layer of RAM the paging
/// budget never saw.
fn read_dense_base(r: &mut impl Read, cfg: &ModelConfig, with_placeholders: bool) -> Result<MoeModel> {
    let h = cfg.d_model;
    let embed = read_t(r, cfg.vocab_size, h)?;
    let mut blocks = Vec::new();
    for _ in 0..cfg.n_layers {
        let attn_norm = read_f32s(r, h)?;
        let wq = read_t(r, h, h)?;
        let wk = read_t(r, h, h)?;
        let wv = read_t(r, h, h)?;
        let wo = read_t(r, h, h)?;
        let moe_norm = read_f32s(r, h)?;
        let gate = read_t(r, h, cfg.n_experts)?;
        let shared: Vec<crate::moe::Expert> = (0..cfg.n_shared_experts)
            .map(|_| {
                Ok(crate::moe::Expert {
                    wg: read_t(r, h, cfg.d_ff)?,
                    wu: read_t(r, h, cfg.d_ff)?,
                    wd: read_t(r, cfg.d_ff, h)?,
                })
            })
            .collect::<Result<_>>()?;
        let n_placeholders = if with_placeholders { cfg.n_experts } else { 0 };
        let experts: Vec<crate::moe::Expert> = (0..n_placeholders)
            .map(|_| crate::moe::Expert {
                wg: Tensor2::zeros(h, cfg.d_ff),
                wu: Tensor2::zeros(h, cfg.d_ff),
                wd: Tensor2::zeros(cfg.d_ff, h),
            })
            .collect();
        blocks.push(crate::moe::model::Block {
            attn_norm,
            attn: crate::moe::attention::Attention::from_parts(
                wq,
                wk,
                wv,
                wo,
                cfg.n_heads,
                cfg.rope_theta,
            ),
            moe_norm,
            gate,
            experts,
            shared,
        });
    }
    let final_norm = read_f32s(r, h)?;
    let lm_head = read_t(r, h, cfg.vocab_size)?;
    Ok(MoeModel { cfg: cfg.clone(), embed, blocks, final_norm, lm_head })
}

/// Everything the JSON header carries (both versions; optional fields
/// are v2-only).
struct Preamble {
    cfg: ModelConfig,
    pmq: PmqConfig,
    allocation: Vec<Vec<u8>>,
    importance: Option<Vec<Vec<f64>>>,
    expert_nbytes: Option<Vec<Vec<u64>>>,
}

fn read_preamble(r: &mut impl Read, path: &str) -> Result<Preamble> {
    let hlen = read_u64(r)? as usize;
    if hlen > (1 << 24) {
        bail!("{path}: implausible header length {hlen}");
    }
    let header = read_bytes(r, hlen)?;
    let v = Value::parse(std::str::from_utf8(&header)?)?;
    let cfg = crate::config::ModelConfig::from_json(v.get("config")?)?;
    let (pmq, allocation) = pmq_from_json(v.get("pmq")?)?;
    if allocation.len() != cfg.n_layers
        || allocation.iter().any(|row| row.len() != cfg.n_experts)
    {
        bail!("{path}: allocation shape does not match config");
    }
    let table_f64 = |v: &Value| -> Result<Vec<Vec<f64>>> {
        v.as_arr()?
            .iter()
            .map(|row| row.as_arr()?.iter().map(|x| x.as_f64()).collect::<Result<Vec<f64>>>())
            .collect()
    };
    let check_shape = |t: &[Vec<f64>], what: &str| -> Result<()> {
        if t.len() != cfg.n_layers || t.iter().any(|row| row.len() != cfg.n_experts) {
            bail!("{path}: {what} shape does not match config");
        }
        Ok(())
    };
    let importance = match v.opt("importance") {
        Some(iv) => {
            let t = table_f64(iv)?;
            check_shape(&t, "importance")?;
            Some(t)
        }
        None => None,
    };
    let expert_nbytes = match v.opt("expert_nbytes") {
        Some(nv) => {
            let t = table_f64(nv)?;
            check_shape(&t, "expert_nbytes")?;
            Some(t.into_iter().map(|row| row.into_iter().map(|x| x as u64).collect()).collect())
        }
        None => None,
    };
    Ok(Preamble { cfg, pmq, allocation, importance, expert_nbytes })
}

fn read_index(
    r: &mut impl Read,
    n_layers: usize,
    n_experts: usize,
    path: &str,
) -> Result<Vec<Vec<(u64, u64)>>> {
    let mut index = vec![vec![(0u64, 0u64); n_experts]; n_layers];
    for l in 0..n_layers {
        for e in 0..n_experts {
            let (il, ie) = (read_u64(r)? as usize, read_u64(r)? as usize);
            if (il, ie) != (l, e) {
                bail!("{path}: index entry ({il},{ie}) out of order (expected ({l},{e}))");
            }
            index[l][e] = (read_u64(r)?, read_u64(r)?);
        }
    }
    Ok(index)
}

/// Save a quantized model in the v2 (indexed, pageable) layout.
pub fn save(q: &QuantModel, path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let cfg = &q.model.cfg;
    // header size table from store metadata — no record I/O, no cache
    // churn when re-saving a paged model
    let mut nbytes = vec![vec![0u64; cfg.n_experts]; cfg.n_layers];
    for (l, row) in nbytes.iter_mut().enumerate() {
        for (e, nb) in row.iter_mut().enumerate() {
            *nb = q.store.expert_nbytes(l, e);
        }
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_V2)?;
    let mut fields = vec![
        ("config", config_json(&q.model)),
        ("pmq", pmq_json(&q.pmq, &q.allocation)),
        (
            "expert_nbytes",
            Value::Arr(
                nbytes
                    .iter()
                    .map(|row| {
                        Value::Arr(row.iter().map(|&b| json::num(b as f64)).collect())
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(imp) = &q.importance {
        fields.push((
            "importance",
            Value::Arr(imp.iter().map(|row| json::arr_f64(row)).collect()),
        ));
    }
    let header = json::obj(fields).to_json();
    write_u64(&mut w, header.len() as u64)?;
    w.write_all(header.as_bytes())?;
    // index placeholder — backpatched once the record offsets are known
    let index_pos = w.stream_position()?;
    let placeholder = [0u8; 32];
    for _ in 0..cfg.n_layers * cfg.n_experts {
        w.write_all(&placeholder)?;
    }
    write_dense_base(&mut w, &q.model)?;
    let mut index: Vec<(u64, u64)> = Vec::with_capacity(cfg.n_layers * cfg.n_experts);
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            let off = w.stream_position()?;
            write_expert_record(&mut w, &q.store.get(l, e)?)?;
            index.push((off, w.stream_position()? - off));
        }
    }
    w.seek(SeekFrom::Start(index_pos))?;
    for (i, &(off, len)) in index.iter().enumerate() {
        write_u64(&mut w, (i / cfg.n_experts) as u64)?;
        write_u64(&mut w, (i % cfg.n_experts) as u64)?;
        write_u64(&mut w, off)?;
        write_u64(&mut w, len)?;
    }
    w.flush()?;
    Ok(())
}

/// Save in the legacy v1 (index-less) layout — kept so the backward
/// -compat path stays exercised and old tooling can still be fed.
pub fn save_v1(q: &QuantModel, path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_V1)?;
    let header = json::obj(vec![
        ("config", config_json(&q.model)),
        ("pmq", pmq_json(&q.pmq, &q.allocation)),
    ])
    .to_json();
    write_u64(&mut w, header.len() as u64)?;
    w.write_all(header.as_bytes())?;
    write_dense_base(&mut w, &q.model)?;
    let cfg = &q.model.cfg;
    for l in 0..cfg.n_layers {
        for e in 0..cfg.n_experts {
            write_expert_record(&mut w, &q.store.get(l, e)?)?;
        }
    }
    w.flush()?;
    Ok(())
}

fn config_json(m: &MoeModel) -> Value {
    let c = &m.cfg;
    json::obj(vec![
        ("name", json::s(&c.name)),
        ("family", json::s(&c.family)),
        ("vocab_size", json::num(c.vocab_size as f64)),
        ("d_model", json::num(c.d_model as f64)),
        ("n_layers", json::num(c.n_layers as f64)),
        ("n_heads", json::num(c.n_heads as f64)),
        ("d_ff", json::num(c.d_ff as f64)),
        ("n_experts", json::num(c.n_experts as f64)),
        ("top_k", json::num(c.top_k as f64)),
        ("n_shared_experts", json::num(c.n_shared_experts as f64)),
        ("max_seq_len", json::num(c.max_seq_len as f64)),
        ("rope_theta", json::num(c.rope_theta as f64)),
        ("modalities", json::num(c.modalities as f64)),
        (
            "buckets",
            Value::Arr(c.buckets.iter().map(|&b| json::num(b as f64)).collect()),
        ),
    ])
}

fn check_bits(bits: u8, allocation: &[Vec<u8>], l: usize, e: usize, path: &str) -> Result<()> {
    if bits != allocation[l][e] && bits != 16 {
        bail!("{path}: expert ({l},{e}) bits {bits} != allocation {}", allocation[l][e]);
    }
    Ok(())
}

/// Load a quantized model (v1 or v2) fully into RAM behind a
/// [`ResidentStore`].
pub fn load(path: &str) -> Result<QuantModel> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 9];
    r.read_exact(&mut magic)?;
    let v2 = match &magic {
        m if m == MAGIC_V2 => true,
        m if m == MAGIC_V1 => false,
        _ => bail!("{path}: not an MC# quantized checkpoint"),
    };
    let p = read_preamble(&mut r, path)?;
    if v2 {
        // records are streamed in index order right after the dense base
        read_index(&mut r, p.cfg.n_layers, p.cfg.n_experts, path)?;
    }
    let model = read_dense_base(&mut r, &p.cfg, true)?;
    let mut experts = Vec::with_capacity(p.cfg.n_layers);
    for l in 0..p.cfg.n_layers {
        let mut row = Vec::with_capacity(p.cfg.n_experts);
        for e in 0..p.cfg.n_experts {
            let rec = read_expert_record(&mut r)?;
            check_bits(rec.bits, &p.allocation, l, e, path)?;
            row.push(rec);
        }
        experts.push(row);
    }
    let mut q = QuantModel {
        model,
        store: std::sync::Arc::new(ResidentStore::new(experts)),
        allocation: p.allocation,
        pmq: p.pmq,
        importance: None,
    };
    if let Some(imp) = p.importance {
        q.set_importance(imp);
    }
    Ok(q)
}

/// Validate an indexed `(offset, len)` span against the mapped file and
/// return the record bytes. Shared by the paged record source and the
/// shard server — the one place corrupt-index handling lives.
fn index_span<'a>(
    map: &'a Mmap,
    index: &[Vec<(u64, u64)>],
    layer: usize,
    expert: usize,
    path: &str,
) -> Result<&'a [u8]> {
    let (off, len) = index[layer][expert];
    // plausibility guard (mirrors the header-length guard): a corrupt
    // index must produce an error, not an allocation abort
    if len == 0 || len > (1 << 31) {
        bail!("{path}: implausible index entry ({off},{len}) for expert ({layer},{expert})");
    }
    let (off, len) = (off as usize, len as usize);
    let data = map.as_slice();
    match off.checked_add(len) {
        Some(end) if end <= data.len() => Ok(&data[off..end]),
        _ => bail!("{path}: index entry ({off},{len}) past file end for expert ({layer},{expert})"),
    }
}

/// [`RecordSource`] over a memory-mapped v2 checkpoint: an expert record
/// read is a decode straight out of the page cache — no seek/read
/// syscall pair, and unrouted records never become resident.
struct FileRecordSource {
    map: Mmap,
    index: Vec<Vec<(u64, u64)>>,
    allocation: Vec<Vec<u8>>,
    path: String,
}

impl RecordSource for FileRecordSource {
    fn read_record(&mut self, layer: usize, expert: usize) -> Result<QuantExpert> {
        let span = index_span(&self.map, &self.index, layer, expert, &self.path)?;
        let rec = decode_expert_record(span)?;
        check_bits(rec.bits, &self.allocation, layer, expert, &self.path)?;
        Ok(rec)
    }
}

/// Read-only view over a v2 checkpoint for `mcsharp shard` mode: only
/// the header and index are parsed; the dense base is skipped entirely
/// and expert payloads stay untouched in the page cache until a FETCH
/// asks for their span. Shard-process footprint is therefore the index
/// plus whatever records the OS keeps warm — O(1) in model size.
pub struct ShardSource {
    map: Mmap,
    index: Vec<Vec<(u64, u64)>>,
    cfg: ModelConfig,
    layers: std::ops::Range<usize>,
    path: String,
}

impl ShardSource {
    /// Open `path` (v2 only) to serve expert records for `layers`.
    pub fn open(path: &str, layers: std::ops::Range<usize>) -> Result<ShardSource> {
        let map = Mmap::open(path)?;
        let (cfg, index) = {
            let mut r: &[u8] = map.as_slice();
            let mut magic = [0u8; 9];
            r.read_exact(&mut magic).with_context(|| format!("{path}: truncated magic"))?;
            if &magic == MAGIC_V1 {
                bail!("{path}: v1 checkpoint has no expert index — re-save as v2 to shard");
            }
            if &magic != MAGIC_V2 {
                bail!("{path}: not an MC# quantized checkpoint");
            }
            let p = read_preamble(&mut r, path)?;
            let index = read_index(&mut r, p.cfg.n_layers, p.cfg.n_experts, path)?;
            (p.cfg, index)
        };
        if layers.start >= layers.end || layers.end > cfg.n_layers {
            bail!(
                "{path}: shard layer range {}..{} invalid for a {}-layer model",
                layers.start,
                layers.end,
                cfg.n_layers
            );
        }
        Ok(ShardSource { map, index, cfg, layers, path: path.to_string() })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The contiguous layer range this shard owns.
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.layers.clone()
    }

    pub fn n_experts(&self) -> usize {
        self.cfg.n_experts
    }

    /// Raw record bytes for `(layer, expert)` — exactly what goes on the
    /// wire after a `REC` line. Layers outside this shard's range are a
    /// request error, not a file read.
    pub fn record_span(&self, layer: usize, expert: usize) -> Result<&[u8]> {
        if !self.layers.contains(&layer) {
            bail!(
                "layer {layer} not on this shard (serves {}..{})",
                self.layers.start,
                self.layers.end
            );
        }
        if expert >= self.cfg.n_experts {
            bail!("expert {expert} out of range ({} experts)", self.cfg.n_experts);
        }
        index_span(&self.map, &self.index, layer, expert, &self.path)
    }
}

/// Open a v2 checkpoint with lazily paged experts under `budget_bytes`
/// of packed-expert residency (the `--expert-cache-mb` serving path).
/// Only the dense base is read eagerly; experts fault in on first route
/// and are evicted LRU (PMQ-importance tie-break) to stay under budget.
pub fn load_paged(path: &str, budget_bytes: u64) -> Result<QuantModel> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 9];
    r.read_exact(&mut magic)?;
    if &magic == MAGIC_V1 {
        bail!("{path}: v1 checkpoint has no expert index — re-save as v2 to enable paging");
    }
    if &magic != MAGIC_V2 {
        bail!("{path}: not an MC# quantized checkpoint");
    }
    let p = read_preamble(&mut r, path)?;
    let index = read_index(&mut r, p.cfg.n_layers, p.cfg.n_experts, path)?;
    // placeholders elided: every routed-expert access goes through the
    // store, so coordinator footprint is dense base + expert budget
    let model = read_dense_base(&mut r, &p.cfg, false)?;
    drop(r);
    let Some(nbytes) = p.expert_nbytes else {
        bail!("{path}: v2 header missing expert_nbytes");
    };
    let importance_tbl = p
        .importance
        .clone()
        .unwrap_or_else(|| super::store::bits_as_importance(&p.allocation));
    let source = FileRecordSource {
        map: Mmap::open(path)?,
        index,
        allocation: p.allocation.clone(),
        path: path.to_string(),
    };
    let store = PagedStore::new(Box::new(source), nbytes, importance_tbl, budget_bytes);
    Ok(QuantModel {
        model,
        store: std::sync::Arc::new(store),
        allocation: p.allocation,
        pmq: p.pmq,
        importance: p.importance,
    })
}

/// Assemble a coordinator-side model whose routed experts live on shard
/// servers: the local v2 file supplies the dense base and header tables
/// (allocation / per-expert sizes / importance); expert records page in
/// over FETCH/REC from `shards` under `budget_bytes` of residency. The
/// expert payload section of the local file is never read.
pub fn load_remote(
    path: &str,
    shards: &[String],
    budget_bytes: u64,
    fetch_timeout_ms: u64,
) -> Result<QuantModel> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 9];
    r.read_exact(&mut magic)?;
    if &magic == MAGIC_V1 {
        bail!("{path}: v1 checkpoint has no expert index — re-save as v2 to shard");
    }
    if &magic != MAGIC_V2 {
        bail!("{path}: not an MC# quantized checkpoint");
    }
    let p = read_preamble(&mut r, path)?;
    read_index(&mut r, p.cfg.n_layers, p.cfg.n_experts, path)?;
    let model = read_dense_base(&mut r, &p.cfg, false)?;
    drop(r);
    let Some(nbytes) = p.expert_nbytes else {
        bail!("{path}: v2 header missing expert_nbytes");
    };
    let importance_tbl = p
        .importance
        .clone()
        .unwrap_or_else(|| super::store::bits_as_importance(&p.allocation));
    let store = super::remote::RemoteStore::connect(
        shards,
        nbytes,
        importance_tbl,
        p.allocation.clone(),
        budget_bytes,
        fetch_timeout_ms,
    )?;
    Ok(QuantModel {
        model,
        store: std::sync::Arc::new(store),
        allocation: p.allocation,
        pmq: p.pmq,
        importance: p.importance,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::model::ForwardOpts;
    use crate::quant::qmodel::QuantMethod;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "qckpt-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 32,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    fn tmppath(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("mcsharp-qckpt-{name}-{}.bin", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn roundtrip_preserves_forward_exactly() {
        let base = MoeModel::new(&cfg(), 50);
        let alloc = vec![vec![1u8, 2, 3, 2], vec![2, 3, 1, 2]];
        let pmq = PmqConfig::default();
        let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Rtn);
        let path = tmppath("rt");
        save(&q, &path).unwrap();
        let q2 = load(&path).unwrap();
        assert_eq!(q2.allocation, alloc);
        assert_eq!(q2.pmq.group, pmq.group);
        let toks: Vec<u16> = vec![1, 9, 30, 45, 8, 22];
        let a = q
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        let b = q2
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q2), ..Default::default() });
        assert_eq!(a.data, b.data, "quantized forward changed across save/load");
        assert_eq!(q.nbytes(), q2.nbytes());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_scaled_awq_variant() {
        let base = MoeModel::new(&cfg(), 51);
        let toks: Vec<u16> = (0..24).map(|i| (i * 5 % 60 + 1) as u16).collect();
        let mut captured: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 2];
        base.forward_opts(
            &toks,
            &mut ForwardOpts { capture_moe_inputs: Some(&mut captured), ..Default::default() },
        );
        let acts: Vec<crate::quant::error::LayerActivations> = captured
            .into_iter()
            .map(|xs| crate::quant::error::LayerActivations { xs })
            .collect();
        let alloc = vec![vec![2u8; 4]; 2];
        let q = QuantModel::quantize(
            &base,
            &alloc,
            &PmqConfig::default(),
            &QuantMethod::Awq(&acts),
        );
        let path = tmppath("awq");
        save(&q, &path).unwrap();
        let q2 = load(&path).unwrap();
        let a = q
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        let b = q2
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q2), ..Default::default() });
        assert_eq!(a.data, b.data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_layout_still_loads() {
        let base = MoeModel::new(&cfg(), 54);
        let alloc = vec![vec![2u8, 1, 3, 2], vec![3, 2, 1, 2]];
        let q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
        let path = tmppath("v1");
        save_v1(&q, &path).unwrap();
        let q2 = load(&path).unwrap();
        assert_eq!(q2.allocation, alloc);
        let toks: Vec<u16> = vec![3, 11, 27, 40, 9];
        let a = q
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        let b = q2
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&q2), ..Default::default() });
        assert_eq!(a.data, b.data, "v1 read path diverged");
        // but v1 cannot page (no index)
        assert!(load_paged(&path, 1 << 20).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_paged_load_matches_resident() {
        let base = MoeModel::new(&cfg(), 55);
        let alloc = vec![vec![2u8; 4]; 2];
        let mut q =
            QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
        q.set_importance(vec![vec![0.1, 0.4, 0.2, 0.3], vec![0.3, 0.1, 0.2, 0.4]]);
        let path = tmppath("paged");
        save(&q, &path).unwrap();
        let resident = load(&path).unwrap();
        assert_eq!(resident.importance, q.importance, "importance must persist");
        // budget below total packed bytes forces paging + eviction
        let budget = q.store.total_nbytes() * 3 / 5;
        let paged = load_paged(&path, budget).unwrap();
        assert_eq!(paged.store.kind(), "paged");
        assert_eq!(paged.store.total_nbytes(), q.store.total_nbytes());
        let toks: Vec<u16> = vec![2, 19, 33, 48, 7, 21];
        let mut opts_r = ForwardOpts { provider: Some(&resident), ..Default::default() };
        let a = resident.model.forward_opts(&toks, &mut opts_r);
        let b = paged
            .model
            .forward_opts(&toks, &mut ForwardOpts { provider: Some(&paged), ..Default::default() });
        assert_eq!(a.data, b.data, "paged experts diverged from resident");
        let c = paged.store.counters();
        assert!(c.misses > 0, "tiny budget must page");
        assert!(c.peak_resident_bytes <= budget, "budget violated: {c:?}");
        // store-backed loads elide the zero placeholder experts — routed
        // FFN math must never touch the dense model, and the coordinator
        // footprint is dense base + expert budget, not + zeros
        assert!(
            paged.model.blocks.iter().all(|b| b.experts.is_empty()),
            "paged load must not materialize placeholder experts"
        );
        assert!(
            resident.model.blocks.iter().all(|b| b.experts.len() == 4),
            "resident load keeps the legacy full-shape model"
        );
        // bit-width metrics stay well-defined on the elided model
        assert!((paged.avg_model_bits() - resident.avg_model_bits()).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_source_serves_decodable_spans() {
        let base = MoeModel::new(&cfg(), 56);
        let alloc = vec![vec![2u8, 1, 3, 2], vec![3, 2, 1, 2]];
        let q = QuantModel::quantize(&base, &alloc, &PmqConfig::default(), &QuantMethod::Rtn);
        let path = tmppath("shard");
        save(&q, &path).unwrap();
        // a shard owning only layer 1
        let s = ShardSource::open(&path, 1..2).unwrap();
        assert_eq!(s.layers(), 1..2);
        assert_eq!(s.n_experts(), 4);
        for e in 0..4 {
            let span = s.record_span(1, e).unwrap();
            let rec = decode_expert_record(span).unwrap();
            assert_eq!(rec.bits, alloc[1][e]);
            // the span is byte-exact: decoding must consume all of it,
            // and a truncated span must fail
            assert!(decode_expert_record(&span[..span.len() - 1]).is_err());
        }
        // layers outside the owned range are request errors
        assert!(s.record_span(0, 0).is_err());
        assert!(s.record_span(2, 0).is_err());
        assert!(s.record_span(1, 4).is_err());
        // invalid ranges and v1 files refuse to open
        assert!(ShardSource::open(&path, 1..1).is_err());
        assert!(ShardSource::open(&path, 0..3).is_err());
        let v1path = tmppath("shard-v1");
        save_v1(&q, &v1path).unwrap();
        assert!(ShardSource::open(&v1path, 0..2).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&v1path).ok();
    }

    #[test]
    fn truncated_qcheckpoint_is_an_error() {
        let base = MoeModel::new(&cfg(), 52);
        let q = QuantModel::quantize(
            &base,
            &vec![vec![2u8; 4]; 2],
            &PmqConfig::default(),
            &QuantMethod::Rtn,
        );
        let path = tmppath("trunc");
        save(&q, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() * 2 / 3]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_is_an_error() {
        let path = tmppath("magic");
        std::fs::write(&path, b"MCSHARP1\0garbage....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_smaller_than_fp16_checkpoint() {
        let base = MoeModel::new(&cfg(), 53);
        let q = QuantModel::quantize(
            &base,
            &vec![vec![2u8; 4]; 2],
            &PmqConfig::default(),
            &QuantMethod::Rtn,
        );
        let qpath = tmppath("size-q");
        let fpath = tmppath("size-f");
        save(&q, &qpath).unwrap();
        base.save(&fpath).unwrap();
        let qsize = std::fs::metadata(&qpath).unwrap().len();
        let fsize = std::fs::metadata(&fpath).unwrap().len();
        // dense base dominates at this toy size, but the packed expert
        // payload must still shrink the file
        assert!(qsize < fsize, "quantized {qsize} !< fp {fsize}");
        std::fs::remove_file(&qpath).ok();
        std::fs::remove_file(&fpath).ok();
    }
}
