//! GPTQ (Frantar et al. 2022) from scratch — the paper's base PTQ tool.
//!
//! Per linear layer: accumulate the Hessian `H = 2 Σ x xᵀ` from
//! calibration activations, then quantize the weight rows (our reduction
//! axis) sequentially with optimal-brain-quantization error compensation
//! driven by the Cholesky factor of `H⁻¹`. Group scale/zero parameters
//! are (re)computed at each group boundary from the *compensated*
//! weights, exactly as the reference implementation does. Supports
//! 2/3/4-bit linear codes and the 1-bit sign/α mode (Eq. 4).

use crate::tensor::Tensor2;

use super::binary::BinaryMatrix;
use super::packed::PackedMatrix;

pub struct GptqQuantizer {
    pub d_in: usize,
    /// Accumulated `2 Σ x xᵀ` (f64 for stability).
    h: Vec<f64>,
    pub n_samples: usize,
    /// Relative damping λ = percdamp · mean(diag H).
    pub percdamp: f64,
}

impl GptqQuantizer {
    pub fn new(d_in: usize) -> GptqQuantizer {
        GptqQuantizer { d_in, h: vec![0.0; d_in * d_in], n_samples: 0, percdamp: 0.01 }
    }

    /// Accumulate one calibration activation row.
    pub fn add_sample(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.d_in);
        let n = self.d_in;
        for i in 0..n {
            let xi = x[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let row = &mut self.h[i * n..(i + 1) * n];
            for (j, &xj) in x.iter().enumerate() {
                row[j] += 2.0 * xi * xj as f64;
            }
        }
        self.n_samples += 1;
    }

    /// Mean Hessian diagonal — HAWQ-style trace sensitivity factor.
    pub fn mean_diag(&self) -> f64 {
        let n = self.d_in;
        (0..n).map(|i| self.h[i * n + i]).sum::<f64>() / n as f64
    }

    /// Quantize `w [d_in, d_out]` to `bits` with group size `group`.
    pub fn quantize_packed(&self, w: &Tensor2, bits: u8, group: usize) -> PackedMatrix {
        assert!(bits >= 2 && bits <= 4, "use quantize_binary for 1-bit");
        let (codes, scales, zeros) = self.quantize_codes(w, bits, group);
        PackedMatrix::from_codes(&codes, scales, zeros, w.rows, w.cols, bits, group)
    }

    /// 1-bit GPTQ: α from the original weights, sign chosen per entry on
    /// the compensated weights.
    pub fn quantize_binary(&self, w: &Tensor2) -> BinaryMatrix {
        let (d_in, d_out) = (w.rows, w.cols);
        let alpha: Vec<f32> = (0..d_out)
            .map(|o| (0..d_in).map(|r| w.at(r, o).abs()).sum::<f32>() / d_in as f32)
            .collect();
        let u = self.chol_inv_upper();
        let mut wk = to_f64(w);
        let mut plane = vec![0u8; d_in / 8 * d_out];
        for r in 0..d_in {
            let d = u[r * d_in + r];
            for o in 0..d_out {
                let v = wk[r * d_out + o];
                let q = if v >= 0.0 { alpha[o] as f64 } else { -(alpha[o] as f64) };
                if v >= 0.0 {
                    plane[(r / 8) * d_out + o] |= 1 << (r % 8);
                }
                let err = (v - q) / d;
                for rr in r + 1..d_in {
                    wk[rr * d_out + o] -= err * u[r * d_in + rr];
                }
            }
        }
        BinaryMatrix::from_parts(plane, alpha, d_in, d_out)
    }

    /// Core GPTQ loop → (codes, scales, zeros).
    pub fn quantize_codes(&self, w: &Tensor2, bits: u8, group: usize) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
        let (d_in, d_out) = (w.rows, w.cols);
        assert_eq!(d_in, self.d_in);
        assert_eq!(d_in % group, 0);
        let levels = ((1u32 << bits) - 1) as f64;
        let u = self.chol_inv_upper();
        let mut wk = to_f64(w);
        let mut codes = vec![0u8; d_in * d_out];
        let n_groups = d_in / group;
        let mut scales = vec![0f32; n_groups * d_out];
        let mut zeros = vec![0f32; n_groups * d_out];
        for r in 0..d_in {
            let gi = r / group;
            if r % group == 0 {
                // find scale/zero per column from the compensated rows of
                // this group
                for o in 0..d_out {
                    let mut wmin = f64::INFINITY;
                    let mut wmax = f64::NEG_INFINITY;
                    for rr in r..r + group {
                        let v = wk[rr * d_out + o];
                        wmin = wmin.min(v);
                        wmax = wmax.max(v);
                    }
                    let span = (wmax - wmin).max(1e-8);
                    let s = span / levels;
                    scales[gi * d_out + o] = s as f32;
                    zeros[gi * d_out + o] = (-wmin / s).round() as f32;
                }
            }
            let d = u[r * d_in + r];
            for o in 0..d_out {
                let s = scales[gi * d_out + o] as f64;
                let z = zeros[gi * d_out + o] as f64;
                let v = wk[r * d_out + o];
                let q = ((v / s).round() + z).clamp(0.0, levels);
                codes[r * d_out + o] = q as u8;
                let deq = (q - z) * s;
                let err = (v - deq) / d;
                // propagate the quantization error to the not-yet-quantized rows
                for rr in r + 1..d_in {
                    wk[rr * d_out + o] -= err * u[r * d_in + rr];
                }
            }
        }
        (codes, scales, zeros)
    }

    /// Upper Cholesky factor `U` of `H⁻¹` (so `H⁻¹ = Uᵀ U`), after
    /// damping and dead-row handling — the matrix GPTQ's inner loop walks.
    fn chol_inv_upper(&self) -> Vec<f64> {
        let n = self.d_in;
        let mut h = self.h.clone();
        // dead inputs: never activated → pin diagonal
        let mean_diag: f64 =
            (0..n).map(|i| h[i * n + i]).sum::<f64>() / n as f64;
        let damp = (self.percdamp * mean_diag).max(1e-8);
        for i in 0..n {
            if h[i * n + i] == 0.0 {
                h[i * n + i] = 1.0;
            }
            h[i * n + i] += damp;
        }
        let l = cholesky_lower(&h, n);
        let hinv = chol_inverse(&l, n);
        let linv = cholesky_lower(&hinv, n);
        // U = Lᵀ
        let mut u = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                u[j * n + i] = linv[i * n + j];
            }
        }
        u
    }
}

fn to_f64(w: &Tensor2) -> Vec<f64> {
    w.data.iter().map(|&v| v as f64).collect()
}

/// Dense lower Cholesky (panics on non-PD — damping prevents that here).
fn cholesky_lower(a: &[f64], n: usize) -> Vec<f64> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite at {i} (s={s})");
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    l
}

/// Inverse of `A = L Lᵀ` from its lower Cholesky factor.
fn chol_inverse(l: &[f64], n: usize) -> Vec<f64> {
    // invert L by forward substitution, then A⁻¹ = L⁻ᵀ L⁻¹
    let mut linv = vec![0.0f64; n * n];
    for i in 0..n {
        linv[i * n + i] = 1.0 / l[i * n + i];
        for j in 0..i {
            let mut s = 0.0;
            for k in j..i {
                s -= l[i * n + k] * linv[k * n + j];
            }
            linv[i * n + j] = s / l[i * n + i];
        }
    }
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in i..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            inv[i * n + j] = s;
            inv[j * n + i] = s;
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn;
    use crate::util::rng::Rng;

    fn calib_activations(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        // correlated activations: low-rank mixture + noise (GPTQ's edge
        // over RTN only exists when H is non-diagonal)
        let basis = Tensor2::randn(4, d, rng, 1.0);
        (0..n)
            .map(|_| {
                let mut x = vec![0.0f32; d];
                for b in 0..4 {
                    let c = rng.normal();
                    for (xi, &bv) in x.iter_mut().zip(basis.row(b)) {
                        *xi += c * bv;
                    }
                }
                for xi in x.iter_mut() {
                    *xi += 0.1 * rng.normal();
                }
                x
            })
            .collect()
    }

    fn recon_err(xs: &[Vec<f32>], w: &Tensor2, w_hat: &Tensor2) -> f64 {
        let mut err = 0.0f64;
        for x in xs {
            for o in 0..w.cols {
                let mut a = 0.0f32;
                let mut b = 0.0f32;
                for (r, &xr) in x.iter().enumerate() {
                    a += xr * w.at(r, o);
                    b += xr * w_hat.at(r, o);
                }
                err += ((a - b) as f64).powi(2);
            }
        }
        err
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_data() {
        let mut rng = Rng::new(20);
        let (d_in, d_out) = (64, 24);
        let w = Tensor2::randn(d_in, d_out, &mut rng, 1.0);
        let xs = calib_activations(&mut rng, 128, d_in);
        let mut q = GptqQuantizer::new(d_in);
        for x in &xs {
            q.add_sample(x);
        }
        for bits in [2u8, 3] {
            let pm = q.quantize_packed(&w, bits, 32);
            let gptq_err = recon_err(&xs, &w, &pm.dequantize());
            let rtn_hat = rtn::fake_quant(&w, bits, 32);
            let rtn_err = recon_err(&xs, &w, &rtn_hat);
            assert!(
                gptq_err < rtn_err,
                "bits={bits}: gptq {gptq_err:.3} !< rtn {rtn_err:.3}"
            );
        }
    }

    #[test]
    fn binary_gptq_not_catastrophic() {
        let mut rng = Rng::new(21);
        let (d_in, d_out) = (64, 16);
        let w = Tensor2::randn(d_in, d_out, &mut rng, 1.0);
        let xs = calib_activations(&mut rng, 64, d_in);
        let mut q = GptqQuantizer::new(d_in);
        for x in &xs {
            q.add_sample(x);
        }
        let bm = q.quantize_binary(&w);
        // error-compensated binary should beat plain sign binarization
        let plain = BinaryMatrix::binarize(&w);
        let e_gptq = recon_err(&xs, &w, &bm.dequantize());
        let e_plain = recon_err(&xs, &w, &plain.dequantize());
        assert!(e_gptq <= e_plain * 1.05, "gptq {e_gptq:.3} vs plain {e_plain:.3}");
    }

    #[test]
    fn cholesky_inverse_correct() {
        let mut rng = Rng::new(22);
        let n = 12;
        // SPD matrix: A = B Bᵀ + I
        let b = Tensor2::randn(n, n, &mut rng, 1.0);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += (b.at(i, k) * b.at(j, k)) as f64;
                }
                a[i * n + j] = s;
            }
        }
        let l = cholesky_lower(&a, n);
        let inv = chol_inverse(&l, n);
        // A * inv ≈ I
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}) {s}");
            }
        }
    }

    #[test]
    fn codes_within_range_and_groups_fresh() {
        let mut rng = Rng::new(23);
        let w = Tensor2::randn(64, 8, &mut rng, 1.0);
        let mut q = GptqQuantizer::new(64);
        for x in calib_activations(&mut rng, 32, 64) {
            q.add_sample(&x);
        }
        let (codes, scales, _) = q.quantize_codes(&w, 2, 32);
        assert!(codes.iter().all(|&c| c < 4));
        assert_eq!(scales.len(), 2 * 8);
        assert!(scales.iter().all(|&s| s > 0.0));
    }
}
