//! Group-wise round-to-nearest (asymmetric min/max) quantizer — paper
//! Eq. 3. The bit-exact Rust mirror of
//! `python/compile/kernels/packing.py::quantize_rtn`.

use crate::tensor::Tensor2;

/// Quantize `w [d_in, d_out]` group-wise along `d_in`.
/// Returns `(codes [d_in*d_out] u8, scales [g*d_out], zeros [g*d_out])`
/// with `g = d_in / group`; dequant is `(code - zero) * scale`.
pub fn quantize_rtn(w: &Tensor2, bits: u8, group: usize) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
    let (d_in, d_out) = (w.rows, w.cols);
    assert_eq!(d_in % group, 0, "d_in {d_in} % group {group}");
    let g = d_in / group;
    let levels = (1u32 << bits) - 1;
    let mut codes = vec![0u8; d_in * d_out];
    let mut scales = vec![0f32; g * d_out];
    let mut zeros = vec![0f32; g * d_out];
    for gi in 0..g {
        for o in 0..d_out {
            let mut wmin = f32::INFINITY;
            let mut wmax = f32::NEG_INFINITY;
            for r in 0..group {
                let v = w.at(gi * group + r, o);
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            let span = (wmax - wmin).max(1e-8);
            let scale = span / levels as f32;
            let zero = (-wmin / scale).round();
            scales[gi * d_out + o] = scale;
            zeros[gi * d_out + o] = zero;
            for r in 0..group {
                let v = w.at(gi * group + r, o);
                let q = ((v / scale).round() + zero).clamp(0.0, levels as f32);
                codes[(gi * group + r) * d_out + o] = q as u8;
            }
        }
    }
    (codes, scales, zeros)
}

/// Dequantize codes back to an f32 matrix (reference / 4-bit "others").
pub fn dequantize(
    codes: &[u8],
    scales: &[f32],
    zeros: &[f32],
    d_in: usize,
    d_out: usize,
    group: usize,
) -> Tensor2 {
    let mut out = Tensor2::zeros(d_in, d_out);
    for r in 0..d_in {
        let gi = r / group;
        for o in 0..d_out {
            let s = scales[gi * d_out + o];
            let z = zeros[gi * d_out + o];
            out.set(r, o, (codes[r * d_out + o] as f32 - z) * s);
        }
    }
    out
}

/// RTN round-trip a matrix at `bits` (used to simulate the uniform 4-bit
/// quantization of attention/gate/shared weights).
pub fn fake_quant(w: &Tensor2, bits: u8, group: usize) -> Tensor2 {
    let (codes, scales, zeros) = quantize_rtn(w, bits, group);
    dequantize(&codes, &scales, &zeros, w.rows, w.cols, group)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn reconstruction_error_bounded_by_step() {
        prop::for_all(61, 20, |rng, _| {
            let bits = 2 + rng.below(3) as u8; // 2..4
            let d_in = prop::dim(rng, 32, 128, 32);
            let d_out = 1 + rng.below(20);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let (codes, scales, zeros) = quantize_rtn(&w, bits, 32);
            let w_hat = dequantize(&codes, &scales, &zeros, d_in, d_out, 32);
            for r in 0..d_in {
                let gi = r / 32;
                for o in 0..d_out {
                    let step = scales[gi * d_out + o];
                    assert!(
                        (w.at(r, o) - w_hat.at(r, o)).abs() <= step + 1e-5,
                        "bits={bits} err {} step {step}",
                        (w.at(r, o) - w_hat.at(r, o)).abs()
                    );
                }
            }
        });
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(8);
        let w = Tensor2::randn(128, 16, &mut rng, 1.0);
        let err = |bits: u8| {
            let q = fake_quant(&w, bits, 32);
            w.data.iter().zip(&q.data).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
        };
        assert!(err(4) < err(3) && err(3) < err(2));
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(9);
        let w = Tensor2::randn(64, 8, &mut rng, 2.0);
        for bits in [2u8, 3, 4] {
            let (codes, _, _) = quantize_rtn(&w, bits, 32);
            assert!(codes.iter().all(|&c| (c as u32) < (1 << bits)));
        }
    }
}
