//! Expert residency over the wire: [`RemoteStore`] pages packed expert
//! records from `mcsharp shard` servers instead of a local file, making
//! residency location-transparent — the deployment step after MC#'s
//! compression (paper §1): a 2.57-bit model that *still* does not fit
//! one node keeps serving, with experts living where the bytes are.
//!
//! Same policy, different fault path: the budget/LRU/importance/prefetch
//! machinery is the exact [`ResidencyCache`] the local
//! [`PagedStore`](super::store::PagedStore) uses — what changes is only
//! that a miss becomes one batched `FETCH id=.. layer=.. experts=..`
//! RPC per layer miss-set (never per-expert round trips; the
//! dispatcher's `prepare` hands us the whole routed set), answered by
//! `REC` frames carrying the same record bytes the v2 checkpoint index
//! spans hold. Next-layer prefetch is *pipelined*: the `FETCH` is
//! written and the responses are left in flight, drained into spare
//! budget the next time that shard's connection is touched — wire
//! latency hides behind the current layer's compute.
//!
//! Failure model: a dead shard or a fetch timeout marks the shard down
//! and surfaces [`FetchUnavailable`], a typed marker the engine
//! scheduler catches to fail the affected requests with `ERR` and keep
//! the engine alive; every later fetch lazily retries the connection,
//! so a restarted shard heals the coordinator without a restart.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::ops::Range;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::protocol::{format_fetch, parse_response, Response};

use super::qcheckpoint::decode_expert_record;
use super::qmodel::QuantExpert;
use super::store::{CacheCounters, ExpertStore, RemoteFetchStats, ResidencyCache};

/// Typed marker for "the bytes are not reachable right now" — shard
/// down, connect refused, read timeout. The engine scheduler downcasts
/// for this to degrade the affected requests to `ERR` instead of
/// treating the step as a fatal engine error.
#[derive(Debug)]
pub struct FetchUnavailable {
    pub shard: String,
    pub detail: String,
}

impl std::fmt::Display for FetchUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard {} unavailable: {}", self.shard, self.detail)
    }
}

impl std::error::Error for FetchUnavailable {}

/// Whether `e` (anywhere in its context chain) is a [`FetchUnavailable`].
pub fn is_fetch_unavailable(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<FetchUnavailable>().is_some())
}

fn unavailable(shard: &str, detail: impl std::fmt::Display) -> anyhow::Error {
    anyhow::Error::new(FetchUnavailable { shard: shard.to_string(), detail: detail.to_string() })
}

/// Cap on one record payload (mirrors the checkpoint index plausibility
/// guard): a corrupt `len=` must error, not abort on allocation.
const MAX_REC_BYTES: usize = 1 << 31;

struct ShardConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// A pipelined prefetch `FETCH` whose `REC` frames are still in flight.
struct PendingFetch {
    tag: u64,
    entries: Vec<(usize, usize)>,
}

struct Shard {
    addr: String,
    layers: Range<usize>,
    conn: Option<ShardConn>,
    pending: Option<PendingFetch>,
}

struct RemoteInner {
    rc: ResidencyCache,
    shards: Vec<Shard>,
    /// `layer -> index into shards` (validated total coverage).
    layer_map: Vec<usize>,
    allocation: Vec<Vec<u8>>,
    timeout: Duration,
    next_tag: u64,
    fetch_rpcs: u64,
    prefetch_rpcs: u64,
    fetched_bytes: u64,
    /// Demand-fetch wait distribution (µs), log2-bucketed: bounded
    /// memory over the whole run, unlike the windowed vector it
    /// replaced.
    fetch_histo: crate::trace::Histo,
}

/// [`ExpertStore`] whose record source is a set of shard servers.
pub struct RemoteStore {
    inner: Mutex<RemoteInner>,
}

/// Extract `layers=a..b` from a shard `STATS` payload.
fn parse_layer_range(stats: &str) -> Result<Range<usize>> {
    let field = stats
        .split_whitespace()
        .find_map(|w| w.strip_prefix("layers="))
        .ok_or_else(|| anyhow!("shard STATS missing layers= field: {stats:?}"))?;
    let (a, b) = field
        .split_once("..")
        .ok_or_else(|| anyhow!("malformed layers range {field:?}"))?;
    let (a, b) = (a.parse::<usize>()?, b.parse::<usize>()?);
    if a >= b {
        bail!("empty layers range {field:?}");
    }
    Ok(a..b)
}

fn open_conn(addr: &str, timeout: Duration) -> Result<ShardConn> {
    let sockaddr = std::net::ToSocketAddrs::to_socket_addrs(addr)
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("{addr}: no socket address"))?;
    let stream = TcpStream::connect_timeout(&sockaddr, timeout)
        .with_context(|| format!("connecting to shard {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    let reader = BufReader::new(stream.try_clone()?);
    Ok(ShardConn { reader, writer: stream })
}

/// Ask a freshly connected shard which layers it owns.
fn query_layers(conn: &mut ShardConn, addr: &str) -> Result<Range<usize>> {
    conn.writer.write_all(b"STATS\n")?;
    let mut line = String::new();
    conn.reader.read_line(&mut line)?;
    match parse_response(&line).with_context(|| format!("shard {addr} STATS reply"))? {
        Response::Stats(payload) => parse_layer_range(&payload),
        other => bail!("shard {addr}: expected STATS reply, got {other:?}"),
    }
}

/// Read the `REC` frames answering one `FETCH` for `want` (in request
/// order) off `conn`. Returns the raw record payloads. Any deviation —
/// wrong tag, wrong expert, implausible len, an `ERR`, a short read —
/// is an error; the caller decides whether it is unavailability (I/O)
/// or a protocol violation (both drop the connection either way, since
/// the stream position is no longer trustworthy).
fn read_rec_frames(
    conn: &mut ShardConn,
    tag: u64,
    layer: usize,
    want: &[usize],
) -> Result<Vec<Vec<u8>>> {
    let mut payloads = Vec::with_capacity(want.len());
    for &e in want {
        let mut line = String::new();
        let n = conn.reader.read_line(&mut line)?;
        if n == 0 || !line.ends_with('\n') {
            // a cleanly killed shard closes the socket: EOF (possibly
            // mid-line) is unavailability, not a protocol violation, so
            // surface it as an io::Error the caller maps to
            // FetchUnavailable
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("connection closed mid-fetch (expected REC for expert {e})"),
            )
            .into());
        }
        match parse_response(&line)? {
            Response::Rec { tag: t, layer: l, expert, len } => {
                if t != tag || l != layer || expert != e {
                    bail!(
                        "REC frame mismatch: got (id={t} layer={l} expert={expert}), \
                         expected (id={tag} layer={layer} expert={e})"
                    );
                }
                if len == 0 || len > MAX_REC_BYTES {
                    bail!("implausible REC len {len} for expert ({layer},{e})");
                }
                let mut buf = vec![0u8; len];
                conn.reader.read_exact(&mut buf)?;
                payloads.push(buf);
            }
            Response::Err { msg, .. } => bail!("shard rejected FETCH: {msg}"),
            other => bail!("expected REC frame, got {other:?}"),
        }
    }
    Ok(payloads)
}

impl RemoteInner {
    fn take_tag(&mut self) -> u64 {
        self.next_tag += 1;
        self.next_tag
    }

    /// Connection to shard `si`, lazily (re)established. An unreachable
    /// shard is [`FetchUnavailable`].
    fn conn(&mut self, si: usize) -> Result<()> {
        if self.shards[si].conn.is_some() {
            return Ok(());
        }
        let addr = self.shards[si].addr.clone();
        match open_conn(&addr, self.timeout) {
            Ok(c) => {
                self.shards[si].conn = Some(c);
                Ok(())
            }
            Err(e) => Err(unavailable(&addr, format!("{e:#}"))),
        }
    }

    /// Drop a shard's connection (and any pipelined prefetch riding it).
    fn mark_down(&mut self, si: usize) {
        self.shards[si].conn = None;
        self.shards[si].pending = None;
    }

    /// Drain a pipelined prefetch on shard `si` if one is in flight:
    /// decode the frames and insert whatever still fits the spare budget.
    /// Errors are speculative-path internal — the shard is marked down
    /// and the demand path will surface its own error if it also fails.
    fn drain_pending(&mut self, si: usize) {
        let Some(pending) = self.shards[si].pending.take() else { return };
        let Some(conn) = self.shards[si].conn.as_mut() else { return };
        // all entries of one prefetch FETCH share one layer
        let layer = pending.entries[0].0;
        let want: Vec<usize> = pending.entries.iter().map(|&(_, e)| e).collect();
        match read_rec_frames(conn, pending.tag, layer, &want) {
            Ok(payloads) => {
                let tick = self.rc.next_tick();
                for (&(l, e), payload) in pending.entries.iter().zip(&payloads) {
                    self.fetched_bytes += payload.len() as u64;
                    let Ok(rec) = decode_expert_record(payload) else {
                        self.mark_down(si);
                        return;
                    };
                    if check_alloc_bits(rec.bits, &self.allocation, l, e).is_err() {
                        self.mark_down(si);
                        return;
                    }
                    self.rc.insert_prefetched_if_fits(l, e, Arc::new(rec), tick);
                }
            }
            Err(_) => self.mark_down(si),
        }
    }

    /// One batched demand fetch: `experts` of `layer` from its owning
    /// shard, decoded and verified. The single RPC per layer miss-set.
    fn fetch_demand(&mut self, layer: usize, experts: &[usize]) -> Result<Vec<QuantExpert>> {
        let si = self.layer_map[layer];
        self.conn(si)?;
        // responses arrive in order: a pipelined prefetch still in
        // flight on this connection must be consumed first
        self.drain_pending(si);
        self.conn(si)?; // drain may have dropped a broken connection
        let tag = self.take_tag();
        let addr = self.shards[si].addr.clone();
        let started = Instant::now();
        let result = (|| -> Result<Vec<Vec<u8>>> {
            let conn = self.shards[si].conn.as_mut().expect("conn established above");
            conn.writer.write_all(format_fetch(tag, layer, experts).as_bytes())?;
            read_rec_frames(conn, tag, layer, experts)
        })();
        let payloads = match result {
            Ok(p) => p,
            Err(e) => {
                // stream position is untrustworthy after any mid-fetch
                // failure — reconnect next time
                self.mark_down(si);
                return Err(if e.downcast_ref::<std::io::Error>().is_some() {
                    unavailable(&addr, format!("{e:#}"))
                } else {
                    e.context(format!("shard {addr}"))
                });
            }
        };
        self.fetch_rpcs += 1;
        self.fetch_histo.record(started.elapsed().as_micros() as u64);
        let mut records = Vec::with_capacity(experts.len());
        for (&e, payload) in experts.iter().zip(&payloads) {
            self.fetched_bytes += payload.len() as u64;
            let rec = decode_expert_record(payload)
                .with_context(|| format!("shard {addr}: expert ({layer},{e})"))?;
            check_alloc_bits(rec.bits, &self.allocation, layer, e)?;
            records.push(rec);
        }
        Ok(records)
    }

    /// Issue the next-layer prefetch plan as one pipelined `FETCH` per
    /// owning shard, leaving the responses in flight. Speculative: any
    /// failure just skips the prefetch.
    fn issue_prefetch(&mut self, layer: usize) {
        let plan = self.rc.prefetch_plan(layer);
        if plan.is_empty() {
            return;
        }
        // one layer -> one shard; the plan is single-layer by design
        let next = plan[0].0;
        let si = self.layer_map[next];
        if self.shards[si].conn.is_none() || self.shards[si].pending.is_some() {
            // never stack pipelined fetches, and never *open* a
            // connection speculatively — prefetch rides warm paths only
            return;
        }
        let tag = self.take_tag();
        let experts: Vec<usize> = plan.iter().map(|&(_, e)| e).collect();
        let line = format_fetch(tag, next, &experts);
        let conn = self.shards[si].conn.as_mut().expect("checked above");
        if conn.writer.write_all(line.as_bytes()).is_err() {
            self.mark_down(si);
            return;
        }
        self.prefetch_rpcs += 1;
        self.shards[si].pending = Some(PendingFetch { tag, entries: plan });
    }

}

/// Bits sanity against the allocation table (the same check the local
/// loaders apply; 16 = fp fallback is always admissible).
fn check_alloc_bits(bits: u8, allocation: &[Vec<u8>], l: usize, e: usize) -> Result<()> {
    if bits != allocation[l][e] && bits != 16 {
        bail!("expert ({l},{e}) bits {bits} != allocation {}", allocation[l][e]);
    }
    Ok(())
}

impl RemoteStore {
    /// Connect to every shard, learn its layer range from `STATS`, and
    /// verify the union covers all layers. Startup is strict (every
    /// shard reachable, full coverage) — *after* startup, shard deaths
    /// degrade per-request instead.
    pub fn connect(
        shards: &[String],
        nbytes: Vec<Vec<u64>>,
        importance: Vec<Vec<f64>>,
        allocation: Vec<Vec<u8>>,
        budget_bytes: u64,
        fetch_timeout_ms: u64,
    ) -> Result<RemoteStore> {
        if shards.is_empty() {
            bail!("no shard addresses given");
        }
        let timeout = Duration::from_millis(fetch_timeout_ms.max(1));
        let rc = ResidencyCache::new(nbytes, importance, budget_bytes);
        let n_layers = rc.n_layers();
        let mut shard_states = Vec::with_capacity(shards.len());
        for addr in shards {
            let mut conn = open_conn(addr, timeout)?;
            let layers = query_layers(&mut conn, addr)?;
            if layers.end > n_layers {
                bail!("shard {addr} serves layers {layers:?} but the model has {n_layers}");
            }
            shard_states.push(Shard {
                addr: addr.clone(),
                layers,
                conn: Some(conn),
                pending: None,
            });
        }
        let mut layer_map = vec![usize::MAX; n_layers];
        for (si, s) in shard_states.iter().enumerate() {
            for l in s.layers.clone() {
                if layer_map[l] != usize::MAX {
                    bail!(
                        "layer {l} served by both {} and {}",
                        shard_states[layer_map[l]].addr,
                        s.addr
                    );
                }
                layer_map[l] = si;
            }
        }
        if let Some(l) = layer_map.iter().position(|&si| si == usize::MAX) {
            bail!("no shard serves layer {l} (got {} shard(s))", shard_states.len());
        }
        Ok(RemoteStore {
            inner: Mutex::new(RemoteInner {
                rc,
                shards: shard_states,
                layer_map,
                allocation,
                timeout,
                next_tag: 0,
                fetch_rpcs: 0,
                prefetch_rpcs: 0,
                fetched_bytes: 0,
                fetch_histo: crate::trace::Histo::default(),
            }),
        })
    }
}

impl ExpertStore for RemoteStore {
    fn get(&self, layer: usize, expert: usize) -> Result<Arc<QuantExpert>> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        if layer >= inner.rc.n_layers() || expert >= inner.rc.n_experts() {
            bail!("expert ({layer},{expert}) out of range");
        }
        let tick = inner.rc.next_tick();
        // no hit count on touch: when this follows ensure_resident it is
        // the same logical access the batch phase already counted
        if let Some(rec) = inner.rc.touch(layer, expert, tick, false) {
            return Ok(rec);
        }
        inner.rc.note_miss();
        let nb = inner.rc.nbytes_of(layer, expert);
        inner.rc.make_room(nb, &[]);
        let rec = Arc::new(inner.fetch_demand(layer, &[expert])?.remove(0));
        inner.rc.insert(layer, expert, Arc::clone(&rec), tick, false);
        Ok(rec)
    }

    fn ensure_resident_batch(&self, layer: usize, experts: &[usize]) -> Result<()> {
        if experts.is_empty() {
            return Ok(());
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // validate before any state changes (history, tick, loads)
        inner.rc.check_bounds(layer, experts)?;
        let tick = inner.rc.begin_batch(layer, experts);
        let protect: Vec<(usize, usize)> = experts.iter().map(|&e| (layer, e)).collect();
        let mut missing = Vec::new();
        let mut incoming = 0u64;
        for &e in experts {
            if inner.rc.touch(layer, e, tick, true).is_some() {
                continue;
            }
            inner.rc.note_miss();
            incoming += inner.rc.nbytes_of(layer, e);
            missing.push(e);
        }
        if !missing.is_empty() {
            inner.rc.make_room(incoming, &protect);
            // ONE batched RPC for the whole layer miss-set
            let records = inner.fetch_demand(layer, &missing)?;
            for (&e, rec) in missing.iter().zip(records) {
                inner.rc.insert(layer, e, Arc::new(rec), tick, false);
            }
        }
        // speculative: pipelined, drained on the shard's next touch
        inner.issue_prefetch(layer);
        Ok(())
    }

    fn expert_nbytes(&self, layer: usize, expert: usize) -> u64 {
        self.inner.lock().unwrap().rc.nbytes_of(layer, expert)
    }

    fn total_nbytes(&self) -> u64 {
        self.inner.lock().unwrap().rc.total_nbytes()
    }

    fn counters(&self) -> CacheCounters {
        self.inner.lock().unwrap().rc.counters()
    }

    fn budget_bytes(&self) -> Option<u64> {
        Some(self.inner.lock().unwrap().rc.budget())
    }

    fn set_importance(&self, importance: &[Vec<f64>]) {
        self.inner.lock().unwrap().rc.set_importance(importance);
    }

    fn clear_cache(&self) {
        self.inner.lock().unwrap().rc.clear();
    }

    fn remote_stats(&self) -> Option<RemoteFetchStats> {
        let inner = self.inner.lock().unwrap();
        Some(RemoteFetchStats {
            fetch_rpcs: inner.fetch_rpcs,
            prefetch_rpcs: inner.prefetch_rpcs,
            fetched_bytes: inner.fetched_bytes,
            fetch_p95_us: inner.fetch_histo.percentile(0.95),
            shards_up: inner.shards.iter().filter(|s| s.conn.is_some()).count(),
            shards_total: inner.shards.len(),
        })
    }

    fn fetch_histo(&self) -> Option<crate::trace::Histo> {
        Some(self.inner.lock().unwrap().fetch_histo)
    }

    fn kind(&self) -> &'static str {
        "remote"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_range_parsing() {
        assert_eq!(parse_layer_range("kind=shard layers=0..4 experts=8").unwrap(), 0..4);
        assert_eq!(parse_layer_range("layers=2..3").unwrap(), 2..3);
        assert!(parse_layer_range("kind=shard").is_err());
        assert!(parse_layer_range("layers=3..3").is_err());
        assert!(parse_layer_range("layers=4..2").is_err());
        assert!(parse_layer_range("layers=x..2").is_err());
    }

    #[test]
    fn fetch_unavailable_survives_anyhow_context() {
        let e = unavailable("127.0.0.1:9", "connection refused")
            .context("ensure_resident failed")
            .context("engine step");
        assert!(is_fetch_unavailable(&e));
        let plain = anyhow!("some other failure").context("engine step");
        assert!(!is_fetch_unavailable(&plain));
    }

    #[test]
    fn connect_requires_reachable_shards() {
        // nothing listens on this port — strict startup must fail fast
        let err = RemoteStore::connect(
            &["127.0.0.1:1".into()],
            vec![vec![24; 2]; 2],
            vec![vec![1.0; 2]; 2],
            vec![vec![2; 2]; 2],
            1 << 20,
            200,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("127.0.0.1:1"));
    }
}
