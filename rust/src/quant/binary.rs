//! 1-bit binarization (paper §3.3, Eq. 4/8/9).
//!
//! Storage is the `(sign(W)+1)/2` bit matrix packed 8-per-byte along the
//! reduction axis plus one per-output-channel L1 scale α. `matvec_fused`
//! implements Eq. 9's multiply-free form:
//!
//! `s · (x @ B) = s (Σ_{b=1} x_j − Σ_{b=0} x_j) = s (2 Σ_{b=1} x_j − Σ x_j)`
//!
//! i.e. one accumulate per (row, col) plus a single multiply per output
//! channel — the MAC reduction the paper claims (dm → m multiplies).
//! Both fused entry points delegate to `quant::kernels` (AVX2+FMA with a
//! portable scalar fallback, thread-local scratch); the kernel keeps
//! Eq. 9's form by accumulating `Σ_{b=1} x` with the 0/1 bit test and
//! applying `α·(2·acc − Σx)` once per channel in the epilogue —
//! arithmetically identical to the ±1 select-sum (multiplying by ±1 *is*
//! the select; see DESIGN.md §Hardware-Adaptation).

use std::sync::OnceLock;

use crate::tensor::Tensor2;

use super::kernels::{self, Repacked};

#[derive(Clone, Debug)]
pub struct BinaryMatrix {
    pub d_in: usize,
    pub d_out: usize,
    /// `(sign(W)+1)/2` packed: `[d_in/8, d_out]` row-major bytes.
    pub plane: Vec<u8>,
    /// Per-output-channel scale α = ‖W‖₁ / d (Eq. 4; paper Eq. 9 uses the
    /// matrix-global variant — per-channel is the XNOR-Net refinement the
    /// paper cites, ref. \[46\]).
    pub alpha: Vec<f32>,
    /// Kernel-layer padded repack (α rides in its `scales`), built
    /// eagerly at pack/load time.
    repack: OnceLock<Repacked>,
}

impl BinaryMatrix {
    pub fn binarize(w: &Tensor2) -> BinaryMatrix {
        let (d_in, d_out) = (w.rows, w.cols);
        assert_eq!(d_in % 8, 0);
        let mut plane = vec![0u8; d_in / 8 * d_out];
        let mut alpha = vec![0f32; d_out];
        for o in 0..d_out {
            let mut l1 = 0.0f32;
            for r in 0..d_in {
                let v = w.at(r, o);
                l1 += v.abs();
                if v >= 0.0 {
                    plane[(r / 8) * d_out + o] |= 1 << (r % 8);
                }
            }
            alpha[o] = l1 / d_in as f32;
        }
        BinaryMatrix::from_parts(plane, alpha, d_in, d_out)
    }

    /// Assemble from an already-packed plane (checkpoint load / GPTQ
    /// path) and build the kernel repack once, up front.
    pub fn from_parts(plane: Vec<u8>, alpha: Vec<f32>, d_in: usize, d_out: usize) -> BinaryMatrix {
        let bm = BinaryMatrix { d_in, d_out, plane, alpha, repack: OnceLock::new() };
        let _ = bm.repacked();
        bm
    }

    /// The kernel layer's padded repack of the sign plane.
    pub fn repacked(&self) -> &Repacked {
        self.repack
            .get_or_init(|| Repacked::from_binary(&self.plane, self.d_in, self.d_out, &self.alpha))
    }

    /// Reconstruct `α * (2b − 1)` as f32 (tests / ε probes).
    pub fn dequantize(&self) -> Tensor2 {
        let mut out = Tensor2::zeros(self.d_in, self.d_out);
        for r in 0..self.d_in {
            for o in 0..self.d_out {
                let b = (self.plane[(r / 8) * self.d_out + o] >> (r % 8)) & 1;
                out.set(r, o, self.alpha[o] * (2.0 * b as f32 - 1.0));
            }
        }
        out
    }

    /// Eq. 9: `y += α ⊙ (2 Σ_{b=1} x − Σ x)` with one α multiply per
    /// output channel (kernel layer, thread-local scratch).
    pub fn matvec_fused(&self, x: &[f32], y: &mut [f32]) {
        kernels::with_scratch(|s| kernels::binary_matvec(self, x, y, s));
    }

    pub fn nbytes(&self) -> u64 {
        (self.plane.len() + self.alpha.len() * 4) as u64
    }

    /// Batched `y += x @ dequant(self)` for a token block: the ±α tile of
    /// an input-row block is decoded once into scratch and reused by
    /// every token (the same HBM→VMEM amortization the Pallas kernel
    /// gets from keeping the whole `[T, d_in]` activation block
    /// resident).
    pub fn matmul_fused(&self, x: &Tensor2, y: &mut Tensor2) {
        assert_eq!(x.cols, self.d_in);
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out));
        kernels::with_scratch(|s| kernels::binary_matmul(self, &x.data, x.rows, &mut y.data, s));
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.d_in * self.d_out) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dequant_matches_sign_times_alpha() {
        prop::for_all(81, 20, |rng, _| {
            let d_in = prop::dim(rng, 8, 64, 8);
            let d_out = 1 + rng.below(16);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let bm = BinaryMatrix::binarize(&w);
            let wb = bm.dequantize();
            for r in 0..d_in {
                for o in 0..d_out {
                    let expect =
                        (if w.at(r, o) >= 0.0 { 1.0 } else { -1.0 }) * bm.alpha[o];
                    assert!((wb.at(r, o) - expect).abs() < 1e-6);
                }
            }
        });
    }

    #[test]
    fn batched_matmul_matches_row_matvecs() {
        prop::for_all(83, 15, |rng, _| {
            let d_in = prop::dim(rng, 8, 96, 8);
            let d_out = 1 + rng.below(24);
            let t = 1 + rng.below(6);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let bm = BinaryMatrix::binarize(&w);
            let x = Tensor2::randn(t, d_in, rng, 1.0);
            let mut got = Tensor2::zeros(t, d_out);
            bm.matmul_fused(&x, &mut got);
            for ti in 0..t {
                let mut want = vec![0.0f32; d_out];
                bm.matvec_fused(x.row(ti), &mut want);
                for (a, b) in got.row(ti).iter().zip(&want) {
                    assert!((a - b).abs() < 1e-3, "row {ti}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn fused_matvec_matches_dequant() {
        prop::for_all(82, 20, |rng, _| {
            let d_in = prop::dim(rng, 8, 96, 8);
            let d_out = 1 + rng.below(24);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let bm = BinaryMatrix::binarize(&w);
            let wb = bm.dequantize();
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal()).collect();
            let mut want = vec![0.0f32; d_out];
            for (r, &xr) in x.iter().enumerate() {
                for o in 0..d_out {
                    want[o] += xr * wb.at(r, o);
                }
            }
            let mut got = vec![0.0f32; d_out];
            bm.matvec_fused(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn alpha_is_l1_over_d() {
        let w = Tensor2::from_vec(8, 1, vec![1.0, -2.0, 3.0, -4.0, 1.0, -1.0, 2.0, -2.0]);
        let bm = BinaryMatrix::binarize(&w);
        assert!((bm.alpha[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn storage_is_about_one_bit() {
        let mut rng = crate::util::rng::Rng::new(3);
        let w = Tensor2::randn(256, 128, &mut rng, 1.0);
        let bm = BinaryMatrix::binarize(&w);
        assert!(bm.bits_per_weight() < 1.2, "{}", bm.bits_per_weight());
    }
}
