//! Quantization substrate: round-to-nearest + GPTQ quantizers, 1-bit
//! binarization (paper Eq. 4/8/9), bit-plane packed storage (the HQQ-role
//! store shared byte-for-byte with the Pallas kernels), the
//! SIMD-specialized fused dequant×matmul kernel layer (`kernels`),
//! quantized linear execution and the per-expert reconstruction-error
//! table (Eq. 6).

pub mod awq;
pub mod binary;
pub mod error;
pub mod gptq;
pub mod kernels;
pub mod packed;
pub mod qcheckpoint;
pub mod qlinear;
pub mod qmodel;
pub mod remote;
pub mod rtn;
pub mod store;

pub use binary::BinaryMatrix;
pub use gptq::GptqQuantizer;
pub use kernels::{Isa, Scratch};
pub use packed::PackedMatrix;
pub use qlinear::QuantLinear;
pub use qmodel::{QuantExpert, QuantModel};
pub use remote::RemoteStore;
pub use store::{
    CacheCounters, ExpertStore, PagedStore, RemoteFetchStats, ResidencyCache, ResidentStore,
};
