//! The quantized MoE model: the deployable artifact PMQ produces.
//!
//! * Routed experts carry **per-expert bit-widths** (the PMQ allocation),
//!   stored packed (`QuantLinear`).
//! * Attention, gating and shared-expert weights are uniformly 4-bit
//!   (paper §3.2.3): simulated by RTN round-trip on the dense weights
//!   (their compute runs f32 on dequantized values, their *memory* is
//!   accounted at 4-bit).
//!
//! `QuantModel` implements [`ExpertProvider`], so every evaluation path
//! (`MoeModel::forward_opts`) can run with quantized experts without
//! duplicating the transformer plumbing; the serving decode path in
//! `backend` uses the same `QuantLinear`s.

use std::sync::Arc;

use crate::config::PmqConfig;
use crate::moe::model::{ExpertId, ExpertProvider, MoeModel};
use crate::tensor::{silu, Tensor2};

use super::gptq::GptqQuantizer;
use super::kernels::{self, Scratch};
use super::qlinear::QuantLinear;
use super::rtn;
use super::store::{ExpertStore, ResidentStore};

/// One quantized SwiGLU expert.
#[derive(Clone, Debug)]
pub struct QuantExpert {
    pub wg: QuantLinear,
    pub wu: QuantLinear,
    pub wd: QuantLinear,
    /// Nominal code bits (1, 2, 3 — or 16 for fp).
    pub bits: u8,
}

impl QuantExpert {
    /// `out += w * F(x)` with fused dequant matvecs.
    // analyze: hot-path
    pub fn ffn_row_acc(&self, x: &[f32], w: f32, out: &mut [f32]) {
        kernels::with_scratch(|s| self.ffn_row_sc(x, w, out, s));
    }

    /// Scratch-threaded variant of [`ffn_row_acc`](Self::ffn_row_acc):
    /// the SwiGLU intermediates `g`/`u` and the weighted-accumulate `tmp`
    /// come out of the thread's kernel scratch arena instead of three
    /// fresh `Vec`s per expert call — zero steady-state allocation on the
    /// decode hot path.
    // analyze: hot-path
    pub fn ffn_row_sc(&self, x: &[f32], w: f32, out: &mut [f32], s: &mut Scratch) {
        let f = self.wg.d_out();
        let mut g = s.take_pool(0, f);
        let mut u = s.take_pool(1, f);
        self.wg.matvec_acc_sc(x, &mut g, s);
        self.wu.matvec_acc_sc(x, &mut u, s);
        for j in 0..f {
            g[j] = silu(g[j]) * u[j];
        }
        if w == 1.0 {
            self.wd.matvec_acc_sc(&g, out, s);
        } else {
            let mut tmp = s.take_pool(2, out.len());
            self.wd.matvec_acc_sc(&g, &mut tmp, s);
            for (o, t) in out.iter_mut().zip(&tmp) {
                *o += w * t;
            }
            s.put_pool(2, tmp);
        }
        s.put_pool(0, g);
        s.put_pool(1, u);
    }

    pub fn nbytes(&self) -> u64 {
        self.wg.nbytes() + self.wu.nbytes() + self.wd.nbytes()
    }

    /// Batched `out += F(x)` over a token block: one decoded weight tile
    /// serves every token (the native analog of running the Pallas
    /// expert-FFN kernel on a padded token bucket).
    // analyze: hot-path
    pub fn ffn_batch_acc(&self, x: &Tensor2, out: &mut Tensor2) {
        assert_eq!(x.cols, self.wg.d_in());
        assert_eq!((out.rows, out.cols), (x.rows, self.wd.d_out()));
        kernels::with_scratch(|s| self.ffn_batch_sc(&x.data, x.rows, &mut out.data, s));
    }

    /// Scratch-threaded batched FFN over `t` row-major tokens
    /// (`x: [t, d_model]`, `out: [t, d_model]`), intermediates pooled in
    /// the scratch arena. Same zero-allocation contract as
    /// [`ffn_row_sc`](Self::ffn_row_sc).
    // analyze: hot-path
    pub fn ffn_batch_sc(&self, x: &[f32], t: usize, out: &mut [f32], s: &mut Scratch) {
        let f = self.wg.d_out();
        let mut g = s.take_pool(0, t * f);
        let mut u = s.take_pool(1, t * f);
        self.wg.matmul_acc_sc(x, t, &mut g, s);
        self.wu.matmul_acc_sc(x, t, &mut u, s);
        for (gv, &uv) in g.iter_mut().zip(&u) {
            *gv = silu(*gv) * uv;
        }
        self.wd.matmul_acc_sc(&g, t, out, s);
        s.put_pool(0, g);
        s.put_pool(1, u);
    }
}

/// A fully quantized model: dense parts 4-bit-round-tripped in the base
/// `MoeModel`, routed experts packed per the allocation and owned by an
/// [`ExpertStore`] (all-resident by default; paged from a v2 qcheckpoint
/// under a byte budget — see `quant::store`).
pub struct QuantModel {
    /// Base model with attention/gate/shared/embed weights replaced by
    /// their 4-bit RTN round-trips. Its routed experts are *unused* at
    /// inference (the provider intercepts them).
    pub model: MoeModel,
    /// Owner of the `[layer][expert]` packed experts.
    pub store: Arc<dyn ExpertStore>,
    /// Per-(layer, expert) nominal bits of the allocation.
    pub allocation: Vec<Vec<u8>>,
    pub pmq: PmqConfig,
    /// Calibrated PMQ significance per (layer, expert), when available —
    /// persisted in v2 checkpoints and used as the paged store's eviction
    /// tie-break. `None` falls back to the allocation bit-widths.
    pub importance: Option<Vec<Vec<f64>>>,
}

/// How expert weights get quantized: plain RTN, GPTQ with per-layer
/// calibration Hessians, or AWQ activation-aware scaling.
pub enum QuantMethod<'a> {
    Rtn,
    /// `[layer]` pair of Hessian accumulators for (d_model-input mats,
    /// d_ff-input mats) — built by `pmq::importance::calibrate`.
    Gptq(&'a [(GptqQuantizer, GptqQuantizer)]),
    /// AWQ per-channel scaling (paper's "orthogonal PTQ" claim, §3.2.3):
    /// per-layer MoE-input activations drive the wg/wu scales; each
    /// expert's SwiGLU intermediate activations drive its wd scales.
    /// 1-bit experts fall back to sign binarization (AWQ scaling is
    /// sign-invariant there).
    Awq(&'a [crate::quant::error::LayerActivations]),
}

impl QuantModel {
    /// Quantize `base` with per-(layer, expert) bit allocation.
    pub fn quantize(
        base: &MoeModel,
        allocation: &[Vec<u8>],
        pmq: &PmqConfig,
        method: &QuantMethod,
    ) -> QuantModel {
        let cfg = &base.cfg;
        assert_eq!(allocation.len(), cfg.n_layers);
        let mut model = clone_model(base);
        // 4-bit the dense parts (compute path uses the round-trip values)
        for b in &mut model.blocks {
            for w in [&mut b.attn.wq, &mut b.attn.wk, &mut b.attn.wv, &mut b.attn.wo] {
                *w = rtn::fake_quant(w, pmq.other_bits, pmq.group);
            }
            b.gate = rtn::fake_quant(&b.gate, pmq.other_bits, pmq.group);
            for e in &mut b.shared {
                e.wg = rtn::fake_quant(&e.wg, pmq.other_bits, pmq.group);
                e.wu = rtn::fake_quant(&e.wu, pmq.other_bits, pmq.group);
                e.wd = rtn::fake_quant(&e.wd, pmq.other_bits, pmq.group);
            }
        }
        let mut experts = Vec::new();
        for (l, block) in base.blocks.iter().enumerate() {
            let mut row = Vec::new();
            for (e, expert) in block.experts.iter().enumerate() {
                let bits = allocation[l][e];
                row.push(quantize_expert(expert, bits, pmq, method, l));
            }
            experts.push(row);
        }
        QuantModel {
            model,
            store: Arc::new(ResidentStore::new(experts)),
            allocation: allocation.to_vec(),
            pmq: pmq.clone(),
            importance: None,
        }
    }

    /// Handle to packed expert `(layer, e)`. Panics on a paging failure —
    /// the recoverable error path is the dispatcher's pre-execute
    /// `ensure_resident`, after which this is a cache hit.
    pub fn expert(&self, layer: usize, e: usize) -> Arc<QuantExpert> {
        self.store.get(layer, e).expect("expert store read failed")
    }

    /// Attach calibrated PMQ significance (φ^α·w^β per (layer, expert)):
    /// persisted by v2 checkpoints, consumed by the paged store's
    /// eviction tie-break.
    pub fn set_importance(&mut self, importance: Vec<Vec<f64>>) {
        self.store.set_importance(&importance);
        self.importance = Some(importance);
    }

    /// Nominal average expert bit-width of the allocation (the paper's
    /// "Bits" column for experts).
    pub fn avg_expert_bits(&self) -> f64 {
        let total: u64 = self.allocation.iter().flatten().map(|&b| b as u64).sum();
        total as f64 / self.allocation.iter().map(|r| r.len()).sum::<usize>() as f64
    }

    /// Average bits over the whole language backbone: experts at their
    /// allocation + everything else at `other_bits` (the paper's reported
    /// "Bits" values, e.g. 2.05 = 2-bit experts + 4-bit others).
    pub fn avg_model_bits(&self) -> f64 {
        let cfg = &self.model.cfg;
        let expert_params = (cfg.n_layers * cfg.n_experts * cfg.expert_params()) as f64;
        // derived from config, not `model.n_params()`: store-backed loads
        // elide the routed-expert placeholders, so the in-RAM model is
        // smaller than the nominal backbone this metric describes
        let other_params = (cfg.total_params()
            - cfg.n_layers * cfg.n_experts * cfg.expert_params()) as f64;
        (self.avg_expert_bits() * expert_params + self.pmq.other_bits as f64 * other_params)
            / (expert_params + other_params)
    }

    /// Packed weight bytes (experts packed + others at 4-bit + embeddings
    /// at 16-bit) — Table 5's "Params (GB→MB here)".
    pub fn nbytes(&self) -> u64 {
        let cfg = &self.model.cfg;
        let expert_bytes: u64 = self.store.total_nbytes();
        let h = cfg.d_model as u64;
        let attn = cfg.n_layers as u64 * (4 * h * h) / 2; // 4-bit
        let gate = cfg.n_layers as u64 * h * cfg.n_experts as u64 / 2;
        let shared =
            (cfg.n_layers * cfg.n_shared_experts * cfg.expert_params()) as u64 / 2;
        let embed = (cfg.vocab_size as u64 * h + h * cfg.vocab_size as u64) * 2; // fp16
        expert_bytes + attn + gate + shared + embed
    }

    /// Average packed bytes activated per token (Table 5 "Act Params"):
    /// top-k experts at their mixed widths (expectation over the
    /// calibrated routing distribution is approximated uniformly over
    /// experts when no stats are given).
    pub fn activated_bytes_per_token(&self, keep_ratio: f64) -> u64 {
        let cfg = &self.model.cfg;
        let mean_expert_bytes: f64 =
            self.store.total_nbytes() as f64 / (cfg.n_layers * cfg.n_experts) as f64;
        let h = cfg.d_model as u64;
        let per_layer_static = (4 * h * h) / 2
            + h * cfg.n_experts as u64 / 2
            + (cfg.n_shared_experts * cfg.expert_params()) as u64 / 2;
        let embed = (2 * cfg.vocab_size as u64 * h) * 2;
        let routed =
            mean_expert_bytes * cfg.top_k as f64 * keep_ratio * cfg.n_layers as f64;
        embed + cfg.n_layers as u64 * per_layer_static + routed as u64
    }
}

impl ExpertProvider for QuantModel {
    fn expert_ffn_acc(&self, layer: usize, id: ExpertId, x: &[f32], w: f32, out: &mut [f32]) {
        match id {
            ExpertId::Routed(e) => self.expert(layer, e).ffn_row_acc(x, w, out),
            // shared experts already 4-bit round-tripped in `model`
            ExpertId::Shared(s) => self.model.blocks[layer].shared[s].ffn_row_acc(x, w, out),
        }
    }

    /// Dispatcher pre-execute: batch the paging I/O for this layer's
    /// routed set (and let the store prefetch the next layer) before the
    /// scoped-thread execute region starts.
    fn ensure_resident(&self, layer: usize, experts: &[usize]) -> anyhow::Result<()> {
        self.store.ensure_resident(layer, experts)
    }

    /// The expert-grouped fast path: one `ffn_batch_acc` per token group
    /// decodes each packed weight tile once and reuses it for every row
    /// (previously only reachable from the serving backend; now this is
    /// the inner loop of every quantized eval through `forward_opts`).
    fn expert_ffn_batch_acc(
        &self,
        layer: usize,
        id: ExpertId,
        x: &Tensor2,
        weights: &[f32],
        out: &mut Tensor2,
    ) {
        let acc_weighted = |y: &Tensor2, out: &mut Tensor2| {
            for i in 0..y.rows {
                let w = weights[i];
                for (o, v) in out.row_mut(i).iter_mut().zip(y.row(i)) {
                    *o += w * v;
                }
            }
        };
        match id {
            ExpertId::Routed(e) => {
                let qe = self.expert(layer, e);
                if weights.iter().all(|&w| w == 1.0) {
                    qe.ffn_batch_acc(x, out);
                } else {
                    // weighted path: tmp comes from the scratch arena's
                    // third pool slot (slots 0/1 feed the SwiGLU
                    // intermediates inside `ffn_batch_sc`)
                    kernels::with_scratch(|s| {
                        let mut tmp = s.take_pool(2, x.rows * out.cols);
                        qe.ffn_batch_sc(&x.data, x.rows, &mut tmp, s);
                        for i in 0..x.rows {
                            let w = weights[i];
                            let trow = &tmp[i * out.cols..][..out.cols];
                            for (o, v) in out.row_mut(i).iter_mut().zip(trow) {
                                *o += w * v;
                            }
                        }
                        s.put_pool(2, tmp);
                    });
                }
            }
            // shared experts are round-tripped f32: batched matmul path
            ExpertId::Shared(s) => {
                let y = self.model.blocks[layer].shared[s].ffn(x);
                acc_weighted(&y, out);
            }
        }
    }
}

fn quantize_expert(
    expert: &crate::moe::Expert,
    bits: u8,
    pmq: &PmqConfig,
    method: &QuantMethod,
    layer: usize,
) -> QuantExpert {
    // AWQ needs this expert's SwiGLU intermediate activations for wd;
    // computed lazily from the layer's captured MoE inputs.
    let ff_acts = |acts: &crate::quant::error::LayerActivations| -> Vec<Vec<f32>> {
        let f = expert.wg.cols;
        acts.xs
            .iter()
            .take(32)
            .map(|x| {
                let mut g = vec![0.0f32; f];
                let mut u = vec![0.0f32; f];
                for (k, &xk) in x.iter().enumerate() {
                    if xk != 0.0 {
                        crate::tensor::axpy(xk, expert.wg.row(k), &mut g);
                        crate::tensor::axpy(xk, expert.wu.row(k), &mut u);
                    }
                }
                for j in 0..f {
                    g[j] = silu(g[j]) * u[j];
                }
                g
            })
            .collect()
    };
    let quant_mat = |w: &Tensor2, is_down: bool| -> QuantLinear {
        match (bits, method) {
            (1, QuantMethod::Rtn) | (1, QuantMethod::Awq(_)) => {
                QuantLinear::Binary(super::binary::BinaryMatrix::binarize(w))
            }
            (1, QuantMethod::Gptq(hs)) => {
                let q = if is_down { &hs[layer].1 } else { &hs[layer].0 };
                QuantLinear::Binary(q.quantize_binary(w))
            }
            (16, _) => QuantLinear::Fp(w.clone()),
            (b, QuantMethod::Rtn) => {
                let (c, s, z) = rtn::quantize_rtn(w, b, pmq.group);
                QuantLinear::Packed(super::packed::PackedMatrix::from_codes(
                    &c, s, z, w.rows, w.cols, b, pmq.group,
                ))
            }
            (b, QuantMethod::Gptq(hs)) => {
                let q = if is_down { &hs[layer].1 } else { &hs[layer].0 };
                QuantLinear::Packed(q.quantize_packed(w, b, pmq.group))
            }
            (b, QuantMethod::Awq(acts)) => {
                let xs: Vec<Vec<f32>> = if is_down {
                    ff_acts(&acts[layer])
                } else {
                    acts[layer].xs.iter().take(32).cloned().collect()
                };
                let (_, ql) = super::awq::awq_quantize(w, &xs, b, pmq.group);
                ql
            }
        }
    };
    QuantExpert {
        wg: quant_mat(&expert.wg, false),
        wu: quant_mat(&expert.wu, false),
        wd: quant_mat(&expert.wd, true),
        bits,
    }
}

/// Deep copy of a model (weights only).
pub fn clone_model(m: &MoeModel) -> MoeModel {
    MoeModel {
        cfg: m.cfg.clone(),
        embed: m.embed.clone(),
        blocks: m
            .blocks
            .iter()
            .map(|b| crate::moe::model::Block {
                attn_norm: b.attn_norm.clone(),
                attn: b.attn.clone(),
                moe_norm: b.moe_norm.clone(),
                gate: b.gate.clone(),
                experts: b.experts.clone(),
                shared: b.shared.clone(),
            })
            .collect(),
        final_norm: m.final_norm.clone(),
        lm_head: m.lm_head.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, PmqConfig};
    use crate::moe::model::ForwardOpts;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "qm-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 32,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    #[test]
    fn quantized_forward_runs_and_degrades_gracefully() {
        let base = MoeModel::new(&cfg(), 5);
        let toks: Vec<u16> = vec![1, 17, 30, 45, 8, 22, 50, 12];
        let alloc3 = vec![vec![3u8; 4]; 2];
        let alloc1 = vec![vec![1u8; 4]; 2];
        let pmq = PmqConfig::default();
        let q3 = QuantModel::quantize(&base, &alloc3, &pmq, &QuantMethod::Rtn);
        let q1 = QuantModel::quantize(&base, &alloc1, &pmq, &QuantMethod::Rtn);
        let base_nll = base.nll(&toks, &mut ForwardOpts::default());
        let nll3 = q3.model.nll(&toks, &mut ForwardOpts { provider: Some(&q3), ..Default::default() });
        let nll1 = q1.model.nll(&toks, &mut ForwardOpts { provider: Some(&q1), ..Default::default() });
        assert!(nll3.is_finite() && nll1.is_finite());
        // 3-bit should be closer to fp than 1-bit (on a random model the
        // ordering in absolute NLL can be noisy, so compare distortion of
        // logits instead)
        let l_base = base.forward(&toks);
        let l3 = q3.model.forward_opts(&toks, &mut ForwardOpts { provider: Some(&q3), ..Default::default() });
        let l1 = q1.model.forward_opts(&toks, &mut ForwardOpts { provider: Some(&q1), ..Default::default() });
        let dist = |a: &crate::tensor::Tensor2, b: &crate::tensor::Tensor2| {
            a.data.iter().zip(&b.data).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        assert!(dist(&l3, &l_base) < dist(&l1, &l_base));
    }

    #[test]
    fn bits_accounting_matches_allocation() {
        let base = MoeModel::new(&cfg(), 6);
        let alloc = vec![vec![1u8, 2, 3, 2], vec![2, 2, 3, 1]];
        let pmq = PmqConfig::default();
        let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Rtn);
        let want = (1 + 2 + 3 + 2 + 2 + 2 + 3 + 1) as f64 / 8.0;
        assert!((q.avg_expert_bits() - want).abs() < 1e-9);
        assert!(q.avg_model_bits() > want); // 4-bit others pull it up
        assert!(q.nbytes() < q.model.nbytes_fp16());
    }

    #[test]
    fn awq_method_quantizes_and_runs() {
        let base = MoeModel::new(&cfg(), 8);
        let pmq = PmqConfig::default();
        // mixed allocation incl. 1-bit (binary fallback) and 2/3-bit (Scaled)
        let alloc = vec![vec![2u8, 3, 1, 2], vec![3, 2, 2, 1]];
        // capture MoE inputs as AWQ's calibration activations
        let toks: Vec<u16> = (0..24).map(|i| (i * 7 % 60 + 1) as u16).collect();
        let mut captured: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 2];
        base.forward_opts(
            &toks,
            &mut ForwardOpts { capture_moe_inputs: Some(&mut captured), ..Default::default() },
        );
        let acts: Vec<crate::quant::error::LayerActivations> = captured
            .into_iter()
            .map(|xs| crate::quant::error::LayerActivations { xs })
            .collect();
        let q = QuantModel::quantize(&base, &alloc, &pmq, &QuantMethod::Awq(&acts));
        // 2/3-bit experts must be Scaled, 1-bit ones Binary
        for (l, row) in alloc.iter().enumerate() {
            for (e, &bits) in row.iter().enumerate() {
                let qe = q.expert(l, e);
                match bits {
                    1 => assert!(matches!(qe.wg, QuantLinear::Binary(_))),
                    _ => assert!(matches!(qe.wg, QuantLinear::Scaled { .. })),
                }
            }
        }
        let nll =
            q.model.nll(&toks, &mut ForwardOpts { provider: Some(&q), ..Default::default() });
        assert!(nll.is_finite());
    }

    #[test]
    fn mixed_allocation_memory_monotone() {
        let base = MoeModel::new(&cfg(), 7);
        let pmq = PmqConfig::default();
        let lo = QuantModel::quantize(&base, &vec![vec![1u8; 4]; 2], &pmq, &QuantMethod::Rtn);
        let hi = QuantModel::quantize(&base, &vec![vec![3u8; 4]; 2], &pmq, &QuantMethod::Rtn);
        assert!(lo.nbytes() < hi.nbytes());
        assert!(lo.activated_bytes_per_token(1.0) < hi.activated_bytes_per_token(1.0));
        assert!(lo.activated_bytes_per_token(0.7) < lo.activated_bytes_per_token(1.0));
    }
}
