//! Expert-weight residency: who owns the packed experts, and how many of
//! them live in RAM at once.
//!
//! MC#'s deployment premise (paper §1, §3.2 "pre-loading") is that expert
//! weights dominate MoE memory, so the serving stack must not assume
//! every packed expert is resident. [`ExpertStore`] is the single trait
//! every consumer — the quantized provider, the serving backends, the
//! checkpoint writer, OTP distillation — goes through:
//!
//! * [`ResidentStore`] — all experts in RAM (the historical behaviour,
//!   still the default for `compress`/`eval` where the model was just
//!   quantized in memory anyway);
//! * [`PagedStore`] — experts load lazily from a seekable record source
//!   (the v2 qcheckpoint's per-expert index) on first touch and are
//!   evicted under a byte budget: least-recently-used first, ties broken
//!   by PMQ significance (`pmq::importance`) so high-significance experts
//!   are evicted last. The dispatcher's pre-execute phase
//!   (`moe::dispatch`) batches the paging I/O for a layer's routed expert
//!   set *before* the scoped-thread execute, and the store prefetches the
//!   next layer's hottest experts (by observed `moe::stats` routing
//!   frequency) into whatever budget remains.
//! * [`RemoteStore`](super::remote::RemoteStore) — the same residency
//!   policy, but records page in over the wire from shard servers
//!   (`mcsharp shard`) instead of a local file.
//!
//! The budget/LRU/importance/prefetch policy itself lives in
//! [`ResidencyCache`], shared by the paged and remote stores so the two
//! cannot drift: what differs between them is only *where a missing
//! record comes from* (a seek + read vs. a batched `FETCH` RPC).
//!
//! Handles are `Arc<QuantExpert>`: eviction drops the store's reference,
//! in-flight executions keep theirs, so no lock is held while an expert
//! runs.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::moe::stats::RoutingStats;

use super::qmodel::QuantExpert;

/// Monotonic cache gauges, cheap to copy into serving metrics each step.
///
/// The counted access unit is one **residency lookup per routed expert**:
/// the dispatcher's `ensure_resident` batch on a paged store, or the
/// execute-phase handle fetch on a resident store. The execute-phase
/// `get` that follows a successful `ensure_resident` is the same logical
/// access and is deliberately *not* re-counted as a hit (it would put a
/// structural ~0.5 floor under the hit rate); it only counts when it has
/// to fault a record in (a miss the batch phase did not cover).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Packed bytes currently resident.
    pub resident_bytes: u64,
    /// High-water mark of `resident_bytes` (the budget-honored proof).
    pub peak_resident_bytes: u64,
    /// Residency lookups served without touching the record source.
    pub hits: u64,
    /// Record faults (every read of the record source except prefetch).
    pub misses: u64,
    /// Experts dropped to fit the budget.
    pub evictions: u64,
    /// Hits on experts that were brought in speculatively.
    pub prefetch_hits: u64,
}

impl CacheCounters {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

/// Wire-side gauges a remote store exposes on top of [`CacheCounters`]
/// (STATS/METRICS `remote_fetch_*` fields). Local stores report `None`
/// from [`ExpertStore::remote_stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RemoteFetchStats {
    /// Demand `FETCH` RPCs issued (one per layer miss-set, not per
    /// expert — the batching proof).
    pub fetch_rpcs: u64,
    /// Speculative `FETCH` RPCs issued (pipelined next-layer prefetch).
    pub prefetch_rpcs: u64,
    /// Σ payload bytes received over all record frames.
    pub fetched_bytes: u64,
    /// p95 demand-fetch round trip in µs (window since last scrape-reset;
    /// 0 when no fetch happened yet).
    pub fetch_p95_us: u64,
    /// Shards currently reachable.
    pub shards_up: usize,
    pub shards_total: usize,
}

/// Allocation bit-widths as the eviction-priority fallback: PMQ gives
/// important experts more bits, so bits are a coarse built-in proxy when
/// no calibrated significance was persisted with the model.
pub fn bits_as_importance(allocation: &[Vec<u8>]) -> Vec<Vec<f64>> {
    allocation.iter().map(|row| row.iter().map(|&b| b as f64).collect()).collect()
}

/// Owner of the packed routed-expert weights.
///
/// `get` may do I/O on a miss; `ensure_resident` batches that I/O for a
/// whole routed set so it never sits inside the dispatcher's parallel
/// execute region. `expert_nbytes` must not fault anything in — serving
/// metrics call it per executed group.
pub trait ExpertStore: Send + Sync {
    /// Handle to expert `(layer, expert)`, loading it on a miss.
    fn get(&self, layer: usize, expert: usize) -> Result<Arc<QuantExpert>>;

    /// The overridable batched fetch plan: make a layer's routed expert
    /// set resident in one pass (one seek sweep for a paged store, one
    /// batched `FETCH` RPC per shard for a remote store) and feed the
    /// store's routing history (which drives next-layer prefetch). No-op
    /// for fully resident stores.
    fn ensure_resident_batch(&self, layer: usize, experts: &[usize]) -> Result<()> {
        let _ = (layer, experts);
        Ok(())
    }

    /// Call-site-facing residency entry point (the dispatcher's
    /// pre-execute phase); forwards to
    /// [`ensure_resident_batch`](Self::ensure_resident_batch) so stores
    /// override in exactly one place.
    fn ensure_resident(&self, layer: usize, experts: &[usize]) -> Result<()> {
        self.ensure_resident_batch(layer, experts)
    }

    /// Packed bytes of one expert, from metadata (never faults it in).
    fn expert_nbytes(&self, layer: usize, expert: usize) -> u64;

    /// Σ packed bytes over every expert the store owns.
    fn total_nbytes(&self) -> u64;

    /// Current cache gauges (all-resident stores report a full cache).
    fn counters(&self) -> CacheCounters;

    /// Residency budget, if this store enforces one.
    fn budget_bytes(&self) -> Option<u64> {
        None
    }

    /// Per-(layer, expert) PMQ significance used as the eviction
    /// tie-break. All-resident stores may ignore it.
    fn set_importance(&self, importance: &[Vec<f64>]);

    /// Drop every cached record and zero the gauges. For one-shot bulk
    /// readers that stream the whole store without serving from it
    /// (PJRT literal staging): without the reset, up to a full budget of
    /// records nothing will read again stays resident, and the staging
    /// misses/evictions masquerade as serving-time cache behaviour.
    /// No-op for all-resident stores.
    fn clear_cache(&self) {}

    /// Wire gauges + shard health, for stores that fetch over the
    /// network. `None` for local stores.
    fn remote_stats(&self) -> Option<RemoteFetchStats> {
        None
    }

    /// Per-RPC demand-fetch wait distribution (µs, log2 buckets), for
    /// stores that fetch over the network. `None` for local stores.
    fn fetch_histo(&self) -> Option<crate::trace::Histo> {
        None
    }

    fn kind(&self) -> &'static str;
}

// ---------------------------------------------------------------- resident

/// Every expert in RAM — the pre-paging behaviour behind the same trait.
pub struct ResidentStore {
    experts: Vec<Vec<Arc<QuantExpert>>>,
    nbytes: Vec<Vec<u64>>,
    total: u64,
    /// Every access is a hit by construction; counted so the serving
    /// hit-rate gauge reads 1.000 for resident stores (EXPERIMENTS.md
    /// §Memory's resident rows) instead of a misleading 0.
    hits: std::sync::atomic::AtomicU64,
}

impl ResidentStore {
    pub fn new(experts: Vec<Vec<QuantExpert>>) -> ResidentStore {
        let nbytes: Vec<Vec<u64>> =
            experts.iter().map(|row| row.iter().map(|e| e.nbytes()).collect()).collect();
        let total = nbytes.iter().flatten().sum();
        ResidentStore {
            experts: experts
                .into_iter()
                .map(|row| row.into_iter().map(Arc::new).collect())
                .collect(),
            nbytes,
            total,
            hits: std::sync::atomic::AtomicU64::new(0),
        }
    }
}

impl ExpertStore for ResidentStore {
    fn get(&self, layer: usize, expert: usize) -> Result<Arc<QuantExpert>> {
        self.hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(Arc::clone(&self.experts[layer][expert]))
    }

    fn expert_nbytes(&self, layer: usize, expert: usize) -> u64 {
        self.nbytes[layer][expert]
    }

    fn total_nbytes(&self) -> u64 {
        self.total
    }

    fn counters(&self) -> CacheCounters {
        CacheCounters {
            resident_bytes: self.total,
            peak_resident_bytes: self.total,
            hits: self.hits.load(std::sync::atomic::Ordering::Relaxed),
            ..Default::default()
        }
    }

    fn set_importance(&self, _importance: &[Vec<f64>]) {}

    fn kind(&self) -> &'static str {
        "resident"
    }
}

// -------------------------------------------------------- residency cache

struct CacheEntry {
    expert: Arc<QuantExpert>,
    /// Tick of the last touch; `ensure_resident` stamps a whole batch
    /// with one tick, which is where the importance tie-break bites.
    last_use: u64,
    /// Loaded speculatively and not yet demanded.
    prefetched: bool,
}

/// The budget/LRU/importance/prefetch policy, independent of where
/// records come from. [`PagedStore`] wires it to a seekable
/// [`RecordSource`]; [`RemoteStore`](super::remote::RemoteStore) wires it
/// to shard-server RPCs. Both stores hold it behind their own mutex; the
/// cache itself is plain data, so the policy cannot fork between the two
/// backends.
///
/// The miss path is split into `note_miss` → `make_room` → (the owner
/// reads the record however it reads records) → `insert`, preserving the
/// paged store's historical accounting order: a failed read leaves the
/// miss counted and the cache untouched.
pub struct ResidencyCache {
    n_layers: usize,
    n_experts: usize,
    nbytes: Vec<Vec<u64>>,
    budget: u64,
    /// Max experts speculatively loaded per ensure batch.
    prefetch_width: usize,
    cache: HashMap<(usize, usize), CacheEntry>,
    tick: u64,
    counters: CacheCounters,
    /// Observed serve-time routing history — the prefetch signal
    /// (activation frequency per (layer, expert), §3.2.2's φ reused as a
    /// deployment heuristic).
    route: RoutingStats,
    /// PMQ significance; falls back to allocation bit-widths when no
    /// calibration importance was persisted.
    importance: Vec<Vec<f64>>,
}

impl ResidencyCache {
    /// `nbytes` is the per-(layer, expert) packed size table (from the v2
    /// header) — budget accounting and metrics read it without faulting
    /// records in.
    pub fn new(nbytes: Vec<Vec<u64>>, importance: Vec<Vec<f64>>, budget_bytes: u64) -> Self {
        let n_layers = nbytes.len();
        let n_experts = nbytes.first().map(|r| r.len()).unwrap_or(0);
        ResidencyCache {
            n_layers,
            n_experts,
            nbytes,
            budget: budget_bytes,
            prefetch_width: 4,
            cache: HashMap::new(),
            tick: 0,
            counters: CacheCounters::default(),
            route: RoutingStats::new(n_layers, n_experts),
            importance,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn nbytes_of(&self, layer: usize, expert: usize) -> u64 {
        self.nbytes[layer][expert]
    }

    pub fn total_nbytes(&self) -> u64 {
        self.nbytes.iter().flatten().sum()
    }

    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    pub fn set_importance(&mut self, importance: &[Vec<f64>]) {
        self.importance = importance.to_vec();
    }

    /// Drop every cached record and zero the gauges (routing history and
    /// the tick survive — they are serving-lifetime signals).
    pub fn clear(&mut self) {
        self.cache.clear();
        self.counters = CacheCounters::default();
    }

    pub fn contains(&self, layer: usize, expert: usize) -> bool {
        self.cache.contains_key(&(layer, expert))
    }

    /// Validate a request before any state changes (history, tick,
    /// loads) — a rejected request must leave no trace.
    pub fn check_bounds(&self, layer: usize, experts: &[usize]) -> Result<()> {
        if layer >= self.n_layers {
            bail!("layer {layer} out of range (n_layers {})", self.n_layers);
        }
        if let Some(&e) = experts.iter().find(|&&e| e >= self.n_experts) {
            bail!("expert ({layer},{e}) out of range (n_experts {})", self.n_experts);
        }
        Ok(())
    }

    pub fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Start one batched ensure: bump the tick and feed the routing
    /// history (one observation unit per batch). Bounds must already have
    /// been checked.
    pub fn begin_batch(&mut self, layer: usize, experts: &[usize]) -> u64 {
        let tick = self.next_tick();
        self.route.bump_tokens();
        for &e in experts {
            self.route.record(layer, e, 1.0);
        }
        tick
    }

    /// Hit path: refresh recency, clear the speculative flag (counting a
    /// prefetch hit), and count a hit when `count_hit` (the batch phase
    /// counts; the execute-phase `get` that follows it does not — same
    /// logical access).
    pub fn touch(
        &mut self,
        layer: usize,
        expert: usize,
        tick: u64,
        count_hit: bool,
    ) -> Option<Arc<QuantExpert>> {
        let entry = self.cache.get_mut(&(layer, expert))?;
        entry.last_use = tick;
        if entry.prefetched {
            entry.prefetched = false;
            self.counters.prefetch_hits += 1;
        }
        if count_hit {
            self.counters.hits += 1;
        }
        Some(Arc::clone(&entry.expert))
    }

    /// Count a record fault. Called before the read so a failed read
    /// still shows up in the gauges.
    pub fn note_miss(&mut self) {
        self.counters.misses += 1;
    }

    /// Free room for `incoming` bytes BEFORE the record is read, so
    /// resident bytes never transiently exceed the budget. `protect`
    /// entries (the working set about to execute) are never dropped — a
    /// working set larger than the budget overflows visibly (peak
    /// counter) instead of thrashing the experts mid-dispatch.
    pub fn make_room(&mut self, incoming: u64, protect: &[(usize, usize)]) {
        while self.counters.resident_bytes + incoming > self.budget {
            let victim = self
                .cache
                .iter()
                .filter(|(k, _)| !protect.contains(*k))
                .min_by(|(ka, a), (kb, b)| {
                    let ia = self.importance[ka.0][ka.1];
                    let ib = self.importance[kb.0][kb.1];
                    // oldest first; among equals, least significant first
                    a.last_use
                        .cmp(&b.last_use)
                        .then(ia.partial_cmp(&ib).unwrap_or(std::cmp::Ordering::Equal))
                        .then(ka.cmp(kb))
                })
                .map(|(k, _)| *k);
            let Some(k) = victim else { break };
            self.cache.remove(&k);
            self.counters.resident_bytes -= self.nbytes[k.0][k.1];
            self.counters.evictions += 1;
        }
    }

    /// Account and cache one record the owner just read.
    pub fn insert(
        &mut self,
        layer: usize,
        expert: usize,
        rec: Arc<QuantExpert>,
        tick: u64,
        prefetched: bool,
    ) {
        self.counters.resident_bytes += self.nbytes[layer][expert];
        self.counters.peak_resident_bytes =
            self.counters.peak_resident_bytes.max(self.counters.resident_bytes);
        self.cache.insert((layer, expert), CacheEntry { expert: rec, last_use: tick, prefetched });
    }

    /// Insert a speculative record only if it still fits the spare budget
    /// (prefetch never evicts). Returns whether it was kept — a remote
    /// store drains pipelined prefetch responses long after planning, so
    /// the fit is re-checked at insert time.
    pub fn insert_prefetched_if_fits(
        &mut self,
        layer: usize,
        expert: usize,
        rec: Arc<QuantExpert>,
        tick: u64,
    ) -> bool {
        if self.contains(layer, expert)
            || self.counters.resident_bytes + self.nbytes[layer][expert] > self.budget
        {
            return false;
        }
        self.insert(layer, expert, rec, tick, true);
        true
    }

    /// Next-layer speculative fetch plan: the historically hottest
    /// experts of `layer + 1` that are not cached and fit the spare
    /// budget *cumulatively* (the plan never requires an eviction),
    /// width-limited. Returns `(layer, expert)` pairs in rank order.
    pub fn prefetch_plan(&self, layer: usize) -> Vec<(usize, usize)> {
        let next = layer + 1;
        if next >= self.n_layers {
            return Vec::new();
        }
        let mut ranked: Vec<(u64, usize)> = (0..self.n_experts)
            .map(|e| (self.route.counts[next * self.n_experts + e], e))
            .filter(|&(c, _)| c > 0)
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let mut plan = Vec::new();
        let mut resident = self.counters.resident_bytes;
        for (_, e) in ranked {
            if plan.len() >= self.prefetch_width {
                break;
            }
            if self.cache.contains_key(&(next, e)) {
                continue;
            }
            if resident + self.nbytes[next][e] > self.budget {
                continue;
            }
            resident += self.nbytes[next][e];
            plan.push((next, e));
        }
        plan
    }
}

// ------------------------------------------------------------------ paged

/// Seekable source of individual expert records (the v2 qcheckpoint's
/// index, or an in-memory table in tests).
pub trait RecordSource: Send {
    fn read_record(&mut self, layer: usize, expert: usize) -> Result<QuantExpert>;
}

struct PagedInner {
    source: Box<dyn RecordSource>,
    rc: ResidencyCache,
}

/// Budgeted lazy store: LRU eviction, PMQ-importance tie-break,
/// frequency-driven next-layer prefetch — the [`ResidencyCache`] policy
/// over a local seekable [`RecordSource`].
pub struct PagedStore {
    inner: Mutex<PagedInner>,
}

impl PagedStore {
    /// `nbytes` is the per-(layer, expert) packed size table (from the v2
    /// header) — budget accounting and metrics read it without faulting
    /// records in. `importance` defaults to the allocation bit-widths
    /// until [`ExpertStore::set_importance`] provides calibrated values.
    pub fn new(
        source: Box<dyn RecordSource>,
        nbytes: Vec<Vec<u64>>,
        importance: Vec<Vec<f64>>,
        budget_bytes: u64,
    ) -> PagedStore {
        PagedStore {
            inner: Mutex::new(PagedInner {
                source,
                rc: ResidencyCache::new(nbytes, importance, budget_bytes),
            }),
        }
    }
}

impl ExpertStore for PagedStore {
    fn get(&self, layer: usize, expert: usize) -> Result<Arc<QuantExpert>> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        if layer >= inner.rc.n_layers() || expert >= inner.rc.n_experts() {
            bail!("expert ({layer},{expert}) out of range");
        }
        let tick = inner.rc.next_tick();
        // no hit count on touch: when this follows ensure_resident it is
        // the same logical access the batch phase already counted
        if let Some(rec) = inner.rc.touch(layer, expert, tick, false) {
            return Ok(rec);
        }
        inner.rc.note_miss();
        let nb = inner.rc.nbytes_of(layer, expert);
        inner.rc.make_room(nb, &[]);
        let rec = Arc::new(inner.source.read_record(layer, expert)?);
        inner.rc.insert(layer, expert, Arc::clone(&rec), tick, false);
        Ok(rec)
    }

    fn ensure_resident_batch(&self, layer: usize, experts: &[usize]) -> Result<()> {
        if experts.is_empty() {
            return Ok(());
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // validate before any state changes (history, tick, loads)
        inner.rc.check_bounds(layer, experts)?;
        let tick = inner.rc.begin_batch(layer, experts);
        let protect: Vec<(usize, usize)> = experts.iter().map(|&e| (layer, e)).collect();
        for &e in experts {
            if inner.rc.touch(layer, e, tick, true).is_some() {
                continue;
            }
            inner.rc.note_miss();
            let nb = inner.rc.nbytes_of(layer, e);
            inner.rc.make_room(nb, &protect);
            let rec = Arc::new(inner.source.read_record(layer, e)?);
            inner.rc.insert(layer, e, rec, tick, false);
        }
        // speculative: a failed prefetch read is not a dispatch error
        // (the demanded set is already resident at this point)
        for (l, e) in inner.rc.prefetch_plan(layer) {
            match inner.source.read_record(l, e) {
                Ok(rec) => inner.rc.insert(l, e, Arc::new(rec), tick, true),
                Err(_) => break,
            }
        }
        Ok(())
    }

    fn expert_nbytes(&self, layer: usize, expert: usize) -> u64 {
        self.inner.lock().unwrap().rc.nbytes_of(layer, expert)
    }

    fn total_nbytes(&self) -> u64 {
        self.inner.lock().unwrap().rc.total_nbytes()
    }

    fn counters(&self) -> CacheCounters {
        self.inner.lock().unwrap().rc.counters()
    }

    fn budget_bytes(&self) -> Option<u64> {
        Some(self.inner.lock().unwrap().rc.budget())
    }

    fn set_importance(&self, importance: &[Vec<f64>]) {
        self.inner.lock().unwrap().rc.set_importance(importance);
    }

    fn clear_cache(&self) {
        self.inner.lock().unwrap().rc.clear();
    }

    fn kind(&self) -> &'static str {
        "paged"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qlinear::QuantLinear;
    use crate::tensor::Tensor2;

    /// In-memory record source (no file needed).
    struct MemSource {
        experts: Vec<Vec<QuantExpert>>,
    }

    impl RecordSource for MemSource {
        fn read_record(&mut self, layer: usize, expert: usize) -> Result<QuantExpert> {
            Ok(self.experts[layer][expert].clone())
        }
    }

    fn tiny_expert(seed: f32) -> QuantExpert {
        // fp QuantLinears keep the test independent of packing details;
        // nbytes = 2 per value (fp counted at fp16)
        let t = |v: f32| Tensor2::from_vec(2, 2, vec![v; 4]);
        QuantExpert {
            wg: QuantLinear::Fp(t(seed)),
            wu: QuantLinear::Fp(t(seed + 0.1)),
            wd: QuantLinear::Fp(t(seed + 0.2)),
            bits: 16,
        }
    }

    /// 2 layers x 3 experts, 24 bytes each (3 mats x 4 vals x 2 B).
    fn store_with_budget(budget: u64) -> PagedStore {
        let experts: Vec<Vec<QuantExpert>> = (0..2)
            .map(|l| (0..3).map(|e| tiny_expert((l * 3 + e) as f32)).collect())
            .collect();
        let nbytes: Vec<Vec<u64>> =
            experts.iter().map(|r| r.iter().map(|e| e.nbytes()).collect()).collect();
        assert_eq!(nbytes[0][0], 24);
        let importance = vec![vec![1.0, 2.0, 3.0]; 2];
        let src = MemSource { experts };
        PagedStore::new(Box::new(src), nbytes, importance, budget)
    }

    #[test]
    fn resident_store_serves_and_accounts() {
        let experts: Vec<Vec<QuantExpert>> =
            (0..2).map(|l| (0..3).map(|e| tiny_expert((l * 3 + e) as f32)).collect()).collect();
        let s = ResidentStore::new(experts);
        assert_eq!(s.total_nbytes(), 2 * 3 * 24);
        assert_eq!(s.expert_nbytes(1, 2), 24);
        let e = s.get(1, 2).unwrap();
        assert_eq!(e.bits, 16);
        let c = s.counters();
        assert_eq!(c.resident_bytes, s.total_nbytes());
        assert_eq!(c.misses, 0);
        assert!(s.remote_stats().is_none(), "local store has no wire gauges");
    }

    #[test]
    fn paged_hits_misses_and_budget() {
        let s = store_with_budget(48); // room for 2 of 6 experts
        s.ensure_resident(0, &[0]).unwrap(); // first fault
        s.ensure_resident(0, &[0]).unwrap(); // still resident
        let c = s.counters();
        assert_eq!((c.hits, c.misses), (1, 1));
        // the execute-phase get after ensure is the same logical access:
        // neither a hit nor a miss is recorded
        let a = s.get(0, 0).unwrap();
        assert_eq!(a.bits, 16);
        assert_eq!(s.counters(), c);
        s.get(0, 1).unwrap(); // direct fault: miss
        s.get(0, 2).unwrap(); // miss; evicts the LRU (0,0)
        let c = s.counters();
        assert_eq!(c.evictions, 1);
        assert_eq!(c.misses, 3);
        assert!(c.resident_bytes <= 48);
        assert!(c.peak_resident_bytes <= 48);
        s.ensure_resident(0, &[0]).unwrap(); // faults again after eviction
        assert_eq!(s.counters().misses, 4);
    }

    #[test]
    fn clear_cache_resets_residency_and_gauges() {
        let s = store_with_budget(72);
        s.ensure_resident(0, &[0, 1]).unwrap();
        assert!(s.counters().resident_bytes > 0);
        s.clear_cache();
        assert_eq!(s.counters(), CacheCounters::default());
        assert!(s.inner.lock().unwrap().rc.cache.is_empty());
        // still serviceable after the reset
        s.ensure_resident(0, &[0]).unwrap();
        assert_eq!(s.counters().misses, 1);
    }

    #[test]
    fn out_of_range_requests_error_without_polluting_history() {
        let s = store_with_budget(48);
        assert!(s.ensure_resident(0, &[7]).is_err());
        assert!(s.ensure_resident(9, &[0]).is_err());
        assert!(s.get(0, 7).is_err());
        let inner = s.inner.lock().unwrap();
        assert_eq!(inner.rc.route.tokens, 0, "failed ensure must not record history");
        assert_eq!(inner.rc.counters, CacheCounters::default());
    }

    #[test]
    fn eviction_prefers_low_importance_on_tied_recency() {
        let s = store_with_budget(48);
        // one batch => one shared tick for experts 1 and 2
        s.ensure_resident(0, &[1, 2]).unwrap();
        // loading (0,0) must evict the tied-recency entry with the LOWER
        // importance: expert 1 (imp 2.0) goes before expert 2 (imp 3.0)
        s.get(0, 0).unwrap();
        assert!(s.inner.lock().unwrap().rc.cache.contains_key(&(0, 2)));
        assert!(!s.inner.lock().unwrap().rc.cache.contains_key(&(0, 1)));
    }

    #[test]
    fn ensure_resident_protects_working_set_over_budget() {
        let s = store_with_budget(24); // budget < 2-expert working set
        s.ensure_resident(0, &[0, 1]).unwrap();
        // both stay resident for the dispatch (overflow is visible in the
        // peak, not destructive)
        let inner = s.inner.lock().unwrap();
        assert!(inner.rc.cache.contains_key(&(0, 0)));
        assert!(inner.rc.cache.contains_key(&(0, 1)));
        assert_eq!(inner.rc.counters.peak_resident_bytes, 48);
    }

    #[test]
    fn prefetch_uses_routing_history_and_counts_hits() {
        let s = store_with_budget(72);
        // build history: layer-1 expert 2 was routed once
        s.ensure_resident(1, &[2]).unwrap();
        // model it aging out of the cache (white-box: drop the entry)
        {
            let mut inner = s.inner.lock().unwrap();
            inner.rc.cache.remove(&(1, 2)).unwrap();
            inner.rc.counters.resident_bytes -= 24;
        }
        // an ensure on layer 0 demands (0,0) and should prefetch (1,2)
        // into the spare budget
        s.ensure_resident(0, &[0]).unwrap();
        {
            let inner = s.inner.lock().unwrap();
            let entry = inner.rc.cache.get(&(1, 2)).expect("(1,2) prefetched");
            assert!(entry.prefetched);
        }
        let before = s.counters();
        s.ensure_resident(1, &[2]).unwrap();
        let after = s.counters();
        assert_eq!(after.prefetch_hits, before.prefetch_hits + 1);
        assert_eq!(after.misses, before.misses, "prefetched expert must not re-read");
    }

    #[test]
    fn prefetch_never_evicts() {
        let s = store_with_budget(24); // exactly one expert fits
        s.ensure_resident(1, &[0]).unwrap();
        s.ensure_resident(0, &[1]).unwrap(); // (1,0) history exists, no room
        let inner = s.inner.lock().unwrap();
        // only the demanded expert is resident; prefetch found no space
        assert!(inner.rc.cache.contains_key(&(0, 1)));
        assert_eq!(inner.rc.cache.len(), 1);
    }

    /// The extracted policy core, driven directly: the prefetch plan is
    /// budget-cumulative (reserving one candidate shrinks the room the
    /// next sees) and `insert_prefetched_if_fits` re-checks at insert
    /// time — the remote store drains pipelined responses long after
    /// planning.
    #[test]
    fn residency_cache_plan_is_cumulative_and_insert_rechecks() {
        let nbytes = vec![vec![24u64; 3]; 2];
        let mut rc = ResidencyCache::new(nbytes, vec![vec![1.0; 3]; 2], 48);
        // history: layer-1 experts 0 and 1 each routed once
        rc.begin_batch(1, &[0, 1]);
        // plan from layer 0 with an empty cache: both fit 48 B? only
        // cumulatively — 24 + 24 == budget, so both make the plan
        assert_eq!(rc.prefetch_plan(0), vec![(1, 0), (1, 1)]);
        // one demand insert consumes half the budget: the plan keeps the
        // hotter candidate and drops the one that no longer fits
        let tick = rc.next_tick();
        rc.insert(0, 0, Arc::new(tiny_expert(0.0)), tick, false);
        assert_eq!(rc.prefetch_plan(0), vec![(1, 0)]);
        // a drained prefetch record that raced past the budget is dropped
        assert!(rc.insert_prefetched_if_fits(1, 0, Arc::new(tiny_expert(1.0)), tick));
        assert!(!rc.insert_prefetched_if_fits(1, 1, Arc::new(tiny_expert(2.0)), tick));
        assert_eq!(rc.counters().resident_bytes, 48);
        assert_eq!(rc.counters().evictions, 0, "prefetch insert never evicts");
    }
}
